"""Serving front: an HTTP surface over DecisionService replica processes.

The paper's §6 deployment is a *service*: the solve runs daily, but the
decisions are consumed as per-user request traffic. This module is that
request path, built entirely from the stdlib (``http.server`` + raw
sockets — no new dependencies):

* :class:`ReplicaServer` — runs in each replica *process*: one
  :class:`~repro.serve.decisions.DecisionService` over the shared
  generation root, served over a tiny length-prefixed JSON RPC (thread
  per connection — the concurrency that makes the service lock in
  :mod:`repro.serve.decisions` load-bearing), plus a **pointer
  watcher** thread that polls ``LIVE.json`` and ``rebind()``s the
  service on every flip, demoting the previous generation to the
  degraded-mode fallback.
* :class:`ReplicaClient` — a connection-pooled RPC client for one
  replica.
* :class:`Front` — a ``ThreadingHTTPServer`` that round-robins lookup
  traffic over N replicas, aggregates every replica's ``health()`` at
  ``/health``, and exposes the cross-generation decision **diff** at
  ``/diff``.
* :func:`decision_diff` — "which of these users changed since
  generation g?", answered as **one grouped chunk pass per
  generation**: both generations' rows come from
  :meth:`~repro.serve.decisions.DecisionService.lookup_batch`, whose
  chunk grouping regenerates each spanned chunk at most once (the
  parity test counts fetches at the source to prove it). Replicas keep
  a small LRU of per-generation services, so repeated diffs against
  the same baseline hit warm chunk caches.

Bitwise contract: a front answer IS a DecisionService answer — the
replica calls the same ``lookup``/``lookup_batch`` the in-process path
uses and the wire encodes the exact bytes (base64 of the bool row
payload), so single, batched, degraded-``stale`` and diff responses
are all bitwise-equal to direct in-process lookups against the same
generations (pinned end-to-end by ``tests/test_front.py``, the same
way ``test_serve_stress.py`` pins the multi-process torn-read story).
"""
from __future__ import annotations

import base64
import itertools
import json
import os
import socket
import struct
import threading
import time
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

import numpy as np

from ..core.faults import process_registry
from ..obs import (MetricsRegistry, NULL_TRACER, label_snapshot,
                   merge_snapshots, render_prometheus, request)

__all__ = ["ReplicaServer", "ReplicaClient", "Front", "FrontRPCError",
           "decision_diff", "pack_array", "unpack_array",
           "send_msg", "recv_msg", "poisoned_factory"]


# ---------------------------------------------------------------------------
# Wire format: 4-byte big-endian length + JSON; arrays as base64 payloads.
# ---------------------------------------------------------------------------

def pack_array(a) -> dict:
    """A JSON-safe encoding of an ndarray preserving its exact bytes."""
    a = np.ascontiguousarray(a)
    return {"b64": base64.b64encode(a.tobytes()).decode("ascii"),
            "dtype": str(a.dtype), "shape": list(a.shape)}


def unpack_array(d: dict) -> np.ndarray:
    """Invert :func:`pack_array` (bitwise: same bytes, dtype, shape)."""
    raw = base64.b64decode(d["b64"])
    return np.frombuffer(raw, dtype=np.dtype(d["dtype"])) \
        .reshape(d["shape"]).copy()


def send_msg(sock: socket.socket, obj: dict) -> None:
    data = json.dumps(obj).encode("utf-8")
    sock.sendall(struct.pack(">I", len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        part = sock.recv(n - len(buf))
        if not part:
            raise ConnectionError("peer closed mid-message")
        buf.extend(part)
    return bytes(buf)


def recv_msg(sock: socket.socket) -> Optional[dict]:
    """One framed message; None on a clean close between messages."""
    try:
        head = _recv_exact(sock, 4)
    except ConnectionError:
        return None
    (length,) = struct.unpack(">I", head)
    return json.loads(_recv_exact(sock, length).decode("utf-8"))


class FrontRPCError(RuntimeError):
    """A replica answered an RPC with an error payload."""

    def __init__(self, message: str, kind: str = "RuntimeError"):
        super().__init__(message)
        self.kind = kind


# ---------------------------------------------------------------------------
# The cross-generation decision diff.
# ---------------------------------------------------------------------------

def decision_diff(new_svc, old_svc, users) -> dict:
    """Which of ``users`` have a different decision row in ``new_svc``'s
    generation than in ``old_svc``'s?

    One grouped chunk pass per generation: each service answers through
    :meth:`~repro.serve.decisions.DecisionService.lookup_batch`, which
    regenerates every spanned chunk at most once (and not at all when
    the service's LRU already holds it — the "two cached generations"
    of the front's diff endpoint). Returns::

        changed   (m,) bool — True where the rows differ, where the old
                  generation never covered the user (traffic growth),
                  or — when K changed — everywhere (no row is
                  comparable across a knapsack-count change)
        compared  users answered by both generations
        new_users users past the old generation's n
        stale     True when either side served any row degraded — the
                  diff is then against fallback data, flagged exactly
                  like a single lookup would be

    plus ``from_gen``/``to_gen`` provenance. Equal to the brute-force
    comparison of both generations' full ``decisions_chunk``
    materialisations (pinned, fetch-counted, in ``tests/test_front.py``).
    """
    users = np.asarray(list(users), np.int64)
    out = {"from_gen": int(old_svc.generation.gen),
           "to_gen": int(new_svc.generation.gen)}
    if new_svc.generation.spec.k != old_svc.generation.spec.k:
        out.update(changed=np.ones(users.size, bool), compared=0,
                   new_users=0, stale=False, k_changed=True)
        return out
    x_new, stale_new, _ = new_svc.lookup_batch(users)
    covered = users < old_svc.source.n
    changed = np.ones(users.size, bool)
    stale = bool(stale_new.any())
    if covered.any():
        x_old, stale_old, _ = old_svc.lookup_batch(users[covered])
        changed[covered] = (x_new[covered] != x_old).any(axis=1)
        stale = stale or bool(stale_old.any())
    out.update(changed=changed, compared=int(covered.sum()),
               new_users=int((~covered).sum()), stale=stale,
               k_changed=False)
    return out


def poisoned_factory(make_source, budget_scale: float, chunk: int):
    """A ``make_source`` whose spec at ``budget_scale`` fails on one chunk.

    Test/chaos instrumentation for the degraded path: sources built for
    a spec whose ``budget_scale`` matches raise ``IOError`` on every
    fetch of ``chunk`` — with a retry policy armed this exhausts into a
    ``ChunkFetchError`` and the service answers those users from its
    fallback generation with ``stale=True``. Keying the poison on the
    spec (not the chunk index alone) leaves the *fallback* generation's
    fetches healthy, which is what makes the degradation observable
    end to end through a replica.
    """
    def factory(spec):
        src = make_source(spec)
        if spec.budget_scale != budget_scale:
            return src
        inner = src.fn

        def fn(i):
            if int(i) == chunk:
                raise IOError(
                    f"poisoned chunk {chunk} (budget_scale "
                    f"{budget_scale}) — injected permanent fault")
            return inner(i)

        return src._replace(fn=fn)

    return factory


# ---------------------------------------------------------------------------
# Replica process: DecisionService + pointer watcher behind a socket RPC.
# ---------------------------------------------------------------------------

class ReplicaServer:
    """One replica: a DecisionService served over socket RPC.

    Binds ``host:port`` (port 0 picks a free one — :attr:`port` after
    :meth:`start`), answers each connection on its own thread, and runs
    a pointer-watcher thread that follows ``LIVE.json`` flips with
    :meth:`~repro.serve.decisions.DecisionService.rebind` — so every
    replica converges on a freshly published generation within
    ``poll_s`` without any coordination with the refresh writer.

    ``engine`` is a :class:`~repro.serve.engine.RefreshEngine` over the
    shared root (usually :meth:`RefreshEngine.attach`-ed). Ops:
    ``lookup``, ``decide_batch`` (rows + per-row stale/gen provenance),
    ``diff`` (see :func:`decision_diff`; per-generation services cached
    under a ``gen_cache``-entry LRU), ``health``, ``metrics`` (merged
    registry snapshot + Prometheus text), ``ping``, ``shutdown``.

    Requests carrying a front-minted ``rid`` have it installed as the
    tracing request id for the duration of the dispatch, so a
    ``serve.fill`` span on this replica correlates with the
    ``front.decide`` span that caused it.
    """

    def __init__(self, engine, index: int = 0, cache_chunks: int = 16,
                 poll_s: float = 0.05, host: str = "127.0.0.1",
                 port: int = 0, gen_cache: int = 2):
        self.engine = engine
        self.index = int(index)
        self.cache_chunks = int(cache_chunks)
        self.poll_s = float(poll_s)
        self.host, self._port_req = host, int(port)
        self.svc = engine.decision_service(cache_chunks=cache_chunks)
        # Replica-level metrics live on their own (always-real) registry
        # so rebind counts survive even when the engine runs without obs;
        # the tracer comes from the engine so replica spans land in the
        # same journal as the fills they trigger.
        self.registry = MetricsRegistry()
        self._c_rebinds = self.registry.counter("replica_rebinds")
        self._tracer = engine.obs.tracer
        self._gen_cache_cap = int(gen_cache)
        self._gen_services: OrderedDict = OrderedDict()
        self._gen_lock = threading.Lock()
        self._stop = threading.Event()
        self._sock: Optional[socket.socket] = None
        self._threads: list = []

    @property
    def rebinds(self) -> int:
        """Pointer flips this replica has followed (monotone)."""
        return int(self._c_rebinds.value)

    # -- lifecycle ----------------------------------------------------------

    @property
    def port(self) -> int:
        if self._sock is None:
            raise RuntimeError("replica not started")
        return self._sock.getsockname()[1]

    def start(self) -> int:
        """Bind, launch the watcher + accept loop threads; returns port."""
        self._sock = socket.create_server((self.host, self._port_req))
        self._sock.settimeout(0.2)
        for fn in (self._watch, self._accept):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            self._threads.append(t)
        return self.port

    def stop(self) -> None:
        self._stop.set()
        if self._sock is not None:
            self._sock.close()

    def serve_forever(self) -> None:
        """Block until :meth:`stop` (the ``--replica`` CLI entry)."""
        if self._sock is None:
            self.start()
        self._stop.wait()

    # -- pointer watcher ----------------------------------------------------

    def _watch(self) -> None:
        while not self._stop.is_set():
            try:
                live = self.engine.live_gen_id()
                if live is not None and live != self.svc.generation.gen:
                    gen = self.engine.generation(live)
                    if self._tracer.enabled:
                        with self._tracer.span("replica.rebind",
                                               replica=self.index,
                                               gen=int(live)):
                            self.svc.rebind(
                                self.engine.make_source(gen.spec), gen)
                    else:
                        self.svc.rebind(
                            self.engine.make_source(gen.spec), gen)
                    self._c_rebinds.inc()
            except (ValueError, OSError):
                # The GC raced this read (vanished generation under a
                # moving pointer — the documented contract): the next
                # poll re-resolves the pointer.
                pass
            self._stop.wait(self.poll_s)

    # -- per-generation services for the diff endpoint ----------------------

    def _gen_service(self, gen_id: int):
        """The diff baseline service for ``gen_id``, LRU-cached.

        The *current* generation always answers through ``self.svc``
        (whose cache is already warm from lookup traffic); baselines
        get their own fallback-less service so a damaged baseline fails
        the diff loudly instead of silently comparing stale rows.
        """
        gen_id = int(gen_id)
        if gen_id == self.svc.generation.gen:
            return self.svc
        with self._gen_lock:
            svc = self._gen_services.get(gen_id)
            if svc is not None:
                self._gen_services.move_to_end(gen_id)
                return svc
        gen = self.engine.generation(gen_id)     # raises on pruned/absent
        svc = self.engine.decision_service(
            generation=gen, cache_chunks=self.cache_chunks, fallback=False)
        with self._gen_lock:
            self._gen_services.setdefault(gen_id, svc)
            self._gen_services.move_to_end(gen_id)
            while len(self._gen_services) > self._gen_cache_cap:
                self._gen_services.popitem(last=False)
            return self._gen_services[gen_id]

    # -- RPC dispatch -------------------------------------------------------

    def metrics_snapshot(self) -> list:
        """Merged metric snapshot for this replica process.

        Combines the service registry (serve_* series), the replica's
        own registry (replica_rebinds) and the process-wide fault
        registry, summed by :func:`~repro.obs.merge_snapshots`.
        """
        return merge_snapshots([self.svc.registry.snapshot(),
                                self.registry.snapshot(),
                                process_registry().snapshot()])

    def _handle(self, req: dict) -> dict:
        rid = req.get("rid")
        if rid is not None:
            # Install the front-minted request id so every span emitted
            # while answering this request (serve.fill in particular)
            # carries it — the wire-level correlation contract.
            with request(str(rid)):
                return self._dispatch(req)
        return self._dispatch(req)

    def _dispatch(self, req: dict) -> dict:
        op = req.get("op")
        if op == "ping":
            return {"ok": True, "gen": int(self.svc.generation.gen),
                    "replica": self.index}
        if op == "lookup":
            r = self.svc.lookup(int(req["user"]))
            return {"x": pack_array(r.x), "stale": bool(r.stale),
                    "gen": int(r.gen)}
        if op == "decide_batch":
            x, stale, gens = self.svc.lookup_batch(req["users"])
            return {"x": pack_array(x), "stale": pack_array(stale),
                    "gens": pack_array(gens)}
        if op == "diff":
            new_svc = self.svc
            old_svc = self._gen_service(req["gen"])
            fills0 = (new_svc.stats["fills"], old_svc.stats["fills"])
            out = decision_diff(new_svc, old_svc, req["users"])
            out["changed"] = pack_array(out["changed"])
            # Chunk-fill deltas for the pass accounting (exact when the
            # replica is otherwise idle, e.g. the bench's diff phase).
            out["fills"] = {"new": new_svc.stats["fills"] - fills0[0],
                            "old": old_svc.stats["fills"] - fills0[1]}
            return out
        if op == "health":
            h = self.svc.health()
            h["replica"] = {"index": self.index, "pid": os.getpid(),
                            "rebinds": self.rebinds,
                            "gen_cache": sorted(self._gen_services)}
            return h
        if op == "metrics":
            snap = self.metrics_snapshot()
            return {"replica": self.index, "snapshot": snap,
                    "text": render_prometheus(snap)}
        if op == "shutdown":
            self._stop.set()
            return {"ok": True}
        raise ValueError(f"unknown RPC op {op!r}")

    # -- socket plumbing ----------------------------------------------------

    def _accept(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # stop() closed the socket
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            conn.settimeout(60.0)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while not self._stop.is_set():
                try:
                    req = recv_msg(conn)
                except (OSError, ValueError):
                    return
                if req is None:
                    return
                try:
                    resp = self._handle(req)
                except Exception as e:      # noqa: BLE001 — RPC boundary
                    resp = {"error": str(e), "type": type(e).__name__}
                try:
                    send_msg(conn, resp)
                except OSError:
                    return


# ---------------------------------------------------------------------------
# Front: HTTP over N replicas.
# ---------------------------------------------------------------------------

class ReplicaClient:
    """Connection-pooled RPC client for one replica."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.addr = (host, int(port))
        self.timeout = timeout
        self._pool: list = []
        self._lock = threading.Lock()

    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._pool:
                return self._pool.pop()
        sock = socket.create_connection(self.addr, timeout=self.timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def call(self, obj: dict) -> dict:
        """One request/response; raises FrontRPCError on replica errors,
        OSError when the replica is unreachable."""
        sock = self._checkout()
        try:
            send_msg(sock, obj)
            resp = recv_msg(sock)
        except OSError:
            sock.close()
            raise
        if resp is None:
            sock.close()
            raise ConnectionError(f"replica {self.addr} closed mid-call")
        with self._lock:
            self._pool.append(sock)
        if "error" in resp:
            raise FrontRPCError(resp["error"], resp.get("type", ""))
        return resp

    def close(self) -> None:
        with self._lock:
            for s in self._pool:
                s.close()
            self._pool.clear()


class _FrontHandler(BaseHTTPRequestHandler):
    """Request handler; the Front instance hangs off the server."""

    protocol_version = "HTTP/1.1"
    disable_nagle_algorithm = True          # small JSON frames: no 40ms stalls

    def log_message(self, fmt, *args):      # quiet: the front keeps counters
        pass

    @property
    def front(self) -> "Front":
        return self.server.front

    def _reply(self, code: int, payload: dict) -> None:
        data = json.dumps(payload).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _reply_text(self, code: int, text: str) -> None:
        data = text.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "text/plain; version=0.0.4")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length", 0))
        if length == 0:
            return {}
        return json.loads(self.rfile.read(length).decode("utf-8"))

    def do_GET(self) -> None:               # noqa: N802 (stdlib casing)
        url = urlparse(self.path)
        try:
            if url.path == "/health":
                self._reply(200, self.front.health())
            elif url.path == "/metrics":
                self._reply_text(200, self.front.metrics_text())
            elif url.path == "/decide":
                user = int(parse_qs(url.query)["user"][0])
                self._reply(200, self.front.decide(user))
            else:
                self._reply(404, {"error": f"no route {url.path}"})
        except FrontRPCError as e:
            self._reply(400 if e.kind == "IndexError" else 502,
                        {"error": str(e), "type": e.kind})
        except (KeyError, ValueError) as e:
            self._reply(400, {"error": f"bad request: {e}"})
        except OSError as e:
            self._reply(502, {"error": f"no replica reachable: {e}"})

    def do_POST(self) -> None:              # noqa: N802
        url = urlparse(self.path)
        try:
            body = self._body()
            if url.path == "/decide_batch":
                self._reply(200, self.front.decide_batch(body["users"]))
            elif url.path == "/diff":
                self._reply(200, self.front.diff(body["gen"],
                                                 body["users"]))
            else:
                self._reply(404, {"error": f"no route {url.path}"})
        except FrontRPCError as e:
            self._reply(400 if e.kind == "IndexError" else 502,
                        {"error": str(e), "type": e.kind})
        except (KeyError, ValueError) as e:
            self._reply(400, {"error": f"bad request: {e}"})
        except OSError as e:
            self._reply(502, {"error": f"no replica reachable: {e}"})


class Front:
    """The HTTP front: round-robin lookups, aggregated health, diffs.

    ``replicas`` is a list of :class:`ReplicaClient`. Lookup traffic
    (``/decide``, ``/decide_batch``) and diffs round-robin over them,
    failing over to the next replica (counted in ``rpc_errors``) when
    one is unreachable; ``/health`` fans out to every replica and
    reports per-replica documents plus an ``agreement`` bit — False
    while a pointer flip is still propagating through the watchers
    (replicas momentarily serve different generations, each one still
    bitwise-correct for the generation it names). ``/metrics`` exports
    Prometheus text: front counters, per-replica series labeled
    ``replica="i"``, and an unlabeled fleet aggregate.
    """

    def __init__(self, replicas: list, host: str = "127.0.0.1",
                 port: int = 0, tracer=None):
        if not replicas:
            raise ValueError("a front needs at least one replica")
        self.replicas = list(replicas)
        self._rr = 0
        self._lock = threading.Lock()
        self.registry = MetricsRegistry()
        self._c_requests = self.registry.counter("front_requests")
        self._c_rpc_errors = self.registry.counter("front_rpc_errors")
        self._c_failovers = self.registry.counter("front_failovers")
        self._h_route = self.registry.histogram("front_route_seconds")
        self._tracer = NULL_TRACER if tracer is None else tracer
        self._rids = itertools.count()
        self._httpd = ThreadingHTTPServer((host, port), _FrontHandler)
        self._httpd.front = self
        self._thread: Optional[threading.Thread] = None

    @property
    def stats(self) -> dict:
        """Routing counters (same keys as the pre-registry dict)."""
        return {"requests": int(self._c_requests.value),
                "rpc_errors": int(self._c_rpc_errors.value),
                "failovers": int(self._c_failovers.value)}

    def _rid(self) -> str:
        """Mint a request id unique across fronts (pid + monotone seq)."""
        return f"{os.getpid():x}-{next(self._rids):x}"

    # -- replica routing ----------------------------------------------------

    def _route(self, req: dict) -> tuple:
        """Round-robin with failover; returns (response, replica index)."""
        self._c_requests.inc()
        with self._lock:
            start = self._rr
            self._rr = (self._rr + 1) % len(self.replicas)
        t0 = time.perf_counter()
        last: Optional[Exception] = None
        for k in range(len(self.replicas)):
            i = (start + k) % len(self.replicas)
            try:
                resp = self.replicas[i].call(req)
            except FrontRPCError:
                raise                        # the op itself failed: surface
            except OSError as e:
                self._c_rpc_errors.inc()
                last = e
                continue
            if k:                            # answered by a later choice
                self._c_failovers.inc()
            self._h_route.observe(time.perf_counter() - t0)
            return resp, i
        raise last

    # -- the endpoints (also the in-process client surface) -----------------

    def decide(self, user: int) -> dict:
        req = {"op": "lookup", "user": int(user), "rid": self._rid()}
        if self._tracer.enabled:
            with self._tracer.span("front.decide", op="lookup",
                                   rid=req["rid"], users=1):
                resp, i = self._route(req)
        else:
            resp, i = self._route(req)
        x = unpack_array(resp["x"])
        return {"user": int(user), "x": [int(v) for v in x],
                "stale": resp["stale"], "gen": resp["gen"], "replica": i}

    def decide_batch(self, users) -> dict:
        users = [int(u) for u in users]
        req = {"op": "decide_batch", "users": users, "rid": self._rid()}
        if self._tracer.enabled:
            with self._tracer.span("front.decide", op="decide_batch",
                                   rid=req["rid"], users=len(users)):
                resp, i = self._route(req)
        else:
            resp, i = self._route(req)
        return {"users": len(users), "x": resp["x"],
                "stale": resp["stale"], "gens": resp["gens"], "replica": i}

    def diff(self, gen: int, users) -> dict:
        resp, i = self._route({"op": "diff", "gen": int(gen),
                               "users": [int(u) for u in users],
                               "rid": self._rid()})
        resp["replica"] = i
        return resp

    def health(self) -> dict:
        docs = []
        for i, rc in enumerate(self.replicas):
            try:
                docs.append(rc.call({"op": "health"}))
            except (OSError, FrontRPCError) as e:
                self._c_rpc_errors.inc()
                docs.append({"error": str(e), "replica": {"index": i}})
        gens = sorted({d["generation"] for d in docs if "generation" in d})
        front = dict(self.stats)
        front["replicas"] = len(self.replicas)
        return {"replicas": docs, "generations": gens,
                "agreement": len(gens) == 1,
                "ok": all("error" not in d for d in docs),
                "front": front}

    def metrics_text(self) -> str:
        """Prometheus text for the fleet: the front's own series, each
        replica's series stamped ``replica="i"``, and an unlabeled
        aggregate summed across the replicas that answered (the same
        fan-out-and-tolerate shape as :meth:`health` — unreachable
        replicas count an rpc_error and drop out of the aggregate).
        """
        series = list(self.registry.snapshot())
        per_replica = []
        for i, rc in enumerate(self.replicas):
            try:
                snap = rc.call({"op": "metrics"})["snapshot"]
            except (OSError, FrontRPCError):
                self._c_rpc_errors.inc()
                continue
            per_replica.append(snap)
            series.extend(label_snapshot(snap, replica=str(i)))
        if per_replica:
            series.extend(merge_snapshots(per_replica))
        return render_prometheus(series)

    # -- lifecycle ----------------------------------------------------------

    @property
    def address(self) -> tuple:
        return self._httpd.server_address

    def start(self) -> tuple:
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self.address

    def shutdown(self) -> None:
        # Only stop the serve loop if one is running: socketserver's
        # shutdown() waits on a flag that serve_forever sets on exit,
        # so calling it on a never-started front would block forever.
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join()
            self._thread = None
        self._httpd.server_close()
        for rc in self.replicas:
            rc.close()
