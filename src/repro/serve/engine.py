"""Generation-based refresh engine: the solver as a daily-called service.

The paper's deployment claim (§6) is not a single solve — "the system
has been deployed to production and called on a daily basis": budgets
and traffic shift between calls, and each day's solve starts from
yesterday's prices rather than cold. This module strings the repo's
existing ingredients (host-fed sharded streaming, ``lam0`` warm starts,
checkpoint/resume) into that production shape.

A **generation** is one immutable published solve of one immutable
workload. The :class:`RefreshEngine` owns a root directory of them:

    <root>/LIVE.json                     atomic live-generation pointer
    <root>/gen_000007/
        spec.json                        the workload + refresh intent
                                         (written BEFORE solving — the
                                         durable record a resumed
                                         process replays from)
        ckpt/                            solver resume states
                                         (core/prefetch.py protocol)
        record/step_00000000/            the published Generation payload

``refresh(**deltas)`` derives the next workload spec from the live one
(budget scaling, traffic/seed churn, chunk-count growth — any
:class:`WorkloadSpec` field), re-solves it with
:func:`repro.core.prefetch.solve_streaming_host` **warm-started from
the live generation's multipliers**, and publishes a constant-size
:class:`Generation` record (lam, tau, finalize histograms, solver
fingerprint — never the O(n) decisions). Publication is two atomic
steps: the record is a ``ckpt.save`` (rename-published), and the LIVE
pointer is a ``ckpt.write_json`` flip — a reader holding the pointer
therefore never observes a half-published solve; it sees the previous
generation until the instant the new one is complete on disk.

Preemption safety falls out of the solver's own resume protocol
(DESIGN.md §7): the refresh checkpoints into the generation's ``ckpt/``
directory, and because ``spec.json`` records the workload and warm
start *before* the solve begins, a killed refresh is re-entrant —
calling ``refresh`` again (or :meth:`RefreshEngine.recover`) resumes
the pending generation mid-solve and publishes a record bitwise
identical to the uninterrupted one (the solver's fingerprint check
refuses a drifted spec or warm start). A crash *between* the record
save and the pointer flip is likewise recovered: the completed record
is found and only the flip is replayed.

Lookups against the live generation never materialise O(n) state — see
:class:`repro.serve.decisions.DecisionService`.
"""
from __future__ import annotations

import dataclasses
import pathlib
import re
import shutil
from typing import Callable, NamedTuple, Optional

import numpy as np

import jax.numpy as jnp

from ..checkpoint import ckpt
from ..core.faults import ChunkFetchError, policy_from_cfg
from ..obs import null_obs
from ..core.prefetch import (
    HostChunkSource,
    chunk_hashes,
    solve_streaming_host,
    source_fingerprint,
)
from ..core.types import SolverConfig

__all__ = ["WorkloadSpec", "Generation", "RefreshEngine",
           "synthetic_source", "synthetic_chunk_diff",
           "content_chunk_diff"]

_POINTER = "LIVE.json"
_FAILED = "FAILED.json"
_RECORD_STEP = 0
_GEN_RE = re.compile(r"gen_(\d+)")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One generation's workload identity (JSON-serialisable, hashable).

    The engine is generic over what these fields *mean*: its
    ``make_source`` callback turns a spec into the
    :class:`~repro.core.prefetch.HostChunkSource` to solve. The default
    (:func:`synthetic_source`) reads them as the §6 synthetic workload;
    the marketing example reads ``budget_scale``/``seed`` against its
    own fixed user base. Refresh deltas are just field replacements:
    ``budget_scale`` models the paper's daily budget shifts, ``seed``
    traffic churn (a different user population), ``n`` traffic growth
    (more chunks), all three composable.
    """

    seed: int
    n: int
    k: int
    chunk: int
    q: int = 1
    tightness: float = 0.5
    budget_scale: float = 1.0
    # Ratio-banded workload knob (data.synth.banded_host_chunk_source):
    # 0 keeps the uniform §6 generator; > 0 draws cold cohorts' profits
    # from [0, band) — the structure active-set screening retires.
    band: float = 0.0

    def replace(self, **kw) -> "WorkloadSpec":
        """A copy with the given fields replaced (the refresh delta)."""
        return dataclasses.replace(self, **kw)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "WorkloadSpec":
        return cls(**d)


def synthetic_source(spec: WorkloadSpec) -> HostChunkSource:
    """Default workload factory: the §6 sparse instance, budget-scaled.

    ``data.synth.sparse_host_chunk_source`` keyed on ``(seed, chunk
    index)`` — restart-deterministic as checkpoint/resume requires —
    with the generator's tightness-scaled budgets multiplied by
    ``spec.budget_scale`` (the daily-refresh knob). The scale is applied
    as a single f32 multiply so the same spec always produces the same
    budget bytes (the solver fingerprint hashes them).
    """
    from ..data.synth import banded_host_chunk_source, sparse_host_chunk_source

    if spec.band > 0:
        src = banded_host_chunk_source(spec.seed, spec.n, spec.k, spec.chunk,
                                       q=spec.q, tightness=spec.tightness,
                                       band=spec.band)
    else:
        src = sparse_host_chunk_source(spec.seed, spec.n, spec.k, spec.chunk,
                                       q=spec.q, tightness=spec.tightness)
    budgets = (src.budgets * np.float32(spec.budget_scale)).astype(np.float32)
    return src._replace(budgets=budgets)


def synthetic_chunk_diff(old: WorkloadSpec, new: WorkloadSpec):
    """Which chunks' *bytes* differ between two synthetic specs.

    The delta-refresh contract (DESIGN.md §11): returns a (c_new,) bool
    mask — True where chunk i of the new workload is NOT byte-identical
    to chunk i of the old one — or None when nothing can be inherited
    (every chunk changed). For the ``data.synth`` generators a chunk is
    a pure function of ``(seed, i, chunk, k, band)`` plus the row-live
    mask from ``n``:

    * ``seed``/``k``/``chunk``/``band`` differ -> None (new instance);
    * ``n`` differs -> chunk i unchanged iff fully live under *both*
      (``(i+1)*chunk <= min(n_old, n_new)``) — the ragged frontier and
      everything past it is conservatively marked changed;
    * ``q``/``tightness``/``budget_scale`` touch only the budgets, never
      the chunk bytes -> zero changed chunks.
    """
    if (old.seed, old.k, old.chunk, old.band) != \
            (new.seed, new.k, new.chunk, new.band):
        return None
    c_new = -(-new.n // new.chunk)
    if old.n == new.n:
        return np.zeros((c_new,), bool)
    idx = np.arange(c_new)
    return ~((idx + 1) * new.chunk <= min(old.n, new.n))


def content_chunk_diff(make_source):
    """A ``chunk_diff`` for *real* (non-generator) sources, by content.

    The synthetic diff above reasons about generator parameters; a
    file-backed workload (``memmap_source`` over yesterday's and today's
    extracts) has no closed form — but it has bytes. The returned
    callable hashes every chunk of both specs' sources
    (:func:`repro.core.prefetch.chunk_hashes`, sha256 over the exact
    f32 payload) and marks chunk i changed iff its digests differ;
    chunks past the old source's end are changed by definition. Layout
    changes (``k``/``chunk``) return None — nothing is inheritable when
    chunk boundaries moved. The two full hashing scans are sequential
    O(n·K) *reads* (no solve, no device work): worth it exactly when the
    day-over-day delta is sparse, which is the delta-refresh premise
    (DESIGN.md §11).

        engine = RefreshEngine(root, spec, make_source=my_memmap_factory,
                               chunk_diff=content_chunk_diff(my_memmap_factory))
    """
    def diff(old: WorkloadSpec, new: WorkloadSpec):
        if (old.k, old.chunk) != (new.k, new.chunk):
            return None
        old_h = chunk_hashes(make_source(old))
        new_h = chunk_hashes(make_source(new))
        m = min(len(old_h), len(new_h))
        changed = np.ones((len(new_h),), bool)
        changed[:m] = ~(old_h[:m] == new_h[:m]).all(axis=1)
        return changed

    return diff


class Generation(NamedTuple):
    """One published solve: everything lookups need, nothing O(n).

    ``lam``/``tau`` are the multipliers and §5.4 removal threshold that
    define the primal decisions (regenerate any row with
    ``chunked.decisions_rows``); ``fin_hist`` the fused-finalize
    removable histograms (None when ``cfg.postprocess`` was off);
    ``fingerprint`` the solver's resume-state identity hash of
    (source, cfg, q, lam0) — the proof of *which* solve this record
    publishes. ``warm`` records whether the refresh started from the
    parent's multipliers.
    """

    gen: int
    spec: WorkloadSpec
    lam: np.ndarray        # (K,)
    tau: np.ndarray        # ()
    iters: int
    r: np.ndarray          # (K,) post-projection consumption
    primal: np.ndarray     # ()
    dual: np.ndarray       # ()
    fin_hist: Optional[tuple]   # (cons_hist (K, E+1), gain_hist (E+1,))
    fingerprint: np.ndarray     # (8,) uint8
    warm: bool
    path: str              # this generation's directory


class RefreshEngine:
    """Immutable-generation refresh driver over one root directory.

    ``make_source`` maps a :class:`WorkloadSpec` to the
    :class:`~repro.core.prefetch.HostChunkSource` to solve (default:
    the §6 synthetic workload). ``cfg``/``mesh``/``slots`` are passed
    straight to :func:`~repro.core.prefetch.solve_streaming_host`; give
    ``cfg.checkpoint_every`` a value to make in-flight refreshes
    preemption-safe (the engine supplies the per-generation checkpoint
    directory either way). Engines are cheap handles: any number of
    processes may *read* (``live()``, ``generation()``) concurrently
    with one writer running ``refresh``.
    """

    def __init__(self, root, base_spec: WorkloadSpec,
                 make_source: Callable[[WorkloadSpec],
                                       HostChunkSource] = synthetic_source,
                 cfg: SolverConfig = SolverConfig(), mesh=None,
                 slots: Optional[int] = None, keep: Optional[int] = None,
                 chunk_diff: Optional[Callable] = None, obs=None):
        self.root = pathlib.Path(root)
        self.base_spec = base_spec
        self.make_source = make_source
        self.cfg = cfg
        self.mesh = mesh
        self.slots = slots
        # Observability bundle (repro.obs.Obs). Default: the shared
        # no-op. The tracer threads into the solver (refresh spans ride
        # next to solve.iterate/finalize in one journal) and into every
        # DecisionService this engine hands out. Never part of the spec
        # or the solver fingerprint — a traced refresh publishes the
        # bitwise-identical record (tests/test_obs.py).
        self.obs = null_obs() if obs is None else obs
        # Delta-refresh hook: (parent_spec, new_spec) -> changed-chunk
        # mask (None = everything changed). Only meaningful with
        # cfg.screening; defaults to the synthetic generators' diff when
        # the engine also uses the synthetic source factory — a custom
        # make_source must bring its own diff (or refresh solves cold).
        if chunk_diff is None and make_source is synthetic_source:
            chunk_diff = synthetic_chunk_diff
        self.chunk_diff = chunk_diff
        # Generation retention (the serving mirror of cfg.checkpoint_keep):
        # every successful refresh sweeps all but the newest `keep`
        # generations — never the live or pending one. None disables the
        # automatic sweep; prune() can still be called explicitly.
        if keep is not None and keep < 1:
            raise ValueError(f"keep must be >= 1 (got {keep}): retaining "
                             "zero generations would delete the live one")
        self.keep = keep

    @classmethod
    def attach(cls, root, timeout: float = 0.0, poll_s: float = 0.05,
               **kw) -> "RefreshEngine":
        """An engine over an *existing* root, spec taken from the live
        generation.

        The replica-process entry point (:mod:`repro.serve.front`): a
        serving replica knows only the generation root it shares with
        the refresh writer, not the workload that seeded it — the live
        generation's spec IS the base spec. Waits up to ``timeout``
        seconds for a first generation to be published (a replica may
        boot while gen 0 is still solving), then raises the usual "run
        refresh() first" error. ``kw`` forwards to the constructor
        (``make_source``, ``cfg``, ``keep``...).
        """
        import time

        probe = cls(root, base_spec=None, **kw)
        deadline = time.monotonic() + timeout
        while True:
            live = probe.live()
            if live is not None:
                probe.base_spec = live.spec
                return probe
            if time.monotonic() >= deadline:
                raise ValueError(
                    f"no live generation under {root} to attach to — "
                    "run refresh() there first (or raise the attach "
                    "timeout past the first publication)")
            time.sleep(poll_s)

    # -- directory layout ---------------------------------------------------

    def _gen_dir(self, gen_id: int) -> pathlib.Path:
        return self.root / f"gen_{gen_id:06d}"

    def live_gen_id(self) -> Optional[int]:
        """The published pointer, or None before the first generation."""
        ptr = ckpt.read_json(self.root, _POINTER)
        return None if ptr is None else int(ptr["gen"])

    def live(self) -> Optional[Generation]:
        """The live generation record (constant-size read), or None."""
        gen_id = self.live_gen_id()
        return None if gen_id is None else self.generation(gen_id)

    def generation(self, gen_id: int) -> Generation:
        """Load one published generation's record by id."""
        gdir = self._gen_dir(gen_id)
        meta = ckpt.read_json(gdir, "spec.json")
        if meta is None:
            raise ValueError(
                f"generation {gen_id} has no spec.json under {gdir} — it "
                "was never started in this root")
        state = ckpt.restore_auto(gdir / "record", _RECORD_STEP)
        fin_hist = None
        if "fin_ch" in state:
            fin_hist = (np.asarray(state["fin_ch"]),
                        np.asarray(state["fin_gh"]))
        return Generation(
            gen=gen_id,
            spec=WorkloadSpec.from_json(meta["spec"]),
            lam=np.asarray(state["lam"]),
            tau=np.asarray(state["tau"]),
            iters=int(np.asarray(state["iters"])),
            r=np.asarray(state["r"]),
            primal=np.asarray(state["primal"]),
            dual=np.asarray(state["dual"]),
            fin_hist=fin_hist,
            fingerprint=np.asarray(state["fingerprint"]),
            warm=bool(np.asarray(state["warm"])),
            path=str(gdir),
        )

    def _pending(self):
        """(gen_id, meta) of a started-but-unpublished generation, or None.

        A generation is pending when its ``spec.json`` exists but the
        LIVE pointer has not reached it. At most one can exist: refresh
        always works on ``live + 1``.
        """
        nxt = (self.live_gen_id() + 1) if self.live_gen_id() is not None \
            else 0
        meta = ckpt.read_json(self._gen_dir(nxt), "spec.json")
        return None if meta is None else (nxt, meta)

    # -- the refresh itself -------------------------------------------------

    def refresh(self, *, warm: bool = True, **deltas) -> Generation:
        """Solve the next generation and atomically publish it.

        ``deltas`` are :class:`WorkloadSpec` field replacements against
        the live generation's spec (the first refresh starts from
        ``base_spec``); ``warm`` starts the solve from the live
        multipliers (the production default — the whole point of the
        daily-call shape) instead of the all-ones cold start.

        Re-entrant under preemption: if a previous call was killed
        mid-solve, the next call with the *same* requested spec resumes
        it from the generation's checkpoint directory and publishes the
        bitwise-identical record; a different spec raises (finish or
        discard the pending generation first — two concurrent intents
        for the same generation id cannot both be honoured).
        """
        live = self.live()
        spec = (live.spec if live is not None else self.base_spec).replace(
            **deltas)
        gen_id = live.gen + 1 if live is not None else 0
        warm = bool(warm and live is not None)   # effective: gen 0 is cold

        pending = self._pending()
        if pending is not None:
            pend_id, meta = pending
            pend_spec = WorkloadSpec.from_json(meta["spec"])
            if pend_spec != spec or bool(meta["warm"]) != warm:
                raise ValueError(
                    f"generation {pend_id} is already pending with spec "
                    f"{pend_spec} (warm={meta['warm']}) but this refresh "
                    f"asked for {spec} (warm={warm}); resume the pending "
                    "refresh by repeating its deltas (or recover()), or "
                    f"delete {self._gen_dir(pend_id)} to discard it")
            return self._run(pend_id, pend_spec, bool(meta["warm"]), live)
        return self._run(gen_id, spec, warm, live)

    def recover(self) -> Optional[Generation]:
        """Finish a preempted refresh, if any; None when nothing pends.

        Replays the pending generation from its durable intent record:
        resumes the solve from its checkpoints (or, when the crash fell
        between the record save and the pointer flip, just flips the
        pointer). The published record is bitwise the one the killed
        process would have produced.
        """
        pending = self._pending()
        if pending is None:
            return None
        gen_id, meta = pending
        spec = WorkloadSpec.from_json(meta["spec"])
        parent = self.live()
        return self._run(gen_id, spec, bool(meta["warm"]), parent)

    def _parent_screen(self, parent: Generation) -> Optional[dict]:
        """The parent generation's screening artifacts, or None when the
        parent was solved unscreened (or predates screening)."""
        state = ckpt.restore_auto(pathlib.Path(parent.path) / "record",
                                  _RECORD_STEP)
        if "screen_active" not in state:
            return None
        return {"active": np.asarray(state["screen_active"]).astype(bool),
                "bmax": np.asarray(state["screen_bmax"], np.float32),
                "lam_lo": np.asarray(state["screen_lam_lo"], np.float32)}

    def _run(self, gen_id: int, spec: WorkloadSpec, warm: bool,
             parent: Optional[Generation]) -> Generation:
        gdir = self._gen_dir(gen_id)
        ckdir = gdir / "ckpt"
        record_done = ckpt.latest_step(gdir / "record") is not None
        source, lam0 = None, None
        if not record_done:
            # Validate the refresh and construct its source BEFORE the
            # intent becomes durable: an invalid call (bad deltas, a
            # make_source that rejects the spec) must fail with nothing
            # pending on disk, or it would wedge every later refresh
            # behind a pending generation that can never complete.
            if warm and parent is not None:
                if parent.spec.k != spec.k:
                    raise ValueError(
                        f"cannot warm-start across a knapsack-count "
                        f"change (K {parent.spec.k} -> {spec.k}); pass "
                        "warm=False")
                lam0 = jnp.asarray(parent.lam, self.cfg.dtype)
            source = self.make_source(spec)
        # Durable intent, written before any solve work: the record a
        # killed refresh is replayed from. Idempotent on resume.
        ckpt.write_json(gdir, "spec.json", {
            "gen": gen_id,
            "spec": spec.to_json(),
            "warm": bool(warm and parent is not None),
            "parent": None if parent is None else parent.gen,
        })

        if not record_done:
            # Delta refresh: seed the new solve's active set from the
            # parent generation's published screening certificates —
            # unchanged chunks start retired (never re-streamed unless
            # the trajectory demands a fallback pass), changed chunks
            # start active with unknown bounds. Recomputed identically
            # on every re-entry (the parent record is immutable), so a
            # resumed refresh still publishes the bitwise record.
            screen_init = None
            if (self.cfg.screening and parent is not None
                    and self.chunk_diff is not None):
                seed_state = self._parent_screen(parent)
                changed = self.chunk_diff(parent.spec, spec)
                if seed_state is not None and changed is not None:
                    seed_state["changed"] = np.asarray(changed, bool)
                    screen_init = seed_state
            try:
                res = solve_streaming_host(
                    source, self.cfg, q=spec.q, lam0=lam0, mesh=self.mesh,
                    slots=self.slots, checkpoint_dir=str(ckdir),
                    resume_from=str(ckdir), screen_init=screen_init,
                    tracer=self.obs.tracer)
            except ChunkFetchError as e:
                # Failure containment: the solve exhausted its retry
                # budget. LIVE.json is untouched (readers keep serving
                # the previous generation); the pending directory is
                # stamped with the failure so operators — and recover()
                # — can see what died and re-drive or discard it.
                ckpt.write_json(gdir, _FAILED, {
                    "gen": gen_id,
                    "error": str(e),
                    "chunk": e.chunk,
                    "attempts": len(e.history),
                    "history": [[a, err, slept]
                                for a, err, slept in e.history],
                })
                raise
            record = {
                "iters": np.int32(res.iters),
                "warm": np.int32(lam0 is not None),
                "lam": np.asarray(res.lam),
                "tau": np.asarray(res.tau),
                "r": np.asarray(res.r),
                "primal": np.asarray(res.primal),
                "dual": np.asarray(res.dual),
                "fingerprint": source_fingerprint(
                    source, self.cfg, spec.q,
                    None if lam0 is None else np.asarray(lam0)),
            }
            if res.fin_hist is not None:
                record["fin_ch"] = np.asarray(res.fin_hist[0])
                record["fin_gh"] = np.asarray(res.fin_hist[1])
            if res.screen is not None:
                # The screening artifacts the NEXT generation's delta
                # refresh inherits (bool stored as uint8 for the
                # checkpoint codec), plus the streamed-chunk counts for
                # observability/benchmarks.
                record["screen_active"] = np.asarray(
                    res.screen["active"], np.uint8)
                record["screen_bmax"] = np.asarray(res.screen["bmax"])
                record["screen_lam_lo"] = np.asarray(res.screen["lam_lo"])
                record["screen_streamed"] = np.asarray(
                    res.screen["streamed_chunks"], np.int64)
            # Publication step 1: the record lands atomically...
            tracer = self.obs.tracer
            if tracer.enabled:
                with tracer.span("refresh.publish", gen=gen_id,
                                 step="record"):
                    ckpt.save(gdir / "record", _RECORD_STEP, record)
            else:
                ckpt.save(gdir / "record", _RECORD_STEP, record)
        # A re-driven refresh that succeeded clears any failure stamp a
        # previous attempt left: the generation is healthy now.
        failed = gdir / _FAILED
        if failed.exists():
            failed.unlink()
        # ...step 2: the pointer flip makes it live. A crash between the
        # two leaves a complete record that recover()/refresh() re-flips.
        tracer = self.obs.tracer
        if tracer.enabled:
            with tracer.span("refresh.publish", gen=gen_id, step="pointer"):
                ckpt.write_json(self.root, _POINTER, {"gen": gen_id})
        else:
            ckpt.write_json(self.root, _POINTER, {"gen": gen_id})
        if self.keep is not None:
            self.prune()
        return self.generation(gen_id)

    # -- failure surface + generation GC ------------------------------------

    def failed(self) -> Optional[dict]:
        """The pending generation's failure stamp, or None.

        A refresh whose solve exhausted its retry budget leaves the
        LIVE pointer untouched and writes ``FAILED.json`` (error, chunk,
        attempt counters) into the pending directory; this surfaces it.
        ``recover()`` / ``refresh()`` with the same deltas re-drive the
        generation (transient outages heal), clearing the stamp on
        success; ``discard_pending()`` throws the intent away instead.
        """
        pending = self._pending()
        if pending is None:
            return None
        return ckpt.read_json(self._gen_dir(pending[0]), _FAILED)

    def discard_pending(self) -> Optional[int]:
        """Delete a pending (unpublished) generation; returns its id.

        The explicit give-up path for a pending refresh that can never
        complete (e.g. its source is permanently gone): removes the
        intent, checkpoints and failure stamp so the next refresh can
        claim the generation id afresh. Published generations are never
        touched. None when nothing pends.
        """
        pending = self._pending()
        if pending is None:
            return None
        gen_id = pending[0]
        shutil.rmtree(self._gen_dir(gen_id))
        return gen_id

    def generation_ids(self) -> list:
        """Ids of every generation directory under the root, sorted."""
        if not self.root.exists():
            return []
        return sorted(int(m.group(1)) for p in self.root.iterdir()
                      if (m := _GEN_RE.fullmatch(p.name)))

    def prune(self, keep: Optional[int] = None) -> list:
        """Delete all but the newest ``keep`` generations; returns the ids
        removed.

        The serving twin of ``ckpt.prune`` (``cfg.checkpoint_keep``):
        bounds the root's disk footprint under daily refresh churn. The
        **live** generation and a **pending** one (live + 1 with a
        durable intent) are never deleted, whatever ``keep`` says — the
        pointer must always resolve and an in-flight refresh must keep
        its resume states. Readers of *older* generations race this
        sweep by design; they must treat a vanished generation as "the
        pointer moved on" and re-resolve (DecisionService lookups are
        unaffected — they hold the record in memory).
        """
        keep = self.keep if keep is None else keep
        if keep is None or keep < 1:
            raise ValueError(f"prune needs keep >= 1, got {keep}")
        gens = self.generation_ids()
        live = self.live_gen_id()
        protected = set()
        if live is not None:
            protected.add(live)
        pending = self._pending()
        if pending is not None:
            protected.add(pending[0])
        survivors = set(gens[-keep:]) | protected
        removed = []
        for g in gens:
            if g not in survivors:
                shutil.rmtree(self._gen_dir(g))
                removed.append(g)
        return removed

    # -- lookups ------------------------------------------------------------

    def decision_service(self, generation: Optional[Generation] = None,
                         cache_chunks: int = 16, fallback: bool = True):
        """A DecisionService over ``generation`` (default: the live one).

        The service inherits the engine cfg's fetch fault policy (its
        chunk regenerations retry like the solver's ingest does), and —
        with ``fallback`` (default) — is armed with the previous
        published generation for degraded serving: a lookup whose chunk
        regeneration exhausts its retries answers from the previous
        generation with an explicit ``stale=True`` flag instead of
        failing the query. No previous generation (gen 0, or pruned):
        no fallback.

        The service's :meth:`~repro.serve.decisions.DecisionService.
        health` also reports this root's supervision status: when the
        refreshes run under ``repro.launch.supervisor`` the coordinator
        publishes ``SUPERVISOR.json`` (restarts, hang takeovers, lease
        ages) into the same root, and the service surfaces it.
        """
        from .decisions import DecisionService

        gen = self.live() if generation is None else generation
        if gen is None:
            raise ValueError("no live generation to serve lookups from — "
                             "run refresh() first")
        fb = None
        if fallback and gen.gen > 0:
            try:
                prev = self.generation(gen.gen - 1)
                fb = (self.make_source(prev.spec), prev)
            except (ValueError, OSError):
                fb = None               # pruned or damaged: degrade without
        return DecisionService(self.make_source(gen.spec), gen,
                               cache_chunks=cache_chunks,
                               fault_policy=policy_from_cfg(self.cfg),
                               verify=self.cfg.verify_refetch,
                               fallback=fb, supervisor_root=self.root,
                               tracer=self.obs.tracer)
