"""On-demand decision lookups: "what is x for user i?" in O(chunk).

Production (§6) does not consume the solve as an O(n) decision matrix —
it asks for single users' allocations as traffic arrives. The solver
already never materialises x (``chunked.decisions_chunk`` streams it);
this module adds the random-access path: a :class:`DecisionService`
bound to one published :class:`~repro.serve.engine.Generation`
regenerates ONLY the chunk owning the queried user from the chunk
source and computes that chunk's decisions with
:func:`repro.core.chunked.decisions_rows` — the exact per-row
arithmetic of full materialisation, so a lookup is **bitwise-equal** to
the corresponding row of ``decisions_chunk`` streamed over the whole
source (pinned by tests).

Why the parity holds: the decision for a row is ``select_sparse`` at
``lam`` intersected with the §5.4 projection ``pt > tau``, and both the
selection and the group-profit row sum ``pt`` are computed behind the
same optimization barriers in every caller (``adjusted_profit_chunk``,
the pinned row reduction), so the comparison against ``tau`` — where a
half-ulp would flip a row sitting exactly on the removal threshold —
resolves identically whether the chunk is one of many in an export scan
or a lone cache fill here. The service jits one per-chunk function and
reuses it for every fill.

Chunks are cached under a small LRU (``cache_chunks``), so serving a
traffic mixture with locality touches the source far less than once per
query; the worst case (adversarially scattered users) degrades to one
chunk regeneration per query, still O(chunk), never O(n).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Iterable

import numpy as np

import jax
import jax.numpy as jnp

from ..core.chunked import ChunkSource, decisions_rows
from ..core.prefetch import HostChunkSource

__all__ = ["DecisionService"]


@functools.lru_cache(maxsize=32)
def _jit_rows(q: int):
    """Jitted decisions_rows for one q — shared across services so
    repeated lookups never re-trace. tau is always an operand: a
    no-projection generation carries tau = -inf, and running it through
    the same compare keeps one compiled signature (and the same
    arithmetic as the materialisation path)."""
    return jax.jit(lambda p, b, lam, valid, tau:
                   decisions_rows(p, b, lam, q, valid, tau))


class DecisionService:
    """Point and batched decision queries against one generation.

    ``source`` is the generation's workload as either source family —
    a traced :class:`~repro.core.chunked.ChunkSource` or a host-side
    :class:`~repro.core.prefetch.HostChunkSource`; the engine's
    :meth:`~repro.serve.engine.RefreshEngine.decision_service` builds it
    from the generation's spec. ``generation`` supplies ``(lam, tau,
    spec.q)``. The service holds O(cache_chunks · chunk · K) host state
    and nothing else.
    """

    def __init__(self, source, generation, cache_chunks: int = 16):
        if cache_chunks < 1:
            raise ValueError(f"cache_chunks must be >= 1, "
                             f"got {cache_chunks}")
        if source.k != generation.spec.k or source.n != generation.spec.n \
                or source.chunk != generation.spec.chunk:
            raise ValueError(
                f"source shape (n={source.n}, k={source.k}, "
                f"chunk={source.chunk}) does not match the generation's "
                f"spec {generation.spec} — lookups would silently answer "
                "for a different workload")
        self.source = source
        self.generation = generation
        self.q = generation.spec.q
        self.lam = jnp.asarray(generation.lam)
        # tau = -inf (nothing removed) still goes through the projection
        # compare so the arithmetic matches the materialisation path.
        self.tau = jnp.asarray(generation.tau)
        self.cache_chunks = cache_chunks
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self.stats = {"queries": 0, "hits": 0, "fills": 0, "evictions": 0}
        self._fn = _jit_rows(self.q)

    def _fetch(self, ci: int):
        if isinstance(self.source, HostChunkSource):
            p, b = self.source.fn(int(ci))
            return jnp.asarray(p), jnp.asarray(b)
        # Traced sources run their fn eagerly on a concrete index.
        return self.source.fn(jnp.int32(ci))

    def _chunk_decisions(self, ci: int) -> np.ndarray:
        """(chunk, K) bool decisions for chunk ``ci``, through the LRU."""
        hit = self._cache.get(ci)
        if hit is not None:
            self.stats["hits"] += 1
            self._cache.move_to_end(ci)
            return hit
        p, b = self._fetch(ci)
        rows = ci * self.source.chunk + np.arange(self.source.chunk)
        valid = jnp.asarray(rows < self.source.n)
        x = np.asarray(self._fn(p, b, self.lam, valid, self.tau))
        self.stats["fills"] += 1
        self._cache[ci] = x
        if len(self._cache) > self.cache_chunks:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1
        return x

    def decide(self, user: int) -> np.ndarray:
        """The (K,) bool decision row for one user of the generation."""
        n, chunk = self.source.n, self.source.chunk
        user = int(user)
        if not 0 <= user < n:
            raise IndexError(f"user {user} outside [0, {n})")
        self.stats["queries"] += 1
        return self._chunk_decisions(user // chunk)[user % chunk]

    def decide_batch(self, users: Iterable[int]) -> np.ndarray:
        """(len(users), K) bool decisions, chunk-grouped source access.

        Queries are answered in input order but the owning chunks are
        each regenerated at most once per call (grouped fills), so a
        batch over m users touches min(m, chunks-spanned) chunks.
        """
        users = np.asarray(list(users), np.int64)
        n, chunk = self.source.n, self.source.chunk
        if users.size and (users.min() < 0 or users.max() >= n):
            bad = users[(users < 0) | (users >= n)][0]
            raise IndexError(f"user {int(bad)} outside [0, {n})")
        self.stats["queries"] += int(users.size)
        out = np.zeros((users.size, self.source.k), bool)
        order = np.argsort(users // chunk, kind="stable")
        for j in order:
            u = int(users[j])
            out[j] = self._chunk_decisions(u // chunk)[u % chunk]
        return out
