"""On-demand decision lookups: "what is x for user i?" in O(chunk).

Production (§6) does not consume the solve as an O(n) decision matrix —
it asks for single users' allocations as traffic arrives. The solver
already never materialises x (``chunked.decisions_chunk`` streams it);
this module adds the random-access path: a :class:`DecisionService`
bound to one published :class:`~repro.serve.engine.Generation`
regenerates ONLY the chunk owning the queried user from the chunk
source and computes that chunk's decisions with
:func:`repro.core.chunked.decisions_rows` — the exact per-row
arithmetic of full materialisation, so a lookup is **bitwise-equal** to
the corresponding row of ``decisions_chunk`` streamed over the whole
source (pinned by tests).

Why the parity holds: the decision for a row is ``select_sparse`` at
``lam`` intersected with the §5.4 projection ``pt > tau``, and both the
selection and the group-profit row sum ``pt`` are computed behind the
same optimization barriers in every caller (``adjusted_profit_chunk``,
the pinned row reduction), so the comparison against ``tau`` — where a
half-ulp would flip a row sitting exactly on the removal threshold —
resolves identically whether the chunk is one of many in an export scan
or a lone cache fill here. The service jits one per-chunk function and
reuses it for every fill.

Chunks are cached under a small LRU (``cache_chunks``) **keyed by the
generation's solver fingerprint plus the chunk index** — never the
chunk index alone. A service that follows a pointer flip
(:meth:`DecisionService.rebind`) therefore can never serve a chunk
computed under the previous generation's multipliers: the old entries
simply stop matching (and stay useful as the degraded-mode fallback's
cache).

Fault domain: chunk regenerations run through the same retry layer as
the solver's ingest (:mod:`repro.core.faults`) when a ``fault_policy``
is given. A lookup whose regeneration exhausts its retries *degrades*
instead of failing when the service is armed with a ``fallback``
generation (the previously published one): the answer comes from the
fallback's decisions with an explicit ``stale=True`` flag, and
:meth:`health` accounts retries, fetch failures and stale serves so the
degradation is observable, never silent.

Thread safety: the service is safe to hammer from concurrent request
threads (the HTTP/RPC front in :mod:`repro.serve.front` does exactly
that) while :meth:`rebind` follows pointer flips underneath. Every
lookup snapshots the ``(current, fallback)`` binding pair **once**
under the service lock and answers entirely from that snapshot — a
concurrent rebind can never mix two generations inside one call (bounds
validated against one generation, rows filled from another) or leave
the degraded path reading a fallback that a rebind just replaced. The
lock also serialises the LRU mutations and the ``stats`` counters;
the jitted chunk fill itself runs *outside* the lock, so concurrent
misses on different chunks still overlap.
"""
from __future__ import annotations

import functools
import threading
import time
from collections import OrderedDict
from typing import Iterable, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core.chunked import decisions_rows
from ..core.faults import (ChunkFetchError, abandoned_workers,
                           fetch_with_retries)
from ..core.prefetch import HostChunkSource
from ..obs import MetricsRegistry, NULL_TRACER

__all__ = ["DecisionService", "LookupResult"]


@functools.lru_cache(maxsize=32)
def _jit_rows(q: int):
    """Jitted decisions_rows for one q — shared across services so
    repeated lookups never re-trace. tau is always an operand: a
    no-projection generation carries tau = -inf, and running it through
    the same compare keeps one compiled signature (and the same
    arithmetic as the materialisation path)."""
    return jax.jit(lambda p, b, lam, valid, tau:
                   decisions_rows(p, b, lam, q, valid, tau))


class LookupResult(NamedTuple):
    """One answered lookup: the decision row, and where it came from.

    ``stale`` is True only on the degraded path — the current
    generation's chunk could not be regenerated and the answer is the
    ``fallback`` generation's decision for the same user. ``gen`` names
    the generation that actually answered.
    """

    x: np.ndarray          # (K,) bool decision row
    stale: bool
    gen: int


class _Bound(NamedTuple):
    """One generation binding: source + record + the cache key prefix."""

    source: object         # HostChunkSource or traced ChunkSource
    generation: object     # serve.engine.Generation
    lam: jnp.ndarray
    tau: jnp.ndarray
    q: int
    key: bytes             # generation fingerprint — the LRU key prefix
    fn: object             # jitted decisions_rows for this q


class DecisionService:
    """Point and batched decision queries against one generation.

    ``source`` is the generation's workload as either source family —
    a traced :class:`~repro.core.chunked.ChunkSource` or a host-side
    :class:`~repro.core.prefetch.HostChunkSource`; the engine's
    :meth:`~repro.serve.engine.RefreshEngine.decision_service` builds it
    from the generation's spec. ``generation`` supplies ``(lam, tau,
    spec.q)``. The service holds O(cache_chunks · chunk · K) host state
    and nothing else.

    ``fault_policy`` (a :class:`repro.core.faults.FaultPolicy`) makes
    every host-source chunk regeneration retry transient failures;
    ``verify`` double-reads each chunk (fetch-is-pure corruption
    check). ``fallback`` — a ``(source, generation)`` pair, normally
    the previously published generation — arms degraded mode: a lookup
    whose regeneration exhausts its retries is answered from the
    fallback with ``stale=True`` instead of raising.
    """

    _STAT_KEYS = ("queries", "hits", "fills", "evictions",
                  "retries", "fetch_failures", "stale_serves")

    def __init__(self, source, generation, cache_chunks: int = 16,
                 fault_policy=None, verify: bool = False,
                 fallback: Optional[tuple] = None, supervisor_root=None,
                 registry=None, tracer=None):
        if cache_chunks < 1:
            raise ValueError(f"cache_chunks must be >= 1, "
                             f"got {cache_chunks}")
        self.cache_chunks = cache_chunks
        self.fault_policy = fault_policy
        self.verify = verify
        # Optional supervision surface: a directory whose SUPERVISOR.json
        # (written by repro.launch.supervisor) is merged into health() —
        # restarts, takeovers and lease ages next to the serving counters.
        self.supervisor_root = supervisor_root
        # One LRU across generations: entries are keyed by (generation
        # fingerprint, chunk index), so a rebind keeps the old entries
        # harmless (they can only answer for their own generation) and
        # the fallback path still hits them.
        self._cache: OrderedDict = OrderedDict()
        # Per-service metrics registry (DESIGN.md §14): the serving
        # counters live here and ``stats`` / ``health()`` are read-only
        # views over it, preserving every pre-registry field name. The
        # registry is per *service* (not process-wide) on purpose — the
        # replica ``diff`` op baselines per-generation services against
        # each other by their own fill counts.
        self.registry = MetricsRegistry() if registry is None else registry
        self._counters = {k: self.registry.counter(f"serve_{k}")
                          for k in self._STAT_KEYS}
        self.registry.gauge("serve_cached_chunks",
                            fn=lambda: len(self._cache))
        self.registry.gauge("serve_cache_chunks").set(cache_chunks)
        self._g_degraded = self.registry.gauge("serve_degraded")
        self._h_fill = self.registry.histogram("serve_fill_seconds")
        self._tracer = NULL_TRACER if tracer is None else tracer
        # Degraded reflects the *current* binding state, not history: a
        # stale serve raises it, a rebind onto a fresh generation
        # clears it (the recovery-transition test pins this).
        self._degraded = False
        # The service lock: held around cache/stats mutation and the
        # binding swap — never around a fetch or the jitted fill.
        self._lock = threading.Lock()
        self._current = self._bind(source, generation)
        self._fallback = (self._bind(*fallback)
                          if fallback is not None else None)

    @property
    def stats(self) -> dict:
        """The serving counters as a plain dict (pre-registry shape)."""
        return {k: c.value for k, c in self._counters.items()}

    @staticmethod
    def _bind(source, generation) -> _Bound:
        if source.k != generation.spec.k or source.n != generation.spec.n \
                or source.chunk != generation.spec.chunk:
            raise ValueError(
                f"source shape (n={source.n}, k={source.k}, "
                f"chunk={source.chunk}) does not match the generation's "
                f"spec {generation.spec} — lookups would silently answer "
                "for a different workload")
        return _Bound(
            source=source, generation=generation,
            lam=jnp.asarray(generation.lam),
            # tau = -inf (nothing removed) still goes through the
            # projection compare so the arithmetic matches the
            # materialisation path.
            tau=jnp.asarray(generation.tau),
            q=generation.spec.q,
            key=np.asarray(generation.fingerprint, np.uint8).tobytes(),
            fn=_jit_rows(generation.spec.q))

    def _snapshot(self):
        """The ``(current, fallback)`` binding pair, read atomically.

        Every public query snapshots once and answers from the
        snapshot: a concurrent :meth:`rebind` swaps both references
        under the same lock, so a call either sees the pre-flip pair or
        the post-flip pair — never the current of one generation with
        the fallback of another.
        """
        with self._lock:
            return self._current, self._fallback

    # -- binding surface (kept for callers that predate degraded mode) ---

    @property
    def source(self):
        return self._current.source

    @property
    def generation(self):
        return self._current.generation

    @property
    def lam(self):
        return self._current.lam

    @property
    def tau(self):
        return self._current.tau

    @property
    def q(self):
        return self._current.q

    def rebind(self, source, generation):
        """Follow a pointer flip: bind the new generation, demote the old.

        The previous binding becomes the degraded-mode fallback; both
        references swap under the service lock in one step, so an
        in-flight lookup observes either the old pair or the new pair
        (its own snapshot — see :meth:`_snapshot`). The chunk cache is
        *not* cleared — its entries are keyed by generation
        fingerprint, so the new generation can never hit the old
        generation's chunks (the cross-generation regression test pins
        this), while the demoted generation's warm entries keep serving
        the fallback path for free.
        """
        new = self._bind(source, generation)   # jit lookup outside the lock
        with self._lock:
            old = self._current
            self._current = new
            self._fallback = old
            # A fresh binding starts healthy: ``degraded`` states "the
            # *current* binding has served stale", not "some binding
            # ever did" (the recovery-transition regression pins this).
            # ``stale_serves`` stays monotone across rebinds.
            self._degraded = False
        self._g_degraded.set(0)

    # -- the chunk pipeline ------------------------------------------------

    def _on_retry(self, chunk, attempt, err, delay):
        self._counters["retries"].inc()

    def _fetch(self, bound: _Bound, ci: int):
        if isinstance(bound.source, HostChunkSource):
            if self.fault_policy is not None:
                p, b = fetch_with_retries(
                    bound.source.fn, int(ci), self.fault_policy,
                    verify=self.verify, on_retry=self._on_retry)
            else:
                p, b = bound.source.fn(int(ci))
            return jnp.asarray(p), jnp.asarray(b)
        # Traced sources run their fn eagerly on a concrete index.
        return bound.source.fn(jnp.int32(ci))

    def _chunk_decisions(self, bound: _Bound, ci: int) -> np.ndarray:
        """(chunk, K) bool decisions for chunk ``ci``, through the LRU.

        The cache probe and the insert each hold the service lock; the
        fetch + jitted fill between them run unlocked, so concurrent
        misses overlap. Two threads racing a miss on the same chunk
        both fill (deterministically identical bytes — the second
        insert is a no-op overwrite) and each counts exactly one of
        hits/fills, keeping ``hits + fills == chunk requests`` exact
        under any interleaving.
        """
        key = (bound.key, ci)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._counters["hits"].inc()
                self._cache.move_to_end(key)
                return hit
        t0 = time.perf_counter()
        tracer = self._tracer
        if tracer.enabled:
            # The fill span carries the request id installed by the
            # replica RPC layer (repro.obs.trace.request), correlating a
            # front HTTP request with the fill that served it.
            with tracer.span("serve.fill", chunk=int(ci),
                             gen=bound.generation.gen):
                p, b = self._fetch(bound, ci)
                rows = (ci * bound.source.chunk
                        + np.arange(bound.source.chunk))
                valid = jnp.asarray(rows < bound.source.n)
                x = np.asarray(bound.fn(p, b, bound.lam, valid, bound.tau))
        else:
            p, b = self._fetch(bound, ci)
            rows = ci * bound.source.chunk + np.arange(bound.source.chunk)
            valid = jnp.asarray(rows < bound.source.n)
            x = np.asarray(bound.fn(p, b, bound.lam, valid, bound.tau))
        self._h_fill.observe(time.perf_counter() - t0)
        with self._lock:
            self._counters["fills"].inc()
            self._cache[key] = x
            while len(self._cache) > self.cache_chunks:
                self._cache.popitem(last=False)
                self._counters["evictions"].inc()
        return x

    # -- lookups -----------------------------------------------------------

    def _lookup(self, cur: _Bound, fb: Optional[_Bound],
                user: int) -> LookupResult:
        """One lookup against an explicit binding snapshot."""
        n, chunk = cur.source.n, cur.source.chunk
        user = int(user)
        if not 0 <= user < n:
            raise IndexError(f"user {user} outside [0, {n})")
        self._counters["queries"].inc()
        try:
            row = self._chunk_decisions(cur, user // chunk)[user % chunk]
            return LookupResult(row, False, cur.generation.gen)
        except ChunkFetchError:
            self._counters["fetch_failures"].inc()
            if fb is None or user >= fb.source.n:
                raise
            row = self._chunk_decisions(
                fb, user // fb.source.chunk)[user % fb.source.chunk]
            self._counters["stale_serves"].inc()
            with self._lock:
                self._degraded = True
            self._g_degraded.set(1)
            return LookupResult(row, True, fb.generation.gen)

    def lookup(self, user: int) -> LookupResult:
        """The decision row for one user, with staleness provenance.

        The degraded path: when the current generation's owning chunk
        cannot be regenerated (retries exhausted — a
        ``ChunkFetchError``) and a fallback generation is armed that
        covers the user, the fallback's decision is returned with
        ``stale=True``. With no fallback (or one the user outgrew) the
        fetch error propagates: an explicit failure beats a silently
        wrong answer. The ``(current, fallback)`` pair is snapshotted
        once — a rebind mid-call cannot redirect the degraded path to
        a different generation than the one that failed.
        """
        cur, fb = self._snapshot()
        return self._lookup(cur, fb, user)

    def decide(self, user: int) -> np.ndarray:
        """The (K,) bool decision row for one user of the generation."""
        return self.lookup(user).x

    def lookup_batch(self, users: Iterable[int]):
        """Batched lookups with per-row provenance.

        Returns ``(x (m, K) bool, stale (m,) bool, gens (m,) int64)`` —
        the rows in input order plus, per row, whether it was served
        degraded and by which generation. The whole batch answers from
        **one** binding snapshot: bounds are validated against the same
        generation that fills the rows, whatever ``rebind`` does
        concurrently (the injected-rebind regression test pins this).
        Owning chunks are regenerated at most once per call (grouped
        fills), so a batch over m users touches min(m, chunks-spanned)
        chunks per generation that answers.
        """
        cur, fb = self._snapshot()
        users = np.asarray(list(users), np.int64)
        n, chunk = cur.source.n, cur.source.chunk
        if users.size and (users.min() < 0 or users.max() >= n):
            bad = users[(users < 0) | (users >= n)][0]
            raise IndexError(f"user {int(bad)} outside [0, {n})")
        x = np.zeros((users.size, cur.source.k), bool)
        stale = np.zeros(users.size, bool)
        gens = np.full(users.size, cur.generation.gen, np.int64)
        order = np.argsort(users // chunk, kind="stable")
        for j in order:
            res = self._lookup(cur, fb, int(users[j]))
            x[j], stale[j], gens[j] = res.x, res.stale, res.gen
        return x, stale, gens

    def decide_batch(self, users: Iterable[int]) -> np.ndarray:
        """(len(users), K) bool decisions, chunk-grouped source access.

        Queries are answered in input order but the owning chunks are
        each regenerated at most once per call (grouped fills), so a
        batch over m users touches min(m, chunks-spanned) chunks.
        Degraded lookups fall back per user (see :meth:`lookup`); use
        :meth:`lookup_batch` when the per-row provenance matters.
        """
        return self.lookup_batch(users)[0]

    # -- observability -----------------------------------------------------

    def health(self) -> dict:
        """Serving health: retry/degradation counters + cache stats.

        ``stale_serves`` counting up means the current generation's
        source is failing past its retry budget and queries are being
        answered by the fallback generation — degraded but alive;
        ``fetch_failures`` without matching ``stale_serves`` means
        queries are *failing* (no fallback covered them). ``degraded``
        is the *current* binding's state — True once this binding has
        served stale, reset when :meth:`rebind` installs a fresh
        generation — so a service that rebinds onto a healed source
        reports healthy again even though ``stale_serves`` (a monotone
        counter) stays nonzero.
        ``abandoned_fetch_workers`` / ``abandoned_fetch_total`` surface
        the process-wide leaked-worker counters of the timeout layer
        (:func:`repro.core.faults.abandoned_workers`) — a backend that
        hangs instead of erroring shows up here. When the service was
        built with a ``supervisor_root``, the supervisor's status
        document (restarts, hang takeovers, lease ages) is merged in
        under ``"supervisor"`` — with an explicit ``{"status":
        "absent"}`` when no SUPERVISOR.json has been written yet (a
        configured-but-not-yet-started supervisor is not the same
        observation as a dead one) and ``{"status": "unreadable"}``
        when the document exists but cannot be parsed (externally
        damaged): one bad supervisor file must degrade that field, not
        take down the health endpoint.
        """
        leaked = abandoned_workers()
        with self._lock:
            cur, fb = self._current, self._fallback
            cached = len(self._cache)
            degraded = self._degraded
        out = {
            **self.stats,
            "generation": cur.generation.gen,
            "fallback_generation": (None if fb is None
                                    else fb.generation.gen),
            "cached_chunks": cached,
            "cache_chunks": self.cache_chunks,
            "degraded": degraded,
            "abandoned_fetch_workers": leaked["live"],
            "abandoned_fetch_total": leaked["total"],
        }
        if self.supervisor_root is not None:
            from ..checkpoint import ckpt

            try:
                doc = ckpt.read_json(self.supervisor_root,
                                     "SUPERVISOR.json")
            except ValueError as e:
                out["supervisor"] = {"status": "unreadable",
                                     "error": str(e)}
            else:
                out["supervisor"] = ({"status": "absent"} if doc is None
                                     else doc)
        return out
