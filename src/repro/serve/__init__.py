"""Serving layer: generation-based refresh + on-demand decision lookups.

The paper's production shape (§6 — "deployed to production and called
on a daily basis") on top of the streaming solver:

    engine.RefreshEngine / WorkloadSpec / Generation — immutable
        published solves, warm-started refreshes, atomic pointer flips,
        preemption-safe via the solver's own checkpoint/resume;
    decisions.DecisionService — O(chunk) point/batched lookups against
        the live generation, bitwise-equal to full materialisation;
        retrying chunk regeneration + degraded (stale-flagged) fallback
        to the previous generation under the core/faults.py policy;
    front.Front / ReplicaServer — the HTTP/RPC request path: N replica
        processes each hosting a DecisionService with a LIVE-pointer
        watcher, round-robined behind a ThreadingHTTPServer front with
        aggregated /health and the cross-generation /diff endpoint.
"""
from .decisions import DecisionService, LookupResult  # noqa: F401
from .engine import (  # noqa: F401
    Generation,
    RefreshEngine,
    WorkloadSpec,
    content_chunk_diff,
    synthetic_chunk_diff,
    synthetic_source,
)
from .front import (  # noqa: F401
    Front,
    FrontRPCError,
    ReplicaClient,
    ReplicaServer,
    decision_diff,
)
