"""Synthetic data pipelines (tokens for LM training, KP instances for the
solver) with restart-deterministic per-shard generation.

Every batch is a pure function of (seed, step, shard): after a failure any
worker regenerates exactly the byte-identical shard it would have seen, so
checkpoint/restart never replays or skips data. No host state, no files.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _batch_key(seed: int, step) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def lm_batch(cfg, cell_or_shape, step, seed=0):
    """Token batch for ``train_step``. cell_or_shape: ShapeCell or (b, s)."""
    if hasattr(cell_or_shape, "global_batch"):
        b, s = cell_or_shape.global_batch, cell_or_shape.seq_len
    else:
        b, s = cell_or_shape
    from ..models import model as M
    tl = M._text_len(cfg, s)
    key = _batch_key(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    # learnable stream: the target is a fixed affine function of the input
    # token (plus 10% label noise) — a model that learns anything at all
    # drives the loss well below ln(vocab) within tens of steps.
    toks = jax.random.randint(k1, (b, tl), 0, cfg.vocab, jnp.int32)
    clean = (toks * 7 + 3) % cfg.vocab
    noise = jax.random.randint(k2, (b, tl), 0, cfg.vocab, jnp.int32)
    flip = jax.random.bernoulli(jax.random.fold_in(k2, 1), 0.1, (b, tl))
    batch = {"tokens": toks, "targets": jnp.where(flip, noise, clean)}
    if cfg.kind == "encdec":
        f = max(s // 2, 8)
        batch["frames"] = jax.random.normal(k3, (b, f, cfg.d_model), cfg.dtype) * 0.02
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            k3, (b, cfg.n_patches, cfg.d_model), cfg.dtype) * 0.02
    return batch


def kp_shard(workload, shard: int, n_shards: int, seed: int = 0):
    """Deterministic shard of a paper-scale sparse instance (§6 setup)."""
    from ..core.instances import sparse_instance, shard_key

    n_local = workload.n_users // n_shards
    kp, q = sparse_instance(
        shard_key(seed, shard), n_local, workload.k, workload.q,
        tightness=workload.tightness,
    )
    # budgets are global: scale the shard-local generator budget up
    kp = kp._replace(budgets=kp.budgets * n_shards)
    return kp, q
