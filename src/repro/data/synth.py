"""Synthetic data pipelines (tokens for LM training, KP instances for the
solver) with restart-deterministic per-shard generation.

Every batch is a pure function of (seed, step, shard): after a failure any
worker regenerates exactly the byte-identical shard it would have seen, so
checkpoint/restart never replays or skips data. No host state, no files.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _batch_key(seed: int, step) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def lm_batch(cfg, cell_or_shape, step, seed=0):
    """Token batch for ``train_step``. cell_or_shape: ShapeCell or (b, s)."""
    if hasattr(cell_or_shape, "global_batch"):
        b, s = cell_or_shape.global_batch, cell_or_shape.seq_len
    else:
        b, s = cell_or_shape
    from ..models import model as M
    tl = M._text_len(cfg, s)
    key = _batch_key(seed, step)
    k1, k2, k3 = jax.random.split(key, 3)
    # learnable stream: the target is a fixed affine function of the input
    # token (plus 10% label noise) — a model that learns anything at all
    # drives the loss well below ln(vocab) within tens of steps.
    toks = jax.random.randint(k1, (b, tl), 0, cfg.vocab, jnp.int32)
    clean = (toks * 7 + 3) % cfg.vocab
    noise = jax.random.randint(k2, (b, tl), 0, cfg.vocab, jnp.int32)
    flip = jax.random.bernoulli(jax.random.fold_in(k2, 1), 0.1, (b, tl))
    batch = {"tokens": toks, "targets": jnp.where(flip, noise, clean)}
    if cfg.kind == "encdec":
        f = max(s // 2, 8)
        batch["frames"] = jax.random.normal(k3, (b, f, cfg.d_model), cfg.dtype) * 0.02
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            k3, (b, cfg.n_patches, cfg.d_model), cfg.dtype) * 0.02
    return batch


def kp_shard(workload, shard: int, n_shards: int, seed: int = 0):
    """Deterministic shard of a paper-scale sparse instance (§6 setup)."""
    from ..core.instances import sparse_instance, shard_key

    n_local = workload.n_users // n_shards
    kp, q = sparse_instance(
        shard_key(seed, shard), n_local, workload.k, workload.q,
        tightness=workload.tightness,
    )
    # budgets are global: scale the shard-local generator budget up
    kp = kp._replace(budgets=kp.budgets * n_shards)
    return kp, q


def sparse_chunk_source(seed, n, k, chunk, q=1, tightness=0.5, b_high=1.0):
    """Out-of-core §6 sparse instance: chunks synthesized on demand.

    Returns a ``core.chunked.ChunkSource`` whose chunk ``i`` — rows
    [i*chunk, (i+1)*chunk) of a virtual (n, K) instance with the same
    distribution and budget scaling as ``instances.sparse_instance`` —
    is a pure function of ``(seed, i)``, generated *inside* the solve's
    scan body. The (n, K) arrays never exist anywhere: n is bounded by
    nothing but the iteration budget, which is how the chunked benchmark
    demonstrates solves far past the unchunked device-memory ceiling at
    flat peak memory. Rows past n (ragged tail / mesh-padded chunk
    indices) are zeroed, i.e. inert per the ChunkSource contract.

    This is also the restart-determinism story of this module applied to
    the solver: after a failure any worker regenerates exactly the
    byte-identical chunks it owned, no host state, no files.
    """
    from ..core.chunked import ChunkSource

    key = jax.random.PRNGKey(seed)
    budgets = jnp.full((k,), tightness * n * q * (b_high / 2.0) / k,
                       jnp.float32)

    def fn(i):
        kp_, kb = jax.random.split(jax.random.fold_in(key, i))
        p = jax.random.uniform(kp_, (chunk, k), jnp.float32)
        b = jax.random.uniform(kb, (chunk, k), jnp.float32, 0.0, b_high)
        live = ((i * chunk + jnp.arange(chunk)) < n)[:, None]
        return jnp.where(live, p, 0.0), jnp.where(live, b, 0.0)

    return ChunkSource(n=n, k=k, chunk=chunk, budgets=budgets, fn=fn)


def sparse_host_chunk_source(seed, n, k, chunk, q=1, tightness=0.5,
                             b_high=1.0):
    """Host-side twin of :func:`sparse_chunk_source`: NumPy chunks.

    Chunk ``i`` is a pure function of ``(seed, i)`` generated with
    NumPy's Philox generator *on the host thread* — the stand-in for a
    real dataset file in the host-fed streaming pipeline
    (core/prefetch.py): the bench uses it to measure double-buffered vs
    synchronous feeding without disk variance, and it keeps the
    restart-determinism contract (any worker regenerates its chunks
    byte-identically). Same workload shape and budget scaling as the
    traced generator; the RNG streams differ (numpy vs jax.random), so
    the *instances* are not row-identical across the two — use
    ``prefetch.host_array_source`` when a host/device parity oracle is
    needed.
    """
    import numpy as np

    from ..core.prefetch import HostChunkSource

    budgets = np.full((k,), tightness * n * q * (b_high / 2.0) / k,
                      np.float32)

    def fn(i):
        rng = np.random.Generator(np.random.Philox(key=seed, counter=i))
        p = rng.random((chunk, k), np.float32)
        b = rng.random((chunk, k), np.float32) * np.float32(b_high)
        live = ((i * chunk + np.arange(chunk)) < n)[:, None]
        return np.where(live, p, 0.0).astype(np.float32), \
            np.where(live, b, 0.0).astype(np.float32)

    return HostChunkSource(n=n, k=k, chunk=chunk, budgets=budgets, fn=fn)


def banded_host_chunk_source(seed, n, k, chunk, q=1, tightness=0.5,
                             band=0.05, period=8, b_lo=0.5):
    """Ratio-banded host instance: the active-set screening workload.

    Uniform-[0,1] profits over uniform-[0,1] costs give every chunk a
    heavy-tailed max(p/b) — no chunk's certificate ever clears the
    bucket ladder's lowest edge, so screening (core/screening.py) has
    nothing to retire. Real serving traffic is not like that: most
    cohorts' value ratios sit far below the marginal cohort's. This
    generator models that structure while staying a pure function of
    ``(seed, chunk index)``:

    * costs are uniform on [b_lo, 1) — bounding every ratio by
      ``p_scale / b_lo``;
    * chunk ``i``'s profits are uniform on [0, band) — a cold cohort —
      except every ``period``-th chunk, which is uniform on [0, 1): the
      hot cohorts that keep the multipliers (and the crossing buckets)
      up where the cold chunks' certificates clear the ladder.

    With ``band=0.05, b_lo=0.5`` a cold chunk bounds at 0.1 while the
    multipliers settle near the hot cohorts' marginal ratio (~1) —
    cold chunks retire after the first epoch and the streamed volume
    drops by roughly the cold fraction, all bitwise-identical to the
    unscreened solve. Budget scaling matches the uniform generators
    (mean cost is ``(b_lo + 1) / 2``).
    """
    import numpy as np

    from ..core.prefetch import HostChunkSource

    budgets = np.full((k,), tightness * n * q * ((b_lo + 1.0) / 2.0) / k,
                      np.float32)

    def fn(i):
        rng = np.random.Generator(np.random.Philox(key=seed, counter=i))
        scale = np.float32(1.0 if i % period == 0 else band)
        p = rng.random((chunk, k), np.float32) * scale
        b = np.float32(b_lo) + rng.random((chunk, k), np.float32) \
            * np.float32(1.0 - b_lo)
        live = ((i * chunk + np.arange(chunk)) < n)[:, None]
        return np.where(live, p, 0.0).astype(np.float32), \
            np.where(live, b, 0.0).astype(np.float32)

    return HostChunkSource(n=n, k=k, chunk=chunk, budgets=budgets, fn=fn)


def sparse_host_shard_sources(seed, n, k, chunk, slots, q=1, tightness=0.5,
                              b_high=1.0):
    """Per-slot host sources of one §6 instance: the sharded-feed twin.

    ``prefetch.sharded_source`` applied to :func:`sparse_host_chunk_source`
    — slot ``s`` serves the contiguous chunk range the traced sharded
    driver would hand shard ``s``, each chunk still a pure function of
    ``(seed, global chunk index)``. Because the Philox counter is the
    *global* index, a worker resumed after preemption — possibly owning
    different slots on a smaller mesh — regenerates exactly the bytes
    the lost worker streamed: the restart-determinism contract that
    checkpoint/resume (``solve_streaming_host(resume_from=...)``)
    requires of every source family. Returns a list of ``slots``
    HostChunkSources.
    """
    from ..core.prefetch import sharded_source

    return sharded_source(
        sparse_host_chunk_source(seed, n, k, chunk, q=q, tightness=tightness,
                                 b_high=b_high), slots)
