from .synth import kp_shard, lm_batch  # noqa: F401
