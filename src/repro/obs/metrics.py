"""Typed metrics registry: counters, gauges, histograms.

This is the process-wide observability substrate (DESIGN.md §14).  The
ad-hoc counter dicts that grew inside ``serve/decisions.py``,
``serve/front.py``, ``launch/supervisor.py`` and ``core/faults.py`` are
refactored onto it, each keeping its public ``health()`` field names as
read-only views assembled from instrument values.

Design rules:

* **Host-side only.**  Nothing here is ever called from inside traced
  (jitted) code; instruments mutate plain Python state under a lock.
* **Null fast path.**  ``NULL_REGISTRY`` hands out shared no-op
  instruments so un-instrumented call sites cost one attribute lookup
  and a no-op call — the bitwise story of a solve is identical with
  observability on or off either way, because instruments never feed
  back into numerics.
* **JSON-safe snapshots.**  ``MetricsRegistry.snapshot()`` returns a
  list of plain dicts that travels the replica RPC wire unchanged;
  ``merge_snapshots`` aggregates replica registries the way the front's
  ``/health`` already aggregates status; ``render_prometheus`` /
  ``parse_prometheus`` are the text exposition used by ``/metrics``.
"""
from __future__ import annotations

import json
import math
import re
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_REGISTRY", "NullRegistry",
    "merge_snapshots", "label_snapshot",
    "render_prometheus", "parse_prometheus",
    "LATENCY_BUCKETS",
]

# Fixed latency ladder (seconds).  Fixed — not configurable per call
# site — so replica snapshots always merge elementwise.
LATENCY_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter.  ``inc`` only; never decremented or set."""

    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        """Add ``n`` (default 1) to the counter."""
        with self._lock:
            self._value += n

    @property
    def value(self):
        """Current count."""
        return self._value

    def _snap(self) -> dict:
        return {"kind": "counter", "name": self.name,
                "labels": dict(self.labels), "value": self._value}


class Gauge:
    """Point-in-time value: ``set``/``set_max``, or a pull callback.

    With ``fn`` the gauge is *computed* — ``value`` calls ``fn()`` at
    snapshot time (used e.g. for live cache sizes).
    """

    kind = "gauge"

    def __init__(self, name: str, labels: dict, fn=None):
        self.name = name
        self.labels = dict(labels)
        self._fn = fn
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self._value = v

    def set_max(self, v) -> None:
        """Raise the gauge to ``v`` if ``v`` exceeds the current value."""
        with self._lock:
            if v > self._value:
                self._value = v

    @property
    def value(self):
        """Current value (calls the pull callback if one was given)."""
        if self._fn is not None:
            return self._fn()
        return self._value

    def _snap(self) -> dict:
        return {"kind": "gauge", "name": self.name,
                "labels": dict(self.labels), "value": self.value}


class Histogram:
    """Cumulative histogram over a fixed, shared bucket ladder."""

    kind = "histogram"

    def __init__(self, name: str, labels: dict, buckets=LATENCY_BUCKETS):
        self.name = name
        self.labels = dict(labels)
        self.buckets = tuple(buckets)
        self._counts = [0] * (len(self.buckets) + 1)   # +1: +Inf
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one observation ``v``."""
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self):
        """Total number of observations."""
        return self._count

    @property
    def sum(self):
        """Sum of all observed values."""
        return self._sum

    def _snap(self) -> dict:
        with self._lock:
            return {"kind": "histogram", "name": self.name,
                    "labels": dict(self.labels),
                    "buckets": list(self.buckets),
                    "counts": list(self._counts),
                    "sum": self._sum, "count": self._count}


class MetricsRegistry:
    """Get-or-create instrument registry keyed by (name, labels)."""

    null = False

    def __init__(self):
        self._instruments: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, labels, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        """Get or create the counter ``name`` with ``labels``."""
        return self._get(Counter, name, labels)

    def gauge(self, name: str, fn=None, **labels) -> Gauge:
        """Get or create the gauge ``name``; ``fn`` makes it computed."""
        g = self._get(Gauge, name, labels, fn=fn)
        if fn is not None and g._fn is None:
            g._fn = fn
        return g

    def histogram(self, name: str, buckets=LATENCY_BUCKETS,
                  **labels) -> Histogram:
        """Get or create the histogram ``name`` over ``buckets``."""
        return self._get(Histogram, name, labels, buckets=buckets)

    def snapshot(self) -> list:
        """JSON-safe list of instrument states, deterministically sorted."""
        with self._lock:
            insts = list(self._instruments.values())
        snaps = [i._snap() for i in insts]
        snaps.sort(key=lambda s: (s["name"], _label_key(s["labels"])))
        return snaps


class _NullInstrument:
    """Shared no-op instrument: every mutator is a cheap no-op."""

    def inc(self, n: int = 1) -> None:  # noqa: D102 - no-op
        pass

    def set(self, v) -> None:
        pass

    def set_max(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    value = 0
    count = 0
    sum = 0.0


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """No-op registry: hands out one shared no-op instrument."""

    null = True

    def counter(self, name: str, **labels):
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def gauge(self, name: str, fn=None, **labels):
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=LATENCY_BUCKETS, **labels):
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def snapshot(self) -> list:
        """Always empty."""
        return []


NULL_REGISTRY = NullRegistry()


def label_snapshot(snapshot: list, **labels) -> list:
    """Return a copy of ``snapshot`` with ``labels`` merged into every
    entry; the caller's labels win on collision (the front uses this to
    stamp ``replica="i"`` onto replica snapshots before merging)."""
    out = []
    for s in snapshot:
        s2 = dict(s)
        merged = dict(s2.get("labels", {}))
        merged.update({str(k): str(v) for k, v in labels.items()})
        s2["labels"] = merged
        out.append(s2)
    return out


def merge_snapshots(snapshots) -> list:
    """Merge an iterable of snapshot lists by (kind, name, labels).

    Counters and gauges sum; histograms add counts elementwise (the
    fixed shared ladders make this well defined) and add sum/count.
    """
    merged: dict = {}
    order: list = []
    for snap in snapshots:
        for s in snap:
            key = (s["kind"], s["name"], _label_key(s.get("labels", {})))
            cur = merged.get(key)
            if cur is None:
                cur = json.loads(json.dumps(s))   # deep, JSON-safe copy
                merged[key] = cur
                order.append(key)
                continue
            if s["kind"] == "histogram":
                if list(s["buckets"]) != list(cur["buckets"]):
                    raise ValueError(
                        f"histogram {s['name']!r}: bucket ladders differ")
                cur["counts"] = [a + b for a, b in
                                 zip(cur["counts"], s["counts"])]
                cur["sum"] += s["sum"]
                cur["count"] += s["count"]
            else:
                cur["value"] += s["value"]
    out = [merged[k] for k in order]
    out.sort(key=lambda s: (s["name"], _label_key(s.get("labels", {}))))
    return out


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(str(k))}="{v}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _prom_num(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if f == math.inf:
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snapshot: list) -> str:
    """Render a snapshot (or merged snapshot) as Prometheus text format."""
    lines = []
    seen_type: set = set()
    for s in snapshot:
        name = _prom_name(s["name"])
        labels = s.get("labels", {})
        if name not in seen_type:
            lines.append(f"# TYPE {name} {s['kind']}")
            seen_type.add(name)
        if s["kind"] == "histogram":
            edges = list(s["buckets"]) + [math.inf]
            cum = 0
            for edge, c in zip(edges, s["counts"]):
                cum += c
                ls = dict(labels)
                ls["le"] = _prom_num(edge)
                lines.append(f"{name}_bucket{_prom_labels(ls)} {cum}")
            lines.append(
                f"{name}_sum{_prom_labels(labels)} {_prom_num(s['sum'])}")
            lines.append(
                f"{name}_count{_prom_labels(labels)} {s['count']}")
        else:
            lines.append(
                f"{name}{_prom_labels(labels)} {_prom_num(s['value'])}")
    return "\n".join(lines) + "\n"


_SERIES_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$')
_LABEL_RE = re.compile(r'([a-zA-Z_:][a-zA-Z0-9_:]*)="([^"]*)"')


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text format into ``{(name, labels): value}``.

    ``labels`` is a sorted tuple of (key, value) pairs.  Used by the CI
    gates to check ``/metrics`` against ``/health`` counters.
    """
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SERIES_RE.match(line)
        if not m:
            continue
        name, labelstr, value = m.groups()
        labels = ()
        if labelstr:
            labels = tuple(sorted(_LABEL_RE.findall(labelstr)))
        v = float("inf") if value == "+Inf" else float(value)
        out[(name, labels)] = v
    return out
