"""Unified observability layer: metrics registry + phase-span tracing.

See DESIGN.md §14.  Everything is host-side only; the null fast path
(``null_obs()``) makes un-instrumented runs cost ~zero and keeps solves
bitwise identical with observability on or off (gated by
``benchmarks/bench_obs.py`` and ``tests/test_obs.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .metrics import (
    Counter, Gauge, Histogram, MetricsRegistry,
    NULL_REGISTRY, NullRegistry,
    merge_snapshots, label_snapshot,
    render_prometheus, parse_prometheus, LATENCY_BUCKETS,
)
from .trace import (
    Tracer, NullTracer, NULL_TRACER, read_trace,
    current_rid, request, trace_path,
)

__all__ = [
    "Obs", "null_obs", "make_obs",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_REGISTRY", "NullRegistry",
    "merge_snapshots", "label_snapshot",
    "render_prometheus", "parse_prometheus", "LATENCY_BUCKETS",
    "Tracer", "NullTracer", "NULL_TRACER", "read_trace",
    "current_rid", "request", "trace_path",
]


@dataclass
class Obs:
    """Bundle of one metrics registry and one trace journal writer."""

    registry: object = field(default_factory=MetricsRegistry)
    tracer: object = NULL_TRACER

    def close(self) -> None:
        """Flush and close the trace journal."""
        self.tracer.close()


_NULL_OBS = Obs(registry=NULL_REGISTRY, tracer=NULL_TRACER)


def null_obs() -> Obs:
    """The shared no-op bundle (null registry + null tracer)."""
    return _NULL_OBS


def make_obs(root=None, role: str = "proc",
             fsync_every: int = 512) -> Obs:
    """Real registry, plus a journal under ``<root>/obs/`` if ``root``
    is given (otherwise tracing stays null)."""
    tracer = (Tracer(trace_path(root, role), fsync_every=fsync_every)
              if root is not None else NULL_TRACER)
    return Obs(registry=MetricsRegistry(), tracer=tracer)
