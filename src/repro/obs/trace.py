"""Phase-span tracing to an fsync-safe JSONL journal per process.

Spans are *host-side only*: a span brackets host Python work (an epoch
of chunk feeding, a finalize pass, a replica fill) and never reaches
inside jitted code — no wall-clock or counter read is ever traced into
an XLA program, which is what keeps a solve with tracing enabled
bitwise identical to one without (DESIGN.md §14).

Journal format: one JSON object per line —

    {"phase": "solve.iterate", "t": <epoch s>, "dur_s": <float>,
     "pid": <int>, "rid": <request id, if any>, ...attrs}

Durability: spans buffer in memory and are JSON-encoded, written in
one batch, flushed and fsynced every ``fsync_every`` spans and on
``flush``/``close``.  A SIGKILL therefore loses at most the last
``fsync_every`` unflushed spans and can tear at most the final line on
disk — ``read_trace`` tolerates a torn tail (it never raises on one)
while still refusing mid-file corruption.  Keeping the hot path to a
locked list append is what holds the enabled-path overhead inside the
bench_obs budget.

Request correlation: the front mints a request id per HTTP request and
sends it over the replica RPC wire; ``ReplicaServer`` installs it in a
``contextvars.ContextVar`` around dispatch so every span emitted while
serving that request (e.g. ``serve.fill``) carries the same ``rid``.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "read_trace",
           "current_rid", "request", "trace_path"]

import contextvars

_RID: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_rid", default=None)


def current_rid():
    """The request id installed for this context, or None."""
    return _RID.get()


@contextlib.contextmanager
def request(rid):
    """Install ``rid`` as the current request id for the duration."""
    tok = _RID.set(rid)
    try:
        yield
    finally:
        _RID.reset(tok)


def trace_path(root, role: str):
    """Canonical journal path for ``role`` under ``<root>/obs/``."""
    return os.path.join(os.fspath(root), "obs",
                        f"{role}-{os.getpid()}.jsonl")


class _Span:
    __slots__ = ("_tracer", "_phase", "_attrs", "_t0", "_p0")

    def __init__(self, tracer, phase, attrs):
        self._tracer = tracer
        self._phase = phase
        self._attrs = attrs

    def __enter__(self):
        self._t0 = time.time()
        self._p0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._p0
        self._tracer._emit(self._phase, self._t0, dur, self._attrs)
        return False


class Tracer:
    """Appends phase spans to one JSONL journal file.

    The file is opened lazily on the first span so constructing a
    Tracer never touches the filesystem; parent directories are created
    on open.  Thread-safe: one lock serialises writes.
    """

    enabled = True

    def __init__(self, path, fsync_every: int = 512):
        self.path = os.fspath(path)
        self.fsync_every = max(1, int(fsync_every))
        self._fh = None
        self._buf: list = []
        self._lock = threading.Lock()

    def span(self, phase: str, **attrs):
        """Context manager timing a host-side phase."""
        return _Span(self, phase, attrs)

    def event(self, phase: str, **attrs) -> None:
        """Zero-duration mark (e.g. ``screen.skip``)."""
        self._emit(phase, time.time(), 0.0, attrs)

    def record(self, phase: str, t0: float, dur_s: float,
               **attrs) -> None:
        """Emit a pre-measured span (host-side aggregated timing).

        The ingest instrumentation uses this to time every chunk fetch
        / upload with bare ``perf_counter`` pairs and emit *one* record
        per phase per epoch — per-chunk span objects on the streaming
        critical path would dominate the cost they measure.
        """
        self._emit(phase, t0, float(dur_s), attrs)

    def _emit(self, phase, t0, dur, attrs):
        # The hot path does no serialisation and no I/O: records buffer
        # in memory and are JSON-encoded + written in one batch every
        # ``fsync_every`` spans (and on flush/close). That batching is
        # what keeps the per-span cost near a list append — the
        # bench_obs overhead budget.
        rec = {"phase": phase, "t": t0, "dur_s": dur, "pid": os.getpid()}
        rid = _RID.get()
        if rid is not None:
            rec["rid"] = rid
        if attrs:
            rec.update(attrs)
        with self._lock:
            self._buf.append(rec)
            if len(self._buf) >= self.fsync_every:
                self._flush_locked()

    def _flush_locked(self) -> None:
        if not self._buf:
            return
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")
        self._fh.write("".join(
            json.dumps(rec, separators=(",", ":")) + "\n"
            for rec in self._buf))
        self._buf.clear()
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def flush(self) -> None:
        """Durably write every buffered span (one line batch + fsync)."""
        with self._lock:
            self._flush_locked()

    def close(self) -> None:
        """Flush, fsync and close the journal (idempotent)."""
        with self._lock:
            self._flush_locked()
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: the default everywhere tracing isn't requested."""

    enabled = False

    def span(self, phase: str, **attrs):
        """Shared no-op context manager."""
        return _NULL_SPAN

    def event(self, phase: str, **attrs) -> None:
        """No-op."""

    def record(self, phase: str, t0: float, dur_s: float,
               **attrs) -> None:
        """No-op."""

    def flush(self) -> None:
        """No-op."""

    def close(self) -> None:
        """No-op."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_TRACER = NullTracer()


def read_trace(path) -> list:
    """Read a span journal, tolerating a torn tail.

    Returns the list of decoded span dicts.  A final line torn by a
    crash (no trailing newline / truncated JSON) is silently dropped;
    an undecodable line *before* the tail raises, because that means
    real corruption rather than a crash mid-append.
    """
    spans = []
    try:
        with open(os.fspath(path), "r", encoding="utf-8") as fh:
            raw = fh.read()
    except FileNotFoundError:
        return spans
    lines = raw.splitlines()
    for i, line in enumerate(lines):
        if not line:
            continue
        try:
            spans.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                continue            # torn tail (crash mid-append)
            raise ValueError(
                f"{path}: corrupt trace line {i + 1}") from None
    return spans
