"""mamba2-370m [ssm]: 48L d_model=1024, attn-free SSD blocks, vocab=50280,
ssm_state=128. Source: arXiv:2405.21060 (state-space duality). d_inner =
2*d_model = 2048, head_dim 64 -> 32 heads, groups=1, conv4."""
from repro.models.config import MambaCfg, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    n_layers=48,
    d_model=1024,
    n_heads=32,          # SSD heads (d_inner / head_dim)
    n_kv_heads=32,
    d_ff=0,
    vocab=50280,
    pattern=("mamba",),
    ffn_pattern=("none",),
    mamba=MambaCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                   chunk=256),
    tie_embeddings=True,
)
