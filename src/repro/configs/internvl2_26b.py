"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternLM2-20B LLM backbone; InternViT frontend is a STUB
per the assignment (input_specs() supplies 1024 patch embeddings that are
prepended to the token embeddings). Source: arXiv:2404.16821."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    modality="vision",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    n_patches=1024,
)
