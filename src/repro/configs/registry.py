"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

import importlib

ARCHS = {
    "mamba2-370m": "mamba2_370m",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "yi-34b": "yi_34b",
    "gemma-2b": "gemma_2b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-4b": "qwen3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-26b": "internvl2_26b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
}


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def names():
    return list(ARCHS)
