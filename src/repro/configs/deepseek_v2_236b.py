"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512
(rope_hd=64, nope_hd=128, v_hd=128, q_lora=1536), vocab=102400; layer 0
dense FFN 12288, layers 1..59 MoE with 2 shared + 160 routed experts
(top-6), expert d_ff=1536. Source: arXiv:2405.04434. Flagship SCD-router
integration (K=160 knapsacks, Q=6)."""
from repro.models.config import MLACfg, MoECfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    first_dense_ff=12288,
    vocab=102400,
    use_mla=True,
    mla=MLACfg(kv_lora=512, q_lora=1536, rope_head_dim=64, nope_head_dim=128,
               v_head_dim=128),
    pattern=("attn",),
    ffn_pattern=("moe",),
    moe=MoECfg(n_experts=160, n_shared=2, topk=6, d_ff=1536, router="scd"),
)
