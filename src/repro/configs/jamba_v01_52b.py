"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave, MoE every other
layer. Source: arXiv:2403.19887. Period-8 pattern x 4; the paper's SCD
router is first-class here (router="scd")."""
from repro.models.config import MambaCfg, MoECfg, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=("attn",) + ("mamba",) * 7,
    ffn_pattern=("moe", "dense") * 4,
    moe=MoECfg(n_experts=16, topk=2, d_ff=14336, router="scd"),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                   chunk=256),
)
