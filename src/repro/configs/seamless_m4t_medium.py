"""seamless-m4t-medium [audio]: enc-dec, 12+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206. Source: arXiv:2308.11596. The speech frontend is a
STUB per the assignment: input_specs() supplies precomputed frame
embeddings (B, F, d_model) to the encoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    kind="encdec",
    modality="audio",
    n_layers=12,            # decoder layers
    n_enc_layers=12,        # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
)
