"""The paper's own production workload as a config: billion-scale sparse
GKP instances (Section 6). ``billion`` is the headline claim (1e9 decision
variables / constraints, solved < 1h on 200 executors); the dry-run lowers
one SCD iteration of it across the full mesh."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class KPWorkload:
    name: str
    n_users: int
    k: int                 # knapsacks (and items, sparse form)
    q: int                 # local cardinality cap
    tightness: float = 0.5


WORKLOADS = {
    "table1": KPWorkload("table1", 100_000_000, 10, 1),
    "billion": KPWorkload("billion", 1_000_000_000, 10, 1),
    "dense-fig1": KPWorkload("dense-fig1", 10_000, 10, 1),
}
