"""moonshot-v1-16b-a3b [moe] (kimi/moonlight): 48L d_model=2048 16H
(GQA kv=16... spec: kv=16) d_ff=1408, MoE 64e top-6, vocab=163840.
Source: hf:moonshotai/Moonlight-16B-A3B. SCD router enabled."""
from repro.models.config import MoECfg, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    pattern=("attn",),
    ffn_pattern=("moe",),
    moe=MoECfg(n_experts=64, topk=6, d_ff=1408, router="scd"),
)
