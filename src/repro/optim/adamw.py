"""AdamW with global-norm clipping and optional int8 error-feedback
gradient compression on the data-parallel reduction.

Optimizer state mirrors the parameter tree, so whatever sharding the
params carry (FSDP over "data" + TP over "model" in production) the
moments inherit — ZeRO-style partitioning falls out of the specs rather
than being a separate mechanism.

Compression (``compress_grads=True``): before the DP mean, gradients are
quantised to int8 with a per-tensor scale; the quantisation error is kept
in an error-feedback accumulator (Seide et al. / EF-SGD) and added back
next step, preserving convergence. With ``in_shardings`` marking grads as
device-local partial sums this turns the all-reduce payload from 4-byte
floats into 1-byte ints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    compress_grads: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    err: Optional[Any]          # error-feedback accumulator (compression)


def init_opt_state(params, cfg: OptConfig) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros32, params),
        nu=jax.tree.map(zeros32, params),
        err=jax.tree.map(zeros32, params) if cfg.compress_grads else None,
    )


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g, err):
    """int8 EF round-trip for one tensor; returns (g_hat, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize_int8(g32)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, g32 - g_hat


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.compress_grads:
        pairs = jax.tree.map(compress_decompress, grads, state.err)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda pr: pr[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    else:
        err = state.err

    # global-norm clip in f32
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, mu, nu, err), {"grad_norm": gnorm, "lr": lr}
