from .adamw import OptConfig, OptState, apply_updates, init_opt_state  # noqa: F401
