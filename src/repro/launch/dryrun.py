import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST run before any other import: jax locks the device count on first
# initialisation. 512 fake host devices back both production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (train_step /
prefill / decode) against ShapeDtypeStruct inputs on the production mesh —
no arrays are ever allocated — then records:

  * memory_analysis()      -> per-device bytes (does it fit 16 GB v5e HBM?)
  * cost_analysis()        -> HLO FLOPs / bytes for the roofline
  * collective bytes       -> parsed from the optimized HLO text
  * (scan correction)      -> a single-block probe program is compiled and
                              its body cost is multiplied by the remaining
                              scan trips, because XLA's cost model counts a
                              while-loop body exactly once.

Also dry-runs the paper's own workload: one SCD iteration of the
billion-user sparse GKP sharded over all 512 devices.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all --out reports/dryrun.json
    python -m repro.launch.dryrun --paper-kp billion
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.configs import registry
from repro.configs.paper_kp import WORKLOADS
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import sharding
from repro.optim import OptConfig, OptState
from repro.optim.adamw import init_opt_state


# ---------------------------------------------------------------------------
# collective-bytes parser
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-kind operand bytes of communication ops in optimized HLO.

    Only the output-shape declaration on the LHS of each collective line is
    counted (per-device payload)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %ag = bf16[4,1024]{1,0} all-gather(%x), ...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op.startswith(kind):
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", -1.0)),
        "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
    }


def _mem_dict(compiled, n_devices=1) -> dict:
    """Calibrated on this backend (see EXPERIMENTS §Dry-run): argument/
    output sizes are PER-DEVICE; temp is the GLOBAL buffer total, so the
    per-device estimate divides by the mesh size."""
    try:
        ma = compiled.memory_analysis()
        arg = int(getattr(ma, "argument_size_in_bytes", -1))
        out = int(getattr(ma, "output_size_in_bytes", -1))
        temp = int(getattr(ma, "temp_size_in_bytes", -1))
        return {
            "argument_bytes": arg,
            "output_bytes": out,
            "temp_bytes": temp,
            "per_device_bytes_est": int(arg + temp / max(n_devices, 1)),
            "fits_16gb_hbm": bool(arg + temp / max(n_devices, 1) < 16e9),
        }
    except Exception as e:  # CPU backend may not implement it fully
        return {"error": str(e)}


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if not isinstance(x, jax.ShapeDtypeStruct) else x, tree)


def lower_cell(arch: str, shape: str, multi_pod: bool, probe: bool = True,
               scan_layers: bool = True, router: str = None,
               fsdp_mode: str = None, batch_override: int = None):
    """Lower+compile one cell. Returns a result dict (see dryrun report)."""
    cfg = registry.get(arch)
    if not scan_layers:
        cfg = cfg.replace(scan_layers=False)
    if router:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, router=router))
    if fsdp_mode:
        cfg = cfg.replace(fsdp_mode=fsdp_mode)
    cell = M.SHAPES[shape]
    if batch_override:
        cell = dataclasses.replace(cell, global_batch=batch_override)
    skip = M.cell_applicable(cfg, cell)
    if skip:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = M.production_rules(multi_pod, cfg.fsdp_mode)
    t0 = time.time()
    result = {"arch": arch, "shape": shape,
              "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok",
              "fsdp_mode": cfg.fsdp_mode, "router": cfg.moe.router or None,
              "global_batch": cell.global_batch}
    with compat.set_mesh(mesh):
        sharding.set_rules(rules)
        try:
            pshape = jax.eval_shape(
                lambda k: M.init(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
            pspecs, ospecs, bspecs = M.shardings(cfg, cell, multi_pod)
            pspecs, ospecs, bspecs = compat.as_shardings(
                mesh, (pspecs, ospecs, bspecs))
            inputs = _abstract(M.input_specs(cfg, cell))

            if cell.kind == "train":
                opt_cfg = OptConfig()
                oshape = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), pshape)
                fn = M.make_train_step(cfg, opt_cfg)
                lowered = jax.jit(
                    fn,
                    in_shardings=(pspecs, ospecs, bspecs),
                    out_shardings=(pspecs, ospecs, None),
                    donate_argnums=(0, 1),
                ).lower(pshape, oshape, inputs)
            elif cell.kind == "prefill":
                fn = M.make_prefill_step(cfg)
                lowered = jax.jit(
                    fn, in_shardings=(pspecs, bspecs), out_shardings=None,
                ).lower(pshape, inputs)
            else:
                fn = M.make_decode_step(cfg)
                cspecs = bspecs["caches"]
                lowered = jax.jit(
                    fn,
                    in_shardings=(pspecs, cspecs, bspecs["token"], bspecs["pos"]),
                    out_shardings=(None, cspecs),
                    donate_argnums=(1,),
                ).lower(pshape, inputs["caches"], inputs["token"], inputs["pos"])

            compiled = lowered.compile()
            result["compile_s"] = round(time.time() - t0, 1)
            result["cost"] = _cost_dict(compiled)
            result["memory"] = _mem_dict(compiled, mesh.size)
            hlo = compiled.as_text()
            result["collectives"] = collective_bytes(hlo)
            import math
            result["n_params"] = int(sum(
                math.prod(l.shape) for l in jax.tree.leaves(pshape)))

            # scan-body probe: cost_analysis counts while bodies once.
            if probe and cfg.scan_layers:
                result["scan_probe"] = _probe_block(cfg, cell, mesh, multi_pod)
        except Exception as e:
            result["status"] = "error"
            result["error"] = f"{type(e).__name__}: {e}"
            result["traceback"] = traceback.format_exc()[-2000:]
        finally:
            sharding.set_rules(None)
    return result


def _probe_block(cfg, cell, mesh, multi_pod):
    """Compile ONE scan period as its own program to correct cost_analysis
    (XLA counts a while body once; the full model runs n_periods trips)."""
    from repro.models import blocks as B

    b = cell.global_batch
    if cell.kind in ("train", "prefill"):
        s = M._text_len(cfg, cell.seq_len)
        x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)

        def one_period(slot_params, x):
            positions = jnp.arange(x.shape[1])
            for i, (slot, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
                x = B.block_apply(slot_params[i], cfg, x, positions, slot, ffn)
            return x

        if cell.kind == "train":
            def probe_fn(slot_params, x):
                def loss(sp, xx):
                    return jnp.sum(one_period(sp, xx).astype(jnp.float32) ** 2)
                g = jax.grad(loss)(slot_params, x)
                return g
        else:
            probe_fn = one_period

        pshape = jax.eval_shape(
            lambda k: [jax.vmap(lambda kk: B.init_block(kk, cfg, slot, ffn))(
                jax.random.split(k, 1))
                for slot, ffn in zip(cfg.pattern, cfg.ffn_pattern)],
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        pshape = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), pshape)
        pspecs = M.param_specs(cfg, {"slots": pshape})["slots"]
        rules = M.production_rules(multi_pod, cfg.fsdp_mode)
        x_spec = M.sanitize(
            P(rules["batch"], rules["seq"], None), x_sds.shape)
        lowered = jax.jit(
            probe_fn,
            in_shardings=compat.as_shardings(mesh, (pspecs, x_spec)),
        ).lower(pshape, x_sds)
    else:
        # decode probe: one period of block_decode
        def probe_fn(slot_params, slot_caches, x, pos):
            new = []
            for i, (slot, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
                x, nc = B.block_decode(slot_params[i], cfg, x, slot_caches[i],
                                       pos, slot, ffn)
                new.append(nc)
            return x, new

        pshape = jax.eval_shape(
            lambda k: [B.init_block(k, cfg, slot, ffn)
                       for slot, ffn in zip(cfg.pattern, cfg.ffn_pattern)],
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        cshape = jax.eval_shape(
            lambda: [B.init_block_cache(cfg, slot, b, cell.seq_len, cfg.dtype)
                     for slot in cfg.pattern])
        pspecs = M.param_specs(cfg, {"slots": jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((1, *l.shape), l.dtype), pshape)})["slots"]
        pspecs = jax.tree.map(lambda s: P(*s[1:]), pspecs,
                              is_leaf=lambda s: isinstance(s, P))
        cspecs_full = M.cache_specs(cfg, cell, {"slots": jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((1, *l.shape), l.dtype), cshape)},
            multi_pod)["slots"]
        cspecs = jax.tree.map(lambda s: P(*s[1:]), cspecs_full,
                              is_leaf=lambda s: isinstance(s, P))
        x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)
        rules = M.production_rules(multi_pod, cfg.fsdp_mode)
        x_spec = M.sanitize(P(rules["batch"], None, None), x_sds.shape)
        lowered = jax.jit(
            probe_fn,
            in_shardings=compat.as_shardings(
                mesh, (pspecs, cspecs, x_spec, P())),
        ).lower(pshape, cshape, x_sds, jax.ShapeDtypeStruct((), jnp.int32))

    compiled = lowered.compile()
    out = _cost_dict(compiled)
    out["collectives"] = collective_bytes(compiled.as_text())
    out["n_periods"] = cfg.n_periods
    return out


# ---------------------------------------------------------------------------
# paper workload dry-run
# ---------------------------------------------------------------------------

def lower_paper_kp(workload: str, multi_pod: bool = True,
                   reduce: str = "bucketed", algo: str = "scd",
                   max_iters: int = 2, chunk_size: int = None,
                   streaming: bool = False, stream_finalize: str = "fused"):
    """One jitted solve of the paper-scale sparse GKP sharded over every
    device of the production mesh. ``reduce``/``algo`` select the §Perf
    A/B variants (exact gather vs §5.2 bucketed psum; DD vs SCD).

    ``chunk_size`` chunks the per-iteration map (core/solver.py);
    ``streaming`` lowers the out-of-core driver (core/chunked.py) whose
    chunks are synthesized inside the program — its memory_analysis shows
    argument + temp bytes independent of N, the headline of the chunked
    solve path (compare against the resident lowering, whose argument
    bytes are 8·N·K). ``stream_finalize`` picks the single-pass fused
    finalize or the legacy three-pass one (DESIGN.md §5c), so the two
    lowered programs' cost/collective profiles can be diffed."""
    from repro.core import SolverConfig, SparseKP
    from repro.core.solver import _solve_entry
    import functools

    wl = WORKLOADS[workload]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    # round to a mesh multiple (shard_map needs exact divisibility)
    n = (wl.n_users // mesh.size) * mesh.size
    k = wl.k
    cfg = SolverConfig(algo=algo, reduce=reduce, max_iters=max_iters,
                       postprocess=True, chunk_size=chunk_size,
                       stream_finalize=stream_finalize)
    t0 = time.time()
    if streaming:
        if reduce != "bucketed":
            raise ValueError("--streaming lowers the bucketed-reduce "
                             "driver only (solve_streaming cannot stream "
                             "the exact reduce)")
        from repro.core.chunked import stream_solve_fn
        from repro.data.synth import sparse_chunk_source
        chunk = chunk_size = chunk_size or 65536
        src = sparse_chunk_source(0, n, k, chunk, q=wl.q,
                                  tightness=wl.tightness)
        cfg = cfg.replace(chunk_size=None)
        # The exact program users run: the shared streaming entry builder.
        fn = stream_solve_fn(src, cfg, wl.q, mesh=mesh)
        lowered = fn.lower(
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32))
    else:
        kp = SparseKP(
            p=jax.ShapeDtypeStruct((n, k), jnp.float32),
            b=jax.ShapeDtypeStruct((n, k), jnp.float32),
            budgets=jax.ShapeDtypeStruct((k,), jnp.float32),
        )
        user = P(axes)
        # out_specs: lam/iters/r/primal/dual replicated; x user-sharded
        from repro.core.solver import SolveResult
        fn = shard_map(
            functools.partial(_solve_entry, q=wl.q, cfg=cfg, axis=axes),
            mesh=mesh,
            in_specs=(SparseKP(p=user, b=user, budgets=P()), P()),
            out_specs=SolveResult(lam=P(), x=P(axes, None), iters=P(), r=P(),
                                  primal=P(), dual=P(), history=None),
            check_vma=False,
        )
        lowered = jax.jit(fn).lower(kp, jax.ShapeDtypeStruct((k,), jnp.float32))
    compiled = lowered.compile()
    res = {
        "workload": workload, "n_users": n, "k": k,
        "algo": algo, "reduce": reduce, "iters": max_iters,
        "chunk_size": chunk_size, "streaming": streaming,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "cost": _cost_dict(compiled),
        "memory": _mem_dict(compiled, mesh.size),
        "collectives": collective_bytes(compiled.as_text()),
    }
    return res


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(M.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-kp", choices=list(WORKLOADS))
    ap.add_argument("--reduce", choices=["bucketed", "exact"], default="bucketed")
    ap.add_argument("--algo", choices=["scd", "dd"], default="scd")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="paper-kp: chunk the per-iteration map "
                         "(core/solver.py chunked mode)")
    ap.add_argument("--streaming", action="store_true",
                    help="paper-kp: lower the out-of-core driver "
                         "(core/chunked.py) — argument/temp bytes flat in N")
    ap.add_argument("--stream-finalize", choices=["fused", "legacy"],
                    default="fused",
                    help="paper-kp --streaming: fused single-pass finalize "
                         "vs the legacy three-pass one (DESIGN.md §5c)")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--unrolled", action="store_true",
                    help="disable scan-over-layers (exact HLO flops)")
    ap.add_argument("--router", choices=["topk", "scd"])
    ap.add_argument("--fsdp", choices=["full", "zero1", "none", "fsdp_only", "dp_full"], default=None)
    ap.add_argument("--batch", type=int, default=None,
                    help="override the cell's global batch")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = []
    if args.paper_kp:
        r = lower_paper_kp(args.paper_kp, multi_pod=True,
                           reduce=args.reduce, algo=args.algo,
                           chunk_size=args.chunk_size,
                           streaming=args.streaming,
                           stream_finalize=args.stream_finalize)
        print(json.dumps(r, indent=2))
        results.append(r)
    elif args.all:
        for arch in registry.names():
            for shape in M.SHAPES:
                for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                    r = lower_cell(arch, shape, mp, probe=not args.no_probe,
                                   scan_layers=not args.unrolled,
                                   router=args.router)
                    print(json.dumps({k: v for k, v in r.items()
                                      if k != "traceback"}))
                    results.append(r)
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            r = lower_cell(args.arch, args.shape, mp,
                           probe=not args.no_probe,
                           scan_layers=not args.unrolled,
                           router=args.router, fsdp_mode=args.fsdp,
                           batch_override=args.batch)
            print(json.dumps({k: v for k, v in r.items() if k != "traceback"},
                             indent=2))
            results.append(r)

    if args.out:
        import pathlib
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    ok = all(r["status"] in ("ok", "skipped") for r in results)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
