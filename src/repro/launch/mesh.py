"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests keep the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data", "model"); 2 pods adds a "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess tests with a few fake host devices."""
    return jax.make_mesh(shape, axes)


# TPU v5e single-chip peak numbers used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
