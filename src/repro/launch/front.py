"""Front launcher: HTTP serving over DecisionService replica processes.

Two entry modes:

* ``--replica`` — run ONE replica process: attach a
  :class:`~repro.serve.engine.RefreshEngine` to the shared generation
  root (waiting for the first publication if needed), serve a
  :class:`~repro.serve.front.ReplicaServer` on a free port and announce
  it atomically under ``<root>/front/replica_<i>.json``. The replica's
  pointer watcher follows LIVE flips on its own; the orchestrator never
  talks to it except over RPC.
* default — the orchestrated scenario (the CI front smoke gate):
  publish generation 0, spawn N replicas (child environments assembled
  by :func:`repro.launch.env.worker_env` — single virtual device per
  replica; lookups are one-chunk jits), boot the HTTP front over them,
  then hammer ``/decide_batch`` from concurrent client threads **while
  the engine refreshes further generations with ``keep=2`` prune churn
  underneath** — the pointer watchers rebind the replicas live. Every
  answered row is then verified **bitwise** against the full
  materialisation of the generation that answered it (each response
  names its generation, so answers from mid-flip replicas verify
  against the generation they claim, exactly like the in-process
  story), and the cross-generation ``/diff`` endpoint is checked
  against the brute-force comparison of two generations' decision
  matrices, with per-replica chunk-fill accounting proving one grouped
  pass per generation (second pass: zero fills — both generations
  cached).

    PYTHONPATH=src python -m repro.launch.front --smoke
    PYTHONPATH=src python -m repro.launch.front --users 65536 \
        --replicas 4 --root /tmp/front

Exit status 1 when any row, provenance flag or diff bit mismatches —
this is the CI gate; ``benchmarks/bench_front.py`` reuses
:func:`run_front_scenario` for BENCH_front.json.
"""
from __future__ import annotations

import argparse
import http.client
import json
import pathlib
import subprocess
import sys
import tempfile
import threading
import time
from typing import Optional

import numpy as np

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.launch import env as envmod
from repro.launch.refresh import _budget_schedule
from repro.serve import Front, RefreshEngine, ReplicaClient, ReplicaServer, \
    WorkloadSpec
from repro.serve.front import poisoned_factory, unpack_array

_FRONT_DIR = "front"


# ---------------------------------------------------------------------------
# Replica process entry.
# ---------------------------------------------------------------------------

def run_replica(root, index: int, cache_chunks: int, retries: int,
                attach_timeout: float, poll_s: float,
                poison_scale: Optional[float] = None,
                poison_chunk: int = 0, obs: bool = False) -> None:
    """The ``--replica`` body: attach, announce, serve until shutdown.

    With ``obs=True`` the replica traces to
    ``<root>/obs/replica<i>-<pid>.jsonl`` (serve.fill spans carrying
    front-minted request ids, replica.rebind spans); metrics are always
    on — the ``metrics`` RPC op and the front's ``/metrics`` read them.
    """
    from repro.obs import make_obs
    from repro.serve import synthetic_source

    make_source = synthetic_source
    if poison_scale is not None:
        make_source = poisoned_factory(synthetic_source, poison_scale,
                                       poison_chunk)
    cfg = SolverConfig(reduce="bucketed", fetch_retries=retries,
                       fetch_backoff=1e-4, fetch_backoff_cap=1e-3)
    obs_bundle = make_obs(root=root if obs else None,
                          role=f"replica{index}")
    engine = RefreshEngine.attach(root, timeout=attach_timeout, cfg=cfg,
                                  make_source=make_source, obs=obs_bundle)
    rep = ReplicaServer(engine, index=index, cache_chunks=cache_chunks,
                        poll_s=poll_s)
    port = rep.start()
    ckpt.write_json(pathlib.Path(root) / _FRONT_DIR,
                    f"replica_{index}.json",
                    {"port": port, "pid": __import__("os").getpid(),
                     "index": index})
    print(f"[replica {index}] serving on 127.0.0.1:{port}", flush=True)
    rep.serve_forever()


def spawn_replicas(root, n: int, cache_chunks: int = 32,
                   retries: int = 2, devices: int = 1,
                   timeout: float = 120.0, poll_s: float = 0.05,
                   extra_args: tuple = ()) -> tuple:
    """Spawn ``n`` replica processes and wait for their announcements.

    Child environments come from :func:`repro.launch.env.worker_env`
    (platform pinned, ``devices`` virtual devices) with the running
    package's ``src`` prepended to PYTHONPATH, same as the supervisor's
    workers. Returns ``(procs, clients)``; raises (after killing the
    children) if any replica dies or fails to announce in time.
    """
    import os

    root = pathlib.Path(root)
    wenv = envmod.worker_env(devices)
    src = str(pathlib.Path(__file__).resolve().parents[2])
    pp = wenv.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        wenv["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    procs = []
    for i in range(n):
        argv = [sys.executable, "-m", "repro.launch.front", "--replica",
                "--root", str(root), "--index", str(i),
                "--cache-chunks", str(cache_chunks),
                "--retries", str(retries), "--poll", str(poll_s),
                *extra_args]
        procs.append(subprocess.Popen(argv, env=wenv))
    clients, deadline = [], time.monotonic() + timeout
    try:
        for i in range(n):
            while True:
                doc = ckpt.read_json(root / _FRONT_DIR,
                                     f"replica_{i}.json")
                if doc is not None:
                    clients.append(ReplicaClient("127.0.0.1", doc["port"]))
                    break
                if procs[i].poll() is not None:
                    raise RuntimeError(
                        f"replica {i} exited rc={procs[i].returncode} "
                        "before announcing")
                if time.monotonic() > deadline:
                    raise RuntimeError(f"replica {i} never announced")
                time.sleep(0.02)
    except Exception:
        for p in procs:
            p.kill()
        raise
    return procs, clients


def stop_replicas(procs, clients) -> None:
    for rc in clients:
        try:
            rc.call({"op": "shutdown"})
        except Exception:                    # noqa: BLE001 — best effort
            pass
    for p in procs:
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:
            p.kill()


# ---------------------------------------------------------------------------
# HTTP client helper (keep-alive; urllib reconnects per request).
# ---------------------------------------------------------------------------

class _HTTPClient:
    """A keep-alive JSON client for one front address (one per thread)."""

    def __init__(self, host: str, port: int):
        import socket

        self.conn = http.client.HTTPConnection(host, port, timeout=60)
        self.conn.connect()
        self.conn.sock.setsockopt(socket.IPPROTO_TCP,
                                  socket.TCP_NODELAY, 1)

    def get(self, path: str) -> dict:
        self.conn.request("GET", path)
        r = self.conn.getresponse()
        body = json.loads(r.read().decode("utf-8"))
        if r.status != 200:
            raise RuntimeError(f"GET {path} -> {r.status}: {body}")
        return body

    def get_text(self, path: str) -> str:
        self.conn.request("GET", path)
        r = self.conn.getresponse()
        body = r.read().decode("utf-8")
        if r.status != 200:
            raise RuntimeError(f"GET {path} -> {r.status}: {body}")
        return body

    def post(self, path: str, payload: dict) -> dict:
        self.conn.request("POST", path, body=json.dumps(payload),
                          headers={"Content-Type": "application/json"})
        r = self.conn.getresponse()
        body = json.loads(r.read().decode("utf-8"))
        if r.status != 200:
            raise RuntimeError(f"POST {path} -> {r.status}: {body}")
        return body

    def close(self) -> None:
        self.conn.close()


# ---------------------------------------------------------------------------
# The orchestrated scenario.
# ---------------------------------------------------------------------------

def _materialise(engine: RefreshEngine, gen) -> np.ndarray:
    """The full (n, K) decision matrix of one generation (reference)."""
    svc = engine.decision_service(generation=gen, fallback=False)
    return svc.decide_batch(np.arange(gen.spec.n))


def run_front_scenario(spec: WorkloadSpec, generations: int, root,
                       cfg: SolverConfig, replicas: int = 2,
                       client_threads: int = 4, batch: int = 128,
                       keep: int = 2, settle_s: float = 0.3,
                       mesh=None, slots=None) -> dict:
    """Refresh churn under live HTTP traffic; returns the accounting
    dict (also the BENCH_front.json point)."""
    root = pathlib.Path(root)
    engine = RefreshEngine(root, spec, cfg=cfg, mesh=mesh, slots=slots,
                           keep=keep)
    scales = _budget_schedule(generations, spec.seed)
    refs = {}
    gen0 = engine.refresh(budget_scale=scales[0])
    refs[gen0.gen] = _materialise(engine, gen0)
    print(f"[front] gen 0 published ({gen0.iters} iters); "
          f"spawning {replicas} replicas")

    procs, clients = spawn_replicas(root, replicas)
    front = Front(clients)
    host, port = front.start()
    print(f"[front] http on {host}:{port}")

    stop = threading.Event()
    results, errors = [], []
    lock = threading.Lock()

    def hammer(seed: int) -> None:
        rng = np.random.default_rng(seed)
        cli = _HTTPClient(host, port)
        try:
            while not stop.is_set():
                users = rng.integers(0, spec.n, batch)
                r = cli.post("/decide_batch",
                             {"users": users.tolist()})
                with lock:
                    results.append((users, r))
        except Exception as e:               # noqa: BLE001 — joined below
            with lock:
                errors.append(repr(e))
        finally:
            cli.close()

    threads = [threading.Thread(target=hammer, args=(1000 + t,))
               for t in range(client_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()

    # The churn: further generations published + pruned while the
    # replicas keep answering; the watchers rebind on each flip.
    try:
        for g in range(1, generations):
            gen = engine.refresh(budget_scale=scales[g])
            refs[gen.gen] = _materialise(engine, gen)
            print(f"[front] gen {gen.gen} published "
                  f"({gen.iters} iters warm); retained "
                  f"{engine.generation_ids()}")
        final = generations - 1
        health_cli = _HTTPClient(host, port)
        deadline = time.monotonic() + 60
        while True:
            h = health_cli.get("/health")
            if h["ok"] and h["generations"] == [final]:
                break
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"replicas never converged on gen {final}: {h}")
            time.sleep(0.05)
        time.sleep(settle_s)                 # post-flip traffic too
    finally:
        stop.set()
        for t in threads:
            t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise RuntimeError(f"client threads failed: {errors}")

    # Bitwise parity: every answered row against the materialisation of
    # the generation that answered it; provenance must be fresh.
    mismatches = stale_rows = total = 0
    gens_served = set()
    for users, r in results:
        x = unpack_array(r["x"])
        gens = unpack_array(r["gens"])
        stale = unpack_array(r["stale"])
        total += users.size
        stale_rows += int(stale.sum())
        for g in np.unique(gens):
            rows = gens == g
            gens_served.add(int(g))
            if x[rows].tobytes() != refs[int(g)][users[rows]].tobytes():
                mismatches += 1
    parity = mismatches == 0 and stale_rows == 0
    qps = total / max(wall, 1e-9)
    print(f"[front] sustained: {total} lookups in {len(results)} batches "
          f"over {wall:.2f}s ({qps:,.0f}/s) across generations "
          f"{sorted(gens_served)}; parity "
          f"{'OK' if parity else 'MISMATCH'}")

    # Single-lookup QPS (informational) on the converged front.
    cli = _HTTPClient(host, port)
    rng = np.random.default_rng(7)
    singles = rng.integers(0, spec.n, 256)
    t0 = time.perf_counter()
    for u in singles:
        cli.get(f"/decide?user={int(u)}")
    single_qps = singles.size / max(time.perf_counter() - t0, 1e-9)

    # The diff endpoint: "which users changed since the previous
    # generation?" — brute-force-checked, with per-replica fill
    # accounting: the baseline costs one grouped pass (== chunks), the
    # repeat costs zero (both generations cached).
    base_gen = final - 1
    chunks = -(-spec.n // spec.chunk)
    brute = (refs[final] != refs[base_gen]).any(axis=1)
    diff_calls, diff_parity, passes = [], True, []
    for _ in range(2 * replicas):
        d = cli.post("/diff", {"gen": base_gen,
                               "users": list(range(spec.n))})
        changed = unpack_array(d["changed"])
        if changed.tobytes() != brute.tobytes() \
                or d["from_gen"] != base_gen or d["to_gen"] != final \
                or d["stale"]:
            diff_parity = False
        diff_calls.append(d)
    by_replica = {}
    for d in diff_calls:
        by_replica.setdefault(d["replica"], []).append(d["fills"])
    for rep, fills in sorted(by_replica.items()):
        passes.append({"replica": rep, "calls": fills})
        if fills[0]["old"] != chunks or \
                any(f != {"new": 0, "old": 0} for f in fills[1:]):
            diff_parity = False
    print(f"[front] diff vs gen {base_gen}: {int(brute.sum())}/{spec.n} "
          f"changed; parity {'OK' if diff_parity else 'FAIL'}; "
          f"passes {passes}")

    health = cli.get("/health")
    rebinds = [d["replica"]["rebinds"] for d in health["replicas"]]

    # The /metrics scrape: Prometheus text must agree with /health —
    # the front counter with the front stats dict, the unlabeled
    # aggregate with the sum of the replica="i" labeled series, and the
    # labeled serve_queries with each replica's own health document.
    # (Traffic is quiesced by now, so the two reads see the same state.)
    metrics = _check_metrics(cli.get_text("/metrics"), health, replicas)
    print(f"[front] /metrics: {metrics['series']} series; consistency "
          f"{'OK' if metrics['consistent'] else 'FAIL'}"
          + ("" if metrics["consistent"]
             else f" ({metrics['failures']})"))
    cli.close()
    health_cli.close()
    front.shutdown()
    stop_replicas(procs, clients)

    return {
        "n": spec.n, "chunk": spec.chunk, "k": spec.k, "q": spec.q,
        "generations": generations, "replicas": replicas,
        "client_threads": client_threads, "batch": batch, "keep": keep,
        "sustained": {"lookups": total, "batches": len(results),
                      "wall_s": round(wall, 3),
                      "batched_qps": round(qps, 1),
                      "single_qps": round(single_qps, 1)},
        "generations_served": sorted(gens_served),
        "rebinds": rebinds,
        "parity": parity, "stale_rows": stale_rows,
        "diff": {"users": spec.n, "base_gen": base_gen,
                 "changed": int(brute.sum()), "chunks": chunks,
                 "parity": diff_parity, "passes": passes},
        "front_stats": health["front"],
        "metrics": metrics,
    }


def _check_metrics(text: str, health: dict, replicas: int) -> dict:
    """Cross-check a /metrics scrape against the /health document."""
    from repro.obs import parse_prometheus

    series = parse_prometheus(text)

    def val(name, **labels):
        return series.get((name, tuple(sorted(labels.items()))), 0.0)

    failures = []
    if val("front_requests") != health["front"]["requests"]:
        failures.append(
            f"front_requests {val('front_requests')} != "
            f"health requests {health['front']['requests']}")
    for name in ("serve_queries", "serve_fills", "serve_stale_serves",
                 "replica_rebinds"):
        per = sum(val(name, replica=str(i)) for i in range(replicas))
        if val(name) != per:
            failures.append(f"{name} aggregate {val(name)} != "
                            f"labeled sum {per}")
    for i, doc in enumerate(health["replicas"]):
        if "error" in doc:
            continue
        if val("serve_queries", replica=str(i)) != doc["queries"]:
            failures.append(
                f"replica {i} serve_queries "
                f"{val('serve_queries', replica=str(i))} != "
                f"health queries {doc['queries']}")
        if val("replica_rebinds", replica=str(i)) \
                != doc["replica"]["rebinds"]:
            failures.append(
                f"replica {i} replica_rebinds != health rebinds "
                f"{doc['replica']['rebinds']}")
    return {"series": len(series), "consistent": not failures,
            "failures": failures}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--users", type=int, default=65536)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tightness", type=float, default=0.4)
    ap.add_argument("--generations", type=int, default=3)
    ap.add_argument("--max-iters", type=int, default=60)
    ap.add_argument("--client-threads", type=int, default=4)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="small scenario (CI gate; exits 1 on any "
                         "parity failure)")
    # --replica mode (one serving process; spawned by the orchestrator).
    ap.add_argument("--replica", action="store_true")
    ap.add_argument("--index", type=int, default=0)
    ap.add_argument("--cache-chunks", type=int, default=32)
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--attach-timeout", type=float, default=60.0)
    ap.add_argument("--poll", type=float, default=0.05)
    ap.add_argument("--poison-scale", type=float, default=None,
                    help="test/chaos: fail one chunk of the generation "
                         "at this budget_scale (degraded-path drills)")
    ap.add_argument("--poison-chunk", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="replica mode: trace spans to <root>/obs/")
    args = ap.parse_args()

    if args.replica:
        if args.root is None:
            ap.error("--replica requires --root")
        run_replica(args.root, args.index, args.cache_chunks,
                    args.retries, args.attach_timeout, args.poll,
                    poison_scale=args.poison_scale,
                    poison_chunk=args.poison_chunk, obs=args.obs)
        return

    if args.smoke:
        args.users, args.chunk, args.generations = 8192, 512, 3
    spec = WorkloadSpec(seed=args.seed, n=args.users, k=args.k,
                        chunk=args.chunk, q=args.q,
                        tightness=args.tightness)
    cfg = SolverConfig(reduce="bucketed", max_iters=args.max_iters,
                       checkpoint_every=0)
    root = args.root or tempfile.mkdtemp(prefix="front_")
    print(f"[front] root {root}; {args.replicas} replicas")
    out = run_front_scenario(spec, args.generations, root, cfg,
                             replicas=args.replicas,
                             client_threads=args.client_threads,
                             batch=args.batch)
    ok = out["parity"] and out["diff"]["parity"] \
        and all(r >= 1 for r in out["rebinds"]) \
        and out["metrics"]["consistent"]
    print(f"[front] {'OK' if ok else 'FAIL'}: "
          f"{out['sustained']['batched_qps']:,.0f} lookups/s sustained, "
          f"rebinds {out['rebinds']}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
