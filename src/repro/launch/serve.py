"""Serving launcher: continuous-batching decode loop with KP admission.

The request scheduler is the paper's solver wearing its serving hat: at
each admission tick the waiting queue is a small knapsack instance —
items = requests, one global constraint (projected KV-cache bytes), one
local cardinality cap (free batch slots) — solved exactly by the same
cyclic-SCD code that prices experts in the MoE router. Admission therefore
maximises scheduler value subject to memory, instead of FIFO.

Successive ticks are the same KP under a drifting workload — exactly the
refresh engine's daily-call shape (repro/serve/engine.py) at tick scale —
so the loop warm-starts each tick's exact solve from the previous tick's
multipliers (``lam0``): the KV price barely moves between ticks, the
cyclic sweeps mostly confirm it, and the admitted sets are unchanged vs
solving cold every tick (pinned by tests/test_serving.py).

On this container it serves the reduced smoke config on one device; on a
pod the same loop runs the pjit'd decode_step over the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import DenseKP, SolverConfig, cardinality_set, solve
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new: int
    done: int = 0


class Admission(NamedTuple):
    """One admission tick's outcome: who got in, at what KV price.

    ``lam`` is the (1,) multiplier vector of the admission KP — the
    KV-cache shadow price — handed back so the next tick can warm-start
    from it; None when no solve ran (empty queue / no free slots).
    ``iters`` is that solve's iteration count (0 when no solve ran):
    the warm-vs-cold accounting the serving tests and bench read.
    """

    picked: list
    lam: Optional[np.ndarray]
    iters: int


def admission_solve(waiting, kv_budget, free_slots, lam0=None) -> Admission:
    """Choose the admitted subset by solving the admission KP exactly.

    ``lam0`` warm-starts the exact cyclic-SCD solve from the previous
    tick's multipliers (same ``lam0`` path the refresh engine uses for
    daily generations); the admitted set must be the one the cold solve
    picks — warm starting buys iterations, never different admissions.
    """
    if not waiting or free_slots <= 0:
        return Admission([], None, 0)
    n = len(waiting)
    # value ~ completed-requests-per-token (shortest remaining first)
    p = np.asarray([1.0 + 1.0 / (1 + r.max_new - r.done) for r in waiting],
                   np.float32)
    kv = np.asarray([r.prompt_len + r.max_new for r in waiting], np.float32)
    sets = cardinality_set(n, min(free_slots, n))
    kp = DenseKP(
        p=jnp.asarray(p)[None, :],
        b=jnp.asarray(kv)[None, :, None],
        budgets=jnp.asarray([float(kv_budget)], jnp.float32),
        sets=sets.sets,
        caps=sets.caps,
    )
    res = solve(kp, SolverConfig(reduce="exact", cd_mode="cyclic",
                                 max_iters=12), q=0, lam0=lam0)
    mask = np.asarray(res.x)[0]
    return Admission([r.rid for r, m in zip(waiting, mask) if m],
                     np.asarray(res.lam), int(res.iters))


def serve_loop(cfg, n_requests=8, cache_len=256, kv_budget=512.0,
               max_batch=4, seed=0, max_ticks=256, warm=True):
    """Continuous decode loop with KP admission each tick.

    ``warm`` threads each admission solve's multipliers into the next
    tick's ``lam0`` (the default); ``warm=False`` solves every tick
    cold — kept so the tests can pin that the two admit identical sets.
    Returns (completed requests, per-tick admitted sets, stats) where
    stats carries the wall time and the per-tick admission iteration
    counts the warm-vs-cold accounting reads.
    """
    params = M.init(cfg, jax.random.PRNGKey(seed))
    dstep = jax.jit(M.make_decode_step(cfg), donate_argnums=(1,))
    rng = np.random.default_rng(seed)
    queue = [
        Request(rid=i, prompt_len=int(rng.integers(4, 32)),
                max_new=int(rng.integers(4, 24)))
        for i in range(n_requests)
    ]
    caches = M.init_cache(cfg, params, max_batch, cache_len)
    token = jnp.zeros((max_batch, 1), jnp.int32)
    active: dict[int, Request] = {}
    done: list[Request] = []
    admitted_sets = []
    admission_iters = []
    lam = None
    t0 = time.time()
    for tick in range(max_ticks):
        if not queue and not active:
            break
        free = max_batch - len(active)
        if queue and free > 0:
            # budget shrinks by what the active set already holds
            held = sum(r.prompt_len + r.max_new for r in active.values())
            adm = admission_solve(queue, kv_budget - held, free,
                                  lam0=lam if warm else None)
            if adm.lam is not None:
                lam = adm.lam
                admission_iters.append(adm.iters)
            admitted_sets.append(adm.picked)
            for rid in adm.picked[:free]:
                req = next(r for r in queue if r.rid == rid)
                queue.remove(req)
                active[rid] = req
        if active:
            logits, caches = dstep(params, caches, token,
                                   jnp.int32(tick % cache_len))
            token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for rid in list(active):
                r = active[rid]
                r.done += 1
                if r.done >= r.max_new:
                    done.append(r)
                    del active[rid]
    stats = {"wall_s": time.time() - t0, "warm": warm,
             "admission_iters": admission_iters,
             "admission_iters_total": sum(admission_iters)}
    return done, admitted_sets, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()
    cfg = registry.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    done, admitted, stats = serve_loop(cfg, n_requests=args.requests,
                                       max_batch=args.max_batch)
    print(f"[serve] completed {len(done)} requests in "
          f"{stats['wall_s']:.2f}s ({len(admitted)} admission solves, "
          f"{stats['admission_iters_total']} warm KP iterations)")


if __name__ == "__main__":
    main()
