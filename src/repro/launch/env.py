"""Computation-environment configuration: platforms, XLA flags, workers.

The multi-host roadmap item needs multi-process CPU simulation before it
needs real pods, and that is an *environment* problem: JAX fixes its
platform and host device count at first import from ``JAX_PLATFORMS`` /
``XLA_FLAGS``, so anything that spawns workers (the supervisor in
:mod:`repro.launch.supervisor`, a future ``jax.distributed`` launcher)
must assemble a child environment **before** the child's interpreter
starts. This module owns that assembly:

* :func:`merged_xla_flags` / :func:`host_device_flags` — pure string
  surgery on an ``XLA_FLAGS`` value: replace one ``--flag=value`` token
  while preserving every other flag the caller (or CI) already set.
* :func:`worker_env` — the subprocess environment for one worker: base
  env (default ``os.environ``) with the platform pinned and the host
  platform forced to ``devices`` virtual devices. This is how the
  supervisor respawns a takeover on a *degraded* device count — the
  child's mesh is smaller, the checkpoint's virtual slot count is not,
  and PR 4's elastic resume keeps the result bitwise.
* :func:`set_host_device_count` / :func:`set_platform` /
  :func:`enable_x64` — in-process setters for the same knobs, guarded
  against the classic footgun of calling them after JAX has already
  initialised its backends (they would silently do nothing).
* :func:`describe` — the effective environment, for logs and health.
"""
from __future__ import annotations

import os
import sys
from typing import Mapping, Optional

__all__ = ["DEVICE_COUNT_FLAG", "merged_xla_flags", "host_device_flags",
           "worker_env", "set_host_device_count", "set_platform",
           "enable_x64", "describe"]

DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def merged_xla_flags(existing: Optional[str], flag: str, value) -> str:
    """An ``XLA_FLAGS`` string with ``flag`` set to ``value``.

    Every other token of ``existing`` is preserved verbatim (CI sets its
    own device count there; a worker override must not clobber unrelated
    flags), and an existing occurrence of ``flag`` is replaced in place
    rather than appended — XLA takes the first occurrence, so appending
    would silently lose the override.
    """
    token = f"{flag}={value}"
    parts = (existing or "").split()
    out, replaced = [], False
    for p in parts:
        if p == flag or p.startswith(flag + "="):
            out.append(token)
            replaced = True
        else:
            out.append(p)
    if not replaced:
        out.append(token)
    return " ".join(out)


def host_device_flags(devices: int, existing: Optional[str] = None) -> str:
    """``XLA_FLAGS`` forcing ``devices`` virtual host-platform devices."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    return merged_xla_flags(existing, DEVICE_COUNT_FLAG, int(devices))


def worker_env(devices: int, base: Optional[Mapping] = None,
               platform: str = "cpu") -> dict:
    """The environment for one spawned worker process.

    ``base`` defaults to ``os.environ`` (the worker inherits PYTHONPATH,
    locale, everything), with ``XLA_FLAGS`` rewritten to force
    ``devices`` virtual devices and ``JAX_PLATFORMS`` pinned to
    ``platform``. The returned dict is a copy — mutating it never
    touches the parent's environment.
    """
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = host_device_flags(devices, env.get("XLA_FLAGS"))
    env["JAX_PLATFORMS"] = platform
    return env


def _jax_initialized() -> bool:
    """Whether this process's JAX has already picked its backends."""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge
        return xla_bridge.backends_are_initialized()
    except Exception:
        # Compat: without the introspection API, jax being imported at
        # all is the conservative signal.
        return True


def set_host_device_count(devices: int) -> None:
    """Force this process's host platform to ``devices`` virtual devices.

    Mutates ``os.environ['XLA_FLAGS']`` (preserving unrelated flags).
    Must run before JAX initialises its backends — afterwards the flag
    is read-once stale and this raises instead of silently doing
    nothing. Worker processes should prefer :func:`worker_env`, which
    sets the child environment before its interpreter even starts.
    """
    if _jax_initialized():
        raise RuntimeError(
            "set_host_device_count called after JAX initialised its "
            "backends — the device count is fixed at first use. Set it "
            "earlier in the process, or spawn the work into a subprocess "
            "with worker_env()")
    os.environ["XLA_FLAGS"] = host_device_flags(
        devices, os.environ.get("XLA_FLAGS"))


def set_platform(platform: str = "cpu") -> None:
    """Pin the JAX platform (cpu/gpu/tpu) for this process.

    Sets ``JAX_PLATFORMS`` and, when JAX is importable, the
    ``jax_platform_name`` config — effective only before backend
    initialisation, so this raises once it is too late (same contract
    as :func:`set_host_device_count`).
    """
    if _jax_initialized():
        raise RuntimeError(
            "set_platform called after JAX initialised its backends — "
            "spawn a subprocess with worker_env() instead")
    os.environ["JAX_PLATFORMS"] = platform
    jax = sys.modules.get("jax")
    if jax is not None:
        jax.config.update("jax_platform_name", platform)


def enable_x64(enable: bool = True) -> None:
    """Toggle 64-bit array defaults (the x64 switch is runtime-safe)."""
    import jax

    jax.config.update("jax_enable_x64", bool(enable))


def describe() -> dict:
    """The effective environment (for logs, health endpoints, and the
    supervisor's status document); imports JAX only if already loaded."""
    out = {
        "xla_flags": os.environ.get("XLA_FLAGS"),
        "jax_platforms": os.environ.get("JAX_PLATFORMS"),
        "jax_imported": "jax" in sys.modules,
        "pid": os.getpid(),
    }
    jax = sys.modules.get("jax")
    if jax is not None and _jax_initialized():
        out["platform"] = jax.default_backend()
        out["device_count"] = jax.device_count()
        out["x64"] = bool(jax.config.read("jax_enable_x64"))
    return out
