"""Elastic supervision: heartbeat-leased workers, hang detection, self-healing.

PR 4 proved the *mechanism* — a SIGKILLed sharded solve resumes bitwise,
even on a degraded mesh — but an operator still had to notice the death
and relaunch. This launcher closes that loop. A solve or a
multi-generation refresh runs as a supervised **worker subprocess**
(``--worker``) that renews an fsync'd heartbeat lease
(:mod:`repro.core.heartbeat`) alongside its normal checkpoint cadence,
while the coordinator (:class:`Supervisor`) watches two signals:

* **exit codes** — a crashed worker (SIGKILL, OOM, a bug) is respawned
  with the same task file; the solver's resume protocol re-drives it
  from the last durable checkpoint, so the eventual result is bitwise
  the undisturbed one;
* **lease expiry** — a *hung* worker (SIGSTOP-shaped: every thread
  frozen, so the renewer stops; or stuck-fetch-shaped via the optional
  progress deadline) is detected when its lease stops advancing for
  ``ttl`` seconds of the coordinator's own clock, exclusively adopted
  (:func:`repro.core.heartbeat.claim_takeover`), killed, and respawned.

Each respawn may run on a **degraded device count** (devices halve per
restart, floor ``min_devices``): the checkpoint's virtual slot count is
fixed, PR 4's ``restore_auto`` elastic re-sharding does the rest, and
the published record stays bitwise. A bounded crash-loop budget
(``max_restarts``) escalates to a root-level ``FAILED.json`` stamp —
PR 6's containment shape: loud, durable, and the serving LIVE pointer
untouched. Every transition publishes supervision counters (restarts,
takeovers, injected chaos, lease ages) to ``SUPERVISOR.json``, which
:meth:`repro.serve.decisions.DecisionService.health` surfaces.

``--chaos-soak`` is the end-to-end proof, in the style of the
``--chaos`` fault gate: a seeded kill/stop/corrupt schedule
(:class:`ChaosSchedule`, FaultPlan-flavoured deterministic thresholds)
is injected into a supervised solve AND a supervised 3-generation
refresh; both must publish records **bitwise identical** to undisturbed
in-process reference runs — including takeovers that resumed on fewer
devices — and a poisoned crash-looping task must exhaust its budget
into ``FAILED.json`` while LIVE still points at the last good
generation. The gate asserts the exercised counters (kills, stops,
hang-takeovers, degraded spawns) so a schedule that silently failed to
fire cannot pass — the skip-proof convention of REQUIRE_HYPOTHESIS.

    PYTHONPATH=src python -m repro.launch.supervisor --chaos-soak --smoke
    PYTHONPATH=src python -m repro.launch.supervisor --supervise refresh \
        --root /tmp/sup --users 65536 --generations 3 --slots 4

Worker environments are assembled by :mod:`repro.launch.env` — the
degraded respawn is literally a smaller
``--xla_force_host_platform_device_count`` in the child's ``XLA_FLAGS``,
which is the same lever the multi-host roadmap item will drive per host.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import pathlib
import re
import signal
import subprocess
import sys
import time
from typing import Callable, Optional

from . import env as envmod
from ..obs import MetricsRegistry

__all__ = ["SupervisorConfig", "ChaosSchedule", "Supervisor",
           "run_solve_task", "run_refresh_task", "run_chaos_soak"]

_STATUS = "SUPERVISOR.json"
_FAILED = "FAILED.json"
_TASK = "task.json"
_HEARTBEAT = "heartbeat.json"
_CLAIM_RE = re.compile(r"\.claim_(\d+)$")


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Coordinator policy: deadlines, cadence, and the crash-loop budget.

    ``ttl`` is the lease deadline — a worker whose lease has not
    advanced for this many seconds (of the coordinator's clock) is
    declared hung and taken over. ``grace`` bounds process startup (the
    first beat lands before any heavy import, so this covers exec + a
    died-before-first-beat worker, not JIT warmup). ``max_restarts``
    bounds crash restarts plus hang takeovers together; exceeding it
    stamps ``FAILED.json`` and stops — the containment path, never a
    spin. ``degrade`` halves the worker device count on every respawn
    (floor ``min_devices``), exercising elastic resume under real loss
    of capacity. ``progress_ttl`` optionally adds stuck-fetch detection
    (beats alive, progress frozen).
    """

    ttl: float = 3.0
    poll: float = 0.05
    grace: float = 120.0
    max_restarts: int = 8
    degrade: bool = True
    min_devices: int = 1
    progress_ttl: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A seeded worker-level fault schedule (the FaultPlan of processes).

    ``events`` is an ordered tuple of ``(kind, at_progress)`` pairs,
    ``kind`` in {"kill", "stop"}: when the *current* worker's lease
    progress counter (chunk fetches) reaches ``at_progress``, the
    coordinator delivers SIGKILL or SIGSTOP and the event is consumed —
    so each event lands in a different worker life. Thresholds are pure
    hashes of ``(seed, index)`` in ``[lo, hi)``, so a soak replays the
    same schedule every run; the *exact* fetch the signal lands on may
    drift with OS scheduling, which is fine — the checkpoint protocol
    guarantees bitwise resume from any kill point, and the gate asserts
    the events fired, not where.
    """

    seed: int = 0
    events: tuple = ()

    @classmethod
    def plan(cls, seed: int, kills: int, stops: int,
             lo: int, hi: int) -> "ChaosSchedule":
        """Interleaved kill/stop events with hashed thresholds."""
        if hi <= lo:
            raise ValueError(f"need hi > lo, got [{lo}, {hi})")
        kinds = []
        k, s = kills, stops
        while k > 0 or s > 0:
            if k > 0:
                kinds.append("kill")
                k -= 1
            if s > 0:
                kinds.append("stop")
                s -= 1
        events = []
        for i, kind in enumerate(kinds):
            h = hashlib.sha256(f"chaos:{seed}:{i}".encode()).digest()
            at = lo + int.from_bytes(h[:8], "big") % (hi - lo)
            events.append((kind, at))
        return cls(seed=seed, events=tuple(events))


class Supervisor:
    """One supervised task: spawn, watch, re-drive, contain.

    ``root`` is the task's working directory — the worker's checkpoint
    and result/generation tree live here, next to the heartbeat lease,
    the durable ``task.json`` intent, the ``SUPERVISOR.json`` status
    document, and (on budget exhaustion) the ``FAILED.json`` stamp.
    ``task`` is the JSON-serialisable task description ``--worker``
    executes (see :func:`run_solve_task` / :func:`run_refresh_task`).
    ``worker_cmd(root, term, devices) -> argv`` overrides the spawned
    command (tests drive the coordinator with scripted fake workers);
    ``env_extra`` is merged into every worker environment.
    """

    def __init__(self, root, task: dict, cfg: SupervisorConfig = None,
                 devices: Optional[int] = None,
                 chaos: Optional[ChaosSchedule] = None,
                 worker_cmd: Optional[Callable] = None,
                 env_extra: Optional[dict] = None):
        self.root = pathlib.Path(root)
        self.cfg = cfg if cfg is not None else SupervisorConfig()
        self.task = dict(task)
        self.task.setdefault("ttl", self.cfg.ttl)
        self.devices0 = int(devices if devices is not None
                            else self.task.get("slots") or 1)
        self.chaos = chaos
        self.worker_cmd = worker_cmd
        self.env_extra = dict(env_extra or {})
        self.hb_path = self.root / _HEARTBEAT
        # Supervision counters live on a typed registry (monotone
        # counters for event tallies, gauges for the point-in-time
        # term/devices/lease-age readings); the :attr:`counters` dict
        # the rest of the stack consumes is assembled on read, with the
        # same 13 keys SUPERVISOR.json has always published.
        self.registry = MetricsRegistry()
        self._ctrs = {
            k: self.registry.counter(f"supervisor_{k}")
            for k in ("spawns", "crash_restarts", "hang_takeovers",
                      "kills_injected", "stops_injected",
                      "degraded_spawns")}
        self._g_ok = self.registry.gauge("supervisor_ok")
        self._g_term = self.registry.gauge("supervisor_term")
        self._g_devices = self.registry.gauge("supervisor_devices")
        self._g_devices.set(self.devices0)
        self._g_lease_age = self.registry.gauge("supervisor_max_lease_age")
        self._info = {"state": "init", "last_rc": None}

    @property
    def counters(self) -> dict:
        """The status-document dict, assembled from the registry."""
        c = {k: int(v.value) for k, v in self._ctrs.items()}
        return {
            "ok": bool(self._g_ok.value),
            "state": self._info["state"],
            "spawns": c["spawns"],
            "crash_restarts": c["crash_restarts"],
            "hang_takeovers": c["hang_takeovers"],
            "restarts": c["crash_restarts"] + c["hang_takeovers"],
            "kills_injected": c["kills_injected"],
            "stops_injected": c["stops_injected"],
            "degraded_spawns": c["degraded_spawns"],
            "max_lease_age": round(float(self._g_lease_age.value), 3),
            "term": int(self._g_term.value),
            "devices": int(self._g_devices.value),
            "last_rc": self._info["last_rc"],
        }

    # -- spawn plumbing -----------------------------------------------------

    def _argv(self, term: int, devices: int) -> list:
        if self.worker_cmd is not None:
            return list(self.worker_cmd(self.root, term, devices))
        return [sys.executable, "-m", "repro.launch.supervisor",
                "--worker", str(self.root), "--term", str(term)]

    def _env(self, devices: int) -> dict:
        wenv = envmod.worker_env(devices)
        # The child must be able to import the running repro package even
        # when the parent was launched from an installed path.
        src = str(pathlib.Path(__file__).resolve().parents[2])
        pp = wenv.get("PYTHONPATH", "")
        if src not in pp.split(os.pathsep):
            wenv["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        wenv.update(self.env_extra)
        return wenv

    def _spawn(self, term: int, devices: int) -> subprocess.Popen:
        return subprocess.Popen(self._argv(term, devices),
                                env=self._env(devices))

    def _next_term(self) -> int:
        """First unused term on this root (lease + claim debris aware).

        A supervisor relaunched over an existing root (its predecessor
        died) must not reuse a term: the lease records the last writer's
        term and the claim files record every adoption, so the next term
        is one past the max of both — keeping claim exclusivity
        meaningful across coordinator generations.
        """
        from ..core.heartbeat import TornLease, read_lease

        last = 0
        try:
            lease = read_lease(self.hb_path)
            if lease is not None:
                last = lease.term
        except TornLease:
            pass
        for p in self.hb_path.parent.glob(self.hb_path.name + ".claim_*"):
            m = _CLAIM_RE.search(p.name)
            if m:
                last = max(last, int(m.group(1)))
        return last + 1

    # -- status publication -------------------------------------------------

    def _publish(self, state: str):
        from ..checkpoint import ckpt

        self._info["state"] = state
        doc = self.counters
        doc["updated_wall"] = time.time()
        ckpt.write_json(self.root, _STATUS, doc)

    # -- the watch loop -----------------------------------------------------

    def _kill(self, proc: subprocess.Popen):
        try:
            os.kill(proc.pid, signal.SIGKILL)   # kills STOPped workers too
        except ProcessLookupError:
            pass
        proc.wait()

    def _watch(self, proc: subprocess.Popen, term: int, events: list):
        """Watch one worker life; returns ('done'|'crash'|'hang', rc)."""
        from ..core.heartbeat import LeaseMonitor

        mon = LeaseMonitor(self.hb_path, ttl=self.cfg.ttl,
                           grace=self.cfg.grace, expect_term=term,
                           progress_ttl=self.cfg.progress_ttl)
        while True:
            rc = proc.poll()
            st = mon.poll()
            if st["age"] is not None:
                self._g_lease_age.set_max(float(st["age"]))
            if rc is not None:
                return ("done", rc) if rc == 0 else ("crash", rc)
            if st["expired"]:
                # The hang path: no liveness evidence for ttl (or the
                # progress deadline). The worker may be SIGSTOPped,
                # wedged in a fetch, or a zombie-to-be — all get the
                # same treatment: kill, then re-drive from checkpoint.
                self._kill(proc)
                return ("hang", None)
            if events and st["state"] == "fresh" \
                    and st["progress"] is not None \
                    and st["progress"] >= events[0][1]:
                kind, _ = events.pop(0)
                try:
                    if kind == "kill":
                        os.kill(proc.pid, signal.SIGKILL)
                        self._ctrs["kills_injected"].inc()
                    else:
                        os.kill(proc.pid, signal.SIGSTOP)
                        self._ctrs["stops_injected"].inc()
                except ProcessLookupError:
                    pass
            time.sleep(self.cfg.poll)

    # -- the coordinator loop -----------------------------------------------

    def run(self) -> dict:
        """Drive the task to completion, a FAILED stamp, or bust.

        Returns the final counter dict (``ok`` True only when a worker
        exited 0). The task intent is written durably before the first
        spawn, so a relaunched supervisor re-drives the identical task.
        """
        from ..checkpoint import ckpt
        from ..core.heartbeat import claim_takeover

        ckpt.write_json(self.root, _TASK, self.task)
        self._info.update(state="starting", last_rc=None)
        self._g_ok.set(0)
        self._g_term.set(0)
        self._g_devices.set(self.devices0)
        events = list(self.chaos.events) if self.chaos is not None else []
        devices = self.devices0
        term = self._next_term()
        while True:
            if term > 1 and not claim_takeover(self.hb_path, term):
                raise RuntimeError(
                    f"takeover claim for term {term} on {self.hb_path} "
                    "was already held — another coordinator owns this "
                    "root; standing down instead of double-driving it")
            proc = self._spawn(term, devices)
            self._ctrs["spawns"].inc()
            self._g_term.set(term)
            self._g_devices.set(devices)
            if devices < self.devices0:
                self._ctrs["degraded_spawns"].inc()
            self._publish("running")
            outcome, rc = self._watch(proc, term, events)
            if outcome == "done":
                self._g_ok.set(1)
                self._publish("done")
                return self.counters
            if outcome == "crash":
                self._ctrs["crash_restarts"].inc()
                self._info["last_rc"] = rc
            else:
                self._ctrs["hang_takeovers"].inc()
            if (self._ctrs["crash_restarts"].value
                    + self._ctrs["hang_takeovers"].value) \
                    > self.cfg.max_restarts:
                # Containment, not a spin: budget exhausted. The stamp is
                # root-level (the per-generation FAILED.json remains the
                # solver-level fetch-exhaustion stamp); LIVE — if this
                # root serves generations — is untouched, so readers
                # keep answering from the last good publication.
                ckpt.write_json(self.root, _FAILED, {
                    "reason": "crash-loop budget exhausted",
                    "max_restarts": self.cfg.max_restarts,
                    "counters": self.counters,
                    "task_kind": self.task.get("kind"),
                })
                self._publish("failed")
                return self.counters
            term += 1
            if self.cfg.degrade:
                devices = max(self.cfg.min_devices, devices // 2)


# ---------------------------------------------------------------------------
# The worker side: task execution (shared with in-process reference runs).
# ---------------------------------------------------------------------------

def _heartbeat_source(source, hb):
    """Wrap a chunk source so every fetch bumps the lease's progress."""
    inner = source.fn

    def fn(i):
        hb.bump()
        return inner(i)

    return source._replace(fn=fn)


def _task_mesh(slots: Optional[int]):
    """The widest local mesh the task's slot count divides over."""
    import jax

    nd = jax.device_count()
    if nd > 1 and slots and slots % nd == 0:
        return jax.make_mesh((nd,), ("slots",))
    return None


def _task_source(task: dict, spec, hb=None):
    """spec -> HostChunkSource per the task: synthetic workload, optional
    FaultPlan injection underneath, heartbeat progress on top."""
    from ..core.faults import FaultPlan, faulty_source
    from ..serve.engine import synthetic_source

    src = synthetic_source(spec)
    if task.get("fault_plan"):
        src = faulty_source(src, FaultPlan(**task["fault_plan"]))
    if hb is not None:
        src = _heartbeat_source(src, hb)
    return src


def _task_cfg(task: dict):
    from ..core.types import SolverConfig

    return SolverConfig(**task.get("cfg", {}))


def run_solve_task(root, task: dict, hb=None) -> dict:
    """Execute (or resume) a ``kind == "solve"`` task under ``root``.

    Solves the task's workload with checkpointing into ``root/ckpt`` and
    resume from the same directory — a respawned worker picks up where
    its predecessor died — and publishes the result record durably at
    ``root/result`` (ckpt protocol, step 0). Idempotent: a worker killed
    between the record save and its exit is a no-op on the next life.
    Returns the record as numpy arrays.
    """
    import numpy as np

    from ..checkpoint import ckpt
    from ..core.prefetch import solve_streaming_host
    from ..serve.engine import WorkloadSpec

    root = pathlib.Path(root)
    result_dir = root / "result"
    if ckpt.latest_step(result_dir) is not None:
        return ckpt.restore_auto(result_dir, 0)
    spec = WorkloadSpec.from_json(task["spec"])
    slots = task.get("slots")
    ckdir = str(root / "ckpt")
    res = solve_streaming_host(
        _task_source(task, spec, hb), _task_cfg(task), q=spec.q,
        mesh=_task_mesh(slots), slots=slots,
        checkpoint_dir=ckdir, resume_from=ckdir)
    record = {
        "lam": np.asarray(res.lam), "tau": np.asarray(res.tau),
        "iters": np.int32(res.iters), "r": np.asarray(res.r),
        "primal": np.asarray(res.primal), "dual": np.asarray(res.dual),
    }
    if res.fin_hist is not None:
        record["fin_ch"] = np.asarray(res.fin_hist[0])
        record["fin_gh"] = np.asarray(res.fin_hist[1])
    ckpt.save(result_dir, 0, record)
    return record


def run_refresh_task(root, task: dict, hb=None) -> dict:
    """Execute (or resume) a ``kind == "refresh"`` task under ``root``.

    Drives a :class:`~repro.serve.engine.RefreshEngine` over ``root``
    through the task's budget-scale schedule until ``generations``
    generations are live. Re-entrant by construction: ``recover()``
    finishes a preempted generation first, then the loop continues from
    the live pointer — the engine's two-step publication makes every
    completed generation bitwise the undisturbed one.
    """
    from ..serve.engine import RefreshEngine, WorkloadSpec

    spec = WorkloadSpec.from_json(task["spec"])
    slots = task.get("slots")
    engine = RefreshEngine(
        pathlib.Path(root), spec,
        make_source=lambda s: _task_source(task, s, hb),
        cfg=_task_cfg(task), mesh=_task_mesh(slots), slots=slots)
    engine.recover()
    generations = int(task["generations"])
    scales = task.get("budget_scales") or [1.0] * generations
    start = (engine.live_gen_id() + 1
             if engine.live_gen_id() is not None else 0)
    for g in range(start, generations):
        engine.refresh(budget_scale=scales[g])
    return {"live": engine.live_gen_id()}


def _worker_main(args) -> int:
    """``--worker`` entry: heartbeat up, then run the durable task.

    The poison hook (``REPRO_WORKER_POISON`` = exit code) sits before
    every heavy import: it is the deterministic crash-loop fixture the
    containment gate and tests drive budget exhaustion with, and its
    earliness keeps those loops cheap.
    """
    if os.environ.get("REPRO_WORKER_POISON"):
        return int(os.environ["REPRO_WORKER_POISON"])
    root = pathlib.Path(args.worker)
    task = json.loads((root / _TASK).read_text())

    from ..core.heartbeat import HeartbeatWriter

    hb = HeartbeatWriter(root / _HEARTBEAT, worker=task.get("kind", "task"),
                         term=args.term, ttl=float(task.get("ttl", 3.0)))
    with hb:
        if task["kind"] == "solve":
            run_solve_task(root, task, hb)
        elif task["kind"] == "refresh":
            run_refresh_task(root, task, hb)
        else:
            raise ValueError(f"unknown task kind {task['kind']!r} in "
                             f"{root / _TASK}")
    return 0


# ---------------------------------------------------------------------------
# The chaos soak: supervised self-healing, proven bitwise.
# ---------------------------------------------------------------------------

# Fetch-level injection riding under the process-level chaos (the
# "corrupt" leg of the soak schedule). Rates are deliberately milder
# than the --chaos gate's: with verify_refetch doubling reads, an
# attempt succeeds with (1 - drop - corrupt)^2 and the soak's workers
# re-fetch across several lives.
_SOAK_PLAN_KW = dict(drop=0.04, slow=0.02, slow_s=0.002, corrupt=0.02)
_SOAK_CFG_KW = dict(fetch_retries=8, fetch_backoff=1e-4,
                    fetch_backoff_cap=1e-3, verify_refetch=True)

_RESULT_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]


def _diff_records(tag: str, want: dict, got: dict, fields) -> list:
    import numpy as np

    diffs = []
    for f in fields:
        a, b = want.get(f), got.get(f)
        if a is None or b is None:
            if (a is None) != (b is None):
                diffs.append(f"{tag}: field {f} present in only one run")
            continue
        if np.asarray(a).tobytes() != np.asarray(b).tobytes():
            diffs.append(f"{tag}: field {f} differs bitwise")
    return diffs


def _sample_decisions(spec, record: dict, users):
    """Decision rows for sampled users straight from a published record
    (lam + tau are the whole decision rule; the source bytes are the
    spec's)."""
    import numpy as np

    from ..core.chunked import decisions_rows
    from ..serve.engine import synthetic_source

    src = synthetic_source(spec)
    out = []
    for u in users:
        ci, off = divmod(int(u), src.chunk)
        p, b = src.fn(ci)
        rows = ci * src.chunk + np.arange(src.chunk)
        x = np.asarray(decisions_rows(p, b, record["lam"], spec.q,
                                      rows < src.n, record["tau"]))
        out.append(x[off])
    return np.asarray(out)


def run_chaos_soak(root, smoke: bool = False, seed: int = 0) -> tuple:
    """The supervision gate; returns ``(ok, report)``.

    Proves, end to end: a supervised solve and a supervised
    multi-generation refresh each survive a seeded schedule of worker
    SIGKILLs and SIGSTOP hangs (plus fetch-level drop/corrupt injection
    under retries) and publish records **bitwise identical** to
    undisturbed in-process reference runs — with at least one takeover
    resuming on a degraded device count — and a poisoned crash-looping
    task exhausts its restart budget into a root-level ``FAILED.json``
    while the serving LIVE pointer still names the last good generation.
    Every exercised path is counter-asserted: a soak in which the
    schedule silently failed to fire fails the gate.
    """
    import numpy as np

    from ..checkpoint import ckpt
    from ..serve.engine import RefreshEngine, WorkloadSpec
    from .refresh import _budget_schedule

    root = pathlib.Path(root)
    if smoke:
        n, chunk, generations, max_iters = 4096, 512, 3, 40
        lo, hi = 12, 40
    else:
        n, chunk, generations, max_iters = 65536, 2048, 3, 60
        lo, hi = 30, 150
    slots = 4
    spec = WorkloadSpec(seed=seed, n=n, k=8, chunk=chunk, q=2,
                        tightness=0.4)
    base_cfg = dict(reduce="bucketed", max_iters=max_iters,
                    checkpoint_every=2, bucket_half=16)
    chaos_cfg = {**base_cfg, **_SOAK_CFG_KW}
    plan = dict(seed=seed, **_SOAK_PLAN_KW)
    scales = _budget_schedule(generations, seed)
    sup_cfg = SupervisorConfig(ttl=2.5, poll=0.05, grace=120.0,
                               max_restarts=10)

    report: dict = {"smoke": smoke, "seed": seed}
    diffs: list = []

    # ---- undisturbed references, in-process, fault-free ------------------
    print(f"[soak] reference solve -> {root / 'solve_ref'}")
    ref_solve = run_solve_task(root / "solve_ref", {
        "kind": "solve", "spec": spec.to_json(), "cfg": base_cfg,
        "slots": slots})
    print(f"[soak] reference refresh ({generations} generations) -> "
          f"{root / 'refresh_ref'}")
    run_refresh_task(root / "refresh_ref", {
        "kind": "refresh", "spec": spec.to_json(), "cfg": base_cfg,
        "slots": slots, "generations": generations,
        "budget_scales": scales})

    # ---- supervised chaos solve ------------------------------------------
    solve_sched = ChaosSchedule.plan(seed, kills=1, stops=1, lo=lo, hi=hi)
    print(f"[soak] supervised chaos solve ({solve_sched.events}) -> "
          f"{root / 'solve_chaos'}")
    s_solve = Supervisor(
        root / "solve_chaos",
        {"kind": "solve", "spec": spec.to_json(), "cfg": chaos_cfg,
         "slots": slots, "fault_plan": plan},
        cfg=sup_cfg, devices=slots, chaos=solve_sched).run()
    report["solve"] = s_solve
    got_solve = ckpt.restore_auto(root / "solve_chaos" / "result", 0) \
        if s_solve["ok"] else {}
    if not s_solve["ok"]:
        diffs.append("solve: supervised run did not complete")
    else:
        got_solve = {k: np.asarray(v) for k, v in got_solve.items()}
        diffs += _diff_records("solve", ref_solve, got_solve,
                               _RESULT_FIELDS + ["fin_ch", "fin_gh"])
        rng = np.random.default_rng(seed)
        users = rng.integers(0, spec.n, 32)
        if not np.array_equal(_sample_decisions(spec, ref_solve, users),
                              _sample_decisions(spec, got_solve, users)):
            diffs.append("solve: sampled decisions differ")

    # ---- supervised chaos refresh ----------------------------------------
    refresh_sched = ChaosSchedule.plan(seed + 1, kills=1, stops=1,
                                       lo=lo, hi=hi)
    print(f"[soak] supervised chaos refresh ({refresh_sched.events}) -> "
          f"{root / 'refresh_chaos'}")
    s_refresh = Supervisor(
        root / "refresh_chaos",
        {"kind": "refresh", "spec": spec.to_json(), "cfg": chaos_cfg,
         "slots": slots, "generations": generations,
         "budget_scales": scales, "fault_plan": plan},
        cfg=sup_cfg, devices=slots, chaos=refresh_sched).run()
    report["refresh"] = s_refresh
    if not s_refresh["ok"]:
        diffs.append("refresh: supervised run did not complete")
    else:
        ref_eng = RefreshEngine(root / "refresh_ref", spec)
        got_eng = RefreshEngine(root / "refresh_chaos", spec)
        rng = np.random.default_rng(seed + 1)
        users = rng.integers(0, spec.n, 32)
        for g in range(generations):
            want, got = ref_eng.generation(g), got_eng.generation(g)
            fields = ["lam", "tau", "iters", "r", "primal", "dual",
                      "fingerprint"]
            diffs += _diff_records(
                f"refresh gen {g}",
                {f: getattr(want, f) for f in fields},
                {f: getattr(got, f) for f in fields}, fields)
            for i, (x, y) in enumerate(zip(want.fin_hist or (),
                                           got.fin_hist or ())):
                if np.asarray(x).tobytes() != np.asarray(y).tobytes():
                    diffs.append(f"refresh gen {g}: fin_hist[{i}] differs")
        live_want, live_got = ref_eng.live(), got_eng.live()
        rec_w = {"lam": live_want.lam, "tau": live_want.tau}
        rec_g = {"lam": live_got.lam, "tau": live_got.tau}
        if not np.array_equal(
                _sample_decisions(live_want.spec, rec_w, users),
                _sample_decisions(live_got.spec, rec_g, users)):
            diffs.append("refresh: sampled live decisions differ")

    # ---- containment: crash-loop budget -> FAILED.json, LIVE untouched ---
    live_before = RefreshEngine(root / "refresh_chaos", spec).live_gen_id()
    print("[soak] containment: poisoned crash-looping task "
          f"(budget 2) on {root / 'refresh_chaos'}")
    s_poison = Supervisor(
        root / "refresh_chaos",
        {"kind": "refresh", "spec": spec.to_json(), "cfg": chaos_cfg,
         "slots": slots, "generations": generations + 1,
         "budget_scales": scales + [1.0]},
        cfg=dataclasses.replace(sup_cfg, max_restarts=2),
        devices=slots, env_extra={"REPRO_WORKER_POISON": "3"}).run()
    report["poison"] = s_poison
    live_after = RefreshEngine(root / "refresh_chaos", spec).live_gen_id()
    failed = ckpt.read_json(root / "refresh_chaos", _FAILED)
    if s_poison["ok"]:
        diffs.append("containment: poisoned task reported success")
    if failed is None:
        diffs.append("containment: no FAILED.json stamped")
    if live_after != live_before:
        diffs.append(f"containment: LIVE moved {live_before} -> "
                     f"{live_after} under a failing task")

    # ---- skip-proof counter assertions -----------------------------------
    kills = s_solve["kills_injected"] + s_refresh["kills_injected"]
    stops = s_solve["stops_injected"] + s_refresh["stops_injected"]
    hangs = s_solve["hang_takeovers"] + s_refresh["hang_takeovers"]
    crashes = s_solve["crash_restarts"] + s_refresh["crash_restarts"]
    degraded = s_solve["degraded_spawns"] + s_refresh["degraded_spawns"]
    exercised = {"kills_injected": kills, "stops_injected": stops,
                 "hang_takeovers": hangs, "crash_restarts": crashes,
                 "degraded_spawns": degraded}
    report["exercised"] = exercised
    for name, got_n, need in [("kills_injected", kills, 2),
                              ("stops_injected", stops, 1),
                              ("hang_takeovers", hangs, 1),
                              ("crash_restarts", crashes, 2),
                              ("degraded_spawns", degraded, 1)]:
        if got_n < need:
            diffs.append(f"soak under-exercised: {name} = {got_n} < {need} "
                         "— the schedule did not fire; the gate proves "
                         "nothing")
    if hangs < stops:
        diffs.append(f"soak: {stops} SIGSTOPs injected but only {hangs} "
                     "lease-expiry takeovers — a hang went undetected")

    report["diffs"] = diffs
    report["ok"] = not diffs
    ckpt.write_json(root, "SOAK.json", report)
    for d in diffs:
        print(f"[soak] FAIL: {d}")
    if not diffs:
        print(f"[soak] OK: solve + {generations}-generation refresh "
              f"bitwise identical to undisturbed runs under {kills} kills, "
              f"{stops} stops ({hangs} lease-expiry takeovers, {degraded} "
              f"degraded respawns); crash-loop contained to FAILED.json "
              "with LIVE untouched")
    return not diffs, report


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def main():
    """CLI dispatch: --worker / --chaos-soak / --supervise."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--worker", default=None, metavar="ROOT",
                    help="internal: run the durable task under ROOT as a "
                         "supervised worker")
    ap.add_argument("--term", type=int, default=1)
    ap.add_argument("--chaos-soak", action="store_true",
                    help="supervised self-healing gate: seeded kills/"
                         "stops/corruption against a solve and a refresh; "
                         "exit 1 unless results are bitwise identical to "
                         "the undisturbed runs and every chaos path "
                         "actually fired")
    ap.add_argument("--smoke", action="store_true",
                    help="small scenario (the CI gate size)")
    ap.add_argument("--supervise", choices=["solve", "refresh"],
                    default=None,
                    help="run one supervised task to completion")
    ap.add_argument("--root", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--users", type=int, default=65536)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--tightness", type=float, default=0.4)
    ap.add_argument("--generations", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-iters", type=int, default=60)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--ttl", type=float, default=3.0)
    ap.add_argument("--max-restarts", type=int, default=8)
    args = ap.parse_args()

    if args.worker is not None:
        sys.exit(_worker_main(args))

    import tempfile

    root = args.root or tempfile.mkdtemp(prefix="supervisor_")
    if args.chaos_soak:
        ok, _ = run_chaos_soak(root, smoke=args.smoke, seed=args.seed)
        sys.exit(0 if ok else 1)
    if args.supervise is not None:
        from ..serve.engine import WorkloadSpec
        from .refresh import _budget_schedule

        spec = WorkloadSpec(seed=args.seed, n=args.users, k=args.k,
                            chunk=args.chunk, q=args.q,
                            tightness=args.tightness)
        cfg = dict(reduce="bucketed", max_iters=args.max_iters,
                   checkpoint_every=args.checkpoint_every)
        task = {"kind": args.supervise, "spec": spec.to_json(),
                "cfg": cfg, "slots": args.slots}
        if args.supervise == "refresh":
            task["generations"] = args.generations
            task["budget_scales"] = _budget_schedule(args.generations,
                                                     args.seed)
        sup = Supervisor(root, task,
                         cfg=SupervisorConfig(ttl=args.ttl,
                                              max_restarts=args.max_restarts),
                         devices=args.slots)
        out = sup.run()
        print(f"[supervisor] {out}")
        sys.exit(0 if out["ok"] else 1)
    ap.error("pick a mode: --worker, --chaos-soak, or --supervise")


if __name__ == "__main__":
    main()
