"""Training launcher: --arch <id>, synthetic data, checkpoint/restart.

Fault-tolerance contract exercised by tests/test_train_loop.py:
  * checkpoints are atomic (ckpt.save) and pruned;
  * on startup the loop resumes from the newest complete checkpoint;
  * data is regenerated deterministically per (seed, step) — restart never
    replays or skips a batch;
  * ``--simulate-failure-at N`` kills the process after step N to prove it.

On a real multi-pod mesh the same script runs under jax.distributed with
``--mesh prod|multipod``; on this container it trains the reduced smoke
config on one device (--smoke).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.data.synth import lm_batch
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import sharding
from repro.optim import OptConfig, init_opt_state


def train(cfg, opt_cfg, steps, ckpt_dir=None, ckpt_every=0, seed=0,
          batch_shape=(4, 128), log_every=10, fail_at=None, mesh=None,
          keep=3):
    params = M.init(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params, opt_cfg)
    start = 0
    state = {"params": params, "opt": opt_state}

    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            state = ckpt.restore(ckpt_dir, last, state)
            start = last
            print(f"[train] resumed from step {last}")

    step_fn = jax.jit(M.make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    params, opt_state = state["params"], state["opt"]

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch = lm_batch(cfg, batch_shape, step, seed=seed)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and (step + 1) % log_every == 0:
            dt = (time.time() - t0) / max(len(losses), 1)
            print(f"[train] step {step + 1} loss {losses[-1]:.4f} "
                  f"({dt * 1e3:.0f} ms/step)")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            ckpt.prune(ckpt_dir, keep=keep)
        if fail_at is not None and step + 1 >= fail_at:
            print(f"[train] simulating hard failure at step {step + 1}",
                  flush=True)
            sys.stdout.flush()
            import os
            os._exit(42)
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--router", choices=["topk", "scd"])
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.router:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, router=args.router))
    opt_cfg = OptConfig(lr=args.lr, warmup=20,
                        compress_grads=args.compress_grads)
    _, _, losses = train(
        cfg, opt_cfg, args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed,
        batch_shape=(args.batch, args.seq),
        fail_at=args.simulate_failure_at,
    )
    print(f"[train] done: first loss {losses[0]:.4f} last loss "
          f"{losses[-1]:.4f}")


if __name__ == "__main__":
    main()
