"""Solver launcher: the paper's production job.

    python -m repro.launch.solve --workload table1 --scale 1e-4
    python -m repro.launch.solve --n 1000000 --k 10 --q 1
    python -m repro.launch.solve --n 4000000 --k 10 --streaming --chunk-size 65536

Runs the distributed SCD solver over however many devices exist (all mesh
axes carry the user shard), reports iterations / primal / duality gap /
violations — i.e., the paper's Table 1 row for the requested size. The
full-size workloads only fit a cluster; ``--scale`` shrinks N while
keeping the structure (budgets scale with N, §6).

``--chunk-size C`` streams the per-iteration map over C-user chunks
(identical results on the SCD bucketed path — see core/solver.py for the
chunked-vs-unchunked contract). ``--streaming`` additionally stops
materialising the instance at all: chunks are synthesized on demand
inside the solve (core/chunked.py), so N is bounded by patience, not
device memory — this is the out-of-core mode the chunked benchmark uses
to run far past the unchunked ceiling. A converged streaming solve
touches the source iters + 1 times (``--stream-finalize legacy`` keeps
the three-pass finalize, iters + 3 — see DESIGN.md §5c). ``--host-feed``
swaps in the host-fed pipeline (core/prefetch.py): chunks are produced
as NumPy arrays on the host and uploaded with double-buffered
``device_put`` (``--no-double-buffer`` for the synchronous baseline) —
the mode a real on-disk dataset runs in. Host-fed solves shard over
the mesh (virtual slots, ``--slots`` to pin more than one per device)
and survive preemption: ``--checkpoint-dir D --checkpoint-every N``
writes the atomic resume state, and a relaunch with ``--resume`` picks
the solve back up bitwise (DESIGN.md §7), e.g.

    python -m repro.launch.solve --n 16000000 --host-feed \
        --chunk-size 65536 --checkpoint-dir ckpt/ --checkpoint-every 8 \
        --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_kp import WORKLOADS, KPWorkload
from repro.core import SolverConfig, solve, solve_sharded
from repro.core.chunked import solve_streaming
from repro.core.instances import shard_key, sparse_instance
from repro.core.prefetch import solve_streaming_host
from repro.data.synth import sparse_chunk_source, sparse_host_chunk_source


def _mesh():
    if jax.device_count() > 1:
        return jax.make_mesh((jax.device_count(),), ("users",))
    return None


def run(workload: KPWorkload, cfg: SolverConfig, seed=0, mesh=None):
    """Solve one §6 sparse workload; returns the Table-1-style row dict.

    The instance is materialised on device and solved with
    ``solve``/``solve_sharded`` (``cfg.chunk_size`` chunks the iteration
    map if set). ``mesh=None`` auto-shards over all visible devices.
    """
    kp, q = sparse_instance(
        shard_key(seed), workload.n_users, workload.k, workload.q,
        tightness=workload.tightness,
    )
    t0 = time.time()
    if mesh is None:
        mesh = _mesh()
    if mesh is not None:
        res = solve_sharded(kp, mesh, cfg, q=q)
    else:
        res = solve(kp, cfg, q=q)
    dt = time.time() - t0
    viol = float(jnp.max((res.r - kp.budgets) / kp.budgets))
    return {
        "n_users": workload.n_users,
        "k": workload.k,
        "iterations": int(res.iters),
        "primal": float(res.primal),
        "dual": float(res.dual),
        "duality_gap": float(res.dual - res.primal),
        "max_violation": viol,
        "wall_s": round(dt, 2),
    }


def run_streaming(workload: KPWorkload, cfg: SolverConfig, chunk: int,
                  seed=0, mesh=None, host_feed=False, double_buffer=True,
                  checkpoint_dir=None, resume=False, slots=None):
    """Out-of-core solve of a §6 workload: chunks generated on demand.

    Nothing O(N) is ever materialised (device state is O(chunk·K + K·E));
    the decision matrix is not returned — stream it per chunk with
    ``core.chunked.decisions_chunk`` using the reported (lam, tau).
    ``host_feed`` produces the chunks as NumPy arrays on the host and
    runs the prefetch pipeline (core/prefetch.py) instead of the traced
    in-program generator — the path a real on-disk dataset takes. In
    host-feed mode the solve shards over the mesh (one virtual slot per
    device by default; ``slots`` to pin more for elastic resume) and,
    with ``cfg.checkpoint_every`` and a ``checkpoint_dir``, survives
    preemption: relaunch with ``resume=True`` and the same directory.
    """
    t0 = time.time()
    if host_feed:
        src = sparse_host_chunk_source(
            seed, workload.n_users, workload.k, chunk, q=workload.q,
            tightness=workload.tightness)
        if mesh is None and cfg.stream_finalize != "legacy":
            # The legacy three-pass finalize lives on the single-device
            # driver only (its benchmark-baseline role); every other
            # host-fed solve shards over the visible devices.
            mesh = _mesh()
        res = solve_streaming_host(
            src, cfg, q=workload.q, double_buffer=double_buffer, mesh=mesh,
            slots=slots, checkpoint_dir=checkpoint_dir,
            resume_from=checkpoint_dir if resume else None)
    else:
        src = sparse_chunk_source(seed, workload.n_users, workload.k, chunk,
                                  q=workload.q, tightness=workload.tightness)
        if mesh is None:
            mesh = _mesh()
        res = solve_streaming(src, cfg, q=workload.q, mesh=mesh)
    dt = time.time() - t0
    viol = float(jnp.max((res.r - src.budgets) / src.budgets))
    out = {
        "n_users": workload.n_users,
        "k": workload.k,
        "chunk_size": chunk,
        "iterations": int(res.iters),
        "primal": float(res.primal),
        "dual": float(res.dual),
        "duality_gap": float(res.dual - res.primal),
        "max_violation": viol,
        "wall_s": round(dt, 2),
    }
    if getattr(res, "screen", None) is not None:
        # Host driver: per-epoch streamed-chunk counts. Traced driver:
        # per-iteration active-chunk counts (-1 rows = never reached).
        if "streamed_chunks" in res.screen:
            counts = np.asarray(res.screen["streamed_chunks"])
        else:
            ac = np.asarray(res.screen["active_chunks"])
            counts = ac[ac >= 0]
        out["screen_chunks_per_iter"] = counts.tolist()
        out["screen_resets"] = int(np.asarray(res.screen["resets"]))
    return out


def main():
    """CLI entry point; prints one ``key: value`` line per metric."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=list(WORKLOADS), default="table1")
    ap.add_argument("--scale", type=float, default=1e-4,
                    help="shrink N by this factor (1.0 = full size)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--algo", choices=["scd", "dd"], default="scd")
    ap.add_argument("--reduce", choices=["bucketed", "exact"], default="bucketed")
    ap.add_argument("--presolve", type=int, default=0)
    ap.add_argument("--max-iters", type=int, default=40)
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernel path (fused map+reduce for the "
                         "sparse bucketed solve; interpret mode off-TPU)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="stream the per-iteration map over user chunks "
                         "of this size (bit-identical on the SCD bucketed "
                         "path; see core/solver.py)")
    ap.add_argument("--streaming", action="store_true",
                    help="out-of-core mode: synthesize chunks on demand, "
                         "never materialise the (N, K) instance "
                         "(requires --chunk-size)")
    ap.add_argument("--stream-finalize", choices=["fused", "legacy"],
                    default="fused",
                    help="streaming finalize: one fused pass (iters + 1 "
                         "source passes) or the legacy three-pass oracle "
                         "(iters + 3); DESIGN.md §5c")
    ap.add_argument("--host-feed", action="store_true",
                    help="streaming mode with host-produced NumPy chunks "
                         "through the double-buffered prefetch pipeline "
                         "(core/prefetch.py)")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="host-feed only: synchronous device_put (the "
                         "naive baseline the bench compares against)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="host-feed only: directory for the atomic "
                         "preemption-safe resume state (DESIGN.md §7)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="write the resume state every N iterations "
                         "(and every N chunk columns inside the fused "
                         "finalize pass); 0 disables")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from "
                         "--checkpoint-dir before solving (fresh start "
                         "when the directory has none, so a relaunch "
                         "loop can always pass --resume)")
    ap.add_argument("--slots", type=int, default=None,
                    help="host-feed only: virtual shard count (default: "
                         "one per device); fixed at first launch so a "
                         "checkpoint can resume on any mesh whose device "
                         "count divides it")
    ap.add_argument("--screening", action="store_true",
                    help="safe λ-interval active-set screening: retire "
                         "chunks that provably bin below the bucket "
                         "ladder and skip them in iteration passes "
                         "(bitwise-identical results; streaming SCD "
                         "bucketed path only, DESIGN.md §11)")
    ap.add_argument("--screening-floor", type=float, default=0.5,
                    help="certify multipliers down to lam * this factor; "
                         "an escape below the floor reactivates every "
                         "chunk for one full pass")
    args = ap.parse_args()

    wl = WORKLOADS[args.workload]
    n = args.n or max(int(wl.n_users * args.scale), 1024)
    wl = KPWorkload(wl.name, n, args.k or wl.k, args.q or wl.q, wl.tightness)
    cfg = SolverConfig(algo=args.algo, reduce=args.reduce,
                       max_iters=args.max_iters,
                       presolve_samples=args.presolve,
                       use_kernels=args.use_kernels,
                       stream_finalize=args.stream_finalize,
                       checkpoint_every=args.checkpoint_every,
                       chunk_size=None if args.streaming else args.chunk_size,
                       screening=args.screening,
                       screening_floor=args.screening_floor)
    if args.screening and not (args.streaming or args.host_feed):
        raise SystemExit("--screening requires --streaming or --host-feed "
                         "(only the chunk-streamed drivers carry an active "
                         "chunk set)")
    if ((args.checkpoint_every or args.checkpoint_dir or args.resume
         or args.slots) and not args.host_feed):
        raise SystemExit("--checkpoint-every/--checkpoint-dir/--resume/"
                         "--slots require --host-feed (only the host-fed "
                         "epoch driver is preemption-safe and slot-sharded)")
    if args.checkpoint_every and not args.checkpoint_dir:
        raise SystemExit("--checkpoint-every requires --checkpoint-dir")
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    if args.streaming or args.host_feed:
        if not args.chunk_size:
            raise SystemExit("--streaming/--host-feed require --chunk-size")
        out = run_streaming(wl, cfg, args.chunk_size,
                            host_feed=args.host_feed,
                            double_buffer=not args.no_double_buffer,
                            checkpoint_dir=args.checkpoint_dir,
                            resume=args.resume, slots=args.slots)
    else:
        out = run(wl, cfg)
    for k, v in out.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
