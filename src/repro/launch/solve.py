"""Solver launcher: the paper's production job.

    python -m repro.launch.solve --workload table1 --scale 1e-4
    python -m repro.launch.solve --n 1000000 --k 10 --q 1

Runs the distributed SCD solver over however many devices exist (all mesh
axes carry the user shard), reports iterations / primal / duality gap /
violations — i.e., the paper's Table 1 row for the requested size. The
full-size workloads only fit a cluster; ``--scale`` shrinks N while
keeping the structure (budgets scale with N, §6).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_kp import WORKLOADS, KPWorkload
from repro.core import SolverConfig, solve, solve_sharded
from repro.core.instances import shard_key, sparse_instance


def run(workload: KPWorkload, cfg: SolverConfig, seed=0, mesh=None):
    kp, q = sparse_instance(
        shard_key(seed), workload.n_users, workload.k, workload.q,
        tightness=workload.tightness,
    )
    t0 = time.time()
    if mesh is None and jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("users",))
    if mesh is not None:
        res = solve_sharded(kp, mesh, cfg, q=q)
    else:
        res = solve(kp, cfg, q=q)
    dt = time.time() - t0
    viol = float(jnp.max((res.r - kp.budgets) / kp.budgets))
    return {
        "n_users": workload.n_users,
        "k": workload.k,
        "iterations": int(res.iters),
        "primal": float(res.primal),
        "dual": float(res.dual),
        "duality_gap": float(res.dual - res.primal),
        "max_violation": viol,
        "wall_s": round(dt, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=list(WORKLOADS), default="table1")
    ap.add_argument("--scale", type=float, default=1e-4,
                    help="shrink N by this factor (1.0 = full size)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--algo", choices=["scd", "dd"], default="scd")
    ap.add_argument("--reduce", choices=["bucketed", "exact"], default="bucketed")
    ap.add_argument("--presolve", type=int, default=0)
    ap.add_argument("--max-iters", type=int, default=40)
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernel path (fused map+reduce for the "
                         "sparse bucketed solve; interpret mode off-TPU)")
    args = ap.parse_args()

    wl = WORKLOADS[args.workload]
    n = args.n or max(int(wl.n_users * args.scale), 1024)
    wl = KPWorkload(wl.name, n, args.k or wl.k, args.q or wl.q, wl.tightness)
    cfg = SolverConfig(algo=args.algo, reduce=args.reduce,
                       max_iters=args.max_iters,
                       presolve_samples=args.presolve,
                       use_kernels=args.use_kernels)
    out = run(wl, cfg)
    for k, v in out.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
