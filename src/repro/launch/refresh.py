"""Refresh launcher: the paper's daily production loop, end to end.

Drives a multi-day scenario through :class:`repro.serve.RefreshEngine`:
N generations of deterministic budget perturbations, each solved
warm-started from the previous generation's multipliers and published
with an atomic pointer flip, then on-demand lookups against the live
generation through :class:`repro.serve.DecisionService`.

Accounting printed per generation: the warm refresh's iteration count
next to a cold reference solve of the *same* workload (the paper's
daily-call argument in numbers — the warm path must win), then lookup
QPS (batched and single-user) with the chunk-cache hit rate, and a
roundtrip verification that sampled lookups are bitwise the rows full
materialisation (``chunked.decisions_chunk``) would produce.

Exit status 1 when the warm path fails to beat cold in total
iterations or a lookup mismatches materialisation — this is the CI
serving smoke gate (``--smoke``), which on the CI image runs over 8
virtual devices (sharded host feeding, slots == devices).

``--chaos`` is the fault-domain gate (DESIGN.md §10): the scenario runs
twice — once clean, once with every chunk fetch injected with
deterministic drops, slow reads, corrupt payloads and a repeat-offender
chunk (:func:`repro.core.faults.faulty_source`) under the retrying
ingest (``fetch_retries``/``verify_refetch``). Every generation's
published record must be **bitwise identical** between the two roots,
every lookup must verify against materialisation, and the chaos run's
serving stats must show zero stale (degraded) serves — the retries
absorbed every fault, no reader ever saw a torn or stale byte.

    PYTHONPATH=src python -m repro.launch.refresh --smoke
    PYTHONPATH=src python -m repro.launch.refresh --smoke --chaos
    PYTHONPATH=src python -m repro.launch.refresh --users 1000000 \
        --generations 7 --root /tmp/refresh
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import SolverConfig, SparseKP
from repro.core.chunked import array_source, decisions_chunk
from repro.core.faults import (
    FaultPlan,
    faulty_source,
    policy_from_cfg,
    resilient_source,
)
from repro.core.prefetch import solve_streaming_host
from repro.serve import RefreshEngine, WorkloadSpec, synthetic_source


def _budget_schedule(generations: int, seed: int):
    """Deterministic daily budget scales: ±15% around the base budgets."""
    rng = np.random.default_rng(seed + 1000)
    return [1.0] + [round(float(s), 4)
                    for s in 1.0 + rng.uniform(-0.15, 0.15, generations - 1)]


def _cold_iters(engine: RefreshEngine, spec: WorkloadSpec) -> int:
    """Iteration count of a cold reference solve of the same workload."""
    res = solve_streaming_host(
        engine.make_source(spec),
        engine.cfg.replace(checkpoint_every=0), q=spec.q,
        mesh=engine.mesh, slots=engine.slots)
    return int(res.iters)


def _verify_lookups(engine: RefreshEngine, svc, users) -> bool:
    """Sampled lookups vs full decisions_chunk materialisation, bitwise."""
    gen = svc.generation
    src = engine.make_source(gen.spec)
    # Under --chaos the raw source injects faults; the oracle read must
    # go through the same retry layer the solver used or the injected
    # corruption would poison the reference bytes.
    policy = policy_from_cfg(engine.cfg)
    if policy is not None:
        src = resilient_source(src, policy, verify=engine.cfg.verify_refetch)
    c = -(-src.n // src.chunk)
    p = np.concatenate([src.fn(i)[0] for i in range(c)])[:src.n]
    b = np.concatenate([src.fn(i)[1] for i in range(c)])[:src.n]
    kp = SparseKP(p=jnp.asarray(p), b=jnp.asarray(b),
                  budgets=jnp.asarray(src.budgets))
    asrc = array_source(kp, src.chunk)
    got = svc.decide_batch(users)
    ok = True
    for ci in np.unique(np.asarray(users) // src.chunk):
        x, _ = decisions_chunk(asrc, gen.lam, gen.spec.q, int(ci),
                               tau=gen.tau)
        rows = np.asarray(users) // src.chunk == ci
        want = np.asarray(x)[np.asarray(users)[rows] % src.chunk]
        if not np.array_equal(got[rows], want):
            ok = False
            print(f"[refresh] LOOKUP MISMATCH in chunk {int(ci)}")
    return ok


def run_scenario(spec: WorkloadSpec, generations: int, root,
                 cfg: SolverConfig, mesh=None, slots=None, lookups=512,
                 verify=True, resume=False, make_source=synthetic_source):
    """The multi-day loop; returns the accounting dict the bench reuses."""
    engine = RefreshEngine(root, spec, make_source=make_source, cfg=cfg,
                           mesh=mesh, slots=slots)
    if resume:
        rec = engine.recover()
        if rec is not None:
            print(f"[refresh] recovered pending generation {rec.gen}")
    scales = _budget_schedule(generations, spec.seed)
    start = (engine.live_gen_id() + 1
             if engine.live_gen_id() is not None else 0)
    per_gen = []
    for g in range(start, generations):
        t0 = time.perf_counter()
        gen = engine.refresh(budget_scale=scales[g])
        wall = time.perf_counter() - t0
        cold = gen.iters if g == 0 else _cold_iters(engine, gen.spec)
        per_gen.append({"gen": g, "budget_scale": scales[g],
                        "warm_iters": gen.iters, "cold_iters": cold,
                        "wall_s": round(wall, 3)})
        tag = "cold (first)" if g == 0 else f"cold would take {cold}"
        print(f"[refresh] gen {g}: budgets {scales[g] - 1.0:+.2%} -> "
              f"{gen.iters} iters warm ({tag}), primal "
              f"{float(gen.primal):,.1f}, {wall:.2f}s")

    warm_entries = [e for e in per_gen if e["gen"] > 0]
    warm_total = sum(e["warm_iters"] for e in warm_entries)
    cold_total = sum(e["cold_iters"] for e in warm_entries)
    if warm_entries:
        print(f"[refresh] totals over {len(warm_entries)} refreshes: "
              f"warm {warm_total} vs cold {cold_total} iterations "
              f"({cold_total / max(warm_total, 1):.2f}x)")
    else:
        # Single-generation scenario, or a --resume relaunch that found
        # everything already published: nothing warm to account.
        print("[refresh] no warm refreshes ran this invocation "
              f"(live generation: {engine.live_gen_id()})")

    svc = engine.decision_service()
    rng = np.random.default_rng(spec.seed)
    users = rng.integers(0, spec.n, lookups)
    t0 = time.perf_counter()
    svc.decide_batch(users)
    batched_s = time.perf_counter() - t0
    singles = users[:min(lookups, 128)]
    t0 = time.perf_counter()
    for u in singles:
        svc.decide(int(u))
    single_s = time.perf_counter() - t0
    lookup = {
        "users": int(lookups),
        "batched_qps": round(lookups / max(batched_s, 1e-9), 1),
        "single_qps": round(len(singles) / max(single_s, 1e-9), 1),
        "cache": dict(svc.stats),
    }
    print(f"[refresh] lookups: {lookup['batched_qps']:.0f}/s batched, "
          f"{lookup['single_qps']:.0f}/s single "
          f"(cache {svc.stats['hits']} hits / {svc.stats['fills']} fills)")

    ok = True
    if verify:
        ok = _verify_lookups(engine, svc, users[:256])
        print(f"[refresh] lookup roundtrip vs materialisation: "
              f"{'bitwise OK' if ok else 'MISMATCH'}")
    return {"per_generation": per_gen, "warm_refreshes": len(warm_entries),
            "warm_iters_total": warm_total,
            "cold_iters_total": cold_total,
            "cold_over_warm": round(cold_total / max(warm_total, 1), 3),
            "lookup": lookup, "lookups_bitwise": ok}


# The chaos injection plan and retry budget must respect the probability
# compounding: verify_refetch doubles every read, so an attempt succeeds
# with (1 - drop - corrupt)^2 and the per-chunk budget has to cover
# thousands of fetches without exhausting. drop 8% + corrupt 4% under 8
# retries keeps P(any exhaustion over a smoke run) negligible while
# still firing hundreds of injected faults.
_CHAOS_PLAN_KW = dict(drop=0.08, slow=0.05, slow_s=0.002, corrupt=0.04,
                      offenders=(1,), offender_failures=2)
_CHAOS_CFG_KW = dict(fetch_retries=8, fetch_backoff=1e-4,
                     fetch_backoff_cap=1e-3, verify_refetch=True)

_RECORD_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual",
                  "fingerprint"]


def run_chaos(spec: WorkloadSpec, generations: int, root,
              cfg: SolverConfig, mesh=None, slots=None, lookups=256):
    """The fault-domain gate: chaos run bitwise-equals the clean run.

    Runs the scenario twice under ``root`` — ``clean/`` fault-free and
    ``chaos/`` with every chunk fetch going through
    :func:`~repro.core.faults.faulty_source` injection absorbed by the
    retrying ingest — then compares every published generation's record
    field-for-field. Returns ``(ok, accounting)``.
    """
    root = pathlib.Path(root)
    print(f"[chaos] clean pass -> {root / 'clean'}")
    clean_out = run_scenario(spec, generations, root / "clean", cfg,
                             mesh=mesh, slots=slots, lookups=lookups)
    plan = FaultPlan(seed=spec.seed, **_CHAOS_PLAN_KW)
    chaos_cfg = cfg.replace(**_CHAOS_CFG_KW)
    print(f"[chaos] injected pass -> {root / 'chaos'} ({plan})")
    chaos_out = run_scenario(
        spec, generations, root / "chaos", chaos_cfg, mesh=mesh,
        slots=slots, lookups=lookups,
        make_source=lambda s: faulty_source(synthetic_source(s), plan))

    clean_eng = RefreshEngine(root / "clean", spec, cfg=cfg)
    chaos_eng = RefreshEngine(root / "chaos", spec, cfg=chaos_cfg)
    ok = True
    for g in range(generations):
        want, got = clean_eng.generation(g), chaos_eng.generation(g)
        for f in _RECORD_FIELDS:
            if np.asarray(getattr(want, f)).tobytes() \
                    != np.asarray(getattr(got, f)).tobytes():
                ok = False
                print(f"[chaos] FAIL: gen {g} field {f} differs from the "
                      "fault-free run")
        for i, (x, y) in enumerate(zip(want.fin_hist or (),
                                       got.fin_hist or ())):
            if np.asarray(x).tobytes() != np.asarray(y).tobytes():
                ok = False
                print(f"[chaos] FAIL: gen {g} fin_hist[{i}] differs")
    stats = chaos_out["lookup"]["cache"]
    if stats.get("stale_serves", 0) != 0:
        ok = False
        print(f"[chaos] FAIL: {stats['stale_serves']} stale serves — "
              "lookup retries did not absorb the injected faults")
    if not (clean_out["lookups_bitwise"] and chaos_out["lookups_bitwise"]):
        ok = False
    if ok:
        print(f"[chaos] OK: {generations} generations bitwise-identical "
              "under injected faults "
              f"({stats.get('retries', 0)} lookup retries absorbed, "
              "0 stale serves)")
    return ok, {"clean": clean_out, "chaos": chaos_out}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=65536)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--generations", type=int, default=5)
    ap.add_argument("--tightness", type=float, default=0.4)
    ap.add_argument("--root", default=None,
                    help="generation root (default: a temp dir)")
    ap.add_argument("--slots", type=int, default=None,
                    help="virtual feed slots (default: device count)")
    ap.add_argument("--max-iters", type=int, default=60)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--lookups", type=int, default=512)
    ap.add_argument("--resume", action="store_true",
                    help="finish a preempted refresh in --root first")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the O(n) lookup-roundtrip check")
    ap.add_argument("--smoke", action="store_true",
                    help="small scenario (CI gate; exits 1 on any failure)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the scenario clean AND under injected "
                         "fetch faults; exit 1 unless every generation "
                         "is bitwise identical between the two")
    ap.add_argument("--screening", action="store_true",
                    help="active-set screening + delta refresh: retire "
                         "provably-inert chunks, seed each generation's "
                         "active set from the parent's certificates and "
                         "re-stream only changed chunks (bitwise "
                         "results; DESIGN.md §11)")
    ap.add_argument("--screening-floor", type=float, default=0.5)
    ap.add_argument("--band", type=float, default=0.0,
                    help="ratio-banded workload (cold-cohort profit "
                         "scale; 0 = uniform §6 generator). Screening "
                         "retires nothing on the uniform workload — "
                         "pair --screening with --band")
    ap.add_argument("--bucket-half", type=int, default=24,
                    help="bucket ladder half-width (smaller ladders "
                         "tighten the screening certificate)")
    args = ap.parse_args()

    if args.smoke:
        args.users, args.chunk, args.generations = 8192, 512, 3
        args.lookups = 256
    spec = WorkloadSpec(seed=args.seed, n=args.users, k=args.k,
                        chunk=args.chunk, q=args.q,
                        tightness=args.tightness, band=args.band)
    cfg = SolverConfig(reduce="bucketed", max_iters=args.max_iters,
                       checkpoint_every=args.checkpoint_every,
                       screening=args.screening,
                       screening_floor=args.screening_floor,
                       bucket_half=args.bucket_half)
    ndev = jax.device_count()
    mesh = jax.make_mesh((ndev,), ("users",)) if ndev > 1 else None
    root = args.root or tempfile.mkdtemp(prefix="refresh_")
    print(f"[refresh] root {root}; {ndev} device(s)"
          + (f", slots {args.slots or ndev}" if mesh else ""))
    if args.chaos:
        ok, _ = run_chaos(spec, args.generations, root, cfg, mesh=mesh,
                          slots=args.slots, lookups=args.lookups)
        sys.exit(0 if ok else 1)
    out = run_scenario(spec, args.generations, root, cfg, mesh=mesh,
                       slots=args.slots, lookups=args.lookups,
                       verify=not args.no_verify, resume=args.resume)
    if out["warm_refreshes"] \
            and out["warm_iters_total"] >= out["cold_iters_total"]:
        print("[refresh] FAIL: warm refreshes did not beat cold "
              f"({out['warm_iters_total']} >= {out['cold_iters_total']})")
        sys.exit(1)
    if not out["lookups_bitwise"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
