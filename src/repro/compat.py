"""Version-compatibility shims for the moving parts of the jax API.

``shard_map`` became a stable top-level API (with the ``check_vma``
kwarg) after the ``jax.experimental.shard_map`` era (``check_rep``
kwarg). Every call site in this repo goes through this module so the
repo runs on both sides of the rename.
"""
from __future__ import annotations

import jax

if hasattr(jax.sharding, "set_mesh"):
    set_mesh = jax.sharding.set_mesh
else:
    def set_mesh(mesh):
        # Pre-set_mesh jax: Mesh is itself the context manager that
        # installs the global resource env.
        return mesh


if hasattr(jax.sharding, "get_abstract_mesh"):
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    def get_abstract_mesh():
        # Pre-AbstractMesh jax: the context mesh installed by
        # ``with mesh:`` is the thread-local physical mesh. It exposes
        # the same .empty/.axis_names/.axis_sizes surface the call
        # sites use, and unlike an AbstractMesh it is directly usable
        # as the mesh argument of the era's shard_map.
        from jax._src import mesh as _mesh
        return _mesh.thread_resources.env.physical_mesh


def as_shardings(mesh, tree):
    """Make a PartitionSpec pytree acceptable to jax.jit shardings args.

    Post-set_mesh jax resolves bare PartitionSpecs against the context
    mesh; older jax requires concrete NamedShardings. None leaves (an
    unconstrained subtree) pass through untouched on both.
    """
    if hasattr(jax.sharding, "set_mesh"):
        return tree
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
        else s,
        tree,
        is_leaf=lambda s: isinstance(s, PartitionSpec),
    )


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
