"""Mixture-of-Experts FFN with expert parallelism.

Two routers: heuristic ``topk`` and the paper's ``scd`` (knapsack-priced,
exact global capacity — see core/moe_router.py). Two compute paths:

* ``moe_train`` — sort-free scatter dispatch + all_to_all over the expert
  (model) mesh axis inside shard_map: tokens travel to the shard owning
  their expert, grouped GEMMs run per local expert, results return by the
  inverse all_to_all. This is the compute-efficient path for train/prefill.

* ``moe_decode`` — dense einsum over the (expert-sharded) E axis with a
  combine mask, in plain pjit/GSPMD. At decode the MoE is bound by reading
  expert weights (which EP reads exactly once per shard either way), and
  the E/topk compute overhead is irrelevant, so this avoids the a2a
  round-trip entirely for one-token steps.

Shared experts (DeepSeek-style) are ordinary dense MLPs handled by the
caller; this module owns routed experts only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import shard_map
from ..compat import get_abstract_mesh
from ..core.moe_router import scd_route, topk_route
from .layers import truncnorm
from . import sharding


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": truncnorm(k1, (d, m.n_experts), jnp.float32, d ** -0.5),
        "wi": truncnorm(k2, (m.n_experts, d, 2, m.d_ff), cfg.param_dtype, d ** -0.5),
        "wo": truncnorm(k3, (m.n_experts, m.d_ff, d), cfg.param_dtype, m.d_ff ** -0.5),
    }


def _route(logits, cfg, decode=False):
    m = cfg.moe
    if m.router == "scd" and not decode:
        out = scd_route(logits, q=m.topk, capacity_factor=m.capacity_factor,
                        iters=m.scd_iters)
    else:
        # Decode always uses plain top-k: a one-token step has no batch-wide
        # capacity to price (knapsack budgets are a throughput-time concept);
        # matches production MoE serving practice.
        out = topk_route(logits, q=m.topk)
    # renormalise combine weights over the chosen experts
    denom = jnp.maximum(out.combine.sum(-1, keepdims=True), 1e-9)
    return out.combine / denom, out.mask


def moe_decode(p, cfg, x, act="silu"):
    """One-token MoE: dense over the expert-sharded axis (see module doc).

    x: (B, 1, D) -> (B, 1, D).
    """
    b, s, d = x.shape
    t = x.reshape(b * s, d)
    logits = t.astype(jnp.float32) @ p["router"]
    combine, _ = _route(logits, cfg, decode=True)           # (T, E)
    h = jnp.einsum("td,edgf->tegf", t, p["wi"].astype(t.dtype))
    gate, up = h[..., 0, :], h[..., 1, :]
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    y = jnp.einsum("tef,efd->ted", g * up, p["wo"].astype(t.dtype))
    y = jnp.einsum("ted,te->td", y, combine.astype(t.dtype))
    return y.reshape(b, s, d)


def moe_train(p, cfg, x, act="silu"):
    """Training/prefill MoE with a2a expert parallelism.

    x: (B, S, D) global view. Runs in shard_map over the full mesh when
    sharding rules are active (batch over data axes, seq + experts over
    the model axis); falls back to a single-device local dispatch when not.
    """
    rules = sharding.get_rules()
    model_ax = sharding.mesh_axis("experts")
    if rules is None or model_ax is None:
        return _moe_local(p, cfg, x, act)

    mesh = get_abstract_mesh()
    batch_ax = sharding.mesh_axis("batch")
    seq_ax = sharding.mesh_axis("seq")
    P = jax.sharding.PartitionSpec
    x_spec = P(batch_ax, seq_ax, None)
    # Experts sharded over the model axis; the fsdp ("data") shards of the
    # weights are re-gathered on shard_map entry (the FSDP all-gather) so
    # the body sees full D / d_ff.
    p_spec = {
        "router": P(),
        "wi": P(model_ax, None, None, None),
        "wo": P(model_ax, None, None),
    }
    # capacity reduction for the scd router spans every token shard
    all_axes = tuple(
        a for a in (batch_ax if isinstance(batch_ax, tuple) else (batch_ax,))
        if a is not None
    ) + ((seq_ax,) if seq_ax else ())

    def body(pp, xx):
        return _moe_a2a(pp, cfg, xx, act, model_ax, all_axes)

    return shard_map(
        body, mesh=mesh, in_specs=(p_spec, x_spec), out_specs=x_spec,
        check_vma=False,
    )(p, x)


def _moe_local(p, cfg, x, act):
    """Reference path (1 device): dense-over-experts with combine mask."""
    b, s, d = x.shape
    t = x.reshape(b * s, d)
    logits = t.astype(jnp.float32) @ p["router"]
    combine, _ = _route(logits, cfg)
    h = jnp.einsum("td,edgf->tegf", t, p["wi"].astype(t.dtype))
    gate, up = h[..., 0, :], h[..., 1, :]
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    y = jnp.einsum("tef,efd->ted", g * up, p["wo"].astype(t.dtype))
    y = jnp.einsum("ted,te->td", y, combine.astype(t.dtype))
    return y.reshape(b, s, d)


def _moe_a2a(p, cfg, x, act, model_ax, token_axes):
    """shard_map body: local tokens -> a2a -> local expert GEMMs -> a2a back.

    x: (B_l, S_l, D) local shard. Expert weights arrive sharded over
    model_ax (E_l local experts) and gathered over the fsdp axis by
    shard_map's in_spec slicing... they arrive as (E_l, D_l?, ...) — we
    keep D unsharded here and shard only E (fsdp on experts' D is applied
    outside via the parameter specs; shard_map re-gathers it).
    """
    m = cfg.moe
    b_l, s_l, d = x.shape
    t_l = b_l * s_l
    xt = x.reshape(t_l, d)
    n_ms = jax.lax.psum(1, model_ax)
    e_l = p["wi"].shape[0]                                  # local experts

    # --- routing (global capacity via psum'd histograms for scd) ---------
    logits = xt.astype(jnp.float32) @ p["router"]           # (T_l, E)
    if m.router == "scd":
        from ..core.moe_router import scd_route_shmap
        axes = tuple(dict.fromkeys(
            token_axes + ((model_ax,) if model_ax else ())))  # dedupe, ordered
        combine, mask = scd_route_shmap(
            logits, q=m.topk, capacity_factor=m.capacity_factor,
            iters=m.scd_iters, axis=axes,
        )
    else:
        combine, mask = _route(logits, cfg)
    wsel, eid = jax.lax.top_k(jnp.where(mask, combine, -1.0), m.topk)  # (T_l,k)
    valid = wsel > 0

    # --- build per-target-shard send buffers ------------------------------
    k = m.topk
    pairs = t_l * k
    eid_f = eid.reshape(pairs)
    valid_f = valid.reshape(pairs)
    target = eid_f // e_l                                   # (pairs,) in [0, n_ms)
    onehot = jax.nn.one_hot(jnp.where(valid_f, target, n_ms), n_ms + 1,
                            dtype=jnp.int32)[:, :n_ms]      # invalid -> dropped
    pos = jnp.cumsum(onehot, axis=0) - onehot               # rank within target
    pos = (pos * onehot).sum(-1)                            # (pairs,)
    cap_send = int(cfg.moe.capacity_factor * pairs / n_ms) + 1
    ok = valid_f & (pos < cap_send)
    slot = jnp.where(ok, target * cap_send + pos, n_ms * cap_send)
    src = xt[jnp.repeat(jnp.arange(t_l), k)]                # (pairs, D)
    send_x = jnp.zeros((n_ms * cap_send + 1, d), x.dtype).at[slot].set(src)[:-1]
    send_le = jnp.full((n_ms * cap_send + 1,), e_l, jnp.int32).at[slot].set(
        eid_f % e_l)[:-1]
    send_x = send_x.reshape(n_ms, cap_send, d)
    send_le = send_le.reshape(n_ms, cap_send)

    # --- a2a to expert shards ---------------------------------------------
    recv_x = jax.lax.all_to_all(send_x, model_ax, 0, 0, tiled=True)
    recv_le = jax.lax.all_to_all(send_le, model_ax, 0, 0, tiled=True)
    rt = n_ms * cap_send
    rx = recv_x.reshape(rt, d)
    rle = recv_le.reshape(rt)                               # e_l == invalid

    # --- group by local expert, grouped GEMM ------------------------------
    r_onehot = jax.nn.one_hot(rle, e_l + 1, dtype=jnp.int32)[:, :e_l]
    r_pos = (jnp.cumsum(r_onehot, axis=0) - r_onehot)
    r_pos = (r_pos * r_onehot).sum(-1)
    cap_e = int(cfg.moe.capacity_factor * rt / e_l) + 1
    r_ok = (rle < e_l) & (r_pos < cap_e)
    r_slot = jnp.where(r_ok, rle * cap_e + r_pos, e_l * cap_e)
    buf = jnp.zeros((e_l * cap_e + 1, d), x.dtype).at[r_slot].set(rx)[:-1]
    buf = buf.reshape(e_l, cap_e, d)
    h = jnp.einsum("ecd,edgf->ecgf", buf, p["wi"].astype(x.dtype))
    gate, up = h[..., 0, :], h[..., 1, :]
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    y_buf = jnp.einsum("ecf,efd->ecd", g * up, p["wo"].astype(x.dtype))

    # --- ungroup, a2a back, combine ---------------------------------------
    y_r = jnp.where(
        r_ok[:, None], y_buf.reshape(e_l * cap_e, d)[jnp.clip(r_slot, 0, e_l * cap_e - 1)],
        0.0,
    )
    y_send = y_r.reshape(n_ms, cap_send, d)
    y_back = jax.lax.all_to_all(y_send, model_ax, 0, 0, tiled=True)
    y_flat = y_back.reshape(n_ms * cap_send, d)
    y_pairs = jnp.where(
        ok[:, None], y_flat[jnp.clip(slot, 0, n_ms * cap_send - 1)], 0.0
    )                                                       # (pairs, D)
    w_pairs = jnp.where(ok, wsel.reshape(pairs), 0.0)
    y = (y_pairs * w_pairs[:, None].astype(x.dtype)).reshape(t_l, k, d).sum(1)
    return y.reshape(b_l, s_l, d)
