"""Logical-axis sharding context for the model zoo.

Models annotate activations with *logical* axes ("batch", "seq", "heads",
"ffn", "experts", "vocab"); the launcher binds logical axes to mesh axes
once (`set_rules`), and `constrain()` becomes `with_sharding_constraint`
under the active mesh — or a no-op on a single device (smoke tests).

Default production binding (launch/mesh.py):
    batch   -> ("pod", "data")     [DP]
    seq     -> "model"             [Megatron-style sequence parallelism for
                                    the residual stream between blocks]
    heads/ffn/experts/vocab -> "model"  [TP/EP]
    fsdp    -> "data"              [parameter + optimizer-state sharding]
"""
from __future__ import annotations

import threading
from typing import Optional

import jax

from ..compat import get_abstract_mesh
from jax.sharding import PartitionSpec as P

_state = threading.local()


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": "model",
    "kv_seq": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "fsdp": "data",
    "d_model": None,
    "state": None,
    None: None,
}


def set_rules(rules: Optional[dict]):
    """Bind logical axes to mesh axes. None disables all constraints."""
    _state.rules = rules


def get_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def spec(*logical) -> P:
    """PartitionSpec for a tuple of logical axis names (None entries ok)."""
    rules = get_rules()
    if rules is None:
        return P()
    return P(*[rules.get(ax, None) for ax in logical])


def constrain(x, *logical):
    """with_sharding_constraint by logical axes; no-op without rules."""
    rules = get_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical))


def mesh_axis(logical: str):
    """The mesh axis (name or tuple) bound to a logical axis, or None."""
    rules = get_rules()
    if rules is None:
        return None
    return rules.get(logical, None)


def axis_size(logical: str) -> int:
    """Size of the mesh axis bound to a logical name (1 if unbound)."""
    ax = mesh_axis(logical)
    if ax is None:
        return 1
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    names = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for n in names:
        size *= dict(zip(mesh.axis_names, mesh.axis_sizes))[n]
    return size
