"""Shared primitive layers: norms, RoPE, embeddings, gated MLPs.

Pure functions over explicit parameter dicts (no framework dependency).
``init_*`` functions only build arrays through jax.random / jnp, so the
whole parameter tree can be abstracted with ``jax.eval_shape`` for
allocation-free AOT lowering (the multi-pod dry-run path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncnorm(key, shape, dtype, scale):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# --- norms -----------------------------------------------------------------

def init_rms(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# --- rotary embeddings -------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    if x.ndim > ang.ndim:
        ang = ang[..., None, :]                            # broadcast heads
    while x.ndim > ang.ndim:
        ang = ang[None]                                    # broadcast batch
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- dense / mlp -------------------------------------------------------------

def init_linear(key, d_in, d_out, dtype, out_shape=None):
    shape = (d_in, d_out) if out_shape is None else (d_in, *out_shape)
    return {"w": truncnorm(key, shape, dtype, d_in ** -0.5)}


def linear(p, x, spec=None):
    w = p["w"]
    if w.ndim == 2:
        return x @ w.astype(x.dtype)
    # (d_in, a, b, ...) fan-out projections
    return jnp.einsum("...d,dab->...ab", x, w.astype(x.dtype))


def init_mlp(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wi": truncnorm(k1, (d, 2, f), dtype, d ** -0.5),   # [gate, up] fused
        "wo": truncnorm(k2, (f, d), dtype, f ** -0.5),
    }


def mlp(p, x, act="silu"):
    h = jnp.einsum("...d,dgf->...gf", x, p["wi"].astype(x.dtype))
    gate, up = h[..., 0, :], h[..., 1, :]
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    return jnp.einsum("...f,fd->...d", g * up, p["wo"].astype(x.dtype))


# --- embeddings --------------------------------------------------------------

def init_embed(key, vocab, d, dtype):
    return {"e": truncnorm(key, (vocab, d), dtype, 1.0)}


def embed(p, tokens, dtype):
    return p["e"].astype(dtype)[tokens]


def unembed(p, x):
    # d^-0.5 keeps logits O(1) at init for both tied and untied heads
    d = x.shape[-1]
    return jnp.einsum("...d,vd->...v", x, p["e"].astype(x.dtype)) * d ** -0.5
