"""Attention mixers: GQA/MQA with RoPE + qk-norm, chunked-causal softmax,
sliding windows, cross attention, KV-cache decode, and DeepSeek-style MLA.

Prefill/train uses an online-softmax double chunk scan (flash-attention
structure in pure jnp): peak memory is O(chunk^2) per head instead of
O(S^2); causally dead (q-chunk, kv-chunk) pairs are still computed and
masked (the TPU answer is the Pallas flash kernel; this is the portable
oracle the dry-run compiles).

Decode consumes a (B, S_max, KV, hd) cache and computes one step. MLA
decode uses the absorbed form: scores through the compressed c_kv cache
directly, so per-token cache is kv_lora + rope_hd floats regardless of the
number of heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear, init_rms, rms_norm, rope, truncnorm

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_attn(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": truncnorm(ks[0], (d, h, hd), cfg.param_dtype, d ** -0.5),
        "wk": truncnorm(ks[1], (d, kv, hd), cfg.param_dtype, d ** -0.5),
        "wv": truncnorm(ks[2], (d, kv, hd), cfg.param_dtype, d ** -0.5),
        "wo": truncnorm(ks[3], (h, hd, d), cfg.param_dtype, (h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_rms(hd, cfg.param_dtype)
        p["knorm"] = init_rms(hd, cfg.param_dtype)
    return p


def _qkv(p, cfg, x, positions, rope_on=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(p["qnorm"], q, cfg.norm_eps)
        k = rms_norm(p["knorm"], k, cfg.norm_eps)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(q, k, v, q_pos, kv_pos, chunk, causal, window=0):
    """Online-softmax over kv chunks, scanned over q chunks.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); positions give the mask.
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh

    def pick(s, c):
        """Largest divisor of s that is <= c (falls back to s itself)."""
        c = min(c, s)
        while s % c:
            c -= 1
        return c

    cq = pick(sq, chunk)
    ck = pick(skv, chunk)
    nq, nk = sq // cq, skv // ck
    scale = hd ** -0.5

    qc = q.reshape(b, nq, cq, kvh, rep, hd)
    qp = q_pos.reshape(nq, cq)
    kc = k.reshape(b, nk, ck, kvh, hd)
    vc = v.reshape(b, nk, ck, kvh, hd)
    kp = kv_pos.reshape(nk, ck)

    def q_step(_, qi):
        qblk, qpos = qi                                    # (b,cq,kvh,rep,hd), (cq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqgrh,bkgh->bgrqk", qblk, kblk) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s.astype(jnp.float32), NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))              # (b,g,r,q)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kvh, rep, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, rep, cq), jnp.float32),
            jnp.zeros((b, kvh, rep, cq, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kp),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,g,r,cq,hd)
        return None, out.transpose(0, 3, 1, 2, 4)          # (b,cq,g,r,hd)

    _, out = jax.lax.scan(q_step, None, (qc.transpose(1, 0, 2, 3, 4, 5), qp))
    # out: (nq, b, cq, kvh, rep, hd) -> (b, sq, h, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention(p, cfg, x, positions, causal=True, kv=None, kv_pos=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv: optional (B, S_enc, D) encoder memory for cross attention.
    """
    if kv is None:
        q, k, v = _qkv(p, cfg, x, positions)
        kv_pos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", kv, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv, p["wv"].astype(x.dtype))
        if cfg.qk_norm:
            q = rms_norm(p["qnorm"], q, cfg.norm_eps)
            k = rms_norm(p["knorm"], k, cfg.norm_eps)
    out = _chunked_attention(
        q, k, v, positions, kv_pos, cfg.attn_chunk, causal, cfg.window
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# --- decode ------------------------------------------------------------------

def init_kv_cache(cfg, batch, seq_len, dtype):
    kv, hd = cfg.n_kv_heads, cfg.hd
    z = jnp.zeros
    return {
        "k": z((batch, seq_len, kv, hd), dtype),
        "v": z((batch, seq_len, kv, hd), dtype),
    }


def decode_attention(p, cfg, x, cache, pos):
    """One-token decode. x: (B, 1, D); pos: () current index. Updates cache."""
    q, k, v = _qkv(p, cfg, x, pos[None][None, :])          # (B,1,H,hd)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1),
    }
    b, s, kvh, hd = cache["k"].shape
    rep = cfg.n_heads // kvh
    qg = q.reshape(b, kvh, rep, hd)
    s_ = jnp.einsum("bgrh,bkgh->bgrk", qg, cache["k"]) * hd ** -0.5
    kv_pos = jnp.arange(s)
    mask = kv_pos <= pos
    if cfg.window:
        mask &= kv_pos > pos - cfg.window
    s_ = jnp.where(mask[None, None, None], s_.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bgrk,bkgh->bgrh", w.astype(cache["v"].dtype), cache["v"])
    o = o.reshape(b, 1, cfg.n_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV + decoupled RoPE head.
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(key, 8)
    qd = m.nope_head_dim + m.rope_head_dim
    p = {
        # q path (low rank)
        "wq_a": truncnorm(ks[0], (d, m.q_lora), cfg.param_dtype, d ** -0.5),
        "q_norm": init_rms(m.q_lora, cfg.param_dtype),
        "wq_b": truncnorm(ks[1], (m.q_lora, h, qd), cfg.param_dtype, m.q_lora ** -0.5),
        # kv path: compressed c_kv plus shared rope key
        "wkv_a": truncnorm(ks[2], (d, m.kv_lora + m.rope_head_dim), cfg.param_dtype, d ** -0.5),
        "kv_norm": init_rms(m.kv_lora, cfg.param_dtype),
        "wk_b": truncnorm(ks[3], (m.kv_lora, h, m.nope_head_dim), cfg.param_dtype, m.kv_lora ** -0.5),
        "wv_b": truncnorm(ks[4], (m.kv_lora, h, m.v_head_dim), cfg.param_dtype, m.kv_lora ** -0.5),
        "wo": truncnorm(ks[5], (h, m.v_head_dim, d), cfg.param_dtype, (h * m.v_head_dim) ** -0.5),
    }
    return p


def _mla_qc(p, cfg, x, positions):
    m = cfg.mla
    ql = rms_norm(p["q_norm"], x @ p["wq_a"].astype(x.dtype), cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", ql, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora], axis=-1)
    c_kv = rms_norm(p["kv_norm"], c_kv, cfg.norm_eps)
    # headless shared rope key: add/strip a singleton head axis for rope()
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, cfg, x, positions):
    """Prefill/train MLA: decompress per head, chunked softmax."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    h = cfg.n_heads
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (*k_rope.shape[:2], h, m.rope_head_dim)
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # pad v to match hd for the shared chunked kernel, slice after
    out = _chunked_attention(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                               (0, q.shape[-1] - v.shape[-1]))),
                             positions, positions, cfg.attn_chunk, True, cfg.window)
    out = out[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def init_mla_cache(cfg, batch, seq_len, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq_len, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, seq_len, m.rope_head_dim), dtype),
    }


def decode_mla(p, cfg, x, cache, pos):
    """Absorbed-form MLA decode: scores/values through c_kv directly."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, pos[None][None, :])
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, pos, 1),
    }
    # absorb W_uk into q: (B,1,H,nope) x (kv_lora,H,nope) -> (B,H,kv_lora)
    q_abs = jnp.einsum("bshk,lhk->bhl", q_nope, p["wk_b"].astype(x.dtype))
    s_c = jnp.einsum("bhl,bsl->bhs", q_abs, cache["c_kv"])
    s_r = jnp.einsum("bshk,btk->bht", q_rope, cache["k_rope"])
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = (s_c + s_r) * scale
    kv_pos = jnp.arange(cache["c_kv"].shape[1])
    s = jnp.where(kv_pos[None, None] <= pos, s.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", w.astype(x.dtype), cache["c_kv"])
    o = jnp.einsum("bhl,lhk->bhk", ctx, p["wv_b"].astype(x.dtype))
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x.dtype))
    return out[:, None, :], cache
