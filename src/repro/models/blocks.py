"""Transformer blocks assembled from a (mixer, ffn) pattern slot.

A block is pre-norm residual: x += mixer(norm(x)); x += ffn(norm(x)).
Slot kinds:  mixer in {"attn", "mla", "mamba"};  ffn in {"dense", "moe",
"moe+shared", "none"}.  The same block code serves train/prefill (full
sequence) and decode (one token + per-block cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding
from .attention import (
    attention,
    decode_attention,
    decode_mla,
    init_attn,
    init_kv_cache,
    init_mla,
    init_mla_cache,
    mla_attention,
)
from .layers import init_mlp, init_rms, mlp, rms_norm
from .mamba2 import decode_mamba, init_mamba, init_mamba_cache, mamba_mixer
from .moe import init_moe, moe_decode, moe_train


def mixer_kind(cfg, slot: str) -> str:
    if slot == "attn" and cfg.use_mla:
        return "mla"
    return slot


def init_block(key, cfg, slot: str, ffn: str):
    ks = jax.random.split(key, 4)
    kind = mixer_kind(cfg, slot)
    p = {"norm1": init_rms(cfg.d_model, cfg.param_dtype)}
    if kind == "attn":
        p["mixer"] = init_attn(ks[0], cfg)
    elif kind == "mla":
        p["mixer"] = init_mla(ks[0], cfg)
    elif kind == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg)
    else:
        raise ValueError(kind)
    if ffn != "none":
        p["norm2"] = init_rms(cfg.d_model, cfg.param_dtype)
    if ffn == "dense":
        p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    elif ffn == "moe":
        p["ffn"] = init_moe(ks[1], cfg)
        if cfg.moe.n_shared:
            p["shared"] = init_mlp(
                ks[2], cfg.d_model, cfg.moe.n_shared * cfg.moe.d_ff,
                cfg.param_dtype,
            )
    return p


def _apply_ffn(p, cfg, x, ffn, decode=False):
    if ffn == "none":
        return x
    h = rms_norm(p["norm2"], x, cfg.norm_eps)
    if ffn == "dense":
        out = mlp(p["ffn"], h, cfg.act)
    else:
        out = moe_decode(p["ffn"], cfg, h, cfg.act) if decode else \
              moe_train(p["ffn"], cfg, h, cfg.act)
        if "shared" in p:
            out = out + mlp(p["shared"], h, cfg.act)
    return x + out


def block_apply(p, cfg, x, positions, slot: str, ffn: str):
    """Full-sequence block (train/prefill/encoder-with-causal=False later)."""
    kind = mixer_kind(cfg, slot)
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        mx = attention(p["mixer"], cfg, h, positions, causal=True)
    elif kind == "mla":
        mx = mla_attention(p["mixer"], cfg, h, positions)
    else:
        mx = mamba_mixer(p["mixer"], cfg, h)
    x = x + mx
    x = _apply_ffn(p, cfg, x, ffn)
    return sharding.constrain(x, "batch", "seq", None)


def init_block_cache(cfg, slot: str, batch, seq_len, dtype):
    kind = mixer_kind(cfg, slot)
    if kind == "attn":
        return init_kv_cache(cfg, batch, seq_len, dtype)
    if kind == "mla":
        return init_mla_cache(cfg, batch, seq_len, dtype)
    return init_mamba_cache(cfg, batch, dtype)


def block_decode(p, cfg, x, cache, pos, slot: str, ffn: str):
    """One-token block step; returns (x, new_cache)."""
    kind = mixer_kind(cfg, slot)
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        mx, cache = decode_attention(p["mixer"], cfg, h, cache, pos)
    elif kind == "mla":
        mx, cache = decode_mla(p["mixer"], cfg, h, cache, pos)
    else:
        mx, cache = decode_mamba(p["mixer"], cfg, h, cache, pos)
    x = x + mx
    x = _apply_ffn(p, cfg, x, ffn, decode=True)
    return x, cache
