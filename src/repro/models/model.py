"""Top-level model API: build, init, steps, input specs, sharding specs.

This is the single entry point used by smoke tests, the launcher, and the
multi-pod dry-run:

    cfg     = configs.registry.get("yi-34b")
    params  = jax.eval_shape(lambda k: init(cfg, k), key)   # no allocation
    specs   = shardings(cfg, cell)                          # PartitionSpec trees
    step    = make_train_step(cfg, opt_cfg)                 # jit-able fn
    inputs  = input_specs(cfg, cell)                        # ShapeDtypeStructs

Shape cells (the assignment's 4 input shapes): ``train_4k`` lowers
train_step; ``prefill_32k`` lowers the prefill serve step;
``decode_32k``/``long_500k`` lower one-token serve_step against a KV/SSM
cache of the given length.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh
from ..optim import OptConfig, apply_updates, init_opt_state
from . import encdec as ed
from . import lm, sharding
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if the (arch, cell) pair runs; otherwise the skip reason."""
    if cell.name == "long_500k":
        has_ssm = "mamba" in cfg.pattern
        if not has_ssm and not cfg.window:
            return ("long_500k needs sub-quadratic attention; "
                    f"{cfg.name} is pure full attention (skip per spec)")
    return None


# ---------------------------------------------------------------------------
# init / steps
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key):
    if cfg.kind == "encdec":
        return ed.init_encdec(key, cfg)
    return lm.init_lm(key, cfg)


def _frames_len(cfg, seq_len):
    # audio stub: encoder frames take half the cell's token budget
    return max(seq_len // 2, 8)


def _text_len(cfg, seq_len):
    if cfg.kind == "encdec":
        return max(seq_len - _frames_len(cfg, seq_len), 8)
    if cfg.n_patches:
        return max(seq_len - cfg.n_patches, 8)
    return seq_len


def loss_fn(params, cfg, batch):
    if cfg.kind == "encdec":
        return ed.encdec_loss(params, cfg, batch["frames"], batch["tokens"],
                              batch["targets"])
    extra = batch.get("patches")
    return lm.lm_loss(params, cfg, batch["tokens"], batch["targets"],
                      mask=batch.get("mask"), extra_embeds=extra)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        if cfg.kind == "encdec":
            memory = ed.encode(params, cfg, batch["frames"])
            h = ed.decode_train(params, cfg, batch["tokens"], memory)
            logits = jnp.einsum("bd,vd->bv", h[:, -1],
                                params["head"]["e"].astype(h.dtype))
        else:
            h = lm.forward(params, cfg, batch["tokens"],
                           extra_embeds=batch.get("patches"))
            head = params.get("head", params["embed"])
            logits = jnp.einsum("bd,vd->bv", h[:, -1], head["e"].astype(h.dtype))
        return sharding.constrain(logits, "batch", "vocab")
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, token, pos):
        if cfg.kind == "encdec":
            return ed.encdec_decode_step(params, cfg, caches, token, pos)
        return lm.decode_step(params, cfg, caches, token, pos)
    return decode_step


def init_cache(cfg: ModelConfig, params, batch, seq_len, frames=None):
    if cfg.kind == "encdec":
        return ed.init_encdec_cache(params, cfg, frames, batch, seq_len)
    return lm.init_cache(cfg, batch, seq_len)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — dry-run currency)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Abstract inputs for the cell. For decode cells this includes the
    cache tree (built with eval_shape; zero allocation)."""
    b = cell.global_batch
    sds = jax.ShapeDtypeStruct
    tl = _text_len(cfg, cell.seq_len)
    if cell.kind in ("train", "prefill"):
        batch = {
            "tokens": sds((b, tl), jnp.int32),
        }
        if cell.kind == "train":
            batch["targets"] = sds((b, tl), jnp.int32)
        if cfg.kind == "encdec":
            batch["frames"] = sds((b, _frames_len(cfg, cell.seq_len), cfg.d_model),
                                  cfg.dtype)
        if cfg.n_patches:
            batch["patches"] = sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
        return batch
    # decode: cache of seq_len, one new token at position seq_len - 1
    def build(key):
        params = init(cfg, key)
        frames = (jnp.zeros((b, _frames_len(cfg, cell.seq_len), cfg.d_model),
                            cfg.dtype) if cfg.kind == "encdec" else None)
        return init_cache(cfg, params, b, cell.seq_len, frames=frames)

    caches = jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return {
        "caches": caches,
        "token": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

BATCH = ("pod", "data")         # logical batch binding (multi-pod aware)
FSDP = "data"
MODEL = "model"


def production_rules(multi_pod: bool, fsdp_mode: str = "full"):
    batch = BATCH if multi_pod else ("data",)
    rules = {
        "batch": batch,
        "seq": MODEL,
        "kv_seq": MODEL,
        "heads": MODEL,
        "kv_heads": MODEL,
        "ffn": MODEL,
        "experts": MODEL,
        "vocab": MODEL,
        "fsdp": FSDP,
        None: None,
    }
    if fsdp_mode == "fsdp_only":
        # No tensor parallelism on heads/ffn — the model axis only carries
        # sequence parallelism and the vocab shard. Weight storage spreads
        # over the whole mesh (see _leaf_spec).
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["ffn"] = None
    elif fsdp_mode == "dp_full":
        # Pure data parallelism over the intra-pod mesh: batch is sharded
        # across data x model (1 sequence/chip at global_batch=256), the
        # residual is never resharded, and the only per-layer collective
        # is the FSDP weight gather. Wins whenever
        #   ~3 * layer_param_bytes  <  ~12 * B_local * S * D bytes,
        # i.e. exactly the train_4k cells where SP/TP was collective-bound.
        # Multi-pod: the pod axis carries sequence parallelism (256
        # sequences don't split 512 ways), so cross-pod traffic is one
        # cheap residual gather per layer instead of weight gathers.
        rules["batch"] = ("data", "model")
        rules["seq"] = "pod" if multi_pod else None
        rules["kv_seq"] = None
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["ffn"] = None
        rules["vocab"] = None
    return rules


def _axis_sizes() -> dict:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return {"pod": 2, "data": 16, "model": 16}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _entry_size(entry, sizes) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for name in names:
        n *= sizes.get(name, 1)
    return n


def sanitize(spec: P, shape, sizes=None) -> P:
    """Drop spec entries whose mesh-axis size does not divide the dim.

    jit/shard_map argument shardings require exact divisibility (unlike
    intermediate constraints, which GSPMD pads); odd vocabs (92553), small
    KV-head counts (1, 2, 8) and batch=1 cells all hit this.
    """
    sizes = sizes or _axis_sizes()
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _entry_size(entry, sizes) == 0 else None)
    return P(*out)


def _leaf_spec(path: str, ndim: int, shape=None) -> P:
    """Pattern-matched PartitionSpec for one (unstacked) parameter."""
    sizes = _axis_sizes()
    last = path.rsplit("/", 1)[-1]
    in_ffn = "/ffn/" in path or path.endswith("ffn") or "/shared/" in path
    if last == "e":                     # embed / head tables (V, D)
        return P(MODEL, FSDP)
    if last in ("wq", "wk", "wv"):      # (D, H, hd)
        if shape is not None and shape[1] % _entry_size(MODEL, sizes) != 0:
            # few KV heads (GQA/MQA): shard head_dim over model instead
            return P(FSDP, None, MODEL)
        return P(FSDP, MODEL, None)
    if last == "wo" and not in_ffn:     # attention out (H, hd, D)
        return P(MODEL, None, FSDP)
    if last == "wi":
        if ndim == 4:                   # moe (E, D, 2, F)
            return P(MODEL, FSDP, None, None)
        return P(FSDP, None, MODEL)     # dense (D, 2, F)
    if last == "wo" and in_ffn:
        if ndim == 3:                   # moe (E, F, D)
            return P(MODEL, None, FSDP)
        return P(MODEL, FSDP)           # dense (F, D)
    if last == "router":
        return P(FSDP, None)
    if last == "in_proj":               # mamba (D, X)
        return P(FSDP, MODEL)
    if last == "conv_w":
        return P(None, MODEL)
    if last in ("conv_b",):
        return P(MODEL)
    if last in ("a_log", "d_skip", "dt_bias"):
        return P(MODEL)
    if last == "out_proj":              # mamba (d_inner, D)
        return P(MODEL, FSDP)
    if last == "wq_a" or last == "wkv_a":   # mla (D, r)
        return P(FSDP, None)
    if last in ("wq_b", "wk_b", "wv_b"):    # mla (r, H, hd)
        return P(None, MODEL, None)
    if last == "scale":
        if "out_norm" in path:          # mamba gated norm over d_inner
            return P(MODEL)
        return P(None)
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_shape):
    """PartitionSpec tree matching the (abstract) parameter tree."""
    stacked_prefixes = ("slots", "enc", "dec")

    sizes = _axis_sizes()

    def strip_fsdp(spec: P) -> P:
        out = []
        for e in spec:
            if e == FSDP:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != FSDP)
                out.append(kept if kept else None)
            else:
                out.append(e)
        return P(*out)

    def fsdp_only_spec(shape) -> P:
        """Spread weight storage over the flattened mesh: the largest dim
        divisible by |model|x|data| gets both axes (fallback: |data|)."""
        both = _entry_size((MODEL, FSDP), sizes)
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % both == 0:
                return P(*[(MODEL, FSDP) if j == i else None
                           for j in range(len(shape))])
        for i in order:
            if shape[i] % _entry_size(FSDP, sizes) == 0:
                return P(*[FSDP if j == i else None for j in range(len(shape))])
        return P(*([None] * len(shape)))

    def one(path, leaf):
        s = _path_str(path)
        stacked = s.split("/", 1)[0] in stacked_prefixes
        shape = leaf.shape[1:] if stacked else leaf.shape
        # MoE expert weights keep expert-parallel sharding in every mode:
        # the a2a dispatch needs E on the model axis; re-sharding them to
        # the generic fsdp layout costs full expert-weight reshards/layer.
        is_expert = ("/ffn/" in s or s.endswith("router")) and len(shape) >= 3
        if (cfg.fsdp_mode in ("fsdp_only", "dp_full") and len(shape) >= 2
                and not is_expert):
            base = fsdp_only_spec(shape)
        else:
            base = _leaf_spec(s, len(shape), shape)
            if cfg.fsdp_mode in ("zero1", "none"):
                base = strip_fsdp(base)
        base = sanitize(base, shape, sizes)
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(cfg: ModelConfig, pspecs, pshape=None):
    from ..optim.adamw import OptState
    mspecs = pspecs
    if cfg.fsdp_mode == "zero1" and pshape is not None:
        sizes = _axis_sizes()

        def shard_first_free(spec, leaf):
            entries = list(spec) + [None] * (leaf.ndim - len(spec))
            for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
                if e is None and dim % sizes.get(FSDP, 1) == 0:
                    entries[i] = FSDP
                    break
            return P(*entries)

        mspecs = jax.tree.map(
            shard_first_free, pspecs, pshape,
            is_leaf=lambda x: isinstance(x, P))
    return OptState(step=P(), mu=mspecs, nu=mspecs, err=None)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, multi_pod: bool):
    batch = production_rules(multi_pod, cfg.fsdp_mode)["batch"]
    sizes = _axis_sizes()
    inputs = input_specs(cfg, cell)
    seq_ax = production_rules(multi_pod, cfg.fsdp_mode)["seq"]
    if cell.kind in ("train", "prefill"):
        specs = {"tokens": P(batch, None)}
        if cell.kind == "train":
            specs["targets"] = P(batch, None)
        if cfg.kind == "encdec":
            specs["frames"] = P(batch, seq_ax, None)
        if cfg.n_patches:
            specs["patches"] = P(batch, seq_ax, None)
        return {k: sanitize(v, inputs[k].shape, sizes) for k, v in specs.items()}
    cspecs = cache_specs(cfg, cell, inputs["caches"], multi_pod)
    return {
        "caches": cspecs,
        "token": sanitize(P(batch, None), inputs["token"].shape, sizes),
        "pos": P(),
    }


def cache_specs(cfg: ModelConfig, cell: ShapeCell, caches_shape, multi_pod: bool,
                model_size: int = 16):
    """Decode-cache PartitionSpecs.

    Batch shards over the data axes when divisible, otherwise the cache
    sequence dim shards there (long_500k, B=1). KV heads shard over model
    when there are enough of them; otherwise (MQA, MLA's headless c_kv) the
    cache sequence dim takes the model axis — flash-decoding style, GSPMD
    psums the partial softmax.
    """
    batch_axes = BATCH if multi_pod else ("data",)
    b = cell.global_batch
    batch_ok = b >= (32 if multi_pod else 16)
    b_ax = batch_axes if batch_ok else None
    kv_ok = cfg.n_kv_heads >= model_size

    def seq_ax(take_model: bool):
        """Axes assigned to the cache sequence dim."""
        axes = () if batch_ok else tuple(
            a for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,))
        )
        if take_model:
            axes = axes + (MODEL,)
        return axes if axes else None

    def one(path, leaf):
        s = _path_str(path)
        nd = leaf.ndim
        last = s.rsplit("/", 1)[-1]
        lead = (None,) if s.split("/", 1)[0] in ("slots", "self", "cross") else ()
        if last in ("k", "v"):          # [lead] (B, S, KV, hd)
            spec = lead + (b_ax, seq_ax(not kv_ok), MODEL if kv_ok else None, None)
        elif last in ("c_kv", "k_rope"):  # [lead] (B, S, r) — headless: seq->model
            spec = lead + (b_ax, seq_ax(True), None)
        elif last == "conv":            # [lead] (B, K, C)
            spec = lead + (b_ax, None, MODEL)
        elif last == "ssm":             # [lead] (B, H, hd, N)
            spec = lead + (b_ax, MODEL, None, None)
        else:
            spec = (None,) * nd
        if len(spec) != nd:
            spec = (None,) * nd
        return sanitize(P(*spec), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, caches_shape)


def shardings(cfg: ModelConfig, cell: ShapeCell, multi_pod: bool,
              opt: bool = True):
    """(param_specs, opt_specs, batch_specs) for a cell."""
    pshape = jax.eval_shape(
        lambda k: init(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    ps = param_specs(cfg, pshape)
    os_ = opt_specs(cfg, ps, pshape) if (opt and cell.kind == "train") else None
    bs = batch_specs(cfg, cell, multi_pod)
    return ps, os_, bs
