"""Model configuration schema for the architecture zoo.

One frozen dataclass describes every assigned architecture: dense/GQA/MQA
attention, MLA, Mamba2 SSD blocks, MoE FFNs (with the paper's SCD router as
an option), encoder-decoder, and modality-frontend stubs. Layer stacking is
expressed as a repeating *pattern* of (mixer, ffn) slots so hybrids like
Jamba scan over whole periods.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 0          # routed experts
    n_shared: int = 0           # always-on shared experts
    topk: int = 2
    d_ff: int = 0               # per-expert hidden
    router: str = "topk"        # "topk" | "scd" (the paper's solver)
    capacity_factor: float = 1.25
    scd_iters: int = 4


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str = "lm"            # "lm" | "encdec"
    modality: str = "text"      # "text" | "audio" | "vision" (frontend stub)
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"           # "silu" (SwiGLU) | "gelu" (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # Layer pattern (repeated): mixers and ffns per slot.
    # mixer in {"attn", "mamba"}; ffn in {"dense", "moe", "none"}.
    pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)
    # Layer 0 override: dense FFN of this width instead of slot ffn
    # (DeepSeek-V2's first dense layer). 0 = no override.
    first_dense_ff: int = 0

    use_mla: bool = False
    mla: MLACfg = MLACfg()
    moe: MoECfg = MoECfg()
    mamba: MambaCfg = MambaCfg()

    # Encoder (kind == "encdec"): encoder layer count; frontend stub length
    # is supplied by the input spec, not the config.
    n_enc_layers: int = 0

    # Vision stub: number of patch embeddings prepended to the text tokens.
    n_patches: int = 0

    # Sliding-window attention (0 = full causal). Needed for long-context
    # cells on hybrid archs.
    window: int = 0

    # Parameter-sharding strategy (the §Perf hillclimb lever):
    #   "full"  — FSDP: weights sharded over data+model, gathered per layer
    #             (baseline; required when TP-only shards exceed HBM)
    #   "zero1" — weights TP-only (model axis); optimizer state sharded
    #             over data (GSPMD then emits reduce-scatter grads +
    #             one all-gather of updated params — classic ZeRO-1)
    #   "none"  — weights TP-only, optimizer unsharded (serving)
    fsdp_mode: str = "full"

    # Numerics / compilation.
    dtype: jnp.dtype = jnp.bfloat16          # activations / compute
    param_dtype: jnp.dtype = jnp.bfloat16    # stored parameters
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024                   # q/kv chunking for long seq
    loss_chunk: int = 1024                   # vocab-proj chunking in the loss

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = len(self.pattern)
        moe = dataclasses.replace(
            self.moe,
            n_experts=min(self.moe.n_experts, 8),
            topk=min(self.moe.topk, 2),
            d_ff=min(self.moe.d_ff, 128) if self.moe.d_ff else 0,
        )
        mla = dataclasses.replace(
            self.mla, kv_lora=64, q_lora=64, rope_head_dim=16,
            nope_head_dim=32, v_head_dim=32,
        )
        mamba = dataclasses.replace(self.mamba, d_state=16, head_dim=16, chunk=32)
        return dataclasses.replace(
            self,
            n_layers=period * 2 if period > 1 else 2,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=32 if self.head_dim else 0,
            d_ff=256,
            first_dense_ff=192 if self.first_dense_ff else 0,
            vocab=512,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_patches=16 if self.n_patches else 0,
            moe=moe,
            mla=mla,
            mamba=mamba,
            dtype=jnp.float32,
            param_dtype=jnp.float32,
            attn_chunk=64,
            loss_chunk=128,
            window=min(self.window, 64) if self.window else 0,
            scan_layers=True,
        )
