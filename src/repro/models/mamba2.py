"""Mamba2 SSD (state-space duality) mixer: chunked train scan + O(1) decode.

Train/prefill uses the SSD chunked algorithm (arXiv 2405.21060): within a
chunk the output is an attention-like quadratic form masked by the decay
kernel; across chunks a small (H, hd, N) state is carried by a linear scan.
Decode keeps (conv window, ssm state) per layer and costs O(H * hd * N) per
token — this is what makes the ``long_500k`` cell tractable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_rms, rms_norm, truncnorm


def _dims(cfg):
    m = cfg.mamba
    d_in = m.d_inner(cfg.d_model)
    nh = m.n_heads(cfg.d_model)
    return m, d_in, nh


def init_mamba(key, cfg):
    m, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * m.n_groups * m.d_state
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    return {
        # order: [z (d_in), xBC (conv_dim), dt (nh)]
        "in_proj": truncnorm(ks[0], (d, 2 * d_in + 2 * m.n_groups * m.d_state + nh),
                             cfg.param_dtype, d ** -0.5),
        "conv_w": truncnorm(ks[1], (m.d_conv, conv_dim), cfg.param_dtype, 0.2),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.param_dtype),
        "d_skip": jnp.ones((nh,), cfg.param_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.param_dtype),
        "out_norm": init_rms(d_in, cfg.param_dtype),
        "out_proj": truncnorm(ks[2], (d_in, d), cfg.param_dtype, d_in ** -0.5),
    }


def _split_proj(p, cfg, x):
    m, d_in, nh = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * m.n_groups * m.d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xbc, dt                                       # dt: (B,S,H) f32


def _causal_conv(p, xbc):
    """Depthwise causal conv via shifted adds (kernel K is tiny)."""
    kw = p["conv_w"].astype(xbc.dtype)                      # (K, C)
    k = kw.shape[0]
    out = jnp.zeros_like(xbc)
    for i in range(k):
        shift = k - 1 - i
        shifted = jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * kw[i]
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _ssd_chunked(xh, dt, a_log, b_, c_, chunk):
    """SSD scan. xh: (B,S,H,P); dt: (B,S,H); b_, c_: (B,S,G,N).

    Returns y: (B,S,H,P). G divides H (head groups share B/C).
    """
    bsz, s, h, p_ = xh.shape
    g, n = b_.shape[2], b_.shape[3]
    rep = h // g
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,) negative
    dta = dt * a[None, None, :]                             # (B,S,H)

    xc = (xh * dt[..., None].astype(xh.dtype)).reshape(bsz, nc, q, h, p_)
    bc = b_.reshape(bsz, nc, q, g, n)
    cc = c_.reshape(bsz, nc, q, g, n)
    dtac = dta.reshape(bsz, nc, q, h)
    seg = jnp.cumsum(dtac, axis=2)                          # within-chunk cumsum

    # Intra-chunk (quadratic, causal, decay-masked):
    # L[i,j] = exp(seg_i - seg_j) for i >= j. Mask BEFORE exp: the upper
    # triangle has positive exponents whose inf would poison the where-grad.
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # (B,nc,q,q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    li = jnp.where(causal[None, None, :, :, None], li, -jnp.inf)
    l_mask = jnp.exp(li)
    cb = jnp.einsum("bcqgn,bckgn->bcqkg", cc, bc)           # (B,nc,q,q,G)
    cb = jnp.repeat(cb, rep, axis=-1)                       # (B,nc,q,q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp",
                         (cb * l_mask).astype(xh.dtype), xc)

    # Chunk state: S_c = sum_j exp(seg_end - seg_j) B_j x_j^T
    decay_b = jnp.exp(seg[:, :, -1:, :] - seg)              # (B,nc,q,H)
    bh = jnp.repeat(bc, rep, axis=3)                        # (B,nc,q,H,N)
    bx = jnp.einsum("bcqhn,bcqhp->bchpn",
                    bh, xc * decay_b[..., None].astype(xh.dtype))

    chunk_decay = jnp.exp(seg[:, :, -1, :])                 # (B,nc,H)

    def scan_state(h_prev, inp):
        bx_c, dec_c = inp                                   # (B,H,P,N), (B,H)
        h_new = h_prev * dec_c[:, :, None, None] + bx_c
        return h_new, h_prev

    init = jnp.zeros((bsz, h, p_, n), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_state, init,
        (bx.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)),
    )                                                       # (nc,B,H,P,N) states BEFORE each chunk
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)

    # Inter-chunk: y_j += C_j exp(seg_j) h_prev
    ch = jnp.repeat(cc, rep, axis=3)                        # (B,nc,q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         ch * jnp.exp(seg)[..., None].astype(ch.dtype),
                         h_prevs.astype(ch.dtype))
    y = (y_intra + y_inter).reshape(bsz, s, h, p_)
    return y


def mamba_mixer(p, cfg, x, positions=None):
    """Full-sequence SSD mixer. x: (B,S,D) -> (B,S,D)."""
    m, d_in, nh = _dims(cfg)
    z, xbc, dt = _split_proj(p, cfg, x)
    xbc = _causal_conv(p, xbc)
    xh, b_, c_ = jnp.split(xbc, [d_in, d_in + m.n_groups * m.d_state], -1)
    bsz, s = x.shape[:2]
    xh = xh.reshape(bsz, s, nh, m.head_dim)
    b_ = b_.reshape(bsz, s, m.n_groups, m.d_state)
    c_ = c_.reshape(bsz, s, m.n_groups, m.d_state)
    y = _ssd_chunked(xh, dt, p["a_log"], b_, c_, m.chunk)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype)


# --- decode ------------------------------------------------------------------

def init_mamba_cache(cfg, batch, dtype):
    m, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * m.n_groups * m.d_state
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, m.head_dim, m.d_state), jnp.float32),
    }


def decode_mamba(p, cfg, x, cache, pos):
    """One-token SSD step. x: (B,1,D)."""
    m, d_in, nh = _dims(cfg)
    z, xbc, dt = _split_proj(p, cfg, x)                     # (B,1,*)
    xbc = xbc[:, 0]
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    kw = p["conv_w"].astype(xbc.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, kw) + p["conv_b"].astype(xbc.dtype)
    conv_out = jax.nn.silu(conv_out)
    xh, b_, c_ = jnp.split(conv_out, [d_in, d_in + m.n_groups * m.d_state], -1)
    xh = xh.reshape(-1, nh, m.head_dim)
    b_ = b_.reshape(-1, m.n_groups, m.d_state)
    c_ = c_.reshape(-1, m.n_groups, m.d_state)
    rep = nh // m.n_groups
    bh = jnp.repeat(b_, rep, axis=1).astype(jnp.float32)    # (B,H,N)
    ch = jnp.repeat(c_, rep, axis=1).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt0 = dt[:, 0]                                          # (B,H)
    decay = jnp.exp(dt0 * a[None])                          # (B,H)
    upd = jnp.einsum("bhp,bhn->bhpn", xh.astype(jnp.float32) * dt0[..., None], bh)
    ssm = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm, ch).astype(x.dtype)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, :, None]
    y = y.reshape(-1, 1, d_in)
    y = rms_norm(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_cache = {"conv": window[:, 1:], "ssm": ssm}
    return out, new_cache
