"""Encoder-decoder transformer (seamless-m4t family).

Encoder: bidirectional attention over stubbed modality frame embeddings.
Decoder: causal self-attention + cross-attention to the encoder memory.
Both stacks scan over layers like lm.py. Decode caches the self-attention
KV per layer; the cross KV is computed once from the encoder memory and is
static across steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding
from .attention import (
    attention,
    decode_attention,
    init_attn,
    init_kv_cache,
)
from .layers import embed, init_embed, init_mlp, init_rms, mlp, rms_norm, unembed

NEG_INF = -2.0 ** 30


def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_rms(cfg.d_model, cfg.param_dtype),
        "attn": init_attn(k1, cfg),
        "norm2": init_rms(cfg.d_model, cfg.param_dtype),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def _init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_rms(cfg.d_model, cfg.param_dtype),
        "self": init_attn(k1, cfg),
        "norm_x": init_rms(cfg.d_model, cfg.param_dtype),
        "cross": init_attn(k2, cfg),
        "norm2": init_rms(cfg.d_model, cfg.param_dtype),
        "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_encdec(key, cfg):
    ks = jax.random.split(key, 6)
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(jax.random.split(ks[1], ne)),
        "enc_norm": init_rms(cfg.d_model, cfg.param_dtype),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(jax.random.split(ks[2], nd)),
        "final_norm": init_rms(cfg.d_model, cfg.param_dtype),
        "head": init_embed(ks[3], cfg.vocab, cfg.d_model, cfg.param_dtype),
    }


def _enc_layer(p, cfg, x, positions):
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    x = x + attention(p["attn"], cfg, h, positions, causal=False)
    h = rms_norm(p["norm2"], x, cfg.norm_eps)
    x = x + mlp(p["ffn"], h, cfg.act)
    return sharding.constrain(x, "batch", "seq", None)


def _dec_layer(p, cfg, x, positions, memory, mem_pos):
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    x = x + attention(p["self"], cfg, h, positions, causal=True)
    h = rms_norm(p["norm_x"], x, cfg.norm_eps)
    x = x + attention(p["cross"], cfg, h, positions, causal=False,
                      kv=memory, kv_pos=mem_pos)
    h = rms_norm(p["norm2"], x, cfg.norm_eps)
    x = x + mlp(p["ffn"], h, cfg.act)
    return sharding.constrain(x, "batch", "seq", None)


def encode(params, cfg, frames):
    """frames: (B, F, D) stubbed modality embeddings -> encoder memory."""
    x = sharding.constrain(frames.astype(cfg.dtype), "batch", "seq", None)
    pos = jnp.arange(x.shape[1])

    def body(x, p):
        fn = jax.checkpoint(_enc_layer, static_argnums=(1,)) if cfg.remat else _enc_layer
        return fn(p, cfg, x, pos), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc"])
    else:
        for t in range(jax.tree.leaves(params["enc"])[0].shape[0]):
            x, _ = body(x, jax.tree.map(lambda a: a[t], params["enc"]))
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params, cfg, tokens, memory):
    """Teacher-forced decoder hidden states."""
    x = embed(params["embed"], tokens, cfg.dtype)
    x = sharding.constrain(x, "batch", "seq", None)
    pos = jnp.arange(x.shape[1])
    mem_pos = jnp.arange(memory.shape[1])

    def body(x, p):
        fn = jax.checkpoint(_dec_layer, static_argnums=(1,)) if cfg.remat else _dec_layer
        return fn(p, cfg, x, pos, memory, mem_pos), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec"])
    else:
        for t in range(jax.tree.leaves(params["dec"])[0].shape[0]):
            x, _ = body(x, jax.tree.map(lambda a: a[t], params["dec"]))
    return rms_norm(params["final_norm"], x, cfg.norm_eps)


def encdec_loss(params, cfg, frames, tokens, targets, mask=None):
    memory = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, memory)
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    nc = s // c
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, c).transpose(1, 0, 2)
    mm = (mask if mask is not None else jnp.ones_like(targets, jnp.float32))
    mm = mm.reshape(b, nc, c).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hh, tt, m_ = inp
        logits = unembed(params["head"], hh)
        logits = sharding.constrain(logits, "batch", None, "vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        return carry + ((lse - gold) * m_).sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, tc, mm))
    return total / jnp.maximum(mm.sum(), 1.0)


# --- decode ------------------------------------------------------------------

def init_encdec_cache(params, cfg, frames, batch, seq_len):
    """Returns (memory, cross-KV per layer, self caches per layer)."""
    memory = encode(params, cfg, frames)

    def cross_kv(p):
        k = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wv"].astype(memory.dtype))
        return {"k": k, "v": v}

    if cfg.scan_layers:
        cross = jax.vmap(cross_kv)(params["dec"]) if False else jax.lax.map(
            cross_kv, params["dec"])
    else:
        nl = jax.tree.leaves(params["dec"])[0].shape[0]
        cross = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[cross_kv(jax.tree.map(lambda a: a[t], params["dec"])) for t in range(nl)],
        )
    nl = jax.tree.leaves(params["dec"])[0].shape[0]
    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (nl, *a.shape)),
        init_kv_cache(cfg, batch, seq_len, cfg.dtype),
    )
    return {"cross": cross, "self": self_cache}


def encdec_decode_step(params, cfg, caches, token, pos):
    x = embed(params["embed"], token, cfg.dtype)

    def body(x, inp):
        p, sc, xc = inp
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        sa, sc = decode_attention(p["self"], cfg, h, sc, pos)
        x = x + sa
        h = rms_norm(p["norm_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(h.dtype))
        b, _, kvh, hd = xc["k"].shape
        rep = cfg.n_heads // kvh
        qg = q.reshape(b, kvh, rep, hd)
        s_ = jnp.einsum("bgrh,bkgh->bgrk", qg, xc["k"]) * hd ** -0.5
        w = jax.nn.softmax(s_.astype(jnp.float32), axis=-1)
        o = jnp.einsum("bgrk,bkgh->bgrh", w.astype(xc["v"].dtype), xc["v"])
        o = o.reshape(b, 1, cfg.n_heads, hd)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["cross"]["wo"].astype(h.dtype))
        h = rms_norm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg.act)
        return x, sc

    if cfg.scan_layers:
        x, new_self = jax.lax.scan(
            body, x, (params["dec"], caches["self"], caches["cross"])
        )
    else:
        nl = jax.tree.leaves(params["dec"])[0].shape[0]
        outs = []
        for t in range(nl):
            x, sc = body(x, (jax.tree.map(lambda a: a[t], params["dec"]),
                             jax.tree.map(lambda a: a[t], caches["self"]),
                             jax.tree.map(lambda a: a[t], caches["cross"])))
            outs.append(sc)
        new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["head"], x)
    return logits, {**caches, "self": new_self}
