"""Decoder-only LM: embed -> pattern-scanned blocks -> norm -> chunked loss.

Layer stacking: parameters for each pattern slot are stacked along a
leading period axis and the stack is traversed with ``jax.lax.scan`` (HLO
and compile time O(pattern), not O(n_layers)); ``cfg.scan_layers=False``
unrolls instead (used by the roofline probe to get exact HLO FLOP counts).

The LM head never materialises (B, S, V) logits: the loss scans over
sequence chunks, projecting to the (model-sharded) vocab one chunk at a
time — the standard memory fix at 150k+ vocabs.

Multimodal stubs per the assignment: "vision"/"audio" models take
precomputed patch/frame embeddings concatenated in front of the token
embeddings; loss is masked to text positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding
from .blocks import block_apply, block_decode, init_block, init_block_cache
from .layers import embed, init_embed, init_rms, rms_norm, unembed


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _slot_ffns(cfg):
    return tuple(cfg.ffn_pattern)


def init_lm(key, cfg):
    """Parameter tree. Block slot s params are stacked over periods."""
    ks = jax.random.split(key, 4 + len(cfg.pattern))
    params = {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": init_rms(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_embed(ks[1], cfg.vocab, cfg.d_model, cfg.param_dtype)
    if cfg.first_dense_ff:
        cfg0 = cfg.replace(d_ff=cfg.first_dense_ff)
        params["block0"] = init_block(ks[2], cfg0, cfg.pattern[0], "dense")
    n_periods = cfg.n_periods - (0 if not cfg.first_dense_ff else 0)

    def init_slot(slot_key, slot, ffn):
        def one(k):
            return init_block(k, cfg, slot, ffn)
        return jax.vmap(one)(jax.random.split(slot_key, n_periods))

    params["slots"] = [
        init_slot(ks[4 + i], slot, ffn)
        for i, (slot, ffn) in enumerate(zip(cfg.pattern, _slot_ffns(cfg)))
    ]
    return params


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def _stack_apply(params, cfg, x, positions, skip_first_of_slot0=False):
    """Scan the stacked periods; unrolled when cfg.scan_layers is False."""
    ffns = _slot_ffns(cfg)

    def period(x, slot_params):
        for i, (slot, ffn) in enumerate(zip(cfg.pattern, ffns)):
            p_i = slot_params[i]
            fn = block_apply
            if cfg.remat:
                fn = jax.checkpoint(block_apply, static_argnums=(1, 4, 5))
            x = fn(p_i, cfg, x, positions, slot, ffn)
        return x

    if cfg.scan_layers:
        def body(x, slot_params):
            return period(x, slot_params), None
        x, _ = jax.lax.scan(body, x, params["slots"])
    else:
        n_periods = jax.tree.leaves(params["slots"][0])[0].shape[0]
        for t in range(n_periods):
            slot_params = jax.tree.map(lambda a: a[t], params["slots"])
            x = period(x, slot_params)
    return x


def forward(params, cfg, tokens, extra_embeds=None):
    """Hidden states (B, S_total, D). extra_embeds: (B, P, D) modality stub
    prepended before the token embeddings."""
    x = embed(params["embed"], tokens, cfg.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    x = sharding.constrain(x, "batch", "seq", None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))[0]
    if params.get("block0") is not None:
        cfg0 = cfg.replace(d_ff=cfg.first_dense_ff or cfg.d_ff)
        fn = jax.checkpoint(block_apply, static_argnums=(1, 4, 5)) if cfg.remat else block_apply
        x = fn(params["block0"], cfg0, x, positions, cfg.pattern[0], "dense")
    x = _stack_apply(params, cfg, x, positions)
    return rms_norm(params["final_norm"], x, cfg.norm_eps)


def _head_params(params):
    return params.get("head", params["embed"])


def lm_loss(params, cfg, tokens, targets, mask=None, extra_embeds=None):
    """Mean CE, chunked over the sequence. targets: (B, S) int; mask (B, S)."""
    h = forward(params, cfg, tokens, extra_embeds)
    if extra_embeds is not None:
        h = h[:, extra_embeds.shape[1]:]                    # text positions only
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    nc = s // c
    assert s % c == 0
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, c).transpose(1, 0, 2)
    mc = (mask if mask is not None else jnp.ones_like(targets, jnp.float32))
    mc = mc.reshape(b, nc, c).transpose(1, 0, 2)
    head = _head_params(params)

    def chunk_loss(carry, inp):
        hh, tt, mm = inp
        logits = unembed(head, hh)                          # (B, c, V)
        logits = sharding.constrain(logits, "batch", None, "vocab")
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, tc, mc))
    denom = jnp.maximum(mc.sum(), 1.0)
    return total / denom


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, seq_len, dtype=None):
    """Per-layer caches, stacked per slot like the params."""
    dtype = dtype or cfg.dtype
    n_periods = cfg.n_periods

    def slot_cache(slot):
        one = init_block_cache(cfg, slot, batch, seq_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods, *a.shape)), one
        )

    caches = {"slots": [slot_cache(s) for s in cfg.pattern]}
    if cfg.first_dense_ff:
        caches["block0"] = init_block_cache(cfg, cfg.pattern[0], batch, seq_len, dtype)
    return caches


def decode_step(params, cfg, caches, token, pos):
    """One decode step. token: (B, 1) int32; pos: () int32 cache index.
    Returns (logits (B, 1, V), new caches)."""
    x = embed(params["embed"], token, cfg.dtype)
    x = sharding.constrain(x, "batch", None, None)
    ffns = _slot_ffns(cfg)
    if caches.get("block0") is not None:
        cfg0 = cfg.replace(d_ff=cfg.first_dense_ff or cfg.d_ff)
        x, c0 = block_decode(params["block0"], cfg0, x, caches["block0"], pos,
                             cfg.pattern[0], "dense")
        caches = {**caches, "block0": c0}

    def body(x, per_period):
        slot_params, slot_caches = per_period
        new_caches = []
        for i, (slot, ffn) in enumerate(zip(cfg.pattern, ffns)):
            x, nc = block_decode(slot_params[i], cfg, x, slot_caches[i], pos,
                                 slot, ffn)
            new_caches.append(nc)
        return x, new_caches

    if cfg.scan_layers:
        x, new_slot_caches = jax.lax.scan(
            body, x, (params["slots"], caches["slots"])
        )
    else:
        n_periods = jax.tree.leaves(params["slots"][0])[0].shape[0]
        new_list = []
        for t in range(n_periods):
            sp = jax.tree.map(lambda a: a[t], params["slots"])
            sc = jax.tree.map(lambda a: a[t], caches["slots"])
            x, nc = body(x, (sp, sc))
            new_list.append(nc)
        new_slot_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(_head_params(params), x)
    logits = sharding.constrain(logits, "batch", None, "vocab")
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, {**caches, "slots": new_slot_caches}
