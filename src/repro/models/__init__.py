from .config import MLACfg, MambaCfg, MoECfg, ModelConfig  # noqa: F401
