"""Shared helpers for the kernel wrappers."""
from __future__ import annotations

import jax.numpy as jnp


def pad_rows(x, pad, value=0.0):
    """Append ``pad`` constant rows on the user axis (no-op if pad == 0).

    Wrappers pad ragged shards up to a tile multiple; the pad values are
    chosen per kernel so padded rows are inert (see each caller).
    """
    if not pad:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)), constant_values=value)
