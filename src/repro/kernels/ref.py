"""Pure-jnp oracles for the Pallas kernels.

These are the semantics contracts: every kernel result is
assert_allclose'd against these across shape/dtype sweeps. They share the
tie-break convention (stable by item index) with core/greedy and
core/sparse_scd, and are themselves cross-checked against those modules in
the kernel tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adjusted_topc_ref(p, b, lam, q):
    """Fused DD/SCD map body, sparse GKP (one item per knapsack).

    p, b: (n, K); lam: (K,). Returns (x (n,K) bool, v (n,K) f32) where x is
    the top-q positive adjusted profits (ties -> smaller index) and
    v = b * x is the per-user consumption.
    """
    ap = p - lam[None, :] * b
    order = jnp.argsort(-ap, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    x = (ap > 0) & (ranks < q)
    return x, jnp.where(x, b, 0.0).astype(p.dtype)


def scd_candidates_ref(p, b, lam, q):
    """Algorithm 5 map: candidate (v1, v2) per (user, knapsack).

    Matches core.sparse_scd.candidates_sparse (invalid -> v1=-1, v2=0).
    """
    n, k = p.shape
    ap = jnp.maximum(p - lam[None, :] * b, 0.0)
    if q >= k:
        pbar = jnp.zeros_like(ap)
    else:
        top, _ = jax.lax.top_k(ap, q + 1)
        q_th = top[:, q - 1] if q >= 1 else jnp.full((n,), jnp.inf, ap.dtype)
        q1_th = top[:, q]
        in_top = ap >= q_th[:, None]
        pbar = jnp.where(in_top, q1_th[:, None], q_th[:, None])
    valid = (p > pbar) & (b > 0)
    v1 = jnp.where(valid, (p - pbar) / jnp.where(b > 0, b, 1.0), -1.0)
    v2 = jnp.where(valid, b, 0.0)
    return v1.astype(p.dtype), v2.astype(p.dtype)


def scd_fused_hist_ref(p, b, lam, edges, q, hist_init=None, top_init=None):
    """Fused SCD map+reduce oracle: the unfused two-stage composition.

    Returns (hist (K, E+1), top (K,)) where hist is
    ``bucket_hist_ref(*scd_candidates_ref(p, b, lam, q), edges)`` and top
    is the per-knapsack max candidate value max(v1, axis=0). Optional
    ``hist_init``/``top_init`` accumulator seeds are combined with
    ``+``/``maximum`` (an allclose-level oracle for the kernel's seeded
    accumulation, not a bit-exact one — the kernel folds the seed into
    its tile chain instead of adding it afterwards).
    """
    v1, v2 = scd_candidates_ref(p, b, lam, q)
    hist = bucket_hist_ref(v1, v2, edges)
    top = jnp.max(v1, axis=0)
    if hist_init is not None:
        hist = hist + hist_init
    if top_init is not None:
        top = jnp.maximum(top, top_init)
    return hist, top


def scd_finalize_ref(p, b, lam, pedges, q, with_hist=True,
                     cons_hist_init=None, gain_hist_init=None, r_init=None,
                     sums_init=None, maxs_init=None):
    """Streaming-finalize oracle: metrics partials + §5.4 histograms.

    Matches ``kernels.scd_fused.scd_finalize_hist`` at allclose level
    (per the repo's kernel-oracle convention the seed combination and the
    tile-grouped sums differ in the last ulp; bucket *indices* are
    bit-identical because pt is the same per-row reduction on both
    sides). Returns the same 7-tuple: (cons_hist (K, E+1), gain_hist
    (E+1,), r (K,), primal (), dual_sum (), lo (), hi ()); the
    histograms are None when ``with_hist`` is False.
    """
    x, cons = adjusted_topc_ref(p, b, lam, q)
    ap = p - lam[None, :] * b
    gain = jnp.sum(jnp.where(x, p, 0.0), axis=-1)            # (n,)
    pt = jnp.sum(jnp.where(x, ap, 0.0), axis=-1)             # (n,)
    r = jnp.sum(cons, axis=0).astype(jnp.float32)
    primal = jnp.sum(jnp.where(x, p, 0.0)).astype(jnp.float32)
    dual_sum = jnp.sum(jnp.where(x, ap, 0.0)).astype(jnp.float32)
    sel = jnp.any(x, axis=-1)
    inf = jnp.asarray(jnp.inf, p.dtype)
    lo = jnp.min(jnp.where(sel, pt, inf))
    hi = jnp.max(jnp.where(sel, pt, -inf))
    if r_init is not None:
        r = r + r_init
    if sums_init is not None:
        primal = primal + sums_init[0]
        dual_sum = dual_sum + sums_init[1]
    if maxs_init is not None:
        hi = jnp.maximum(hi, maxs_init[0])
        lo = jnp.minimum(lo, -maxs_init[1])
    if not with_hist:
        return None, None, r, primal, dual_sum, lo, hi
    e = pedges.shape[-1]
    idx = jnp.searchsorted(pedges, pt, side="left")          # (n,)
    onehot = jax.nn.one_hot(idx, e + 1, dtype=jnp.float32)   # (n, E+1)
    ch = jnp.einsum("nb,nk->kb", onehot, cons.astype(jnp.float32))
    gh = jnp.einsum("nb,n->b", onehot, gain.astype(jnp.float32))
    if cons_hist_init is not None:
        ch = ch + cons_hist_init
    if gain_hist_init is not None:
        gh = gh + gain_hist_init
    return ch, gh, r, primal, dual_sum, lo, hi


def bucket_hist_ref(v1, v2, edges):
    """Section 5.2 histogram: mass of v2 per (knapsack, bucket).

    v1, v2: (n, K); edges: (K, E) ascending. Bucket j of row k holds
    candidates with edges[k, j-1] < v1 <= edges[k, j]; returns (K, E+1).
    """
    n, k = v1.shape
    e = edges.shape[-1]
    idx = jax.vmap(jnp.searchsorted, in_axes=(0, 1))(edges, v1)   # (K, n)
    onehot = jax.nn.one_hot(idx, e + 1, dtype=v2.dtype)           # (K, n, E+1)
    return jnp.einsum("kne,nk->ke", onehot, v2)
