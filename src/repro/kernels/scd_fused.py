"""Pallas TPU kernel: fused SCD map + §5.2 bucketed reduce.

One grid pass per user tile does the whole per-iteration SCD hot path:
adjusted profits ``ap = max(p - lam*b, 0)``, the two Alg-5 order
statistics (Q-th / (Q+1)-th largest per user), the candidate pairs
``v1 = (p - pbar)/b``, ``v2 = b``, the §5.2 binning of ``v1`` against the
per-knapsack edge ladder, and the running per-knapsack max of ``v1`` —
accumulating straight into the (K, E+1) histogram and (1, K) top blocks
that live in VMEM across the whole grid.

This is the paper's communication-compression argument applied one level
down the memory hierarchy: across machines only the constant-size
histogram is shuffled (§5.2); within a device only the constant-size
histogram leaves the core. The unfused pair (scd_candidates ->
bucket_hist) writes and re-reads the full (n, K) ``v1``/``v2`` arrays
through HBM every iteration — 4 O(n*K) transfers this kernel deletes.

Order statistics use the same Q+1 sequential masked-max passes as
scd_candidates.py (quick-select does not vectorise on the VPU); binning
is the same branch-free edge-ladder compare + one-hot MXU contraction as
bucket_hist.py. Both unfused kernels remain the parity oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows
from .bucket_hist import hist_block
from .scd_candidates import candidates_block


def _kernel(p_ref, b_ref, lam_ref, edges_ref, hist0_ref, top0_ref,
            hist_ref, top_ref, *, q):
    # Alg 5 map, then the §5.2 binning — the same shared blocks the two
    # standalone kernels run, but v1/v2 stay in VMEM between them.
    v1, v2 = candidates_block(p_ref[...], b_ref[...], lam_ref[...], q)
    tile_hist = hist_block(v1, v2, edges_ref[...])        # (K, E+1)
    tile_top = jnp.max(v1, axis=0, keepdims=True)         # (1, K)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = hist0_ref[...]
        top_ref[...] = top0_ref[...]

    hist_ref[...] += tile_hist
    top_ref[...] = jnp.maximum(top_ref[...], tile_top)


@functools.partial(jax.jit, static_argnames=("q", "tile_n", "interpret"))
def scd_fused_hist(p, b, lam, edges, q, tile_n=512, interpret=None,
                   hist_init=None, top_init=None):
    """Fused Alg-5 map + §5.2 histogram. No (n, K) intermediate in HBM.

    p, b: (n, K); lam: (K,); edges: (K, E) ascending. Returns
    (hist (K, E+1) f32, top (K,) p.dtype) — exactly
    ``bucket_hist(*scd_candidates(p, b, lam, q), edges)`` and
    ``max(v1, axis=0)``, with v1/v2 never materialised off-chip.

    ``hist_init`` (K, E+1) / ``top_init`` (K,) seed the VMEM accumulators
    (defaults: zeros / -inf, the unseeded behaviour). The out-of-core
    chunked solve scans user chunks through this kernel with the running
    (hist, top) carried between calls; because the accumulators are
    *seeded* rather than summed afterwards, the f32 addition chain over
    tiles is the same one the single unchunked call performs — chunked
    and unchunked results are bit-identical whenever the tile
    decomposition of the user axis is the same (chunk_size a multiple of
    tile_n; see core/solver.py). The seed inputs are aliased to the
    outputs so the carried accumulator is updated in place on TPU.

    Ragged n is handled by padding the user axis with (p=0, b=0) rows:
    those are invalid candidates (v1=-1, v2=0), contributing zero mass
    and never raising the top (real v1 is -1 or positive).
    """
    n, k = p.shape
    e = edges.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    lam2 = lam.reshape(1, k).astype(p.dtype)
    if hist_init is None:
        hist_init = jnp.zeros((k, e + 1), jnp.float32)
    if top_init is None:
        top_init = jnp.full((k,), -jnp.inf, p.dtype)
    hist, top = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((k, e), lambda i: (0, 0)),
            pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, e + 1), jnp.float32),
            jax.ShapeDtypeStruct((1, k), p.dtype),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(p, b, lam2, edges.astype(p.dtype),
      hist_init.astype(jnp.float32), top_init.reshape(1, k).astype(p.dtype))
    return hist, top[0]
