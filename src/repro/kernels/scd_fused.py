"""Pallas TPU kernel: fused SCD map + §5.2 bucketed reduce.

One grid pass per user tile does the whole per-iteration SCD hot path:
adjusted profits ``ap = max(p - lam*b, 0)``, the two Alg-5 order
statistics (Q-th / (Q+1)-th largest per user), the candidate pairs
``v1 = (p - pbar)/b``, ``v2 = b``, the §5.2 binning of ``v1`` against the
per-knapsack edge ladder, and the running per-knapsack max of ``v1`` —
accumulating straight into the (K, E+1) histogram and (1, K) top blocks
that live in VMEM across the whole grid.

This is the paper's communication-compression argument applied one level
down the memory hierarchy: across machines only the constant-size
histogram is shuffled (§5.2); within a device only the constant-size
histogram leaves the core. The unfused pair (scd_candidates ->
bucket_hist) writes and re-reads the full (n, K) ``v1``/``v2`` arrays
through HBM every iteration — 4 O(n*K) transfers this kernel deletes.

Order statistics use the same Q+1 sequential masked-max passes as
scd_candidates.py (quick-select does not vectorise on the VPU); binning
is the same branch-free edge-ladder compare + one-hot MXU contraction as
bucket_hist.py. Both unfused kernels remain the parity oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows
from .adjusted_topc import _topq_mask
from .bucket_hist import hist_block
from .scd_candidates import candidates_block


def _kernel(p_ref, b_ref, lam_ref, edges_ref, hist0_ref, top0_ref,
            hist_ref, top_ref, *, q):
    # Alg 5 map, then the §5.2 binning — the same shared blocks the two
    # standalone kernels run, but v1/v2 stay in VMEM between them.
    v1, v2 = candidates_block(p_ref[...], b_ref[...], lam_ref[...], q)
    tile_hist = hist_block(v1, v2, edges_ref[...])        # (K, E+1)
    tile_top = jnp.max(v1, axis=0, keepdims=True)         # (1, K)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = hist0_ref[...]
        top_ref[...] = top0_ref[...]

    hist_ref[...] += tile_hist
    top_ref[...] = jnp.maximum(top_ref[...], tile_top)


@functools.partial(jax.jit, static_argnames=("q", "tile_n", "interpret"))
def scd_fused_hist(p, b, lam, edges, q, tile_n=512, interpret=None,
                   hist_init=None, top_init=None):
    """Fused Alg-5 map + §5.2 histogram. No (n, K) intermediate in HBM.

    p, b: (n, K); lam: (K,); edges: (K, E) ascending. Returns
    (hist (K, E+1) f32, top (K,) p.dtype) — exactly
    ``bucket_hist(*scd_candidates(p, b, lam, q), edges)`` and
    ``max(v1, axis=0)``, with v1/v2 never materialised off-chip.

    ``hist_init`` (K, E+1) / ``top_init`` (K,) seed the VMEM accumulators
    (defaults: zeros / -inf, the unseeded behaviour). The out-of-core
    chunked solve scans user chunks through this kernel with the running
    (hist, top) carried between calls; because the accumulators are
    *seeded* rather than summed afterwards, the f32 addition chain over
    tiles is the same one the single unchunked call performs — chunked
    and unchunked results are bit-identical whenever the tile
    decomposition of the user axis is the same (chunk_size a multiple of
    tile_n; see core/solver.py). The seed inputs are aliased to the
    outputs so the carried accumulator is updated in place on TPU.

    Ragged n is handled by padding the user axis with (p=0, b=0) rows:
    those are invalid candidates (v1=-1, v2=0), contributing zero mass
    and never raising the top (real v1 is -1 or positive).
    """
    n, k = p.shape
    e = edges.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    lam2 = lam.reshape(1, k).astype(p.dtype)
    if hist_init is None:
        hist_init = jnp.zeros((k, e + 1), jnp.float32)
    if top_init is None:
        top_init = jnp.full((k,), -jnp.inf, p.dtype)
    hist, top = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((k, e), lambda i: (0, 0)),
            pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, e + 1), jnp.float32),
            jax.ShapeDtypeStruct((1, k), p.dtype),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(p, b, lam2, edges.astype(p.dtype),
      hist_init.astype(jnp.float32), top_init.reshape(1, k).astype(p.dtype))
    return hist, top[0]


def finalize_block(p, b, lam, q):
    """Primal map for one VMEM-resident block of the streaming finalize.

    p, b: (tile_n, K); lam: (1, K). Returns (x bool, cons, gain (tile, 1),
    pt (tile, 1)): the Alg-1 greedy selection at lam, its consumption,
    and per-user raw/cost-adjusted selected profit. ``pt`` is the sum of
    selected adjusted profits — the sparse group profit of §5.4 — in the
    per-row reduction form shared with the jnp streaming body
    (core/chunked.py), so kernel and jnp paths bin it into identical
    buckets (a half-ulp difference would shift whole mass units between
    adjacent buckets).
    """
    ap = p - lam * b
    x = _topq_mask(ap, q)
    cons = jnp.where(x, b, jnp.zeros_like(b))
    gain = jnp.sum(jnp.where(x, p, jnp.zeros_like(p)), axis=1, keepdims=True)
    pt = jnp.sum(jnp.where(x, ap, jnp.zeros_like(ap)), axis=1, keepdims=True)
    return x, cons, gain, pt


def _finalize_kernel(p_ref, b_ref, lam_ref, *refs, q, with_hist):
    """One kernel body for both finalize variants (metrics ± histograms).

    The bit-exactness-critical metrics accumulation exists once; the
    ``with_hist`` closure only decides whether the §5.4 histogram refs
    are present and binned into. Ref order matches the pallas_call specs
    built in :func:`scd_finalize_hist`.
    """
    if with_hist:
        (pedges_ref, ch0_ref, gh0_ref, r0_ref, s0_ref, m0_ref,
         ch_ref, gh_ref, r_ref, s_ref, m_ref) = refs
    else:
        r0_ref, s0_ref, m0_ref, r_ref, s_ref, m_ref = refs
    x, cons, gain, pt = finalize_block(p_ref[...], b_ref[...], lam_ref[...], q)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        if with_hist:
            ch_ref[...] = ch0_ref[...]
            gh_ref[...] = gh0_ref[...]
        r_ref[...] = r0_ref[...]
        s_ref[...] = s0_ref[...]
        m_ref[...] = m0_ref[...]

    r_ref[...] += jnp.sum(cons, axis=0, keepdims=True).astype(jnp.float32)
    primal = jnp.sum(jnp.where(x, p_ref[...], 0.0), keepdims=True)
    dual = jnp.sum(jnp.where(x, p_ref[...] - lam_ref[...] * b_ref[...], 0.0),
                   keepdims=True)
    s_ref[...] += jnp.concatenate(
        [primal.reshape(1, 1), dual.reshape(1, 1)], axis=1).astype(jnp.float32)
    # Group-profit range over users with any selection; inert/empty rows
    # are excluded (their pt = 0 carries no removable mass anyway). lo is
    # tracked negated so one maximum-combine covers both ends.
    sel = jnp.any(x, axis=1, keepdims=True)
    inf = jnp.asarray(jnp.inf, pt.dtype)
    hi = jnp.max(jnp.where(sel, pt, -inf), keepdims=True).reshape(1, 1)
    nlo = jnp.max(jnp.where(sel, -pt, -inf), keepdims=True).reshape(1, 1)
    m_ref[...] = jnp.maximum(m_ref[...], jnp.concatenate([hi, nlo], axis=1))
    if not with_hist:
        return
    # §5.4 removable histograms: searchsorted-left edge-ladder binning of
    # pt (same convention as hist_block), mass = consumption / raw profit.
    tile_n = pt.shape[0]
    e = pedges_ref.shape[-1]
    idx = jnp.sum(pt > pedges_ref[...], axis=1).astype(jnp.int32)  # (tile,)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (tile_n, e + 1), 1)
    onehot = (buckets == idx[:, None]).astype(jnp.float32)
    ch_ref[...] += jnp.einsum("nb,nk->kb", onehot, cons.astype(jnp.float32))
    gh_ref[...] += jnp.sum(onehot * gain.astype(jnp.float32), axis=0,
                           keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("q", "tile_n", "interpret", "with_hist"))
def scd_finalize_hist(p, b, lam, pedges, q, tile_n=512, interpret=None,
                      with_hist=True, cons_hist_init=None,
                      gain_hist_init=None, r_init=None, sums_init=None,
                      maxs_init=None):
    """Fused streaming-finalize pass: metrics partials + §5.4 histograms.

    One grid pass over the user tiles computes everything the streaming
    solve needs after convergence — the greedy primal selection at
    ``lam``, its consumption ``r``, the primal / dual-sum scalars, the
    group-profit range, and (``with_hist``) the removable consumption and
    raw-profit histograms binned against the fixed ladder ``pedges``
    (E,) — accumulating all of it in VMEM across the grid, exactly like
    :func:`scd_fused_hist` does for the per-iteration reduce. This is
    the kernel behind the iters+1 pass accounting of DESIGN.md §5c: the
    legacy finalize runs three separate passes for the same outputs.

    Returns ``(cons_hist (K, E+1), gain_hist (E+1,), r (K,), primal (),
    dual_sum (), lo (), hi ())`` — all f32 except lo/hi in p.dtype; the
    first two are None when ``with_hist=False`` (metrics-only variant,
    used by the sampled-history path). The ``*_init`` seeds continue a
    carried accumulation chunk by chunk (input/output aliased, in-place
    on TPU): because the seeds initialise the running VMEM accumulators,
    the f32 chain over tiles is the one a single whole-shard call
    performs, so chunked and unchunked finalizes are bit-identical under
    the same tile decomposition — the same contract as
    :func:`scd_fused_hist`. Ragged n pads with inert (p = b = 0) rows:
    nothing is selected there, so they contribute zero mass everywhere
    and never touch the lo/hi range.
    """
    n, k = p.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    lam2 = lam.reshape(1, k).astype(p.dtype)
    if r_init is None:
        r_init = jnp.zeros((k,), jnp.float32)
    if sums_init is None:
        sums_init = jnp.zeros((2,), jnp.float32)
    if maxs_init is None:
        maxs_init = jnp.full((2,), -jnp.inf, p.dtype)
    r_init = r_init.reshape(1, k).astype(jnp.float32)
    sums_init = sums_init.reshape(1, 2).astype(jnp.float32)
    maxs_init = maxs_init.reshape(1, 2).astype(p.dtype)
    scalar_specs = [
        pl.BlockSpec((1, k), lambda i: (0, 0)),
        pl.BlockSpec((1, 2), lambda i: (0, 0)),
        pl.BlockSpec((1, 2), lambda i: (0, 0)),
    ]
    scalar_shapes = [
        jax.ShapeDtypeStruct((1, k), jnp.float32),
        jax.ShapeDtypeStruct((1, 2), jnp.float32),
        jax.ShapeDtypeStruct((1, 2), p.dtype),
    ]
    row_specs = [
        pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        pl.BlockSpec((1, k), lambda i: (0, 0)),
    ]
    if not with_hist:
        r, s, m = pl.pallas_call(
            functools.partial(_finalize_kernel, q=q, with_hist=False),
            grid=grid,
            in_specs=row_specs + scalar_specs,
            out_specs=scalar_specs,
            out_shape=scalar_shapes,
            input_output_aliases={3: 0, 4: 1, 5: 2},
            interpret=interpret,
        )(p, b, lam2, r_init, sums_init, maxs_init)
        return (None, None, r[0], s[0, 0], s[0, 1], -m[0, 1], m[0, 0])
    e = pedges.shape[-1]
    if cons_hist_init is None:
        cons_hist_init = jnp.zeros((k, e + 1), jnp.float32)
    if gain_hist_init is None:
        gain_hist_init = jnp.zeros((e + 1,), jnp.float32)
    hist_specs = [
        pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
        pl.BlockSpec((1, e + 1), lambda i: (0, 0)),
    ]
    hist_shapes = [
        jax.ShapeDtypeStruct((k, e + 1), jnp.float32),
        jax.ShapeDtypeStruct((1, e + 1), jnp.float32),
    ]
    ch, gh, r, s, m = pl.pallas_call(
        functools.partial(_finalize_kernel, q=q, with_hist=True),
        grid=grid,
        in_specs=row_specs + [pl.BlockSpec((1, e), lambda i: (0, 0))]
        + hist_specs + scalar_specs,
        out_specs=hist_specs + scalar_specs,
        out_shape=hist_shapes + scalar_shapes,
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3, 8: 4},
        interpret=interpret,
    )(p, b, lam2, pedges.reshape(1, e).astype(p.dtype),
      cons_hist_init.astype(jnp.float32),
      gain_hist_init.reshape(1, e + 1).astype(jnp.float32),
      r_init, sums_init, maxs_init)
    return (ch, gh[0], r[0], s[0, 0], s[0, 1], -m[0, 1], m[0, 0])
