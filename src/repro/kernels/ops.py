"""Jitted public wrappers for the kernel layer.

On TPU these call the Pallas kernels compiled natively; on CPU (this
container) they run the same kernel bodies under ``interpret=True``, which
traces the kernel through XLA so correctness (incl. the grid accumulation
pattern) is exercised end to end. ``use_pallas=False`` falls back to the
pure-jnp oracle — the solver uses that switch to A/B the kernel path.
"""
from __future__ import annotations

import jax

from . import ref
from .adjusted_topc import adjusted_topc as _adjusted_topc
from .bucket_hist import bucket_hist as _bucket_hist
from .scd_candidates import scd_candidates as _scd_candidates


def adjusted_topc(p, b, lam, q, use_pallas=True, **kw):
    """Fused DD map: (x mask, consumption v) for the sparse GKP."""
    if not use_pallas:
        return ref.adjusted_topc_ref(p, b, lam, q)
    return _adjusted_topc(p, b, lam, q, **kw)


def scd_candidates(p, b, lam, q, use_pallas=True, **kw):
    """Alg 5 map: candidate (v1, v2) pairs."""
    if not use_pallas:
        return ref.scd_candidates_ref(p, b, lam, q)
    return _scd_candidates(p, b, lam, q, **kw)


def bucket_hist(v1, v2, edges, use_pallas=True, **kw):
    """§5.2 reduce-side histogram (K, E+1)."""
    if not use_pallas:
        return ref.bucket_hist_ref(v1, v2, edges)
    return _bucket_hist(v1, v2, edges, **kw)
