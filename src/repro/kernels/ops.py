"""Jitted public wrappers for the kernel layer.

On TPU these call the Pallas kernels compiled natively; on CPU (this
container) they run the same kernel bodies under ``interpret=True``, which
traces the kernel through XLA so correctness (incl. the grid accumulation
pattern) is exercised end to end. ``use_pallas=False`` falls back to the
pure-jnp oracle — the solver uses that switch to A/B the kernel path.
"""
from __future__ import annotations

import jax

from . import ref
from .adjusted_topc import adjusted_topc as _adjusted_topc
from .bucket_hist import bucket_hist as _bucket_hist
from .scd_candidates import scd_candidates as _scd_candidates
from .scd_fused import scd_finalize_hist as _scd_finalize_hist
from .scd_fused import scd_fused_hist as _scd_fused_hist
from .screen_bound import screen_bound as _screen_bound

_TILE_LADDER = (512, 256, 128)


def pick_tile(n, max_tile=512):
    """User-axis tile for a shard of n rows.

    Prefers the largest ladder tile that divides n (no padding, full
    sublane occupancy). Otherwise the shard runs as a single tile
    (n <= max_tile) or as max_tile-sized tiles with the ragged tail
    padded inside the kernel wrappers. The ladder stops at 128: a
    smaller dividing tile would serialise the grid (n=100000 -> 3125
    steps at tile 32 vs 196 padded steps at tile 512), which costs far
    more than <= tile-1 inert padded rows.
    """
    for t in _TILE_LADDER:
        if t <= max_tile and n % t == 0:
            return t
    return min(max_tile, max(n, 1))


def adjusted_topc(p, b, lam, q, use_pallas=True, **kw):
    """Fused DD map: (x mask, consumption v) for the sparse GKP."""
    if not use_pallas:
        return ref.adjusted_topc_ref(p, b, lam, q)
    return _adjusted_topc(p, b, lam, q, **kw)


def scd_candidates(p, b, lam, q, use_pallas=True, **kw):
    """Alg 5 map: candidate (v1, v2) pairs."""
    if not use_pallas:
        return ref.scd_candidates_ref(p, b, lam, q)
    return _scd_candidates(p, b, lam, q, **kw)


def bucket_hist(v1, v2, edges, use_pallas=True, **kw):
    """§5.2 reduce-side histogram (K, E+1)."""
    if not use_pallas:
        return ref.bucket_hist_ref(v1, v2, edges)
    return _bucket_hist(v1, v2, edges, **kw)


def scd_fused_hist(p, b, lam, edges, q, use_pallas=True, **kw):
    """Fused Alg-5 map + §5.2 histogram: (hist (K, E+1), top (K,)).

    The candidate (v1, v2) intermediates never leave VMEM — this is the
    solver's bucketed-reduce hot path when ``cfg.use_kernels``. Pass
    ``hist_init``/``top_init`` to seed the accumulators when scanning
    user chunks (the chunked solve's bit-identity contract; the ref
    oracle combines seeds at allclose level only).
    """
    if not use_pallas:
        return ref.scd_fused_hist_ref(
            p, b, lam, edges, q,
            hist_init=kw.get("hist_init"), top_init=kw.get("top_init"))
    return _scd_fused_hist(p, b, lam, edges, q, **kw)


def screen_bound(p, b, use_pallas=True, **kw):
    """Masked max-ratio accumulation: the (K,) per-chunk screening
    certificate of core/screening.py (row-max of p/b over b > 0 rows;
    masked rows bound to -inf). Bit-identical across the kernel and
    oracle paths — f32 max carries no rounding."""
    if not use_pallas:
        from ..core.screening import chunk_bound
        return chunk_bound(p, b)
    return _screen_bound(p, b, **kw)


def scd_finalize_hist(p, b, lam, pedges, q, use_pallas=True, **kw):
    """Fused streaming-finalize pass (DESIGN.md §5c): the post-solve
    metrics partials (r, primal, dual_sum, group-profit lo/hi) and the
    §5.4 removable consumption/profit histograms, accumulated in one
    VMEM grid pass. Seed the ``*_init`` accumulators when scanning user
    chunks (carry-seeded, like :func:`scd_fused_hist`; the ref oracle
    combines seeds at allclose level only). Returns (cons_hist,
    gain_hist, r, primal, dual_sum, lo, hi)."""
    if not use_pallas:
        kw.pop("tile_n", None)
        return ref.scd_finalize_ref(p, b, lam, pedges, q, **kw)
    return _scd_finalize_hist(p, b, lam, pedges, q, **kw)
