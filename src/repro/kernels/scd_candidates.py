"""Pallas TPU kernel: Algorithm 5 linear-time candidate generation.

Per user tile in VMEM: adjusted profits ``ap = max(p - lam*b, 0)``, the
Q-th / (Q+1)-th largest entries per row (the two order statistics Alg 5
needs), the per-item beat-threshold ``pbar``, and the emitted candidate
pairs ``v1 = (p - pbar)/b``, ``v2 = b`` — fused so neither ``ap`` nor the
thresholds ever leave VMEM.

Order statistics are computed with Q+1 sequential masked-max passes (see
adjusted_topc.py for why quick-select doesn't map to the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows


def _order_stats(ap, q):
    """(n,K) -> (q_th (n,1), q1_th (n,1)) largest values (with multiplicity)."""
    n, k = ap.shape
    neg_inf = jnp.asarray(-jnp.inf, ap.dtype)
    work = ap
    q_th = jnp.full((n, 1), jnp.inf, ap.dtype)
    q1_th = jnp.full((n, 1), jnp.inf, ap.dtype)
    idx = jax.lax.broadcasted_iota(jnp.int32, (n, k), 1)
    for i in range(q + 1):
        m = jnp.max(work, axis=1, keepdims=True)
        if i == q - 1:
            q_th = m
        if i == q:
            q1_th = m
        is_max = work == m
        pick_idx = jnp.min(jnp.where(is_max, idx, k), axis=1, keepdims=True)
        work = jnp.where(idx == pick_idx, neg_inf, work)
    return q_th, q1_th


def candidates_block(p, b, lam, q):
    """Alg 5 candidate pairs (v1, v2) for one VMEM-resident block.

    p, b: (tile_n, K); lam: (1, K). Invalid candidates are encoded as
    v1 = -1, v2 = 0. Shared by this kernel and the fused map+reduce
    kernel (scd_fused.py) so the tie-sensitive semantics exist once.
    """
    ap = jnp.maximum(p - lam * b, 0.0)
    k = p.shape[-1]
    if q >= k:
        pbar = jnp.zeros_like(ap)
    else:
        q_th, q1_th = _order_stats(ap, q)
        in_top = ap >= q_th
        pbar = jnp.where(in_top, q1_th, q_th)
    valid = (p > pbar) & (b > 0)
    safe_b = jnp.where(b > 0, b, jnp.ones_like(b))
    v1 = jnp.where(valid, (p - pbar) / safe_b, -jnp.ones_like(p))
    v2 = jnp.where(valid, b, jnp.zeros_like(b))
    return v1, v2


def _kernel(p_ref, b_ref, lam_ref, v1_ref, v2_ref, *, q):
    v1, v2 = candidates_block(p_ref[...], b_ref[...], lam_ref[...], q)
    v1_ref[...] = v1
    v2_ref[...] = v2


@functools.partial(jax.jit, static_argnames=("q", "tile_n", "interpret"))
def scd_candidates(p, b, lam, q, tile_n=512, interpret=None):
    """p, b: (n, K); lam: (K,). Returns (v1, v2): (n, K) Alg 5 candidates."""
    n, k = p.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    # Ragged n: pad with (p=0, b=0) rows — invalid candidates (v1=-1,
    # v2=0) by construction — and slice the outputs back.
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    lam2 = lam.reshape(1, k).astype(p.dtype)
    v1, v2 = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, k), p.dtype),
            jax.ShapeDtypeStruct((n + pad, k), p.dtype),
        ],
        interpret=interpret,
    )(p, b, lam2)
    return (v1[:n], v2[:n]) if pad else (v1, v2)
