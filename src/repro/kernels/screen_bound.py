"""Pallas TPU kernel: masked max-ratio accumulation for screening.

Computes the per-chunk screening certificate of core/screening.py — the
row-max of ``p / b`` over rows with ``b > 0`` (masked accumulation:
invalid rows contribute -inf, never a NaN from the 0/0 division) — as
one grid pass over user tiles with the (1, K) running max held in VMEM,
the same sequential-grid accumulation pattern as ``bucket_hist``. The
certificate is consumed on the host between iteration epochs, so this
kernel is bandwidth-trivial; it exists so the kernel feeding path can
issue the bound computation on device memory it already holds instead
of staging chunks back to the host oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows


def bound_block(p, b):
    """(tile_n, K) -> (1, K) masked max ratio block, in f32.

    The mask is applied to *both* operands before the divide (the
    select-then-divide order of ``screening.chunk_bound``): a masked
    lane divides 0-free and then selects -inf, so no spurious inf/NaN
    ever enters the VPU max tree.
    """
    valid = b > 0
    safe = jnp.where(valid, b, jnp.ones_like(b))
    ratio = jnp.where(valid, p / safe, -jnp.inf).astype(jnp.float32)
    return jnp.max(ratio, axis=0, keepdims=True)


def _kernel(p_ref, b_ref, out_ref):
    tile = bound_block(p_ref[...], b_ref[...])

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def screen_bound(p, b, tile_n=512, interpret=None):
    """p, b: (n, K). Returns the (K,) f32 chunk certificate.

    max is associative/commutative in IEEE f32 (no rounding), so the
    tiled accumulation is bit-identical to the single-reduction oracle
    ``screening.chunk_bound`` regardless of tiling — unlike the
    histogram kernels, no tile-order contract is needed.
    """
    n, k = p.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    # Ragged n: padded rows carry b = 0, i.e. masked to -inf.
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=interpret,
    )(p, b)
    return out[0]
