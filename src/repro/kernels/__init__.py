"""Pallas TPU kernels for the solver's compute hot-spots (+ jnp oracles).

adjusted_topc   — fused adjusted-profit + top-Q select + consumption (DD map)
scd_candidates  — Algorithm 5 linear-time candidate generation (SCD map)
bucket_hist     — Section 5.2 bucketed-reduce histogram (SCD reduce, map side)
scd_fused_hist  — scd_candidates + bucket_hist in one streaming pass: the
                  (n, K) candidate intermediates never leave VMEM
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    adjusted_topc,
    bucket_hist,
    pick_tile,
    scd_candidates,
    scd_fused_hist,
)
