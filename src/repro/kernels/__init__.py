"""Pallas TPU kernels for the solver's compute hot-spots (+ jnp oracles).

adjusted_topc   — fused adjusted-profit + top-Q select + consumption (DD map)
scd_candidates  — Algorithm 5 linear-time candidate generation (SCD map)
bucket_hist     — Section 5.2 bucketed-reduce histogram (SCD reduce, map side)
scd_fused_hist  — scd_candidates + bucket_hist in one streaming pass: the
                  (n, K) candidate intermediates never leave VMEM. Accepts
                  ``hist_init``/``top_init`` accumulator seeds so the
                  out-of-core chunked solve can carry the (K, E+1)
                  histogram across chunk calls with the identical f32
                  addition chain as one unchunked call (bit-identity
                  contract: core/solver.py).

All wrappers take a user-axis tile (``pick_tile`` chooses; ragged shards
are padded with inert rows inside the wrapper) and run under the Pallas
interpreter off-TPU. ``use_pallas=False`` dispatches to the pure-jnp
oracles in ``ref``.
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    adjusted_topc,
    bucket_hist,
    pick_tile,
    scd_candidates,
    scd_fused_hist,
)
