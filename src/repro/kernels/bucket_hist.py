"""Pallas TPU kernel: Section 5.2 bucketed-reduce histogram.

Accumulates candidate mass ``v2`` into per-knapsack buckets keyed by
``searchsorted(edges[k], v1[:, k])``. The (K, E+1) accumulator lives in
VMEM across the whole user grid (all grid steps map to the same output
block; TPU grids execute sequentially, so ``out += tile`` is safe), and is
exactly the array the solver psums across the mesh — i.e. this kernel IS
the map-side of the paper's communication-compression trick.

Binning is branch-free: bucket index = #(edges < v1), computed as a sum
of compares against the edge ladder; accumulation is a (tile_n x nb)
one-hot contraction on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows


def hist_block(v1, v2, edges):
    """(tile_n, K) candidates -> (K, E+1) bucket-mass block, in f32.

    idx[n, k] = number of edges < v1, in [0, E]: bucket j holds
    edges[j-1] < v1 <= edges[j] — the same tie convention as
    searchsorted(side="left") so kernel and jnp reduces agree when a
    candidate lands exactly on an edge. Shared by this kernel and the
    fused map+reduce kernel (scd_fused.py).
    """
    tile_n, k = v1.shape
    e = edges.shape[-1]
    nb = e + 1
    gt = v1[:, :, None] > edges[None, :, :]               # (tile_n, K, E)
    idx = gt.sum(axis=-1).astype(jnp.int32)               # (tile_n, K)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (tile_n, k, nb), 2)
    onehot = (buckets == idx[:, :, None]).astype(jnp.float32)
    return jnp.einsum("nkb,nk->kb", onehot, v2.astype(jnp.float32))


def _kernel(v1_ref, v2_ref, edges_ref, out_ref):
    tile_hist = hist_block(v1_ref[...], v2_ref[...], edges_ref[...])

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += tile_hist


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def bucket_hist(v1, v2, edges, tile_n=512, interpret=None):
    """v1, v2: (n, K); edges: (K, E) ascending. Returns (K, E+1) f32."""
    n, k = v1.shape
    e = edges.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    # Ragged n: padded rows carry v2 = 0, i.e. zero mass in every bucket.
    pad = -n % tile_n
    v1 = pad_rows(v1, pad, value=-1.0)
    v2 = pad_rows(v2, pad)
    grid = ((n + pad) // tile_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((k, e), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k, e + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, e + 1), jnp.float32),
        interpret=interpret,
    )(v1, v2, edges.astype(v1.dtype))
