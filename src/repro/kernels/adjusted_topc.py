"""Pallas TPU kernel: fused cost-adjusted profit + top-Q select + consumption.

The DD/SCD map body for the sparse GKP (one item per knapsack): for a tile
of users resident in VMEM, compute ``ap = p - lam * b``, select the top-Q
strictly-positive entries per user (ties broken by smaller item index, the
same convention as core.sparse_scd), and emit the selection mask and the
per-knapsack consumption ``v = b * x`` — all in one pass so ``ap`` never
round-trips to HBM (the paper's mapper materialises it per user; at 1e9
users that intermediate is the memory bottleneck).

TPU adaptation of quick-select: a data-dependent partition does not
vectorise on the VPU. Q is small and static, so selection runs as Q
sequential argmax passes over the (tile_n, K) block — each pass is a pair
of lane reductions (max, then min-index among maxima) and a mask update.
O(Q * tile_n * K) VPU work, no data-dependent control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import pad_rows


def _topq_mask(ap, q):
    """(tile_n, K) -> bool mask of top-q positive entries, min-index ties."""
    n, k = ap.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (n, k), 1)
    neg_inf = jnp.asarray(-jnp.inf, ap.dtype)
    x = jnp.zeros((n, k), jnp.bool_)
    work = ap
    for _ in range(q):
        m = jnp.max(work, axis=1, keepdims=True)                  # (n,1)
        is_max = (work == m) & (m > 0)
        pick_idx = jnp.min(jnp.where(is_max, idx, k), axis=1, keepdims=True)
        pick = idx == pick_idx                                    # one-hot row
        x = x | pick
        work = jnp.where(pick, neg_inf, work)
    return x


def _kernel(p_ref, b_ref, lam_ref, x_ref, v_ref, *, q):
    p = p_ref[...]
    b = b_ref[...]
    lam = lam_ref[...]                                            # (1, K)
    ap = p - lam * b
    x = _topq_mask(ap, q)
    x_ref[...] = x
    v_ref[...] = jnp.where(x, b, jnp.zeros_like(b))


@functools.partial(jax.jit, static_argnames=("q", "tile_n", "interpret"))
def adjusted_topc(p, b, lam, q, tile_n=512, interpret=None):
    """p, b: (n, K); lam: (K,). Returns (x bool (n,K), v (n,K))."""
    n, k = p.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tile_n = min(tile_n, n)
    # Ragged n: padded rows have ap = 0, never strictly positive, so the
    # top-q mask is all-False there; slice the outputs back.
    pad = -n % tile_n
    p = pad_rows(p, pad)
    b = pad_rows(b, pad)
    grid = ((n + pad) // tile_n,)
    lam2 = lam.reshape(1, k).astype(p.dtype)
    x, v = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, k), jnp.bool_),
            jax.ShapeDtypeStruct((n + pad, k), p.dtype),
        ],
        interpret=interpret,
    )(p, b, lam2)
    return (x[:n], v[:n]) if pad else (x, v)
