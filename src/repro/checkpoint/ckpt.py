"""Sharded, atomic, resharding-capable checkpoints.

Layout (one directory per step):

    <dir>/step_000042.tmp/...      (written first)
    <dir>/step_000042/             (atomic rename on completion)
        manifest.json              (tree structure, shapes, dtypes, step)
        arr_00000.npy ...          (one file per leaf, host-gathered)

* Atomicity: a crash mid-save leaves only a ``.tmp`` directory, which
  restore ignores and the next save overwrites — a restart can never see a
  torn checkpoint.
* Durability: leaf files and manifests are fsynced before the rename and
  the parent directory after it, so a published step (or pointer flip)
  survives power loss, not just SIGKILL — see ``fsync_dir``.
* Restart: ``latest_step`` + ``restore`` rebuild the exact pytree.
* Elastic re-sharding: restore takes an optional ``sharding_tree``; arrays
  are re-placed with ``jax.device_put`` against the *current* mesh, which
  may have a different size/topology than the one that saved (scale-up or
  degraded scale-down after node loss).
* Corruption is loud: a step directory whose manifest exists but cannot be
  parsed, or whose manifest names a leaf file that is missing or
  unreadable, raises an actionable ``ValueError`` naming the offending
  path — never a silent fresh start. (The atomic rename makes such states
  impossible under this writer; seeing one means external damage, which
  must not be mistaken for "no checkpoint".) Only stray ``.tmp``
  directories — the expected residue of a killed save — are skipped.
* Pointer flips: ``write_json`` / ``read_json`` are the small atomic
  documents higher layers publish through — e.g. the serving refresh
  engine's live-generation pointer (repro/serve/engine.py), flipped with
  the same ``os.replace`` so a reader never observes a half-published
  generation.

For the container-scale tests this host-gathers leaves (np.save). On a
real pod the same layout is written per-host with process-local shards;
the manifest format already records the global shape, so the swap to
tensorstore is mechanical and isolated here.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def fsync_dir(path) -> None:
    """fsync a directory so its entries (renames, creations) are durable.

    ``os.replace`` gives *atomicity* (a reader sees old or new, never a
    tear) but not *durability*: after a power loss the rename itself can
    be rolled back unless the parent directory's metadata was synced.
    Platforms whose directory handles reject fsync are skipped — the
    write stays atomic there, just not power-loss-durable.

    Public because it is the shared durability primitive of every
    rename-published artifact in the repo: checkpoint steps and pointer
    documents here, heartbeat lease records in
    :mod:`repro.core.heartbeat`.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(directory, step: int, tree) -> str:
    """Atomically AND durably write ``tree`` as checkpoint ``step``.

    Every leaf file and the manifest are fsynced before the directory
    rename, and the parent directory is fsynced after it — without the
    first, the rename can land while the data blocks are still only in
    the page cache (a post-power-loss restore would see complete-looking
    files full of zeros); without the second, the rename itself can be
    undone. Returns the final path.
    """
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:08d}"
    tmp = d / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        with open(tmp / fname, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    fsync_dir(tmp)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    fsync_dir(d)
    return str(final)


def _read_manifest(step_dir: pathlib.Path) -> dict:
    """Parse a step directory's manifest, failing actionably on damage."""
    mpath = step_dir / "manifest.json"
    if not mpath.exists():
        raise ValueError(
            f"checkpoint step directory {step_dir} has no manifest.json — "
            "it is not a checkpoint this layer wrote (the atomic rename "
            "publishes the manifest with the step); remove the directory "
            "if it is debris")
    try:
        with open(mpath) as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"checkpoint manifest {mpath} is corrupt (truncated or "
            f"overwritten: {e}); the atomic save protocol cannot produce "
            "this state, so the directory was damaged after the fact — "
            f"delete {step_dir} to discard the step (an older step, if "
            "any, will be restored instead)") from e


def _load_leaf(step_dir: pathlib.Path, meta: dict) -> np.ndarray:
    """Load one manifest-named leaf array, failing actionably on damage."""
    fpath = step_dir / meta["file"]
    if not fpath.exists():
        raise ValueError(
            f"checkpoint {step_dir} is missing leaf file {meta['file']} "
            f"(tree path {meta['path']}, shape {meta['shape']}): the "
            f"manifest exists but the step is incomplete — delete "
            f"{step_dir} to discard it")
    try:
        return np.load(fpath)
    except Exception as e:
        raise ValueError(
            f"checkpoint leaf {fpath} (tree path {meta['path']}) is "
            f"unreadable: {e} — delete {step_dir} to discard the "
            "corrupt step") from e


def latest_step(directory):
    """Newest complete step in ``directory``; None when there is none.

    A step counts as soon as its ``manifest.json`` EXISTS — parseability
    is restore's concern, and a damaged-but-present manifest must surface
    as restore's actionable error, not be silently skipped here (a resume
    loop that fell back to "no checkpoint" would quietly discard the run).
    ``.tmp`` directories (killed saves) and directories without a
    manifest are not steps and are ignored.
    """
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [
        int(m.group(1))
        for p in d.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name)) and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(directory, step: int, like, sharding_tree=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``sharding_tree``: optional matching pytree of
    shardings for elastic re-placement on the current mesh."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = _read_manifest(d)
    flat_like, treedef = _leaves_with_paths(like)
    assert len(flat_like) == len(manifest["leaves"]), (
        len(flat_like), len(manifest["leaves"]))
    shard_flat = None
    if sharding_tree is not None:
        shard_flat = jax.tree_util.tree_flatten(
            sharding_tree, is_leaf=lambda x: x is None)[0]
    out = []
    for i, ((path, leaf), meta) in enumerate(zip(flat_like, manifest["leaves"])):
        got = jax.tree_util.keystr(path)
        assert got == meta["path"], f"tree mismatch: {got} vs {meta['path']}"
        arr = _load_leaf(d, meta)
        assert list(arr.shape) == list(leaf.shape), (got, arr.shape, leaf.shape)
        if shard_flat is not None and shard_flat[i] is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


_KEY_RE = re.compile(r"\['([^']*)'\]")


def restore_auto(directory, step: int, sharding_tree=None):
    """Restore a checkpoint whose structure is a flat dict of arrays,
    reconstructing the tree from the manifest alone (no ``like`` needed).

    This is the entry point a *resuming* process uses when the saved
    structure is part of what it must recover — e.g. the streaming
    resume state (core/prefetch.py) stores the virtual-slot count as the
    leading axis of its accumulator arrays, and the resumer cannot build
    a ``like`` tree before knowing it. Only flat string-keyed dicts are
    supported (leaf paths of the form ``['name']``). ``sharding_tree``:
    optional dict mapping leaf names to shardings for elastic
    re-placement on the current mesh (names absent from it are placed on
    the default device).
    """
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = _read_manifest(d)
    out = {}
    for meta in manifest["leaves"]:
        keys = _KEY_RE.findall(meta["path"])
        assert len(keys) == 1 and f"['{keys[0]}']" == meta["path"], (
            f"restore_auto supports flat dict checkpoints only, "
            f"got leaf path {meta['path']!r}")
        arr = _load_leaf(d, meta)
        sh = (sharding_tree or {}).get(keys[0])
        out[keys[0]] = jax.device_put(arr, sh) if sh is not None \
            else jax.device_put(arr)
    return out


def write_json(directory, name: str, payload: dict) -> str:
    """Atomically publish a small JSON document at ``<directory>/<name>``.

    The pointer-flip primitive of the generation-based serving layer
    (repro/serve/engine.py): the document is written to ``<name>.tmp``
    and renamed into place with ``os.replace``, so a concurrent or
    subsequent :func:`read_json` sees either the previous complete
    document or the new complete document — never a torn write. Returns
    the final path.
    """
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"{name}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    final = d / name
    os.replace(tmp, final)
    fsync_dir(d)
    return str(final)


def read_json(directory, name: str):
    """Read a :func:`write_json` document; None when it was never written.

    A *present but unparseable* document raises an actionable
    ``ValueError`` (the atomic flip cannot produce one, so it means
    external damage) — the same no-silent-fresh-start contract as
    :func:`latest_step` / :func:`restore_auto`.
    """
    path = pathlib.Path(directory) / name
    if not path.exists():
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise ValueError(
            f"pointer document {path} is corrupt ({e}); write_json flips "
            "it atomically, so this state means external damage — delete "
            "the file to discard the pointer") from e


def prune(directory, keep: int = 3):
    """Drop all but the newest ``keep`` checkpoints (and stray .tmp dirs)."""
    d = pathlib.Path(directory)
    if not d.exists():
        return
    for p in d.glob("*.tmp"):
        shutil.rmtree(p)
    steps = sorted(
        int(m.group(1))
        for p in d.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name))
    )
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(d / f"step_{s:08d}")
