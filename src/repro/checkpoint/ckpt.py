"""Sharded, atomic, resharding-capable checkpoints.

Layout (one directory per step):

    <dir>/step_000042.tmp/...      (written first)
    <dir>/step_000042/             (atomic rename on completion)
        manifest.json              (tree structure, shapes, dtypes, step)
        arr_00000.npy ...          (one file per leaf, host-gathered)

* Atomicity: a crash mid-save leaves only a ``.tmp`` directory, which
  restore ignores and the next save overwrites — a restart can never see a
  torn checkpoint.
* Restart: ``latest_step`` + ``restore`` rebuild the exact pytree.
* Elastic re-sharding: restore takes an optional ``sharding_tree``; arrays
  are re-placed with ``jax.device_put`` against the *current* mesh, which
  may have a different size/topology than the one that saved (scale-up or
  degraded scale-down after node loss).

For the container-scale tests this host-gathers leaves (np.save). On a
real pod the same layout is written per-host with process-local shards;
the manifest format already records the global shape, so the swap to
tensorstore is mechanical and isolated here.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def save(directory, step: int, tree) -> str:
    """Atomically write ``tree`` as checkpoint ``step``. Returns the path."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:08d}"
    tmp = d / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return str(final)


def latest_step(directory):
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [
        int(m.group(1))
        for p in d.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name)) and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(directory, step: int, like, sharding_tree=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``sharding_tree``: optional matching pytree of
    shardings for elastic re-placement on the current mesh."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    flat_like, treedef = _leaves_with_paths(like)
    assert len(flat_like) == len(manifest["leaves"]), (
        len(flat_like), len(manifest["leaves"]))
    shard_flat = None
    if sharding_tree is not None:
        shard_flat = jax.tree_util.tree_flatten(
            sharding_tree, is_leaf=lambda x: x is None)[0]
    out = []
    for i, ((path, leaf), meta) in enumerate(zip(flat_like, manifest["leaves"])):
        got = jax.tree_util.keystr(path)
        assert got == meta["path"], f"tree mismatch: {got} vs {meta['path']}"
        arr = np.load(d / meta["file"])
        assert list(arr.shape) == list(leaf.shape), (got, arr.shape, leaf.shape)
        if shard_flat is not None and shard_flat[i] is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


_KEY_RE = re.compile(r"\['([^']*)'\]")


def restore_auto(directory, step: int, sharding_tree=None):
    """Restore a checkpoint whose structure is a flat dict of arrays,
    reconstructing the tree from the manifest alone (no ``like`` needed).

    This is the entry point a *resuming* process uses when the saved
    structure is part of what it must recover — e.g. the streaming
    resume state (core/prefetch.py) stores the virtual-slot count as the
    leading axis of its accumulator arrays, and the resumer cannot build
    a ``like`` tree before knowing it. Only flat string-keyed dicts are
    supported (leaf paths of the form ``['name']``). ``sharding_tree``:
    optional dict mapping leaf names to shardings for elastic
    re-placement on the current mesh (names absent from it are placed on
    the default device).
    """
    d = pathlib.Path(directory) / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    out = {}
    for meta in manifest["leaves"]:
        keys = _KEY_RE.findall(meta["path"])
        assert len(keys) == 1 and f"['{keys[0]}']" == meta["path"], (
            f"restore_auto supports flat dict checkpoints only, "
            f"got leaf path {meta['path']!r}")
        arr = np.load(d / meta["file"])
        sh = (sharding_tree or {}).get(keys[0])
        out[keys[0]] = jax.device_put(arr, sh) if sh is not None \
            else jax.device_put(arr)
    return out


def prune(directory, keep: int = 3):
    """Drop all but the newest ``keep`` checkpoints (and stray .tmp dirs)."""
    d = pathlib.Path(directory)
    if not d.exists():
        return
    for p in d.glob("*.tmp"):
        shutil.rmtree(p)
    steps = sorted(
        int(m.group(1))
        for p in d.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name))
    )
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(d / f"step_{s:08d}")
