"""Heartbeat leases: durable liveness records for supervised workers.

The checkpoint protocol (DESIGN.md §7) makes a killed solve *resumable*;
this module makes a dead or wedged solve *detectable*. A supervised
worker process renews a small on-disk lease — the heartbeat file — on a
fixed cadence, and a coordinator decides from that file alone whether
the worker is alive, hung, or gone:

* :class:`LeaseRecord` / :func:`write_lease` / :func:`read_lease` — one
  JSON payload (worker id, takeover ``term``, per-process ``seq``
  counter, a ``progress`` counter bumped per unit of real work, clocks,
  ``ttl``) plus a sha256 checksum line, written with the checkpoint
  layer's atomic-and-durable discipline (tmp file, fsync, ``os.replace``,
  directory fsync). The checksum is what makes *externally* torn or
  non-atomic writes detectable: a record that does not verify is treated
  as expired (:class:`TornLease`), never trusted.
* :class:`HeartbeatWriter` — the worker side: a daemon thread renews the
  lease every ``interval`` seconds (default ``ttl / 4``) with a strictly
  increasing ``seq``; the worker's fetch path calls :meth:`bump` so the
  lease also carries a work-progress counter.
* :class:`LeaseMonitor` — the coordinator side. Staleness is judged by
  observing ``seq`` **advancement against the observer's own monotonic
  clock**, never by comparing clocks across processes: a lease is fresh
  while its ``seq`` keeps moving, expired once it has not moved for
  ``ttl`` seconds of the observer's time. A SIGSTOPped worker freezes
  every thread including the renewer, so its lease stops advancing and
  expires within one ttl — the hang-detection signal the supervisor
  acts on. ``progress_ttl`` adds the second level: beats that continue
  while ``progress`` stagnates (a stuck fetch inside a live process).
* :func:`claim_takeover` — exclusive adoption of an expired worker:
  ``O_CREAT | O_EXCL`` on a per-term claim file means exactly one of any
  number of racing coordinators wins the right to kill and respawn
  (property-tested in tests/test_heartbeat_props.py).

The module is deliberately tiny and dependency-light (stdlib + the
checkpoint fsync helper); it is the substrate `launch/supervisor.py`
drives and the one every future multi-host PR supervises its hosts
with.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import threading
import time
from typing import Callable, Optional

from ..checkpoint.ckpt import fsync_dir

__all__ = ["LeaseRecord", "TornLease", "write_lease", "read_lease",
           "lease_status", "HeartbeatWriter", "LeaseMonitor",
           "claim_takeover"]


class TornLease(ValueError):
    """A heartbeat file failed its checksum or did not parse.

    The atomic write protocol cannot produce this state, so it means the
    file was damaged externally (or written by something that is not
    this module). A torn lease carries **no liveness evidence** and is
    treated as expired by every consumer — restarting a live worker is
    recoverable, trusting a damaged record is not.
    """


@dataclasses.dataclass(frozen=True)
class LeaseRecord:
    """One heartbeat: who is alive, how alive, and until when.

    ``term`` is the takeover epoch (incremented per adoption, raft
    style) — records from a previous term are a dead incarnation's
    ghost, not evidence about the current worker. ``seq`` increases
    strictly within one writer's life; ``progress`` counts units of real
    work (chunk fetches) so a coordinator can distinguish "alive and
    working" from "alive and stuck". ``mono``/``wall`` are the writer's
    ``time.monotonic()``/``time.time()`` at write; ``ttl`` is the
    renewal deadline the writer promises to beat.
    """

    worker: str
    pid: int
    term: int
    seq: int
    progress: int
    ttl: float
    mono: float
    wall: float

    def to_json(self) -> dict:
        """Plain-dict form, the JSON payload of the lease file."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "LeaseRecord":
        """Rebuild a record from its ``to_json`` dict."""
        return cls(**d)


def _encode(record: LeaseRecord) -> bytes:
    payload = json.dumps(record.to_json(), sort_keys=True).encode()
    digest = hashlib.sha256(payload).hexdigest().encode()
    return payload + b"\n" + digest + b"\n"


def write_lease(path, record: LeaseRecord) -> str:
    """Atomically and durably publish ``record`` at ``path``.

    Same discipline as the checkpoint layer's ``write_json``: the
    payload (plus its checksum line) is written to ``<path>.tmp``,
    fsynced, renamed into place, and the parent directory fsynced — a
    reader sees the previous complete record or the new one, and a
    published beat survives power loss. Returns the final path.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(_encode(record))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(path.parent)
    return str(path)


def read_lease(path) -> Optional[LeaseRecord]:
    """The record at ``path``; None when absent; :class:`TornLease` when
    the file exists but fails its checksum or does not parse.

    Raising (rather than returning None) keeps "never started" and
    "damaged" distinguishable; both classify as expired — see
    :func:`lease_status`.
    """
    path = pathlib.Path(path)
    try:
        raw = path.read_bytes()
    except FileNotFoundError:
        return None
    lines = raw.split(b"\n")
    if len(lines) < 2:
        raise TornLease(f"heartbeat file {path} is truncated "
                        "(no checksum line); treating the lease as expired")
    payload, digest = lines[0], lines[1]
    if hashlib.sha256(payload).hexdigest().encode() != digest:
        raise TornLease(f"heartbeat file {path} fails its checksum — the "
                        "record was torn or damaged mid-write; treating "
                        "the lease as expired")
    try:
        return LeaseRecord.from_json(json.loads(payload.decode()))
    except (ValueError, TypeError) as e:
        raise TornLease(f"heartbeat file {path} checksummed but does not "
                        f"parse as a lease record ({e})") from e


def lease_status(path, ttl: Optional[float] = None,
                 now: Optional[float] = None) -> dict:
    """Same-host classification of the lease at ``path``.

    Returns ``{"state", "expired", "age", "lease"}`` with state one of
    ``absent`` / ``torn`` / ``fresh`` / ``expired``; ``expired`` is True
    for every state except ``fresh`` (no record, a damaged record, and a
    stale record all carry no liveness evidence). Age is measured
    against the *caller's* ``time.monotonic()``, which on Linux is the
    system-wide CLOCK_MONOTONIC and therefore comparable with the
    writer's — cross-host coordinators must use :class:`LeaseMonitor`,
    which never compares clocks across processes.
    """
    now = time.monotonic() if now is None else now
    try:
        lease = read_lease(path)
    except TornLease:
        return {"state": "torn", "expired": True, "age": None, "lease": None}
    if lease is None:
        return {"state": "absent", "expired": True, "age": None,
                "lease": None}
    age = now - lease.mono
    deadline = lease.ttl if ttl is None else ttl
    state = "fresh" if age <= deadline else "expired"
    return {"state": state, "expired": state != "fresh", "age": age,
            "lease": lease}


class HeartbeatWriter:
    """The worker side: renew one lease on a cadence, forever.

    ``start()`` writes an immediate first beat (so the coordinator's
    startup grace is about process launch, not thread scheduling) and
    then renews every ``interval`` seconds from a daemon thread until
    ``stop()``. ``bump(k)`` advances the progress counter from any
    thread; the next beat publishes it. ``seq`` increases strictly per
    write — the monotonicity the coordinator's advancement check and the
    property tests rely on. Usable as a context manager.
    """

    def __init__(self, path, worker: str, term: int, ttl: float,
                 interval: Optional[float] = None,
                 now_fn: Callable[[], float] = time.monotonic):
        if ttl <= 0:
            raise ValueError(f"ttl must be > 0 (got {ttl}): a lease that "
                             "never needs renewal cannot expire")
        self.path = pathlib.Path(path)
        self.worker = str(worker)
        self.term = int(term)
        self.ttl = float(ttl)
        self.interval = float(interval) if interval is not None \
            else self.ttl / 4.0
        self._now = now_fn
        self._seq = 0
        self._progress = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def bump(self, k: int = 1) -> int:
        """Advance the work-progress counter; returns the new value."""
        with self._lock:
            self._progress += int(k)
            return self._progress

    def beat(self) -> LeaseRecord:
        """Write one renewal now (also called by the background thread)."""
        with self._lock:
            self._seq += 1
            record = LeaseRecord(
                worker=self.worker, pid=os.getpid(), term=self.term,
                seq=self._seq, progress=self._progress, ttl=self.ttl,
                mono=self._now(), wall=time.time())
        write_lease(self.path, record)
        return record

    def _run(self):
        while not self._stop.wait(self.interval):
            try:
                self.beat()
            except OSError:
                # A failed renewal must not kill the worker: the solve
                # is still making progress, and the coordinator treating
                # the stale lease as a hang (restart from checkpoint) is
                # the designed, bitwise-safe response.
                pass

    def start(self) -> "HeartbeatWriter":
        """First beat synchronously, then renew from a daemon thread."""
        if self._thread is not None:
            raise RuntimeError("HeartbeatWriter already started")
        self.beat()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"heartbeat-{self.worker}")
        self._thread.start()
        return self

    def stop(self):
        """Stop renewing (the last record is left in place)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=max(1.0, 2 * self.interval))
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


class LeaseMonitor:
    """The coordinator side: staleness by observed advancement only.

    The monitor remembers the last ``(term, seq)`` it saw and *when it
    saw it on its own clock*; the lease is ``fresh`` while seq keeps
    advancing, ``expired`` once it has not advanced for ``ttl`` seconds,
    ``absent`` until a record of ``expect_term`` (or newer) first
    appears — records from older terms are a previous incarnation's
    ghost and count as absent — and ``expired`` immediately when the
    file is torn. ``grace`` bounds the absent state: a worker that never
    writes its first beat within ``grace`` seconds of monitor creation
    classifies as expired (covers a worker that dies before its first
    beat AND one that never starts).

    ``progress_ttl`` (optional) adds stuck-fetch detection: state
    ``stalled`` (also ``expired=True``) when beats keep arriving but
    ``progress`` has not advanced for that long.
    """

    def __init__(self, path, ttl: float, grace: float,
                 expect_term: int = 0,
                 progress_ttl: Optional[float] = None,
                 now_fn: Callable[[], float] = time.monotonic):
        self.path = pathlib.Path(path)
        self.ttl = float(ttl)
        self.grace = float(grace)
        self.expect_term = int(expect_term)
        self.progress_ttl = progress_ttl
        self._now = now_fn
        t = self._now()
        self._born = t
        self._last_seq: Optional[tuple] = None     # (term, seq)
        self._last_advance = t
        self._last_progress: Optional[int] = None
        self._last_progress_advance = t

    def poll(self) -> dict:
        """One observation: ``{"state", "expired", "age", "progress",
        "lease"}``.

        ``age`` is seconds since the last observed seq advancement (or
        since monitor creation while absent) on the monitor's own clock.
        """
        now = self._now()
        try:
            lease = read_lease(self.path)
        except TornLease:
            return {"state": "torn", "expired": True,
                    "age": now - self._last_advance, "progress": None,
                    "lease": None}
        if lease is None or lease.term < self.expect_term:
            age = now - self._born
            return {"state": "absent" if age <= self.grace else "expired",
                    "expired": age > self.grace, "age": age,
                    "progress": None, "lease": lease}
        key = (lease.term, lease.seq)
        if self._last_seq is None or key > self._last_seq:
            self._last_seq = key
            self._last_advance = now
        if self._last_progress is None or lease.progress > self._last_progress:
            self._last_progress = lease.progress
            self._last_progress_advance = now
        age = now - self._last_advance
        if age > self.ttl:
            return {"state": "expired", "expired": True, "age": age,
                    "progress": lease.progress, "lease": lease}
        if self.progress_ttl is not None \
                and now - self._last_progress_advance > self.progress_ttl:
            return {"state": "stalled", "expired": True, "age": age,
                    "progress": lease.progress, "lease": lease}
        return {"state": "fresh", "expired": False, "age": age,
                "progress": lease.progress, "lease": lease}


def claim_takeover(path, term: int) -> bool:
    """Exclusively claim the right to adopt (kill + respawn) a worker.

    The claim for ``term`` is ``<path>.claim_<term>`` created with
    ``O_CREAT | O_EXCL`` — the filesystem's atomic create-if-absent, so
    of any number of coordinators racing to adopt the same expired
    worker exactly one returns True (and proceeds to SIGKILL + respawn
    at ``term``); every other racer returns False and must stand down.
    The claim file records the winner's pid for the post-mortem.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    claim = path.with_name(f"{path.name}.claim_{int(term):08d}")
    try:
        fd = os.open(claim, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    try:
        os.write(fd, f"{os.getpid()}\n".encode())
        os.fsync(fd)
    finally:
        os.close(fd)
    fsync_dir(path.parent)
    return True
