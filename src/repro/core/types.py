"""Problem containers for the generalized knapsack problem (GKP).

Paper: "Solving Billion-Scale Knapsack Problems" (WWW'20), eqs. (1)-(4).

Two instance families are first-class:

* ``DenseKP`` — the general form: N users x M items, K global knapsacks with
  dense cost tensor ``b[i, j, k]`` and laminar (hierarchical) local
  constraints described by boolean index-set masks.
* ``SparseKP`` — the Section 5.1 sparse form: M == K, one item per knapsack
  (``b[i, j, k] = 0`` for j != k, stored as the diagonal ``b[i, k]``) and a
  single cardinality local constraint (choose at most Q items per user).

Both are NamedTuples of arrays, hence JAX pytrees: they can be sharded,
donated and passed through jit/shard_map directly. Static structure
(number of local constraints, Q) travels separately as Python ints.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp


class LaminarSets(NamedTuple):
    """Hierarchical local constraints (Definition 2.1).

    ``sets`` is an (L, M) boolean mask matrix; row l is the index set S_l.
    Rows MUST be in topological (leaf -> root) order: if S_a is a strict
    subset of S_b then a < b. ``caps`` is the (L,) int32 vector of C_l.
    """

    sets: jnp.ndarray  # (L, M) bool
    caps: jnp.ndarray  # (L,) int32


class DenseKP(NamedTuple):
    """General GKP shard: ``p`` (n, M) profits, ``b`` (n, M, K) costs,
    ``budgets`` (K,), plus laminar local constraints."""

    p: jnp.ndarray        # (n, M) f32
    b: jnp.ndarray        # (n, M, K) f32, non-negative
    budgets: jnp.ndarray  # (K,) f32, strictly positive
    sets: jnp.ndarray     # (L, M) bool
    caps: jnp.ndarray     # (L,) int32


class SparseKP(NamedTuple):
    """Section 5.1 sparse GKP shard: item j consumes only knapsack j.

    ``p`` (n, K) profits, ``b`` (n, K) diagonal costs b[i, k, k],
    ``budgets`` (K,). The single local constraint (at most Q items per
    user) is static and passed alongside.
    """

    p: jnp.ndarray        # (n, K) f32
    b: jnp.ndarray        # (n, K) f32, non-negative
    budgets: jnp.ndarray  # (K,) f32, strictly positive


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Static solver configuration (hashable; safe as a jit static arg).

    algo: "scd" (Alg 4) or "dd" (Alg 2).
    reduce: "bucketed" (Section 5.2 production path) or "exact"
        (bit-faithful Alg 4 reduce; gathers candidates, test scale only).
    chunk_size: None runs the per-iteration map over the whole local shard
        at once; an int streams the user axis through the map in fixed-size
        chunks via ``lax.scan`` (see core/solver.py "Chunked map" and the
        chunked-vs-unchunked contract in the ``solve`` docstring). Requires
        ``reduce="bucketed"`` (the exact reduce must see all candidates).
    """

    algo: str = "scd"
    # §4.3.2: synchronous CD updates every lam_k at once (production mode);
    # cyclic CD sweeps coordinates one at a time (K reduces per iteration,
    # converges monotonically on small/strongly-coupled instances).
    cd_mode: str = "sync"
    reduce: str = "bucketed"
    max_iters: int = 32
    tol: float = 1e-3
    # Per-coordinate damping applied to SCD when a multiplier's step
    # reverses direction (delta_t * delta_{t-1} < 0): the step is scaled
    # by this factor. Breaks the sync-CD period-2 limit cycle near the
    # fixed point (bucket-interpolation wobble + Jacobi coupling) by
    # geometrically shrinking oscillations below tol; monotone
    # trajectories are untouched (no reversal, no damping), and DD is
    # exempt (Alg 2's projected step must reach the lam = 0 boundary
    # exactly). 1.0 disables.
    cd_damping: float = 0.5
    # Stream the per-iteration map over user chunks of this size (None =
    # whole shard at once). See core/solver.py.
    chunk_size: Optional[int] = None
    # Override the kernel user-axis tile (None = kernels.ops.pick_tile).
    # Chunked and unchunked kernel paths are bit-identical only when both
    # run the same tile decomposition; tests pin this to compare them.
    kernel_tile: Optional[int] = None
    # DD (Alg 2) learning rate.
    dd_lr: float = 1e-3
    # Section 5.2 bucketing: edges at lam_t +/- delta * growth**i,
    # i in [0, half). n_buckets = 2 * half + 2.
    bucket_half: int = 24
    bucket_delta: float = 1e-4
    bucket_growth: float = 1.6
    # Section 5.3 pre-solving.
    presolve_samples: int = 0  # 0 disables
    # Fraction of map shards the reduce is allowed to proceed with
    # (straggler mitigation; 1.0 = wait for all).
    partial_fraction: float = 1.0
    # Record per-iteration (lam, primal, dual, gap, violation) traces.
    record_history: bool = False
    # Streaming solves only: with record_history, compute the streamed
    # metrics every this-many iterations (each sample is one extra pass
    # over the chunk source; unsampled rows record NaN scalars). 0
    # disables sampling, which makes record_history=True an error when
    # streaming — see core/chunked.stream_solve_fn.
    metrics_every: int = 0
    # Host-fed streaming solves only (core/prefetch.py): write a
    # constant-size StreamCheckpointState through checkpoint/ckpt.py
    # every this-many iterations (and, during the fused finalize pass,
    # every this-many chunk columns), so a preempted solve resumes
    # bitwise from `solve_streaming_host(resume_from=...)`. 0 disables.
    # Requires a checkpoint_dir at the call site; see DESIGN.md §7.
    checkpoint_every: int = 0
    # Streaming checkpoint retention: how many resume states ckpt.prune
    # keeps in the checkpoint directory (must be >= 1 — pruning every
    # step would leave nothing to resume from). Excluded from the
    # resume-state fingerprint like checkpoint_every: changing the
    # retention across a restart is legitimate.
    checkpoint_keep: int = 3
    # Host-fed streaming fault tolerance (core/faults.py): with
    # fetch_retries > 0 every source.fn chunk read — epochs, sharded
    # sub-sources, the presolve head, the fingerprint's chunk-0 probe —
    # runs through a retrying fetcher with capped exponential backoff
    # and deterministic (chunk, attempt)-keyed jitter. Retries re-run
    # only the pure fetch, never the accumulate, so a solve that
    # survives transient faults is bitwise the fault-free solve.
    # 0 disables the wrapper entirely (fail-fast, the historical path).
    # All fetch_* knobs and verify_refetch are excluded from the resume
    # fingerprint: changing the fault policy across a restart is
    # legitimate, like checkpoint_every.
    fetch_retries: int = 0
    fetch_backoff: float = 0.05
    fetch_backoff_growth: float = 2.0
    fetch_backoff_cap: float = 2.0
    fetch_jitter: float = 0.25
    # Per-fetch wall-clock bound in seconds, enforced by a worker
    # thread; overruns are retryable timeouts. 0 disables.
    fetch_timeout: float = 0.0
    # Paranoid fetch-is-pure check: read every chunk twice and require
    # byte-equality, turning silent payload corruption into a detected,
    # retryable fault. Doubles source reads; off by default.
    verify_refetch: bool = False
    # Streaming finalize strategy (core/chunked.py): "fused" folds the
    # final metrics, the §5.4 removable histograms and the projection
    # into ONE pass over the chunk source (iters + 1 total); "legacy"
    # keeps the PR-2 three-pass finalize (metrics, histogram, apply;
    # iters + 3) as the oracle/benchmark baseline. See DESIGN.md §5c.
    stream_finalize: str = "fused"
    # §5.4 group-profit ladder: bucket count (both finalize paths) and
    # the fixed geometric range of the fused single-pass ladder.
    profit_buckets: int = 512
    profit_ladder_lo: float = 1e-6
    profit_ladder_hi: float = 1e6
    # Safe λ-interval active-set screening (core/screening.py): retire
    # chunks whose items provably bin below the bucket ladder for every
    # remaining multiplier value, and skip them in subsequent iteration
    # passes. The screened solve is bitwise-identical to the unscreened
    # oracle (DESIGN.md §11); requires the sync-SCD bucketed streaming
    # path. Excluded from the resume fingerprint like checkpoint_every:
    # screening never steers the trajectory, so toggling it across a
    # restart is legitimate.
    screening: bool = False
    # Floor protocol: each iteration certifies multipliers down to
    # lam * screening_floor; a multiplier escaping below its floor
    # reactivates every chunk for one full pass and re-anchors. Smaller
    # values retire chunks earlier but survive larger downward swings.
    screening_floor: float = 0.5
    # Use the Pallas kernels for the sparse map + histogram (TPU target;
    # interpret-mode on CPU — slow, used for integration testing).
    use_kernels: bool = False
    # Apply the §5.4 feasibility projection to the returned primal.
    postprocess: bool = True
    dtype: jnp.dtype = jnp.float32

    def replace(self, **kw) -> "SolverConfig":
        """Functional update: a copy with the given fields replaced."""
        return dataclasses.replace(self, **kw)


def disjoint_partition_sets(group_sizes, caps, m=None):
    """Build a LaminarSets for disjoint groups of consecutive items."""
    total = int(sum(group_sizes))
    m = total if m is None else m
    rows, start = [], 0
    for g in group_sizes:
        row = jnp.zeros((m,), bool).at[start:start + g].set(True)
        rows.append(row)
        start += g
    return LaminarSets(jnp.stack(rows), jnp.asarray(caps, jnp.int32))


def cardinality_set(m, cap):
    """Single local constraint: choose at most ``cap`` of the m items."""
    return LaminarSets(jnp.ones((1, m), bool), jnp.asarray([cap], jnp.int32))


def hierarchy_from_lists(index_lists, caps, m):
    """LaminarSets from explicit index lists (validated laminar, topo-sorted).

    Raises ValueError if the family is not laminar (Definition 2.1).
    """
    sets = [frozenset(s) for s in index_lists]
    for a in sets:
        for b in sets:
            inter = a & b
            if inter and not (a <= b or b <= a):
                raise ValueError("local constraint family is not laminar")
    order = sorted(range(len(sets)), key=lambda i: len(sets[i]))
    rows = []
    out_caps = []
    for i in order:
        row = jnp.zeros((m,), bool).at[jnp.asarray(sorted(sets[i]), jnp.int32)].set(True)
        rows.append(row)
        out_caps.append(caps[i])
    return LaminarSets(jnp.stack(rows), jnp.asarray(out_caps, jnp.int32))
