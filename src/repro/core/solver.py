"""Distributed GKP solver driver: DD (Alg 2) and SCD (Alg 4).

One jitted program runs the whole iterative solve: the per-iteration
map (candidate generation / greedy solve) happens on the local user shard,
the reduce is a constant-size ``psum`` (bucketed histogram or consumption
vector), and the multiplier update is replicated. Distribution is explicit
``shard_map`` over the mesh with the user dimension sharded across *all*
mesh axes; ``mesh=None`` runs the identical code path on one device.

Deviations from the paper's Spark driver are listed in DESIGN.md §6:
notably the T-iteration loop runs inside the program (no per-iteration
job scheduling) — a ``lax.while_loop`` that exits at convergence, or,
when per-iteration history is recorded, a fixed-length ``lax.scan`` with
converged iterations frozen so the recorded iteration count matches
Alg 2/4 semantics. With ``cfg.use_kernels`` the sparse bucketed path runs
map + reduce as one fused Pallas kernel (kernels/scd_fused.py): only the
(K, E+1) histogram leaves the chip, never the (n, K) candidates.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .bucketing import (
    bucket_histogram,
    exact_threshold,
    make_edges,
    threshold_from_hist,
)
from .greedy import adjusted_profit, consumption, greedy_solve
from .postprocess import (
    feasibility_threshold_bucketed,
    feasibility_threshold_exact,
    group_profit,
)
from .scd import candidates_general
from .sparse_scd import candidates_sparse, consumption_sparse, select_sparse
from .types import DenseKP, SolverConfig, SparseKP

__all__ = ["SolveResult", "solve", "solve_sharded", "dual_objective"]


class SolveResult(NamedTuple):
    lam: jnp.ndarray        # (K,) final multipliers
    x: jnp.ndarray          # (n, K) or (n, M) bool primal solution (post-processed)
    iters: jnp.ndarray      # () int32, iterations until convergence
    r: jnp.ndarray          # (K,) final consumption (post-processed)
    primal: jnp.ndarray     # () primal objective (post-processed)
    dual: jnp.ndarray       # () dual objective at lam
    history: Optional[dict]  # per-iteration records when cfg asks


# --------------------------------------------------------------------------
# Per-iteration lambda updates (map + reduce fused).
# --------------------------------------------------------------------------

def _psum(x, axis):
    return jax.lax.psum(x, axis) if axis is not None else x


def _flat_axis_index(axis):
    """Flattened linear index across one or many mesh axes."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    idx = jnp.int32(0)
    for name in names:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


def _straggler_mask(cfg, axis):
    """Simulated straggler mitigation: proceed with a fraction of shards.

    Map results from slow shards are dropped and the histogram is unbiased
    by 1/fraction (same estimator as §5.3 pre-solving). With
    partial_fraction == 1.0 this is the identity.
    """
    if axis is None or cfg.partial_fraction >= 1.0:
        return 1.0, 1.0
    idx = _flat_axis_index(axis)
    size = jax.lax.psum(1, axis)
    keep = (idx.astype(jnp.float32) + 1.0) <= cfg.partial_fraction * size
    frac = jnp.maximum(cfg.partial_fraction, 1.0 / size)
    return keep.astype(jnp.float32), 1.0 / frac


def _scd_candidates(kp, lam, q, cfg=None):
    """Alg 5 (sparse) or Alg 3 (dense) map. Returns v1, v2: (Z, K)."""
    if isinstance(kp, SparseKP):
        if cfg is not None and cfg.use_kernels:
            from ..kernels import ops as kops
            n = kp.p.shape[0]
            return kops.scd_candidates(kp.p, kp.b, lam, q,
                                       tile_n=kops.pick_tile(n))
        return candidates_sparse(kp.p, kp.b, lam, q)       # (n, K)
    v1, v2 = candidates_general(kp.p, kp.b, lam, kp.sets, kp.caps)
    n, k, pp = v1.shape
    v1 = v1.transpose(0, 2, 1).reshape(n * pp, k)
    v2 = v2.transpose(0, 2, 1).reshape(n * pp, k)
    return v1, v2


def _scd_reduce(v1, v2, lam, budgets, cfg, axis):
    """Alg 4 reduce over all K coordinates: exact or §5.2 bucketed."""
    if cfg.reduce == "exact":
        if axis is not None:
            v1 = jax.lax.all_gather(v1, axis, axis=0, tiled=True)
            v2 = jax.lax.all_gather(v2, axis, axis=0, tiled=True)
        return jax.vmap(exact_threshold, in_axes=(1, 1, 0))(v1, v2, budgets)
    edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth, cfg.bucket_half)
    if cfg.use_kernels:
        from ..kernels import ops as kops
        hist = kops.bucket_hist(v1, v2, edges,
                                tile_n=kops.pick_tile(v1.shape[0]))
    else:
        hist = bucket_histogram(v1, v2, edges)
    top = jnp.max(v1, axis=0)
    hist = _psum(hist, axis)
    top = jax.lax.pmax(top, axis) if axis is not None else top
    return threshold_from_hist(hist, edges, budgets, top)


def _scd_step_fused(kp, lam, q, keep, scale, cfg, axis):
    """Map + bucketed reduce in ONE Pallas kernel (sparse GKP hot path).

    The (n, K) candidate arrays stay in VMEM; only the (K, E+1) histogram
    and the (K,) running max reach HBM / the mesh collective. The
    straggler mask multiplies the histogram instead of v2 — the histogram
    is linear in v2, so the estimator is unchanged.
    """
    from ..kernels import ops as kops
    edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth, cfg.bucket_half)
    hist, top = kops.scd_fused_hist(kp.p, kp.b, lam, edges, q,
                                    tile_n=kops.pick_tile(kp.p.shape[0]))
    hist = _psum(hist * (keep * scale), axis)
    top = jax.lax.pmax(top, axis) if axis is not None else top
    return threshold_from_hist(hist, edges, kp.budgets, top)


def _scd_update(kp, lam, q, cfg, axis):
    """One SCD iteration: candidates -> reduce -> new lam.

    cd_mode "sync": all K coordinates updated from one map pass (Alg 4).
    cd_mode "cyclic": K passes, coordinate k re-mapped at the already
    updated multipliers (classic Gauss-Seidel CD; §4.3.2's other mode).
    """
    keep, scale = _straggler_mask(cfg, axis)
    fused = (isinstance(kp, SparseKP) and cfg.use_kernels
             and cfg.reduce == "bucketed")
    if cfg.cd_mode == "cyclic":
        k = kp.budgets.shape[0]
        for kk in range(k):
            if fused:
                lam_k = _scd_step_fused(kp, lam, q, keep, scale, cfg, axis)[kk]
            else:
                v1, v2 = _scd_candidates(kp, lam, q, cfg)
                lam_k = _scd_reduce(v1, v2 * keep * scale, lam, kp.budgets,
                                    cfg, axis)[kk]
            lam = lam.at[kk].set(lam_k)
        return lam
    if fused:
        return _scd_step_fused(kp, lam, q, keep, scale, cfg, axis)
    v1, v2 = _scd_candidates(kp, lam, q, cfg)
    return _scd_reduce(v1, v2 * keep * scale, lam, kp.budgets, cfg, axis)


def _solve_primal(kp, lam, q):
    """Greedy primal solution and its consumption at multipliers lam."""
    if isinstance(kp, SparseKP):
        x = select_sparse(kp.p, kp.b, lam, q)
        cons = kp.b * x.astype(kp.b.dtype)                 # (n, K) per-user
    else:
        x = greedy_solve(adjusted_profit(kp.p, kp.b, lam), kp.sets, kp.caps)
        cons = consumption(kp.b, x)                        # (n, K)
    return x, cons


def _dd_update(kp, lam, q, cfg, axis):
    """Alg 2: projected sub-gradient step on the dual."""
    _, cons = _solve_primal(kp, lam, q)
    keep, scale = _straggler_mask(cfg, axis)
    r = _psum(jnp.sum(cons, axis=0) * keep, axis) * scale  # (K,)
    return jnp.maximum(lam + cfg.dd_lr * (r - kp.budgets), 0.0)


def dual_objective(kp, lam, q, axis=None, primal=None):
    """g(lam) = sum_i max_x [ p~ . x_i ] + lam . B  (upper bounds the IP).

    ``primal`` optionally passes a precomputed ``_solve_primal`` result so
    callers that already ran the map pass at lam don't run it twice.
    """
    x, _ = _solve_primal(kp, lam, q) if primal is None else primal
    if isinstance(kp, SparseKP):
        ap = kp.p - lam[None, :] * kp.b
        per_user = jnp.sum(jnp.where(x, ap, 0.0), axis=-1)
    else:
        ap = adjusted_profit(kp.p, kp.b, lam)
        per_user = jnp.sum(jnp.where(x, ap, 0.0), axis=-1)
    tot = _psum(jnp.sum(per_user), axis)
    return tot + jnp.dot(lam, kp.budgets)


# --------------------------------------------------------------------------
# Driver.
# --------------------------------------------------------------------------

def _metrics(kp, lam, q, axis):
    x, cons = _solve_primal(kp, lam, q)
    r = _psum(jnp.sum(cons, axis=0), axis)
    primal = _psum(jnp.sum(jnp.where(x, kp.p, 0.0)), axis)
    dual = dual_objective(kp, lam, q, axis, primal=(x, cons))
    viol = jnp.max(jnp.maximum(r - kp.budgets, 0.0) / kp.budgets)
    return x, cons, r, primal, dual, viol


def _solve_local(kp, lam0, q, cfg, axis=None):
    """The full solve on one shard (axis=None) or inside shard_map.

    record_history=True runs a fixed-length ``lax.scan`` (converged
    iterations frozen) so every recorded trace has ``max_iters`` rows.
    record_history=False runs the same step inside a ``lax.while_loop``
    that exits at convergence — no frozen iterations are computed. Both
    drivers share ``step``, so lam / iters trajectories are identical.
    """
    update = _scd_update if cfg.algo == "scd" else _dd_update

    def step(carry, _):
        lam, it, done = carry
        lam_new = update(kp, lam, q, cfg, axis)
        moved = jnp.max(jnp.abs(lam_new - lam)) > cfg.tol * (1.0 + jnp.max(lam))
        lam_next = jnp.where(done, lam, lam_new)
        it_next = it + jnp.where(done, 0, 1).astype(jnp.int32)
        done_next = done | ~moved
        if cfg.record_history:
            _, _, r, primal, dual, viol = _metrics(kp, lam_next, q, axis)
            rec = {
                "lam": lam_next,
                "primal": primal,
                "dual": dual,
                "gap": dual - primal,
                "max_violation": viol,
            }
        else:
            rec = None
        return (lam_next, it_next, done_next), rec

    init = (lam0, jnp.int32(0), jnp.asarray(False))
    if cfg.record_history:
        (lam, iters, _), hist = jax.lax.scan(
            step, init, None, length=cfg.max_iters
        )
    else:
        (lam, iters, _) = jax.lax.while_loop(
            lambda c: (c[1] < cfg.max_iters) & ~c[2],
            lambda c: step(c, None)[0],
            init,
        )
        hist = None

    # Final primal + §5.4 feasibility projection.
    x, cons, r, primal, dual, _ = _metrics(kp, lam, q, axis)
    if cfg.postprocess:
        pt = group_profit(kp.p, cons, lam, x)
        if axis is None:
            tau = feasibility_threshold_exact(pt, cons, kp.budgets)
        else:
            tau = feasibility_threshold_bucketed(pt, cons, r, kp.budgets, axis)
        drop = pt <= tau
        x = x & ~drop[:, None]
        cons = cons * (~drop[:, None]).astype(cons.dtype)
        r = _psum(jnp.sum(cons, axis=0), axis)
        primal = _psum(jnp.sum(jnp.where(x, kp.p, 0.0)), axis)
    return SolveResult(lam, x, iters, r, primal, dual, hist)


def _presolve(kp, lam0, q, cfg, axis):
    """§5.3: warm-start lam by solving a sampled shard with scaled budgets."""
    s = cfg.presolve_samples
    if s <= 0:
        return lam0
    n = kp.p.shape[0]
    s = min(s, n)
    # Sampled users per shard / users per shard == global sample fraction.
    frac = s / n
    small = kp._replace(
        p=kp.p[:s],
        b=kp.b[:s],
        budgets=kp.budgets * frac,
    )
    sub_cfg = cfg.replace(
        presolve_samples=0, record_history=False, postprocess=False
    )
    res = _solve_local(small, lam0, q, sub_cfg, axis)
    return res.lam


def _solve_entry(kp, lam0, q, cfg, axis):
    lam0 = _presolve(kp, lam0, q, cfg, axis)
    return _solve_local(kp, lam0, q, cfg, axis)


# --------------------------------------------------------------------------
# Public API.
# --------------------------------------------------------------------------

def solve(kp, cfg: SolverConfig = SolverConfig(), q: int = 1, lam0=None):
    """Single-device solve (the N-user shard fits on one device)."""
    k = kp.budgets.shape[0]
    if lam0 is None:
        lam0 = jnp.ones((k,), cfg.dtype)
    fn = jax.jit(
        functools.partial(_solve_entry, q=q, cfg=cfg, axis=None),
    )
    return fn(kp, lam0)


def solve_sharded(kp, mesh, cfg: SolverConfig = SolverConfig(), q: int = 1,
                  lam0=None, axes: Optional[tuple] = None):
    """Multi-device solve: users sharded over every axis of ``mesh``.

    ``kp`` holds *global* arrays (or ShapeDtypeStructs for AOT lowering);
    the user dimension must divide the mesh size. Returns globally
    replicated lam/scalars and a user-sharded x.
    """
    axes = tuple(mesh.axis_names) if axes is None else axes
    k = kp.budgets.shape[0]
    if lam0 is None:
        lam0 = jnp.ones((k,), cfg.dtype)
    user_spec = P(axes)
    if isinstance(kp, SparseKP):
        in_kp_specs = SparseKP(p=user_spec, b=user_spec, budgets=P())
        x_spec = P(axes, None)
    else:
        in_kp_specs = DenseKP(
            p=user_spec, b=user_spec, budgets=P(), sets=P(), caps=P()
        )
        x_spec = P(axes, None)
    out_specs = SolveResult(
        lam=P(), x=x_spec, iters=P(), r=P(), primal=P(), dual=P(),
        history=None if not getattr(cfg, "record_history", False) else {
            "lam": P(), "primal": P(), "dual": P(), "gap": P(),
            "max_violation": P(),
        },
    )
    fn = shard_map(
        functools.partial(_solve_entry, q=q, cfg=cfg, axis=axes),
        mesh=mesh,
        in_specs=(in_kp_specs, P()),
        out_specs=out_specs,
        # lam/scalars are replicated by construction (psum / tiled gather);
        # VMA inference cannot see that through the gather, so opt out.
        check_vma=False,
    )
    return jax.jit(fn)(kp, lam0)
