"""Distributed GKP solver driver: DD (Alg 2) and SCD (Alg 4).

One jitted program runs the whole iterative solve: the per-iteration
map (candidate generation / greedy solve) happens on the local user shard,
the reduce is a constant-size ``psum`` (bucketed histogram or consumption
vector), and the multiplier update is replicated. Distribution is explicit
``shard_map`` over the mesh with the user dimension sharded across *all*
mesh axes; ``mesh=None`` runs the identical code path on one device.

Deviations from the paper's Spark driver are listed in DESIGN.md §6:
notably the T-iteration loop runs inside the program (no per-iteration
job scheduling) — a ``lax.while_loop`` that exits at convergence, or,
when per-iteration history is recorded, a fixed-length ``lax.scan`` with
converged iterations frozen so the recorded iteration count matches
Alg 2/4 semantics. With ``cfg.use_kernels`` the sparse bucketed path runs
map + reduce as one fused Pallas kernel (kernels/scd_fused.py): only the
(K, E+1) histogram leaves the chip, never the (n, K) candidates.

Chunked map (``cfg.chunk_size``)
--------------------------------
With ``chunk_size=c`` the per-iteration map becomes a ``lax.scan`` over
fixed-size user chunks: each chunk is driven through the same map
(fused Pallas kernel or jnp candidates), accumulating into the running
(K, E+1) histogram / (K,) top (SCD) or (K,) consumption (DD). The
device-resident *working set* of an iteration is then O(c·K + K·E)
instead of O(n·K) — the shard's input arrays remain resident, so this
mode bounds intermediates, not inputs. For instances whose inputs do not
fit device memory, use :mod:`repro.core.chunked` (``solve_streaming``),
which generates or uploads chunks on the fly and keeps *nothing* O(n) on
device.

Chunked-vs-unchunked contract: with ``reduce="bucketed"`` the chunked
solve is **bit-identical** to the unchunked one — the histogram is
accumulated by seeding each chunk's scatter-add (jnp path) or Pallas
accumulator (kernel path) with the carried value, so the f32 addition
chain over rows is exactly the one the unchunked reduce performs. On the
kernel path this additionally requires the same user-tile decomposition
on both sides (``cfg.kernel_tile`` pins it; the default tile is derived
from the chunk size). The exact reduce cannot be chunked (it must sort
all candidates) and raises ``ValueError``. DD's consumption reduce is a
plain sum whose grouping follows the chunking, so chunked DD matches
unchunked DD only to f32 reduce-order (~1 ulp), not bitwise.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .bucketing import (
    bucket_histogram,
    exact_threshold,
    make_edges,
    threshold_from_hist,
)
from .greedy import adjusted_profit, consumption, greedy_solve
from .postprocess import (
    feasibility_threshold_bucketed,
    feasibility_threshold_exact,
    group_profit,
)
from .scd import candidates_general
from .sparse_scd import candidates_sparse, select_sparse
from .types import DenseKP, SolverConfig, SparseKP

__all__ = ["SolveResult", "solve", "solve_sharded", "dual_objective"]


class SolveResult(NamedTuple):
    """Everything a solve returns. Scalars/lam are replicated across the
    mesh; ``x`` is user-sharded like the inputs. ``x``/``history`` are
    ``None`` when the solve mode does not produce them (streaming solves
    never materialise x; history only exists with record_history)."""

    lam: jnp.ndarray        # (K,) final multipliers
    x: jnp.ndarray          # (n, K) or (n, M) bool primal solution (post-processed)
    iters: jnp.ndarray      # () int32, iterations until convergence
    r: jnp.ndarray          # (K,) final consumption (post-processed)
    primal: jnp.ndarray     # () primal objective (post-processed)
    dual: jnp.ndarray       # () dual objective at lam
    history: Optional[dict]  # per-iteration records when cfg asks


# --------------------------------------------------------------------------
# Per-iteration lambda updates (map + reduce fused).
# --------------------------------------------------------------------------

def _psum(x, axis):
    return jax.lax.psum(x, axis) if axis is not None else x


def _flat_axis_index(axis):
    """Flattened linear index across one or many mesh axes."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    idx = jnp.int32(0)
    for name in names:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


def _straggler_mask(cfg, axis):
    """Simulated straggler mitigation: proceed with a fraction of shards.

    Map results from slow shards are dropped and the histogram is unbiased
    by 1/fraction (same estimator as §5.3 pre-solving). With
    partial_fraction == 1.0 this is the identity.
    """
    if axis is None or cfg.partial_fraction >= 1.0:
        return 1.0, 1.0
    idx = _flat_axis_index(axis)
    size = jax.lax.psum(1, axis)
    keep = (idx.astype(jnp.float32) + 1.0) <= cfg.partial_fraction * size
    frac = jnp.maximum(cfg.partial_fraction, 1.0 / size)
    return keep.astype(jnp.float32), 1.0 / frac


def _kernel_tile(cfg, n):
    """User-axis tile for the Pallas kernels: cfg override or the ladder."""
    from ..kernels import ops as kops
    return cfg.kernel_tile if cfg.kernel_tile else kops.pick_tile(n)


def _scd_candidates(kp, lam, q, cfg=None):
    """Alg 5 (sparse) or Alg 3 (dense) map. Returns v1, v2: (Z, K)."""
    if isinstance(kp, SparseKP):
        if cfg is not None and cfg.use_kernels:
            from ..kernels import ops as kops
            n = kp.p.shape[0]
            return kops.scd_candidates(kp.p, kp.b, lam, q,
                                       tile_n=_kernel_tile(cfg, n))
        return candidates_sparse(kp.p, kp.b, lam, q)       # (n, K)
    v1, v2 = candidates_general(kp.p, kp.b, lam, kp.sets, kp.caps)
    n, k, pp = v1.shape
    v1 = v1.transpose(0, 2, 1).reshape(n * pp, k)
    v2 = v2.transpose(0, 2, 1).reshape(n * pp, k)
    return v1, v2


def _scd_reduce(v1, v2, lam, budgets, cfg, axis):
    """Alg 4 reduce over all K coordinates: exact or §5.2 bucketed."""
    if cfg.reduce == "exact":
        if axis is not None:
            v1 = jax.lax.all_gather(v1, axis, axis=0, tiled=True)
            v2 = jax.lax.all_gather(v2, axis, axis=0, tiled=True)
        return jax.vmap(exact_threshold, in_axes=(1, 1, 0))(v1, v2, budgets)
    edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth, cfg.bucket_half)
    if cfg.use_kernels:
        from ..kernels import ops as kops
        hist = kops.bucket_hist(v1, v2, edges,
                                tile_n=_kernel_tile(cfg, v1.shape[0]))
    else:
        hist = bucket_histogram(v1, v2, edges)
    top = jnp.max(v1, axis=0)
    hist = _psum(hist, axis)
    top = jax.lax.pmax(top, axis) if axis is not None else top
    return threshold_from_hist(hist, edges, budgets, top)


def _scd_step_fused(kp, lam, q, keep, scale, cfg, axis):
    """Map + bucketed reduce in ONE Pallas kernel (sparse GKP hot path).

    The (n, K) candidate arrays stay in VMEM; only the (K, E+1) histogram
    and the (K,) running max reach HBM / the mesh collective. The
    straggler mask multiplies the histogram instead of v2 — the histogram
    is linear in v2, so the estimator is unchanged.
    """
    from ..kernels import ops as kops
    edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth, cfg.bucket_half)
    hist, top = kops.scd_fused_hist(kp.p, kp.b, lam, edges, q,
                                    tile_n=_kernel_tile(cfg, kp.p.shape[0]))
    hist = _psum(hist * (keep * scale), axis)
    top = jax.lax.pmax(top, axis) if axis is not None else top
    return threshold_from_hist(hist, edges, kp.budgets, top)


# --------------------------------------------------------------------------
# Chunked map: lax.scan over fixed-size user chunks.
# --------------------------------------------------------------------------

def _chunk_xs(kp, chunk):
    """Pad the user axis to a chunk multiple and reshape for lax.scan.

    Returns (p, b) reshaped to (C, chunk, ...). Padded rows are
    ``p = b = 0`` — inert everywhere: invalid SCD candidates (v1 = -1,
    v2 = 0, zero histogram mass, never raise the running max), never
    selected by the greedy primal (adjusted profit 0), zero consumption.
    Scatter-adding their zero mass onto the histogram is bit-invisible
    (x + 0.0 == x for the non-negative masses involved), which is what
    keeps the ragged-final-chunk case bit-identical to unchunked.
    """
    n = kp.p.shape[0]
    c = -(-n // chunk)
    pad = c * chunk - n

    def rs(a):
        if pad:
            a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        return a.reshape((c, chunk) + a.shape[1:])

    return rs(kp.p), rs(kp.b)


def scd_chunk_accumulate(p_c, b_c, lam, edges, q, cfg, hist, top,
                         keep=None, scale=None):
    """Fold one user chunk into the running SCD (hist, top) accumulators.

    p_c, b_c: (c, K) sparse chunk; hist: (K, E+1) f32; top: (K,). The
    carried accumulators *seed* the chunk's reduction (Pallas accumulator
    init / scatter-add operand) rather than being summed with a
    per-chunk sub-histogram afterwards — that seeding is the bitwise
    chunked==unchunked guarantee (see the module docstring). ``keep`` /
    ``scale`` (straggler mask) are applied per-row on the jnp path,
    matching the unfused unchunked convention; the fused kernel path
    scales the final histogram instead (both are exact: the histogram is
    linear in v2). Shared by the in-memory chunked solve below and the
    streaming driver in core/chunked.py.
    """
    if cfg.use_kernels:
        from ..kernels import ops as kops
        return kops.scd_fused_hist(p_c, b_c, lam, edges, q,
                                   tile_n=_kernel_tile(cfg, p_c.shape[0]),
                                   hist_init=hist, top_init=top)
    v1, v2 = candidates_sparse(p_c, b_c, lam, q)
    if keep is not None:
        v2 = v2 * keep * scale
    hist = bucket_histogram(v1, v2, edges, init=hist)
    top = jnp.maximum(top, jnp.max(v1, axis=0))
    return hist, top


def _scd_pass_chunked(kp, lam, q, keep, scale, cfg, axis, fused):
    """One SCD map+reduce with the user axis streamed in chunks."""
    edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth, cfg.bucket_half)
    k = kp.budgets.shape[0]
    hist0 = jnp.zeros((k, edges.shape[-1] + 1), jnp.float32)
    top0 = jnp.full((k,), -jnp.inf, kp.p.dtype)
    xs = _chunk_xs(kp, cfg.chunk_size)
    dense = isinstance(kp, DenseKP)

    def body(carry, xs_c):
        hist, top = carry
        p_c, b_c = xs_c
        if dense:
            v1, v2 = candidates_general(p_c, b_c, lam, kp.sets, kp.caps)
            c, kk, pp = v1.shape
            v1 = v1.transpose(0, 2, 1).reshape(c * pp, kk)
            v2 = v2.transpose(0, 2, 1).reshape(c * pp, kk) * keep * scale
            hist = bucket_histogram(v1, v2, edges, init=hist)
            top = jnp.maximum(top, jnp.max(v1, axis=0))
        elif fused:
            hist, top = scd_chunk_accumulate(p_c, b_c, lam, edges, q, cfg,
                                             hist, top)
        else:
            hist, top = scd_chunk_accumulate(p_c, b_c, lam, edges, q, cfg,
                                             hist, top, keep, scale)
        return (hist, top), None

    (hist, top), _ = jax.lax.scan(body, (hist0, top0), xs)
    if fused:
        hist = hist * (keep * scale)
    hist = _psum(hist, axis)
    top = jax.lax.pmax(top, axis) if axis is not None else top
    return threshold_from_hist(hist, edges, kp.budgets, top)


def _scd_pass(kp, lam, q, keep, scale, cfg, axis):
    """One full SCD map+reduce at ``lam`` -> proposed multipliers (K,)."""
    fused = (isinstance(kp, SparseKP) and cfg.use_kernels
             and cfg.reduce == "bucketed")
    if cfg.chunk_size is not None:
        return _scd_pass_chunked(kp, lam, q, keep, scale, cfg, axis, fused)
    if fused:
        return _scd_step_fused(kp, lam, q, keep, scale, cfg, axis)
    v1, v2 = _scd_candidates(kp, lam, q, cfg)
    return _scd_reduce(v1, v2 * keep * scale, lam, kp.budgets, cfg, axis)


def _scd_update(kp, lam, q, cfg, axis):
    """One SCD iteration: candidates -> reduce -> new lam.

    cd_mode "sync": all K coordinates updated from one map pass (Alg 4).
    cd_mode "cyclic": K passes, coordinate k re-mapped at the already
    updated multipliers (classic Gauss-Seidel CD; §4.3.2's other mode).
    """
    keep, scale = _straggler_mask(cfg, axis)
    if cfg.cd_mode == "cyclic":
        for kk in range(kp.budgets.shape[0]):
            lam_k = _scd_pass(kp, lam, q, keep, scale, cfg, axis)[kk]
            lam = lam.at[kk].set(lam_k)
        return lam
    return _scd_pass(kp, lam, q, keep, scale, cfg, axis)


def _solve_primal(kp, lam, q):
    """Greedy primal solution and its consumption at multipliers lam."""
    if isinstance(kp, SparseKP):
        x = select_sparse(kp.p, kp.b, lam, q)
        cons = kp.b * x.astype(kp.b.dtype)                 # (n, K) per-user
    else:
        x = greedy_solve(adjusted_profit(kp.p, kp.b, lam), kp.sets, kp.caps)
        cons = consumption(kp.b, x)                        # (n, K)
    return x, cons


def _dd_update(kp, lam, q, cfg, axis):
    """Alg 2: projected sub-gradient step on the dual.

    With ``cfg.chunk_size`` the shard consumption is accumulated chunk by
    chunk (running (K,) carry); the grouping of that sum follows the
    chunking, so chunked DD tracks unchunked DD to reduce-order (~1 ulp),
    not bitwise — see the module docstring.
    """
    keep, scale = _straggler_mask(cfg, axis)
    if cfg.chunk_size is None:
        _, cons = _solve_primal(kp, lam, q)
        r = jnp.sum(cons, axis=0)
    else:
        def body(r, xs_c):
            ck = kp._replace(p=xs_c[0], b=xs_c[1])
            _, cons = _solve_primal(ck, lam, q)
            return r + jnp.sum(cons, axis=0), None
        r, _ = jax.lax.scan(body, jnp.zeros_like(lam),
                            _chunk_xs(kp, cfg.chunk_size))
    r = _psum(r * keep, axis) * scale                      # (K,)
    return jnp.maximum(lam + cfg.dd_lr * (r - kp.budgets), 0.0)


def dual_objective(kp, lam, q, axis=None, primal=None):
    """g(lam) = sum_i max_x [ p~ . x_i ] + lam . B  (upper bounds the IP).

    ``primal`` optionally passes a precomputed ``_solve_primal`` result so
    callers that already ran the map pass at lam don't run it twice.
    """
    x, _ = _solve_primal(kp, lam, q) if primal is None else primal
    if isinstance(kp, SparseKP):
        ap = kp.p - lam[None, :] * kp.b
        per_user = jnp.sum(jnp.where(x, ap, 0.0), axis=-1)
    else:
        ap = adjusted_profit(kp.p, kp.b, lam)
        per_user = jnp.sum(jnp.where(x, ap, 0.0), axis=-1)
    tot = _psum(jnp.sum(per_user), axis)
    return tot + jnp.dot(lam, kp.budgets)


# --------------------------------------------------------------------------
# Driver.
# --------------------------------------------------------------------------

def damped_multiplier_step(lam, dprev, prop, cfg):
    """One damped fixed-point step: proposed lam -> (lam_new, delta, moved).

    The single definition of the reversal-damping and convergence
    arithmetic (see :func:`iterate_multipliers` for the rationale),
    shared by the traced drivers here and the host-fed epoch driver
    (core/prefetch.py) — a second copy would silently break their
    bit-identical-trajectory contract the first time one was edited.
    """
    delta = prop - lam
    if cfg.cd_damping < 1.0 and cfg.algo == "scd":
        delta = delta * jnp.where(delta * dprev < 0.0, cfg.cd_damping, 1.0)
    lam_new = lam + delta
    moved = jnp.max(jnp.abs(lam_new - lam)) > cfg.tol * (1.0 + jnp.max(lam))
    return lam_new, delta, moved


def iterate_multipliers(update, lam0, cfg, metrics_fn=None, aux0=None):
    """Run the damped multiplier fixed-point iteration to convergence.

    ``update``: lam -> proposed lam (one Alg 2/4 iteration at lam).
    ``metrics_fn``: (lam, it) -> history record dict, called per
    iteration when ``cfg.record_history`` (fixed-length ``lax.scan``,
    converged iterations frozen; ``it`` is the just-finished iteration
    number, frozen too, so samplers like the streaming
    ``cfg.metrics_every`` path can key off it); otherwise a
    ``lax.while_loop`` exits at convergence. Both drivers share one step
    function, so lam / iters trajectories are bit-identical between
    them.

    Damping (``cfg.cd_damping``, SCD only): a coordinate whose step
    reverses sign relative to the previous iteration
    (delta_t * delta_{t-1} < 0) has its step scaled by the damping
    factor. This breaks the sync-CD period-2 limit cycle
    (bucket-interpolation wobble + Jacobi coupling keeps |delta|
    plateaued just above tol on small tight instances): each reversal
    halves the oscillation, so movement drops below tol geometrically.
    Monotone coordinates never see a reversal and are untouched. DD is
    exempt — its projected sub-gradient step (Alg 2) must be allowed to
    land exactly on the lam = 0 boundary, which a half-step would
    overshoot into the interior. Shared by the in-memory and streaming
    solve drivers, so their trajectories agree bit-for-bit given
    bit-identical updates.

    ``aux0``: optional pytree of auxiliary loop state the update owns
    (active-set screening carries its survivor masks / bounds through
    the loop this way). When given, ``update`` is called as
    ``update(lam, aux) -> (prop, aux_new)`` and the aux is frozen — like
    lam — once the solve converges (fixed-length scan mode keeps
    stepping the frozen carry). The no-aux path below is byte-for-byte
    the historical step function; the aux path is a separate closure so
    existing traced programs keep their exact jaxpr.

    Returns (lam, iters, history) — or (lam, iters, history, aux) when
    ``aux0`` is given.
    """
    def step(carry, _):
        lam, dprev, it, done = carry
        prop = update(lam)
        lam_new, delta, moved = damped_multiplier_step(lam, dprev, prop, cfg)
        lam_next = jnp.where(done, lam, lam_new)
        d_next = jnp.where(done, dprev, delta)
        it_next = it + jnp.where(done, 0, 1).astype(jnp.int32)
        done_next = done | ~moved
        rec = metrics_fn(lam_next, it_next) if cfg.record_history else None
        return (lam_next, d_next, it_next, done_next), rec

    def step_aux(carry, _):
        lam, dprev, it, done, aux = carry
        prop, aux_new = update(lam, aux)
        lam_new, delta, moved = damped_multiplier_step(lam, dprev, prop, cfg)
        lam_next = jnp.where(done, lam, lam_new)
        d_next = jnp.where(done, dprev, delta)
        it_next = it + jnp.where(done, 0, 1).astype(jnp.int32)
        done_next = done | ~moved
        aux_next = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), aux, aux_new)
        rec = metrics_fn(lam_next, it_next) if cfg.record_history else None
        return (lam_next, d_next, it_next, done_next, aux_next), rec

    init = (lam0, jnp.zeros_like(lam0), jnp.int32(0), jnp.asarray(False))
    body = step if aux0 is None else step_aux
    if aux0 is not None:
        init = init + (aux0,)
    if cfg.record_history:
        out, hist = jax.lax.scan(body, init, None, length=cfg.max_iters)
    else:
        out = jax.lax.while_loop(
            lambda c: (c[2] < cfg.max_iters) & ~c[3],
            lambda c: body(c, None)[0],
            init,
        )
        hist = None
    lam, iters = out[0], out[2]
    if aux0 is None:
        return lam, iters, hist
    return lam, iters, hist, out[4]


def _metrics(kp, lam, q, axis):
    x, cons = _solve_primal(kp, lam, q)
    r = _psum(jnp.sum(cons, axis=0), axis)
    primal = _psum(jnp.sum(jnp.where(x, kp.p, 0.0)), axis)
    dual = dual_objective(kp, lam, q, axis, primal=(x, cons))
    viol = jnp.max(jnp.maximum(r - kp.budgets, 0.0) / kp.budgets)
    return x, cons, r, primal, dual, viol


def _solve_local(kp, lam0, q, cfg, axis=None):
    """The full solve on one shard (axis=None) or inside shard_map.

    The iteration loop is ``iterate_multipliers`` (while_loop fast path /
    scan history path). The final primal, metrics and §5.4 projection run
    over the whole resident shard even when ``cfg.chunk_size`` chunks the
    iteration map — the inputs are resident in this mode anyway, and it
    makes every SolveResult field bit-identical to the unchunked solve
    once lam is (the streaming driver in core/chunked.py is the one that
    must also stream these passes).
    """
    update_fn = _scd_update if cfg.algo == "scd" else _dd_update
    update = functools.partial(update_fn, kp, q=q, cfg=cfg, axis=axis)

    def metrics_fn(lam, _it):
        _, _, r, primal, dual, viol = _metrics(kp, lam, q, axis)
        return {
            "lam": lam,
            "primal": primal,
            "dual": dual,
            "gap": dual - primal,
            "max_violation": viol,
        }

    lam, iters, hist = iterate_multipliers(
        lambda lam: update(lam), lam0, cfg, metrics_fn
    )

    # Final primal + §5.4 feasibility projection.
    x, cons, r, primal, dual, _ = _metrics(kp, lam, q, axis)
    if cfg.postprocess:
        pt = group_profit(kp.p, cons, lam, x)
        if axis is None:
            tau = feasibility_threshold_exact(pt, cons, kp.budgets)
        else:
            tau = feasibility_threshold_bucketed(pt, cons, r, kp.budgets, axis)
        drop = pt <= tau
        x = x & ~drop[:, None]
        cons = cons * (~drop[:, None]).astype(cons.dtype)
        r = _psum(jnp.sum(cons, axis=0), axis)
        primal = _psum(jnp.sum(jnp.where(x, kp.p, 0.0)), axis)
    return SolveResult(lam, x, iters, r, primal, dual, hist)


def _presolve(kp, lam0, q, cfg, axis):
    """§5.3: warm-start lam by solving a sampled shard with scaled budgets."""
    s = cfg.presolve_samples
    if s <= 0:
        return lam0
    n = kp.p.shape[0]
    s = min(s, n)
    # Sampled users per shard / users per shard == global sample fraction.
    frac = s / n
    small = kp._replace(
        p=kp.p[:s],
        b=kp.b[:s],
        budgets=kp.budgets * frac,
    )
    sub_cfg = cfg.replace(
        presolve_samples=0, record_history=False, postprocess=False
    )
    res = _solve_local(small, lam0, q, sub_cfg, axis)
    return res.lam


def _solve_entry(kp, lam0, q, cfg, axis):
    lam0 = _presolve(kp, lam0, q, cfg, axis)
    return _solve_local(kp, lam0, q, cfg, axis)


def _validate_cfg(cfg):
    if cfg.chunk_size is not None:
        if cfg.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {cfg.chunk_size}")
        if cfg.algo == "scd" and cfg.reduce != "bucketed":
            raise ValueError(
                "chunk_size requires reduce='bucketed': the exact reduce "
                "sorts all candidates and cannot stream the item dimension"
            )


# --------------------------------------------------------------------------
# Public API.
# --------------------------------------------------------------------------

def solve(kp, cfg: SolverConfig = SolverConfig(), q: int = 1, lam0=None):
    """Single-device solve (the N-user shard fits on one device).

    kp: ``SparseKP`` (p, b: (n, K)) or ``DenseKP`` (p: (n, M),
    b: (n, M, K)); q: the sparse at-most-Q local cap (static; ignored for
    dense). lam0: (K,) warm start, default all-ones. Returns a
    ``SolveResult`` with x: (n, K)/(n, M) bool.

    Chunked-vs-unchunked contract: ``cfg.chunk_size=c`` streams the
    per-iteration map over ceil(n/c) user chunks. For the SCD bucketed
    reduce the result is bit-identical to ``chunk_size=None`` for every
    field of the SolveResult (any c >= 1, ragged tail included; on the
    kernel path both sides must run the same tile, see
    ``cfg.kernel_tile``). Chunked DD agrees to f32 reduce-order instead.
    The instance itself stays device-resident — for out-of-core n see
    ``repro.core.chunked.solve_streaming``.
    """
    _validate_cfg(cfg)
    k = kp.budgets.shape[0]
    if lam0 is None:
        lam0 = jnp.ones((k,), cfg.dtype)
    fn = jax.jit(
        functools.partial(_solve_entry, q=q, cfg=cfg, axis=None),
    )
    return fn(kp, lam0)


def solve_sharded(kp, mesh, cfg: SolverConfig = SolverConfig(), q: int = 1,
                  lam0=None, axes: Optional[tuple] = None):
    """Multi-device solve: users sharded over every axis of ``mesh``.

    ``kp`` holds *global* arrays (or ShapeDtypeStructs for AOT lowering);
    the user dimension must divide the mesh size. Returns globally
    replicated lam/scalars and a user-sharded x (spec ``P(axes)`` on the
    user axis). Every mesh axis participates by default; pass ``axes`` to
    shard users over a subset.

    The per-iteration reduce moves O(K·E) bytes per device regardless of
    n (§5.2's communication-compression claim). ``cfg.chunk_size``
    applies per shard — each device scans its local n/|mesh| rows in
    chunks — and the bit-identity contract of :func:`solve` holds
    shard-locally, so chunked and unchunked sharded solves also agree
    bit-for-bit on the SCD bucketed path.
    """
    _validate_cfg(cfg)
    axes = tuple(mesh.axis_names) if axes is None else axes
    k = kp.budgets.shape[0]
    if lam0 is None:
        lam0 = jnp.ones((k,), cfg.dtype)
    user_spec = P(axes)
    if isinstance(kp, SparseKP):
        in_kp_specs = SparseKP(p=user_spec, b=user_spec, budgets=P())
        x_spec = P(axes, None)
    else:
        in_kp_specs = DenseKP(
            p=user_spec, b=user_spec, budgets=P(), sets=P(), caps=P()
        )
        x_spec = P(axes, None)
    out_specs = SolveResult(
        lam=P(), x=x_spec, iters=P(), r=P(), primal=P(), dual=P(),
        history=None if not getattr(cfg, "record_history", False) else {
            "lam": P(), "primal": P(), "dual": P(), "gap": P(),
            "max_violation": P(),
        },
    )
    fn = shard_map(
        functools.partial(_solve_entry, q=q, cfg=cfg, axis=axes),
        mesh=mesh,
        in_specs=(in_kp_specs, P()),
        out_specs=out_specs,
        # lam/scalars are replicated by construction (psum / tiled gather);
        # VMA inference cannot see that through the gather, so opt out.
        check_vma=False,
    )
    return jax.jit(fn)(kp, lam0)
