"""Fault domain for host-fed chunk ingest: retries, timeouts, injection.

The paper's system is "deployed to production and called on a daily
basis" (§6) — which means chunk fetches that fail transiently, fetches
that hang, payloads that arrive damaged, and the occasional chunk whose
storage shard is having a bad day. This module is the repo's single
fault-tolerance layer for the host-fed ingest path
(:mod:`repro.core.prefetch`) and the serving lookups built on it
(:mod:`repro.serve.decisions`):

* :class:`FaultPolicy` — max retries, capped exponential backoff with
  **deterministic** jitter keyed on ``(chunk_index, attempt)`` (no
  ``random`` or wall-clock anywhere in the schedule, so a test replays
  the exact delays a production run would have slept), and an optional
  per-fetch timeout enforced by a worker thread.
* :func:`fetch_with_retries` — runs one chunk fetch under the policy.
  Retries re-run *only the pure fetch*: the caller's accumulate never
  observes a failed attempt, which is the whole bitwise story — a solve
  that survives injected transient faults is byte-identical to the
  fault-free solve. Exhaustion raises :class:`ChunkFetchError` naming
  the chunk index and the full attempt history.
* :func:`resilient_source` — wraps any ``HostChunkSource``-shaped
  object (anything with an ``fn`` field and ``_replace``) so every
  downstream consumer — the epoch loops, the sharded sub-sources, the
  presolve head read, the fingerprint's chunk-0 read — fetches through
  the policy without knowing it exists.
* :class:`FaultPlan` / :func:`faulty_source` — deterministic fault
  *injection* for tests and the chaos CLI: transient ``IOError`` drops,
  slow fetches, corrupt payloads (different bytes on every occurrence,
  so a verified double-read always catches them), and repeat-offender
  chunks that fail a fixed number of times before recovering.

Verification (``verify=True`` / ``cfg.verify_refetch``) is the paranoid
fetch-is-pure check: the chunk is read twice and the two payloads must
be byte-equal; a mismatch means one of the reads was corrupt (or the
source is not restart-deterministic, which breaks checkpoint/resume
anyway) and is retried like any transient fault. This is what turns
silent payload corruption — the one fault a retry loop cannot see —
into a retryable, *detected* fault.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Callable, Optional

import numpy as np

from ..obs import MetricsRegistry

__all__ = ["FaultPolicy", "FaultPlan", "ChunkFetchError",
           "ChunkFetchTimeout", "ChunkIntegrityError", "FetchCapacityError",
           "fetch_with_retries", "resilient_source", "faulty_source",
           "policy_from_cfg", "abandoned_workers", "ABANDONED_WORKER_CAP",
           "process_registry"]

# Exceptions a retry may recover from. Anything else (a programming
# error, an injected kill) propagates immediately: retrying it would
# only mask the bug.
RETRYABLE = (IOError, OSError, TimeoutError)


class ChunkFetchTimeout(IOError):
    """A fetch exceeded the policy's per-fetch timeout (retryable)."""


class FetchCapacityError(IOError):
    """Too many abandoned fetch workers are still running (retryable).

    Each timed-out fetch abandons a daemon worker thread; a source that
    hangs *persistently* would otherwise accumulate them without bound
    (every retry of every chunk parks another thread on the same dead
    backend). The cap makes that failure mode loud and finite: once
    :data:`ABANDONED_WORKER_CAP` abandoned workers are still alive, new
    timed fetches fail fast with this retryable error — the backoff
    schedule gives stragglers time to drain, and true exhaustion
    surfaces as the usual :class:`ChunkFetchError` naming this cause.
    """


class ChunkIntegrityError(IOError):
    """The verified double-read of a chunk disagreed with itself
    (retryable): one of the two payloads was corrupt, or the source
    violates the fetch-is-pure contract."""


class ChunkFetchError(RuntimeError):
    """A chunk fetch exhausted its retry budget (terminal).

    ``chunk`` is the failing chunk index; ``history`` the full attempt
    record as ``(attempt, error_repr, backoff_slept)`` tuples — the
    message names both, so the operator knows exactly which chunk of
    which source to look at and what each attempt died of.
    """

    def __init__(self, chunk: int, history):
        self.chunk = int(chunk)
        self.history = list(history)
        attempts = "; ".join(
            f"attempt {a}: {err} (slept {slept:.3g}s before retry)"
            if slept is not None else f"attempt {a}: {err}"
            for a, err, slept in self.history)
        super().__init__(
            f"chunk {self.chunk}: fetch failed after "
            f"{len(self.history)} attempt(s) — {attempts}. The retry "
            "budget (FaultPolicy.max_retries) is exhausted; the chunk's "
            "storage is persistently unavailable or persistently corrupt.")


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Retry/backoff/timeout policy for one chunk-fetch site (hashable).

    ``max_retries`` bounds the *re*-attempts: a fetch runs at most
    ``max_retries + 1`` times. Backoff before retry ``a`` (1-based) is
    ``min(cap, base * growth**(a-1) * (1 + jitter * u(chunk, a)))``
    where ``u`` is a deterministic hash of ``(chunk, a)`` in [0, 1) —
    no RNG state, no wall-clock, so the schedule replays exactly and
    two workers retrying different chunks still decorrelate. The
    constructor enforces ``growth >= 1 + jitter``, which makes the
    schedule monotone non-decreasing until the cap (property-tested).

    ``timeout`` (seconds, 0 disables) bounds each individual fetch via
    a daemon worker thread; an overrun raises the retryable
    :class:`ChunkFetchTimeout`. The abandoned worker may still complete
    in the background — harmless under the fetch-is-pure contract, the
    late payload is simply dropped — but it is *tracked*: live
    abandoned workers are capped at :data:`ABANDONED_WORKER_CAP`
    (:class:`FetchCapacityError` past it) and counted in
    :func:`abandoned_workers`.
    """

    max_retries: int = 4
    backoff_base: float = 0.05
    backoff_growth: float = 2.0
    backoff_cap: float = 2.0
    jitter: float = 0.25
    timeout: float = 0.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.backoff_base < 0 or self.backoff_cap < 0 or self.timeout < 0:
            raise ValueError("backoff_base/backoff_cap/timeout must be >= 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")
        if self.backoff_growth < 1.0 + self.jitter:
            raise ValueError(
                f"backoff_growth ({self.backoff_growth}) must be >= "
                f"1 + jitter ({1.0 + self.jitter}): the deterministic "
                "jitter band must not undo the exponential growth, or "
                "the schedule loses its monotone-until-cap guarantee")

    @staticmethod
    def _unit(chunk: int, attempt: int) -> float:
        """Deterministic u in [0, 1) keyed on (chunk, attempt) only."""
        h = hashlib.sha256(f"backoff:{int(chunk)}:{int(attempt)}".encode())
        return int.from_bytes(h.digest()[:8], "big") / float(2 ** 64)

    def backoff(self, chunk: int, attempt: int) -> float:
        """Seconds to sleep before retry ``attempt`` (1-based) of ``chunk``."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        raw = self.backoff_base * self.backoff_growth ** (attempt - 1)
        return min(self.backoff_cap,
                   raw * (1.0 + self.jitter * self._unit(chunk, attempt)))

    def schedule(self, chunk: int) -> tuple:
        """The full replayable delay schedule for one chunk's retries."""
        return tuple(self.backoff(chunk, a)
                     for a in range(1, self.max_retries + 1))


# Abandoned-worker accounting (process-wide). A timed-out fetch parks
# its daemon worker here; dead threads are reaped before every timed
# fetch and on every read, so "live" is the number still actually
# holding a thread. ``ABANDONED_WORKER_CAP`` bounds them — tests may
# monkeypatch it (it is read at call time, never cached).
ABANDONED_WORKER_CAP = 64
_abandoned_lock = threading.Lock()
_abandoned: list = []      # threads abandoned by a timeout, maybe live
_abandoned_total = 0       # monotone count of every abandonment

# Process-wide fault metrics (DESIGN.md §14). Always a real registry —
# these counters are the source of truth the serving layers' health
# fields read through, so there is no null path here; the instruments
# are plain locked integers, cheap on failure paths by definition.
_REGISTRY = MetricsRegistry()
_RETRIES = _REGISTRY.counter("faults_retries_total")
_ABANDONED_CTR = _REGISTRY.counter("faults_abandoned_total")


def _abandoned_live() -> int:
    with _abandoned_lock:
        _reap_abandoned_locked()
        return len(_abandoned)


_REGISTRY.gauge("faults_abandoned_live", fn=_abandoned_live)


def process_registry() -> MetricsRegistry:
    """The process-wide fault-domain metrics registry.

    Exported by every ``/metrics`` endpoint alongside the per-service
    registries, so retry pressure and leaked fetch workers are visible
    without a :class:`~repro.serve.decisions.DecisionService` in play.
    """
    return _REGISTRY


def _reap_abandoned_locked() -> None:
    _abandoned[:] = [t for t in _abandoned if t.is_alive()]


def abandoned_workers() -> dict:
    """Leaked-fetch-worker counters: ``{"live", "total", "cap"}``.

    ``live`` is the number of abandoned daemon threads still running
    right now (hung fetches that never returned); ``total`` counts every
    abandonment since process start. Surfaced by
    :meth:`repro.serve.decisions.DecisionService.health` so a backend
    that hangs rather than fails shows up in serving health before the
    cap trips.
    """
    with _abandoned_lock:
        _reap_abandoned_locked()
        return {"live": len(_abandoned), "total": _abandoned_total,
                "cap": ABANDONED_WORKER_CAP}


def _call_with_timeout(fn: Callable, i: int, timeout: float):
    """Run ``fn(i)`` bounded by ``timeout`` seconds (0 = unbounded).

    The fetch runs on a daemon worker thread; an overrun raises
    :class:`ChunkFetchTimeout` and abandons the worker (the fetch is
    pure, so its late result is simply never read). Abandoned workers
    are tracked and capped — see :class:`FetchCapacityError` — so
    repeated timeouts leak a bounded number of threads, not one per
    retry forever.
    """
    if timeout <= 0:
        return fn(i)
    global _abandoned_total
    with _abandoned_lock:
        _reap_abandoned_locked()
        if len(_abandoned) >= ABANDONED_WORKER_CAP:
            raise FetchCapacityError(
                f"chunk {i}: {len(_abandoned)} abandoned fetch workers "
                f"are still running (cap {ABANDONED_WORKER_CAP}) — the "
                "source is hanging persistently; refusing to park "
                "another thread on it")
    box = {}

    def run():
        try:
            box["val"] = fn(i)
        except BaseException as e:        # delivered to the caller below
            box["err"] = e

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(timeout)
    if t.is_alive():
        with _abandoned_lock:
            _abandoned.append(t)
            _abandoned_total += 1
        _ABANDONED_CTR.inc()
        raise ChunkFetchTimeout(
            f"chunk {i}: fetch exceeded the {timeout:g}s per-fetch "
            "timeout (the worker thread was abandoned)")
    if "err" in box:
        raise box["err"]
    return box["val"]


def _payload_equal(a, b) -> bool:
    """Byte-equality of two (p, b) chunk payloads (NaN-safe)."""
    return all(np.asarray(x, np.float32).tobytes()
               == np.asarray(y, np.float32).tobytes()
               for x, y in zip(a, b))


def fetch_with_retries(fn: Callable, i: int, policy: FaultPolicy,
                       verify: bool = False, sleep: Callable = time.sleep,
                       on_retry: Optional[Callable] = None):
    """Fetch chunk ``i`` through ``fn`` under ``policy``.

    Retries only the pure fetch on :data:`RETRYABLE` errors, sleeping
    the policy's deterministic backoff between attempts (``sleep`` is
    injectable so tests record the schedule instead of waiting it out).
    ``verify`` double-reads the chunk and requires byte-equality
    (corruption detection; the matching payload is returned).
    ``on_retry(chunk, attempt, error, delay)`` observes every retryable
    failure — the hook serving health counters hang off.

    Exhaustion raises :class:`ChunkFetchError` with the chunk index and
    the complete attempt history; the final cause is chained.
    """
    history = []
    for attempt in range(policy.max_retries + 1):
        try:
            out = _call_with_timeout(fn, i, policy.timeout)
            if verify:
                again = _call_with_timeout(fn, i, policy.timeout)
                if not _payload_equal(out, again):
                    raise ChunkIntegrityError(
                        f"chunk {i}: verified re-read returned different "
                        "bytes — one payload was corrupt (or the source "
                        "is not restart-deterministic)")
                out = again
            return out
        except RETRYABLE as e:
            last = attempt == policy.max_retries
            delay = None if last else policy.backoff(i, attempt + 1)
            history.append((attempt, repr(e), delay))
            if last:
                raise ChunkFetchError(i, history) from e
            _RETRIES.inc()
            if on_retry is not None:
                on_retry(i, attempt, e, delay)
            sleep(delay)


def resilient_source(source, policy: FaultPolicy, verify: bool = False,
                     sleep: Callable = time.sleep,
                     on_retry: Optional[Callable] = None):
    """Wrap a chunk source so every ``fn(i)`` goes through the policy.

    Returns ``source._replace(fn=...)`` — duck-typed over
    :class:`repro.core.prefetch.HostChunkSource` (or anything
    NamedTuple-shaped with an ``fn``), so this module stays free of
    import cycles. Wrapping composes: a :func:`faulty_source` *under* a
    resilient source is the chaos-test sandwich (faults injected below,
    retries absorbing them above).
    """
    inner = source.fn

    def fn(i):
        return fetch_with_retries(inner, i, policy, verify=verify,
                                  sleep=sleep, on_retry=on_retry)

    return source._replace(fn=fn)


def policy_from_cfg(cfg) -> Optional[FaultPolicy]:
    """The :class:`FaultPolicy` a SolverConfig's fetch knobs describe.

    None when the config requests no fault handling at all
    (``fetch_retries == 0``, no timeout, no verification) — the caller
    then skips wrapping entirely and the ingest path is byte-for-byte
    the pre-fault-layer one.
    """
    if cfg.fetch_retries == 0 and cfg.fetch_timeout == 0 \
            and not cfg.verify_refetch:
        return None
    return FaultPolicy(max_retries=cfg.fetch_retries,
                       backoff_base=cfg.fetch_backoff,
                       backoff_growth=cfg.fetch_backoff_growth,
                       backoff_cap=cfg.fetch_backoff_cap,
                       jitter=cfg.fetch_jitter,
                       timeout=cfg.fetch_timeout)


# ---------------------------------------------------------------------------
# Deterministic fault injection: the chaos side of the layer.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic injection plan for :func:`faulty_source`.

    Every injection decision is a pure hash of ``(seed, chunk,
    occurrence)`` where *occurrence* counts the calls made for that
    chunk so far — so a plan replays identically across runs, and a
    retried fetch sees a fresh (independent) decision rather than the
    same fault forever. Rates are probabilities per fetch:

    * ``drop`` — raise a transient ``IOError``;
    * ``slow`` — sleep ``slow_s`` seconds, then return the clean chunk
      (pair with a ``FaultPolicy.timeout < slow_s`` to exercise the
      timeout-and-retry path);
    * ``corrupt`` — return a perturbed payload whose perturbation is
      keyed on the occurrence (two corrupt reads of the same chunk
      never match, so a verified double-read always detects them).

    ``offenders`` are chunk indices whose first ``offender_failures``
    fetches raise unconditionally — the repeat-offender shard. Set
    ``offender_failures > max_retries`` to force retry exhaustion.
    """

    seed: int = 0
    drop: float = 0.0
    slow: float = 0.0
    slow_s: float = 0.02
    corrupt: float = 0.0
    offenders: tuple = ()
    offender_failures: int = 0

    def __post_init__(self):
        if min(self.drop, self.slow, self.corrupt) < 0 \
                or self.drop + self.slow + self.corrupt > 1.0:
            raise ValueError(
                "drop/slow/corrupt must be non-negative rates summing "
                f"to <= 1, got {(self.drop, self.slow, self.corrupt)}")

    def _unit(self, chunk: int, occurrence: int) -> float:
        h = hashlib.sha256(
            f"fault:{self.seed}:{int(chunk)}:{int(occurrence)}".encode())
        return int.from_bytes(h.digest()[:8], "big") / float(2 ** 64)


def faulty_source(source, plan: FaultPlan):
    """Inject the plan's faults under any chunk source (tests + chaos CLI).

    The wrapper keeps a per-chunk occurrence counter (thread-safe: a
    timed-out fetch's abandoned worker may still be counting) and
    decides each fetch's fate from the plan's hash. Clean fetches pass
    the inner payload through untouched, so a solve whose faults are all
    absorbed by the retry layer above consumes exactly the fault-free
    bytes.
    """
    inner = source.fn
    lock = threading.Lock()
    counts: dict = {}

    def fn(i):
        i = int(i)
        with lock:
            occ = counts.get(i, 0)
            counts[i] = occ + 1
        if i in plan.offenders and occ < plan.offender_failures:
            raise IOError(
                f"injected repeat-offender fault: chunk {i} "
                f"occurrence {occ} (< {plan.offender_failures})")
        u = plan._unit(i, occ)
        if u < plan.drop:
            raise IOError(f"injected transient fault: chunk {i} "
                          f"occurrence {occ}")
        if u < plan.drop + plan.slow:
            time.sleep(plan.slow_s)
            return inner(i)
        if u < plan.drop + plan.slow + plan.corrupt:
            p, b = inner(i)
            p = np.array(p, np.float32, copy=True)
            # Occurrence-keyed perturbation: two corrupt reads of the
            # same chunk can never return identical bytes, so the
            # verified double-read detects every corruption.
            p.flat[:: max(1, p.size // 8)] += np.float32(occ + 1)
            return p, b
        return inner(i)

    return source._replace(fn=fn)
