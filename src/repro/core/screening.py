"""Safe λ-interval active-set screening for the streamed SCD solve.

Every SCD iteration streams all n items, yet most items stop mattering
long before convergence: their candidate thresholds sit so far below the
multiplier that they can never again influence the bucketed reduce. This
module retires whole *chunks* of such items from the iteration passes —
the big algorithmic lever the screening literature (Jooken et al.
instance features; Li et al. large-scale 0-1 KP) grounds — while keeping
the multiplier trajectory, and therefore the final picked set,
**bitwise identical** to the unscreened solve. The unscreened solve is
the oracle; screening is only ever a proof that streaming less changes
nothing.

The safety argument (DESIGN.md §11 walks the float-level details):

1. **A λ-independent per-item bound.** The sparse candidate threshold is
   ``v1 = (p - pbar(λ)) / b`` with ``pbar(λ) >= 0`` (Alg 5 clamps the
   adjusted profits at zero before taking order statistics), so
   ``v1 <= p / b`` for every λ — IEEE rounding is monotone, so the
   f32-evaluated bound dominates the f32-evaluated ``v1``. The per-chunk
   certificate :func:`chunk_bound` is the row-max of that ratio: one
   number per knapsack, computed once, valid forever (the data never
   changes; only λ does).

2. **A λ floor makes the bound a bucket-0 certificate.** The bucket
   ladder's lowest edge ``e0(λ)`` (``make_edges(...)[:, 0]``) is
   monotone non-decreasing in λ (an f32 subtraction of a constant).
   Maintain a floor ``lam_lo`` with ``λ >= lam_lo`` checked every
   iteration; then ``chunk_bound <= e0(lam_lo) <= e0(λ)`` proves every
   item of the chunk bins into bucket 0 (``searchsorted`` left: index 0
   iff ``v1 <= edges[0]``) at every future iteration. Skipping the chunk
   therefore leaves **every histogram bucket >= 1 bit-identical** — the
   scatter-adds that would have happened all target bucket 0, and the
   remaining adds keep their relative order. If λ escapes below the
   floor, every chunk is reactivated and the floor re-anchored (one
   full-width iteration, still bitwise — a full pass is the unscreened
   pass).

3. **A per-iteration crossing guard covers bucket 0.** Bucket-0 mass
   does leak into ``threshold_from_hist`` through two doors: the
   ``total <= budgets`` early-out and a crossing that lands *in* bucket
   0. Both are closed by checking — on the screened histogram, with the
   exact float ops of ``threshold_from_hist`` via
   :func:`repro.core.bucketing.hist_crossings` — that every knapsack has
   a budget crossing in some bucket >= 1. Buckets >= 1 being
   bit-identical, the crossing bucket, its interpolation inputs and the
   ``total > budgets`` predicates then resolve identically in the
   screened and unscreened programs (the reversed cumulative sums never
   touch bucket 0 above index 0). When the guard fails, the iteration
   falls back to one full unscreened pass — bitwise by construction.

4. **The global max candidate is immune.** ``top`` only enters through
   ``max(top, edges[:, -1])``; retired items satisfy
   ``v1 <= e0 <= edges[:, -1]`` (and invalid rows carry ``v1 = -1``,
   also below the top edge), so dropping them can never change that max.

The finalize/metrics passes and :func:`~repro.core.chunked.
decisions_chunk` always stream *all* chunks — the final (r, primal,
dual, tau) and the exported decisions are full-pass quantities, which is
what makes the screened solve's outputs field-for-field the oracle's.

Both drivers share these helpers: the traced scan
(``chunked.solve_streaming``) carries (active, bound, floor) through the
``while_loop``; the host-fed driver (``prefetch.solve_streaming_host``)
keeps them in a :class:`HostScreen` and simply never fetches retired
chunks. :class:`HostScreen` state also seeds the serving layer's *delta
refresh* (``repro.serve.engine``): chunks whose bytes are unchanged
between generations inherit the parent generation's certificates and
start retired.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from .bucketing import hist_crossings, make_edges

__all__ = ["chunk_bound", "crossing_trusted", "lowest_edges", "HostScreen"]


def chunk_bound(p_c, b_c):
    """λ-independent upper bound on a chunk's candidate thresholds.

    (chunk, K) profits/costs -> (K,) f32: the row-max of ``p / b`` over
    rows with ``b > 0`` (rows with ``b == 0`` — including the inert
    ragged tail — never produce a valid candidate and bound to -inf).
    Dominates the f32 ``candidates_sparse`` ``v1`` at every λ because
    ``pbar >= 0`` and IEEE rounding is monotone.
    """
    safe = jnp.where(b_c > 0, b_c, jnp.ones_like(b_c))
    ratio = jnp.where(b_c > 0, p_c / safe, -jnp.inf)
    return jnp.max(ratio.astype(jnp.float32), axis=0)


def crossing_trusted(hist, budgets):
    """() bool: every knapsack's budget crossing lands in a bucket >= 1.

    Computed with :func:`~repro.core.bucketing.hist_crossings` — the
    exact reversed-cumsum / comparison floats ``threshold_from_hist``
    uses — so "trusted" here means *provably* that the screened
    histogram yields the bit-identical multiplier proposal: the chosen
    crossing bucket, its interpolation inputs and the ``total > budgets``
    predicates involve no bucket-0 quantity when a crossing exists above
    bucket 0.
    """
    _, _, in_bucket = hist_crossings(hist, budgets)
    return jnp.all(jnp.any(in_bucket[:, 1:], axis=-1))


def lowest_edges(lam_lo, cfg):
    """(K,) f32 lowest bucket edge at the floor, via ``make_edges`` itself.

    Using the same op that builds the solve's ladder keeps the
    certificate comparison exact: a chunk retired against
    ``e0(lam_lo)`` bins into bucket 0 at every λ >= lam_lo because the
    f32 edge is monotone in λ.
    """
    edges = make_edges(jnp.asarray(lam_lo, jnp.float32), cfg.bucket_delta,
                       cfg.bucket_growth, cfg.bucket_half)
    return np.asarray(edges[:, 0], np.float32)


@functools.partial(jax.jit, static_argnames=())
def _np_bound(p, b):
    return chunk_bound(p, b)


class HostScreen:
    """Active-set state for the host-fed driver (and the delta refresh).

    Tracks, per global chunk index, whether the chunk is still streamed
    (``active``), its λ-independent certificate (``bmax``) and the λ
    floor the certificates were issued against (``lam_lo``). The driver
    calls :meth:`begin_iter` before each iteration epoch (floor check —
    an escaped λ reactivates everything), :meth:`note_bound` as chunks
    are fetched, and :meth:`retire` after the multiplier step is
    accepted. ``seed=`` warm-starts the state from a previous solve's
    :meth:`stats` — the delta-refresh path: unchanged chunks inherit
    their certificates and start retired; changed chunks start active
    with an unknown (+inf) bound. Screening state is deliberately *not*
    part of the checkpoint resume state: it never steers the trajectory,
    so a resumed solve safely rebuilds it from all-active.
    """

    def __init__(self, c: int, k: int, cfg, lam0, seed: Optional[dict] = None):
        self.cfg = cfg
        self.c = c
        self.active = np.ones((c,), bool)
        self.bmax = np.full((c, k), np.inf, np.float32)
        lam0 = np.asarray(lam0, np.float32)
        self.lam_lo = (lam0 * np.float32(cfg.screening_floor)).astype(
            np.float32)
        if seed is not None:
            m = min(c, int(np.asarray(seed["active"]).shape[0]))
            self.active[:m] = np.asarray(seed["active"], bool)[:m]
            self.bmax[:m] = np.asarray(seed["bmax"], np.float32)[:m]
            changed = seed.get("changed")
            if changed is not None:
                ch = np.asarray(changed, bool)
                mm = min(c, ch.shape[0])
                self.active[:mm] |= ch[:mm]
                self.bmax[:mm][ch[:mm]] = np.inf
            # The floor must keep covering the inherited certificates:
            # a seeded retired chunk was certified against
            # ``e0(seed lam_lo)``, so the floor can never start *below*
            # the seed's (``e0`` is monotone — a lower floor would let λ
            # sink under the certified interval while the chunk stays
            # retired). A warm start below the resulting floor is
            # handled by the begin_iter escape check: everything
            # reactivates and the floor re-anchors.
            self.lam_lo = np.maximum(
                self.lam_lo, np.asarray(seed["lam_lo"], np.float32))
        self.resets = 0
        self.fallbacks = 0
        self.streamed = []          # chunks streamed per iteration epoch
        self.seeded_active = int(self.active.sum())

    def begin_iter(self, lam) -> bool:
        """Floor check before an epoch; False means everything was
        reactivated (λ escaped the certified interval)."""
        lam = np.asarray(lam, np.float32)
        ok = bool(np.all(lam >= self.lam_lo))
        floor = (lam * np.float32(self.cfg.screening_floor)).astype(
            np.float32)
        if ok:
            self.lam_lo = np.maximum(self.lam_lo, floor)
        else:
            self.active[:] = True
            self.resets += 1
            self.lam_lo = floor
        return ok

    def note_bound(self, i: int, p, b) -> None:
        if np.isfinite(self.bmax[i]).all():
            return
        self.bmax[i] = np.asarray(
            _np_bound(np.asarray(p, np.float32), np.asarray(b, np.float32)))

    def active_indices(self):
        return np.flatnonzero(self.active)

    def any_retired(self) -> bool:
        return not bool(self.active.all())

    def record_streamed(self, n: int, fallback: bool = False) -> None:
        if fallback:
            self.fallbacks += 1
            self.streamed[-1] += n
        else:
            self.streamed.append(int(n))

    def retire(self) -> None:
        """Retire every active chunk whose certificate clears the floor
        edge for *all* knapsacks (the histogram is per-knapsack; a chunk
        must be bucket-0 everywhere to be skippable)."""
        e0 = lowest_edges(self.lam_lo, self.cfg)
        can = np.all(self.bmax <= e0[None, :], axis=-1)
        self.active &= ~can

    def stats(self) -> dict:
        return {
            "active": self.active.copy(),
            "bmax": self.bmax.copy(),
            "lam_lo": self.lam_lo.copy(),
            "resets": self.resets,
            "fallbacks": self.fallbacks,
            "streamed_chunks": np.asarray(self.streamed, np.int64),
            "seeded_active": self.seeded_active,
        }
