"""Section 5.2 fine-tuned bucketing + the Alg 4 reduce-side threshold search.

The SCD reduce must find, per knapsack k, the minimal threshold v such that

    sum_{candidates with v1 >= v} v2  <=  B_k.

Exact mode sorts all candidates (bit-faithful to Alg 4; O(Z log Z) with a
full gather — test scale). Production mode is the paper's bucketing trick:
candidates are histogrammed into buckets whose widths grow exponentially
away from the previous iterate lam_t (where the new lam is expected to
land), the (K, n_buckets) histogram is psum'd across the mesh — a
constant-size collective independent of N — and v is recovered by linear
interpolation inside the crossing bucket.

This reduce doubles as the paper's communication-compression trick: the
shuffle of O(N*M) candidate tuples becomes an all-reduce of a few KiB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "make_edges",
    "bucket_histogram",
    "hist_crossings",
    "threshold_from_hist",
    "exact_threshold",
]


def make_edges(lam_t, delta, growth, half):
    """Bucket edges per knapsack, centred at the previous iterate.

    lam_t: (K,) -> edges (K, 2*half + 1), strictly increasing per row:
        lam_t - delta*growth**(half-1) ... lam_t ... lam_t + delta*growth**(half-1)

    bucket_id(lam) = sign(lam - lam_t) * floor(log_growth(|lam - lam_t| / delta))
    from the paper is equivalent to binning against this geometric edge
    ladder; we materialise the edges so searchsorted can do the binning.
    """
    i = jnp.arange(half, dtype=lam_t.dtype)
    offs = delta * growth ** i                      # (half,)
    pos = lam_t[:, None] + offs[None, :]            # (K, half)
    neg = lam_t[:, None] - offs[None, ::-1]         # (K, half)
    return jnp.concatenate([neg, lam_t[:, None], pos], axis=-1)


def bucket_histogram(v1, v2, edges, init=None):
    """Accumulate candidate mass into per-knapsack buckets.

    v1, v2: (n, K) candidate thresholds / incremental consumptions
    (invalid candidates carry v2 == 0). edges: (K, E). Returns
    (K, E+1) f32 histogram; bucket j holds mass of candidates with
    edges[j-1] < v1 <= edges[j] (open ladder at both ends; the
    searchsorted-left tie convention, shared with the Pallas kernels).

    ``init`` (K, E+1) seeds the accumulation: the rows of ``v1``/``v2``
    are scatter-added *onto* it in row order. This is what makes the
    chunked solve bit-identical to the unchunked one: XLA scatter-add
    applies updates sequentially in operand order, so accumulating chunk
    c's rows onto the running histogram of chunks < c performs exactly
    the same f32 additions, in the same order, as one scatter over all n
    rows. Adding chunks' sub-histograms with ``+`` instead would regroup
    the sums and drift in the last ulp.
    """
    n, k = v1.shape
    e = edges.shape[-1]
    nb = e + 1
    # Per-knapsack searchsorted: vmap over K.
    idx = jax.vmap(jnp.searchsorted, in_axes=(0, 1))(edges, v1)  # (K, n)
    seg = idx + (jnp.arange(k, dtype=idx.dtype) * nb)[:, None]
    acc = (jnp.zeros((k * nb,), jnp.float32) if init is None
           else init.astype(jnp.float32).reshape(-1))
    hist = acc.at[seg.reshape(-1)].add(v2.T.reshape(-1).astype(jnp.float32))
    return hist.reshape(k, nb)


def hist_crossings(hist, budgets):
    """The budget-crossing structure of a bucketed histogram.

    Returns ``(rev, cum_above, in_bucket)``: the reversed cumulative
    sums (``rev[:, j]`` = mass in buckets >= j), the mass strictly above
    each bucket, and the per-bucket crossing mask (feasible above,
    infeasible including). Factored out of :func:`threshold_from_hist`
    so active-set screening (core/screening.py) can test "does every
    knapsack cross in a bucket >= 1" with the *exact* float ops the
    threshold recovery uses — the screened-histogram trust check is only
    sound because both run this same f32 chain.
    """
    rev = jnp.cumsum(hist[:, ::-1], axis=-1)[:, ::-1]
    cum_above = rev - hist                                  # (K, nb)
    feasible = cum_above <= budgets[:, None]
    in_bucket = feasible & (rev > budgets[:, None])
    return rev, cum_above, in_bucket


def threshold_from_hist(hist, edges, budgets, top=None):
    """Recover lam_k^{t+1} = minimal v with sum_{v1 >= v} v2 <= B_k.

    hist: (K, E+1), edges: (K, E), budgets: (K,). Linear interpolation
    inside the crossing bucket (the paper's "interpolating within the
    bucket"). ``top`` (K,) is the global max candidate value (pmax'd by the
    caller); it closes the otherwise-unbounded top bucket so the first
    iterations (edges still centred far from the fixed point) interpolate
    instead of guessing. Clamped to >= 0.
    """
    k, nb = hist.shape
    if top is None:
        top = edges[:, -1]
    # cum_above[j] = mass in buckets strictly above bucket j.
    rev, cum_above, in_bucket = hist_crossings(hist, budgets)
    total = rev[:, 0]
    # Crossing bucket: the highest bucket where the budget line is crossed.
    # (feasible above it, infeasible including it.)
    any_cross = jnp.any(in_bucket, axis=-1)
    j = jnp.argmax(
        jnp.where(in_bucket, jnp.arange(nb)[None, :], -1), axis=-1
    )  # (K,)
    top_edge = jnp.maximum(top, edges[:, -1]) * (1.0 + 1e-6) + 1e-12
    lo = jnp.take_along_axis(
        jnp.pad(edges, ((0, 0), (1, 0))), j[:, None], axis=-1
    )[:, 0]  # edges[j-1]; pad -> bucket 0 lower edge := 0 (clamped anyway)
    hi = jnp.take_along_axis(
        jnp.concatenate([edges, top_edge[:, None]], axis=-1), j[:, None], axis=-1
    )[:, 0]  # edges[j]; top bucket closed by the global max candidate
    mass = jnp.take_along_axis(hist, j[:, None], axis=-1)[:, 0]
    above = jnp.take_along_axis(cum_above, j[:, None], axis=-1)[:, 0]
    width = jnp.maximum(hi - lo, 0.0)
    frac = jnp.where(mass > 0, (budgets - above) / jnp.maximum(mass, 1e-30), 1.0)
    v = hi - width * frac
    # No crossing anywhere => even taking everything fits => lam = 0 (Alg 4).
    v = jnp.where(any_cross, v, 0.0)
    v = jnp.where(total <= budgets, 0.0, v)
    return jnp.maximum(v, 0.0)


def exact_threshold(v1, v2, budget, pad_rel=1e-6):
    """Bit-faithful Alg 4 reduce for one knapsack: sort + prefix scan.

    v1, v2: (Z,) flattened candidates (invalid entries must have v2 == 0).
    Returns the minimal candidate value v with sum_{v1 >= v} v2 <= budget;
    0 if all candidates fit; slightly above the max candidate if nothing
    fits (consumption above every candidate is 0 by construction).
    """
    order = jnp.argsort(-v1, stable=True)
    s1 = v1[order]
    s2 = v2[order]
    csum = jnp.cumsum(s2)
    # Ties: the sum at threshold s1[i] includes every candidate tied with it.
    # last index j with s1[j] == s1[i]  ==  searchsorted(-s1, -s1[i], 'right') - 1
    last = jnp.searchsorted(-s1, -s1, side="right") - 1
    tot = csum[last]
    feas = tot <= budget
    z = s1.shape[0]
    idx_last_feas = jnp.max(jnp.where(feas, jnp.arange(z), -1))
    all_feas = feas[z - 1]
    none_feas = ~feas[0]
    v = s1[jnp.maximum(idx_last_feas, 0)]
    v = jnp.where(none_feas, s1[0] * (1.0 + pad_rel) + pad_rel, v)
    v = jnp.where(all_feas, 0.0, v)
    return jnp.maximum(v, 0.0)
