"""Section 5.1 / Algorithm 5: linear-time candidate generation (sparse GKP).

Sparse form: M == K, item k consumes only knapsack k (b[i,k] on the
diagonal), one local constraint "choose at most Q items per user". Each
user emits at most one candidate per knapsack:

  * adjusted_profits[k] = max(p_ik - lam_k * b_ik, 0)
  * pbar = (Q+1)-th largest if item k is currently in the top-Q, else the
    Q-th largest — the profit level item k has to beat to (stay) in.
  * if p_ik > pbar:  candidate v1 = (p_ik - pbar) / b_ik, mass v2 = b_ik.

TPU adaptation: the paper uses quick_select (O(K) average, data-dependent
control flow) inside a scalar mapper. Quick-select does not vectorise on a
systolic/VPU machine; ``jax.lax.top_k`` over the K axis gives the same two
order statistics for the whole user shard at once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["candidates_sparse", "select_sparse", "consumption_sparse"]


def candidates_sparse(p, b, lam, q):
    """Algorithm 5, batched over the user shard.

    p, b: (n, K); lam: (K,); q: static int. Returns (v1, v2): (n, K) each.
    Invalid candidates are encoded as v1 = -1, v2 = 0 (sort below real
    candidates in the exact reduce; zero mass in the bucketed reduce).
    """
    n, k = p.shape
    ap = jnp.maximum(p - lam[None, :] * b, 0.0)            # (n, K)
    if q >= k:
        # Local constraint can never bind: the only candidate is the zero
        # crossing (pbar = 0).
        pbar = jnp.zeros_like(ap)
    else:
        top, _ = jax.lax.top_k(ap, q + 1)                  # (n, q+1) desc
        q_th = top[:, q - 1] if q >= 1 else jnp.full((n,), jnp.inf, ap.dtype)
        q1_th = top[:, q]
        in_top = ap >= q_th[:, None]
        pbar = jnp.where(in_top, q1_th[:, None], q_th[:, None])
    valid = (p > pbar) & (b > 0)
    v1 = jnp.where(valid, (p - pbar) / jnp.where(b > 0, b, 1.0), -1.0)
    v2 = jnp.where(valid, b, 0.0)
    return v1, v2


def select_sparse(p, b, lam, q):
    """Primal solution at multipliers lam: top-Q positive adjusted profits.

    Matches Algorithm 1 for the sparse instance (single cardinality set).
    Returns x: (n, K) bool.
    """
    ap = p - lam[None, :] * b
    n, k = p.shape
    if q >= k:
        return ap > 0
    # top-q mask by adjusted profit, ties broken by item index (stable).
    order = jnp.argsort(-ap, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return (ap > 0) & (ranks < q)


def consumption_sparse(b, x):
    """Per-knapsack use of one shard: R_k = sum_i b_ik x_ik. -> (K,)"""
    return jnp.einsum("nk,nk->k", b, x.astype(b.dtype))
