"""Section 5.4: post-processing for feasibility.

Groups are ranked by their cost-adjusted group profit

    p~_i = sum_j p_ij x_ij - sum_k lam_k sum_j b_ijk x_ij

(the dual value contributed by group i) and zeroed out in ascending order
until every global constraint holds.

Distributed adaptation: the paper sorts groups globally — a full shuffle.
We reuse the Section 5.2 machinery instead: histogram group profits against
a fixed edge ladder, psum the (K, E) per-knapsack removable-consumption
histogram, and pick the smallest edge tau such that removing every group
with p~_i <= tau restores feasibility for ALL knapsacks. Because the
removal set is exactly "buckets below an edge", the removed consumption is
exactly the histogram prefix sum — the projection is conservative-exact
(always feasible), only the removal granularity is bucketed. An exact
sort-based mode is kept for single-shard use and tests.

The bucketed path is decomposed into :func:`profit_edges`,
:func:`removable_hist` and :func:`threshold_from_removable_hist` so the
out-of-core driver (core/chunked.py) can stream the item dimension through
it: edges from a first pass's global (lo, hi), the histogram accumulated
chunk by chunk via carry-seeded scatter-add (bit-identical to the one-shot
histogram — scatter updates apply in row order), then one constant-size
threshold recovery. ``feasibility_threshold_bucketed`` composes the same
three pieces for resident shards.

Single-pass streaming (DESIGN.md §5c): the fused finalize pass cannot
build edges from the global (lo, hi) — those are only known once the same
pass completes — so it bins group profits against the *fixed* geometric
ladder :func:`profit_edges_fixed` instead, and accumulates a removable
*profit* histogram next to the consumption one. With both histograms,
:func:`threshold_and_removed` recovers tau AND the exact post-projection
(r, primal) as prefix subtractions, which is what deletes the dedicated
projection-apply pass entirely.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "group_profit",
    "feasibility_threshold_exact",
    "feasibility_threshold_bucketed",
    "profit_edges",
    "profit_edges_fixed",
    "removable_hist",
    "threshold_from_removable_hist",
    "threshold_and_removed",
]


def group_profit(p, cons, lam, x):
    """p~_i for a shard. p: (n, M), cons: (n, K), lam: (K,), x: (n, M)."""
    gain = jnp.einsum("nm,nm->n", p, x.astype(p.dtype))
    price = jnp.einsum("nk,k->n", cons, lam)
    return gain - price


def feasibility_threshold_exact(ptilde, cons, budgets):
    """Minimal prefix (ascending p~) whose removal restores feasibility.

    Returns tau; zero out groups with p~_i <= tau. Single shard / test path.
    """
    order = jnp.argsort(ptilde, stable=True)
    sorted_p = ptilde[order]
    csum = jnp.cumsum(cons[order], axis=0)                 # (n, K)
    total = csum[-1]
    excess = jnp.maximum(total - budgets, 0.0)             # (K,)
    ok = jnp.all(csum >= excess[None, :], axis=-1)         # (n,)
    n = ptilde.shape[0]
    first_ok = jnp.argmax(ok)                              # minimal prefix end
    need = jnp.any(excess > 0)
    tau = jnp.where(need, sorted_p[first_ok], -jnp.inf)
    return tau


def profit_edges(lo, hi, n_edges=512):
    """Fixed group-profit edge ladder between the global (lo, hi).

    lo/hi must already be globally reduced (pmin/pmax across the mesh, or
    a running min/max across chunks — both are exact, so the streaming
    and resident paths build bit-identical edges). Returns (E,)."""
    return jnp.linspace(lo, hi, n_edges)


def profit_edges_fixed(n_edges=512, lo=1e-6, hi=1e6, dtype=jnp.float32):
    """Fixed geometric group-profit ladder — no data-dependent endpoints.

    The single-pass streaming finalize (DESIGN.md §5c) bins group profits
    in the *same* source pass that discovers their range, so its edges
    cannot come from the global (lo, hi). Sparse group profits are sums
    of selected positive adjusted profits, hence >= 0, and a geometric
    ladder gives constant *relative* granularity: tau lands within one
    growth factor (~(hi/lo)^(1/(E-1)), ≈5.6% at the defaults) of the
    minimal removal — finer than the linear (lo, hi)/E ladder in the
    low-profit region where removal happens, coarser near hi where it
    doesn't. Profits below ``lo`` share bucket 0 (their consumption is ~0
    by construction); profits above ``hi`` land in the overflow bucket,
    which :func:`threshold_and_removed` can still remove via its
    tau = +inf fallback, so conservative-exactness survives any range.
    Built in f64 numpy then cast, so every caller gets the bit-identical
    ladder. Returns (E,) ascending.
    """
    return jnp.asarray(np.logspace(np.log10(lo), np.log10(hi), n_edges),
                       dtype=dtype)


def removable_hist(ptilde, cons, edges, init=None):
    """(K, E+1) removable-consumption mass per group-profit bucket.

    ptilde: (n,), cons: (n, K), edges: (E,) ascending. Bucket j holds
    sum of cons over groups with edges[j-1] < p~ <= edges[j]
    (searchsorted-left, the repo-wide tie convention). ``init`` seeds the
    accumulation for chunked streaming: rows scatter-add *onto* it in row
    order, so accumulating chunks sequentially performs the identical f32
    addition chain as one pass over all n rows (bit-identical results).
    Invalid/padded rows must carry cons == 0 (their zero mass lands in
    whatever bucket their p~ bins to, adding exactly 0.0)."""
    n, k = cons.shape
    n_edges = edges.shape[0]
    idx = jnp.searchsorted(edges, ptilde, side="left")     # bucket i: (e[i-1], e[i]]
    nb = n_edges + 1
    seg = idx[:, None] + jnp.arange(k)[None, :] * nb
    acc = (jnp.zeros((k * nb,), cons.dtype) if init is None
           else init.reshape(-1))
    return acc.at[seg.reshape(-1)].add(cons.reshape(-1)).reshape(k, nb)


def threshold_from_removable_hist(hist, edges, r_total, budgets):
    """Minimal edge tau whose prefix removal restores every budget.

    hist: (K, E+1) (already psum'd / fully accumulated), edges: (E,),
    r_total: (K,) global consumption, budgets: (K,). Removing
    {i : p~_i <= edges[e]} removes exactly the histogram prefix sum, so
    the projection is conservative-exact. Returns tau (-inf when already
    feasible: nothing is removed)."""
    n_edges = edges.shape[0]
    excess = jnp.maximum(r_total - budgets, 0.0)
    cum = jnp.cumsum(hist[:, :n_edges], axis=-1)           # (K, E)
    feas_e = jnp.all(cum >= excess[:, None], axis=0)       # (E,)
    need = jnp.any(excess > 0)
    e_star = jnp.argmax(feas_e)                            # minimal feasible edge
    return jnp.where(need, edges[e_star], -jnp.inf)


def threshold_and_removed(cons_hist, gain_hist, edges, r_total, budgets):
    """tau plus the exact removed (consumption, profit) prefix masses.

    cons_hist: (K, E+1) removable-consumption histogram, gain_hist:
    (E+1,) removable raw-profit histogram, both fully accumulated /
    psum'd over the same group-profit ``edges`` (E,). Removing every
    group with p~ <= edges[e] removes exactly the prefix sums of both
    histograms, so the caller can report post-projection totals as
    ``r - removed_cons`` / ``primal - removed_gain`` without ever
    touching the items again — this is what lets the streaming finalize
    drop the dedicated projection-apply pass (DESIGN.md §5c).

    Returns (tau, removed_cons (K,), removed_gain ()). tau is -inf when
    already feasible (nothing removed) and +inf when no edge prefix
    covers the excess (mass above the ladder: every group is removed —
    always feasible, since zero consumption fits any budget).
    """
    n_edges = edges.shape[0]
    excess = jnp.maximum(r_total - budgets, 0.0)
    ccum = jnp.cumsum(cons_hist, axis=-1)                  # (K, E+1)
    gcum = jnp.cumsum(gain_hist, axis=-1)                  # (E+1,)
    feas_e = jnp.all(ccum[:, :n_edges] >= excess[:, None], axis=0)  # (E,)
    need = jnp.any(excess > 0)
    covered = jnp.any(feas_e)
    e_star = jnp.argmax(feas_e)                            # minimal feasible edge
    inf = jnp.asarray(jnp.inf, edges.dtype)
    tau = jnp.where(covered, edges[e_star], inf)
    tau = jnp.where(need, tau, -inf)
    # Prefix through e_star, or through the overflow bucket on fallback.
    j = jnp.where(covered, e_star, n_edges)
    removed_c = jnp.where(need, jnp.take_along_axis(
        ccum, jnp.full((ccum.shape[0], 1), j), axis=-1)[:, 0], 0.0)
    removed_g = jnp.where(need, gcum[j], 0.0)
    return tau, removed_c, removed_g


def feasibility_threshold_bucketed(ptilde, cons, r_total, budgets, axis=None, n_edges=512):
    """Distributed tau via histogramming; guaranteed feasible removal.

    ptilde: (n,), cons: (n, K) shard-local; r_total: (K,) global consumption
    (already psum'd); axis: mesh axis name(s) for the collectives. Composes
    profit_edges -> removable_hist -> threshold_from_removable_hist; the
    streaming driver runs the same pieces with the n rows arriving in
    chunks instead.
    """
    lo = jnp.min(ptilde)
    hi = jnp.max(ptilde)
    if axis is not None:
        lo = jax.lax.pmin(lo, axis)
        hi = jax.lax.pmax(hi, axis)
    edges = profit_edges(lo, hi, n_edges)                  # (E,)
    hist = removable_hist(ptilde, cons, edges)
    if axis is not None:
        hist = jax.lax.psum(hist, axis)
    return threshold_from_removable_hist(hist, edges, r_total, budgets)
