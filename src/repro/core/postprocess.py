"""Section 5.4: post-processing for feasibility.

Groups are ranked by their cost-adjusted group profit

    p~_i = sum_j p_ij x_ij - sum_k lam_k sum_j b_ijk x_ij

(the dual value contributed by group i) and zeroed out in ascending order
until every global constraint holds.

Distributed adaptation: the paper sorts groups globally — a full shuffle.
We reuse the Section 5.2 machinery instead: histogram group profits against
a fixed edge ladder, psum the (K, E) per-knapsack removable-consumption
histogram, and pick the smallest edge tau such that removing every group
with p~_i <= tau restores feasibility for ALL knapsacks. Because the
removal set is exactly "buckets below an edge", the removed consumption is
exactly the histogram prefix sum — the projection is conservative-exact
(always feasible), only the removal granularity is bucketed. An exact
sort-based mode is kept for single-shard use and tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["group_profit", "feasibility_threshold_exact", "feasibility_threshold_bucketed"]


def group_profit(p, cons, lam, x):
    """p~_i for a shard. p: (n, M), cons: (n, K), lam: (K,), x: (n, M)."""
    gain = jnp.einsum("nm,nm->n", p, x.astype(p.dtype))
    price = jnp.einsum("nk,k->n", cons, lam)
    return gain - price


def feasibility_threshold_exact(ptilde, cons, budgets):
    """Minimal prefix (ascending p~) whose removal restores feasibility.

    Returns tau; zero out groups with p~_i <= tau. Single shard / test path.
    """
    order = jnp.argsort(ptilde, stable=True)
    sorted_p = ptilde[order]
    csum = jnp.cumsum(cons[order], axis=0)                 # (n, K)
    total = csum[-1]
    excess = jnp.maximum(total - budgets, 0.0)             # (K,)
    ok = jnp.all(csum >= excess[None, :], axis=-1)         # (n,)
    n = ptilde.shape[0]
    first_ok = jnp.argmax(ok)                              # minimal prefix end
    need = jnp.any(excess > 0)
    tau = jnp.where(need, sorted_p[first_ok], -jnp.inf)
    return tau


def feasibility_threshold_bucketed(ptilde, cons, r_total, budgets, axis=None, n_edges=512):
    """Distributed tau via histogramming; guaranteed feasible removal.

    ptilde: (n,), cons: (n, K) shard-local; r_total: (K,) global consumption
    (already psum'd); axis: mesh axis name(s) for the collectives.
    """
    k = cons.shape[-1]
    lo = jnp.min(ptilde)
    hi = jnp.max(ptilde)
    if axis is not None:
        lo = jax.lax.pmin(lo, axis)
        hi = jax.lax.pmax(hi, axis)
    edges = jnp.linspace(lo, hi, n_edges)                  # (E,)
    idx = jnp.searchsorted(edges, ptilde, side="left")     # bucket i: (e[i-1], e[i]]
    nb = n_edges + 1
    seg = idx[:, None] + jnp.arange(k)[None, :] * nb
    hist = jax.ops.segment_sum(
        cons.reshape(-1), seg.reshape(-1), num_segments=k * nb
    ).reshape(k, nb)
    if axis is not None:
        hist = jax.lax.psum(hist, axis)
    excess = jnp.maximum(r_total - budgets, 0.0)
    # Removing {i : p~_i <= edges[e]} removes exactly cum[k, e].
    cum = jnp.cumsum(hist[:, :n_edges], axis=-1)           # (K, E)
    feas_e = jnp.all(cum >= excess[:, None], axis=0)       # (E,)
    need = jnp.any(excess > 0)
    e_star = jnp.argmax(feas_e)                            # minimal feasible edge
    tau = jnp.where(need, edges[e_star], -jnp.inf)
    return tau
