"""Algorithm 1: greedy optimal solver for the per-user IP subproblem.

With laminar (hierarchical) local constraints the per-user subproblem

    max_x  sum_j p~_ij x_ij   s.t.  sum_{j in S_l} x_ij <= C_l,  x in {0,1}

is solved optimally (Proposition 4.1) by keeping, for every set S_l in
topological (leaf -> root) order, only the top-C_l currently selected items
ranked by cost-adjusted profit ``p~``.

TPU adaptation: the paper runs a scalar greedy per user inside a Spark
mapper. Here the whole user shard is solved at once as fixed-shape dense
linear algebra — ranks come from a double argsort (stable, deterministic
tie-break by item index), set masks are applied with where(), and the loop
over the L sets is unrolled at trace time (L is small and static).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["adjusted_profit", "greedy_solve", "consumption", "topc_mask"]


def adjusted_profit(p, b, lam):
    """Cost-adjusted profit p~_ij = p_ij - sum_k lam_k b_ijk.

    p: (..., M), b: (..., M, K), lam: (K,) -> (..., M).
    """
    return p - jnp.einsum("...mk,k->...m", b, lam)


def topc_mask(score, c):
    """Boolean mask of the top-``c`` entries of ``score`` along the last axis.

    Deterministic: ties are broken by (stable) ascending item index.
    ``c`` may be a traced scalar.
    """
    order = jnp.argsort(-score, axis=-1, stable=True)      # best first
    ranks = jnp.argsort(order, axis=-1, stable=True)       # inverse perm
    return ranks < c


def greedy_solve(p_adj, sets, caps):
    """Algorithm 1, batched. p_adj: (..., M); sets: (L, M) bool; caps: (L,).

    Returns x: (..., M) bool. Rows of ``sets`` must be topo-sorted
    (leaf -> root; see types.LaminarSets).
    """
    x = p_adj > 0
    neg_inf = jnp.asarray(-jnp.inf, p_adj.dtype)
    for l in range(sets.shape[0]):
        mask = sets[l]
        score = jnp.where(x & mask, p_adj, neg_inf)
        keep = topc_mask(score, caps[l])
        x = x & jnp.where(mask, keep, True)
    return x


def consumption(b, x):
    """Per-user per-knapsack resource use v_ik = sum_j b_ijk x_ij.

    b: (..., M, K), x: (..., M) -> (..., K).
    """
    return jnp.einsum("...mk,...m->...k", b, x.astype(b.dtype))
