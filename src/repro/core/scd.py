"""Algorithms 3 + 4 (map side): SCD candidate generation for the general GKP.

For coordinate k, each item j defines a line in the (lam_k, z) plane:

    z_j(lam_k) = a_j - lam_k * b_jk,
    a_j        = p_j - sum_{k' != k} lam_k' b_jk'.

The greedy solution (Alg 1) depends only on the *order* of the z_j and
their signs, so it can only change at (1) pairwise line intersections and
(2) zero crossings (Alg 3). The map evaluates the greedy solution at every
candidate, sweeping lam_k downward, and emits the *incremental* consumption
(v1 = candidate value, v2 = consumption increase) exactly as Alg 4's Map.

Candidate count per user per coordinate: P = M(M-1)/2 + M (M is small; the
billion-scale path is the sparse Alg 5). Everything is batched over the
user shard; the per-candidate greedy re-solve is vmapped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .greedy import adjusted_profit, greedy_solve

__all__ = ["candidates_general", "num_candidates"]


def num_candidates(m: int) -> int:
    return m * (m - 1) // 2 + m


def _pair_indices(m):
    iu, ju = jnp.triu_indices(m, k=1)
    return iu, ju


def candidates_general(p, b, lam, sets, caps):
    """Algorithm 3 + Alg 4 map, batched. Returns (v1, v2): (n, K, P).

    p: (n, M), b: (n, M, K), lam: (K,). Invalid candidates are encoded as
    v1 = -1, v2 = 0.
    """
    n, m = p.shape
    k = lam.shape[0]
    pa = adjusted_profit(p, b, lam)                        # (n, M)
    iu, ju = _pair_indices(m)

    def per_k(kk):
        slope = b[:, :, kk]                                # (n, M)
        a = pa + lam[kk] * slope                           # intercepts (n, M)
        # (1) pairwise intersections.
        da = a[:, iu] - a[:, ju]
        db = slope[:, iu] - slope[:, ju]
        inter = jnp.where(jnp.abs(db) > 1e-12, da / jnp.where(db == 0, 1.0, db), -1.0)
        # (2) zero crossings.
        zero = jnp.where(slope > 1e-12, a / jnp.where(slope <= 1e-12, 1.0, slope), -1.0)
        cand = jnp.concatenate([inter, zero], axis=-1)     # (n, P)
        cand = jnp.where(jnp.isfinite(cand) & (cand >= 0.0), cand, -1.0)

        # Alg 4 map: sweep candidates in decreasing order, emit increments.
        cand_sorted = -jnp.sort(-cand, axis=-1)            # desc (n, P)

        def cons_at(c):
            # c: (n,) candidate lam_k. Sample the LEFT limit lam_k = c - eps:
            # the items that activate exactly at c must be attributed to c
            # (their mass belongs to every threshold v <= c), otherwise the
            # reduce under-predicts consumption by ~1 item per user and the
            # chosen lam systematically violates the budget.
            c_eff = c - 1e-5 * (1.0 + jnp.abs(c))
            padj = pa + (lam[kk] - c_eff)[:, None] * slope
            x = greedy_solve(padj, sets, caps)
            return jnp.einsum("nm,nm->n", slope, x.astype(slope.dtype))

        cons = jax.vmap(cons_at, in_axes=1, out_axes=1)(cand_sorted)  # (n, P)
        prev = jnp.concatenate([jnp.zeros((n, 1), cons.dtype), cons[:, :-1]], axis=-1)
        inc = cons - prev
        valid = (cand_sorted >= 0.0) & (inc > 0.0)
        v1 = jnp.where(valid, cand_sorted, -1.0)
        v2 = jnp.where(valid, inc, 0.0)
        return v1, v2

    v1, v2 = jax.vmap(per_k, out_axes=1)(jnp.arange(k))    # (n, K, P)
    return v1, v2
