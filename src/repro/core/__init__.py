"""The paper's primary contribution: billion-scale GKP solving in JAX.

Public API:
    types.SparseKP / types.DenseKP / types.SolverConfig — instances + config
    solver.solve / solver.solve_sharded                 — DD (Alg 2) & SCD (Alg 4)
    chunked.solve_streaming / chunked.ChunkSource       — out-of-core solves
    prefetch.solve_streaming_host / HostChunkSource     — host-fed (disk) solves
    greedy.greedy_solve                                 — Alg 1 (laminar IP, optimal)
    sparse_scd.candidates_sparse                        — Alg 5 (linear-time map)
    bucketing.*                                         — §5.2 bucketed reduce
    postprocess.*                                       — §5.4 feasibility projection
    moe_router.scd_route                                — the solver as an MoE router
"""
from .types import (  # noqa: F401
    DenseKP,
    LaminarSets,
    SolverConfig,
    SparseKP,
    cardinality_set,
    disjoint_partition_sets,
    hierarchy_from_lists,
)
from .greedy import adjusted_profit, consumption, greedy_solve  # noqa: F401
from .sparse_scd import candidates_sparse, select_sparse  # noqa: F401
from .scd import candidates_general  # noqa: F401
from .bucketing import (  # noqa: F401
    bucket_histogram,
    exact_threshold,
    make_edges,
    threshold_from_hist,
)
from .solver import SolveResult, dual_objective, solve, solve_sharded  # noqa: F401
from .chunked import (  # noqa: F401
    ChunkSource,
    StreamResult,
    array_source,
    decisions_chunk,
    decisions_rows,
    solve_streaming,
)
from .prefetch import (  # noqa: F401
    HostChunkSource,
    host_array_source,
    memmap_source,
    solve_streaming_host,
    source_fingerprint,
)
from .faults import (  # noqa: F401
    ChunkFetchError,
    FaultPlan,
    FaultPolicy,
    faulty_source,
    fetch_with_retries,
    resilient_source,
)
from .instances import dense_instance, shard_key, sparse_instance  # noqa: F401
from .moe_router import RouterOut, scd_route, topk_route  # noqa: F401
