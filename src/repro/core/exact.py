"""Exact / relaxation oracles for tests and the Figure 1 benchmark.

* ``brute_force`` — exhaustive optimum of the full GKP (tiny N*M only).
* ``brute_force_subproblem`` — exhaustive optimum of one per-user IP
  (validates Prop 4.1: Alg 1 greedy == optimum for laminar constraints).
* ``lp_upper_bound`` — LP relaxation via scipy.optimize.linprog (HiGHS):
  the paper's Figure 1 upper bound ("optimality ratio" denominator).

These run on host (numpy / scipy) by design: they are the independent
reference implementations the JAX system is validated against.
"""
from __future__ import annotations

import itertools

import numpy as np

__all__ = ["brute_force", "brute_force_subproblem", "lp_upper_bound"]


def _local_ok(xi, sets, caps):
    return all(xi[s].sum() <= c for s, c in zip(sets, caps))


def brute_force_subproblem(p_adj, sets, caps):
    """Optimal value/solution of max p_adj . x s.t. laminar caps. O(2^M)."""
    m = p_adj.shape[0]
    sets = np.asarray(sets)
    caps = np.asarray(caps)
    best_v, best_x = 0.0, np.zeros(m, bool)
    for bits in itertools.product([0, 1], repeat=m):
        xi = np.asarray(bits, bool)
        if not _local_ok(xi, sets, caps):
            continue
        v = float(p_adj[xi].sum())
        if v > best_v + 1e-12:
            best_v, best_x = v, xi
    return best_v, best_x


def brute_force(p, b, budgets, sets, caps):
    """Exhaustive optimum of the full GKP. p: (N, M), b: (N, M, K)."""
    n, m = p.shape
    sets = np.asarray(sets)
    caps = np.asarray(caps)
    budgets = np.asarray(budgets)
    per_user = []
    for i in range(n):
        opts = []
        for bits in itertools.product([0, 1], repeat=m):
            xi = np.asarray(bits, bool)
            if _local_ok(xi, sets, caps):
                opts.append(xi)
        per_user.append(opts)
    best_v = -1.0
    best_x = None
    for combo in itertools.product(*per_user):
        x = np.stack(combo)                                  # (N, M)
        use = np.einsum("nmk,nm->k", b, x.astype(np.float64))
        if np.any(use > budgets + 1e-9):
            continue
        v = float((p * x).sum())
        if v > best_v:
            best_v, best_x = v, x
    return best_v, best_x


def lp_upper_bound(p, b, budgets, sets, caps):
    """LP relaxation (0 <= x <= 1) optimum via scipy HiGHS; Figure 1's bound."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    p = np.asarray(p, np.float64)
    b = np.asarray(b, np.float64)
    budgets = np.asarray(budgets, np.float64)
    sets = np.asarray(sets, bool)
    caps = np.asarray(caps, np.float64)
    n, m = p.shape
    k = budgets.shape[0]
    l = sets.shape[0]
    nv = n * m
    a = lil_matrix((k + n * l, nv))
    rhs = np.empty(k + n * l)
    for kk in range(k):
        a[kk, :] = b[:, :, kk].reshape(-1)
        rhs[kk] = budgets[kk]
    row = k
    for i in range(n):
        for ll in range(l):
            cols = i * m + np.nonzero(sets[ll])[0]
            a[row, cols] = 1.0
            rhs[row] = caps[ll]
            row += 1
    res = linprog(
        -p.reshape(-1), A_ub=a.tocsr(), b_ub=rhs, bounds=(0.0, 1.0),
        method="highs",
    )
    assert res.status == 0, res.message
    return -res.fun


def milp_optimum(p, b, budgets, sets, caps, time_limit=60.0):
    """Exact IP optimum via scipy.optimize.milp (HiGHS branch-and-bound)."""
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    p = np.asarray(p, np.float64)
    b = np.asarray(b, np.float64)
    budgets = np.asarray(budgets, np.float64)
    sets = np.asarray(sets, bool)
    caps = np.asarray(caps, np.float64)
    n, m = p.shape
    k = budgets.shape[0]
    l = sets.shape[0]
    nv = n * m
    a = lil_matrix((k + n * l, nv))
    rhs = np.empty(k + n * l)
    for kk in range(k):
        a[kk, :] = b[:, :, kk].reshape(-1)
        rhs[kk] = budgets[kk]
    row = k
    for i in range(n):
        for ll in range(l):
            cols = i * m + np.nonzero(sets[ll])[0]
            a[row, cols] = 1.0
            rhs[row] = caps[ll]
            row += 1
    res = milp(
        -p.reshape(-1),
        constraints=LinearConstraint(a.tocsr(), -np.inf, rhs),
        integrality=np.ones(nv),
        bounds=(0, 1),
        options={"time_limit": time_limit},
    )
    assert res.status == 0, res.message
    return -res.fun


def lp_upper_bound_sparse(p, b, budgets, q):
    """LP bound for the sparse (Section 5.1) form."""
    n, k = p.shape
    sets = np.ones((1, k), bool)
    caps = np.asarray([q])
    b_dense = np.zeros((n, k, k))
    idx = np.arange(k)
    b_dense[:, idx, idx] = np.asarray(b)
    return lp_upper_bound(p, b_dense, budgets, sets, caps)
