"""Host-fed streaming solves: chunks that live on disk, not in a trace.

``core.chunked`` streams instances whose chunks are *traceable* — a
generated function of the chunk index, or slices of device-resident
arrays. Real datasets are neither: they sit in files on the host. This
module adds the third source family — a :class:`HostChunkSource`
producing NumPy chunks (memory-mapped files, in-memory arrays, or any
callable) — and a Python-level epoch driver, :func:`solve_streaming_host`,
that feeds them through the *same* accumulation kernels as the traced
driver with the next chunk's host-to-device transfer overlapped against
the current chunk's compute:

* **Double buffering.** Each per-chunk step is dispatched
  asynchronously; while the device works, the host produces chunk i+1
  (memmap page-in, decompression, whatever ``fn`` does) and issues its
  ``jax.device_put``, so H2D rides under the kernel. The synchronous
  mode (``double_buffer=False``) blocks on every transfer and every
  step — the naive feeding loop — and exists as the benchmark baseline
  (BENCH_stream_passes.json measures the gap).
* **Donated carries.** The running (histogram, top) / finalize
  accumulators are donated back to each step, so the constant-size
  carry state is updated in place rather than reallocated per chunk.
* **Sharding.** With ``mesh`` the chunk range is split into ``slots``
  *virtual shards* (:func:`sharded_source`), each an independent
  carry-seeded accumulator; every column step uploads one chunk per
  slot with per-device shardings and runs the accumulation under
  ``shard_map`` (one dispatch, all devices in parallel), and the
  constant-size slot partials are combined with
  :func:`repro.core.chunked.ordered_fold` — a fixed in-slot-order f32
  addition chain. With ``slots == devices`` this reproduces the traced
  ``stream_solve_fn`` sharded driver field-for-field (the CPU psum
  all-reduces in rank order — pinned by tests); because the slot
  partials and the fold never depend on which physical device ran a
  slot, the same solve is *bitwise invariant to the mesh size*, which
  is what makes elastic resume possible.
* **Fault tolerance.** With ``cfg.fetch_retries > 0`` (or a
  ``fetch_timeout`` / ``verify_refetch``) the source is wrapped in
  :func:`repro.core.faults.resilient_source` at solve entry, so *every*
  fetch site — the epoch loops, the sharded per-slot sub-sources, the
  presolve head read, the fingerprint's chunk-0 probe — retries
  transient failures under a capped, deterministically jittered backoff
  and an optional per-fetch timeout. Retries re-run only the pure
  fetch, never the accumulate, so a solve that survives injected
  transient faults is **bitwise identical** to the fault-free solve
  (chaos-parity tests pin this); exhausted retries raise a
  ``ChunkFetchError`` naming the chunk index and the attempt history.
* **Preemption safety.** ``cfg.checkpoint_every`` writes a
  constant-size resume state (lam, the damping carry, the
  fused-finalize slot partials, an epoch/chunk cursor and a source
  fingerprint) through the atomic checkpoint layer
  (:mod:`repro.checkpoint.ckpt`) every N iterations — and every N
  columns inside the fused finalize pass. ``resume_from=`` restores the
  latest checkpoint (torn ``.tmp`` writes are ignored by construction),
  re-places the slot partials onto the *current* mesh via the elastic
  re-sharding path, and continues to a result bitwise-identical to the
  uninterrupted run — on the same mesh or a degraded one (8 -> 4 -> 1
  devices), as long as the device count divides ``slots``. Resume
  requires the source to be restart-deterministic (memmap files and the
  ``data/synth`` generators are; the fingerprint hashes chunk 0 to
  catch feeding a different instance).

Bit-identity: every per-chunk step runs ``solver.scd_chunk_accumulate``
and ``chunked.finalize_chunk_accumulate`` — the exact functions the
traced scan bodies run — and the multiplier update replays the
``iterate_multipliers`` step arithmetic, so a host-fed solve over the
same rows and chunking is bit-identical to ``solve_streaming`` over an
``array_source``, fields for fields, single-device and sharded alike
(tests pin both). Deviation: the sharded host presolve (§5.3) samples
the *global* stream head like the single-device driver, not each
shard's head like the traced sharded presolve — pass ``lam0`` for exact
warm-start parity, or leave ``presolve_samples=0`` (the default).
"""
from __future__ import annotations

import functools
import hashlib
import time
from typing import Callable, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import ckpt
from ..compat import shard_map
from ..obs import NULL_TRACER
from .bucketing import make_edges, threshold_from_hist
from .faults import policy_from_cfg, resilient_source
from .chunked import (
    StreamResult,
    _metrics_init,
    _num_chunks,
    _pinned_dot,
    _validate_stream_cfg,
    adjusted_profit_chunk,
    finalize_chunk_accumulate,
    ordered_fold,
)
from .postprocess import (
    profit_edges,
    profit_edges_fixed,
    removable_hist,
    threshold_and_removed,
    threshold_from_removable_hist,
)
from .screening import HostScreen, crossing_trusted
from .solver import damped_multiplier_step, scd_chunk_accumulate, solve
from .sparse_scd import select_sparse
from .types import SolverConfig, SparseKP

__all__ = ["HostChunkSource", "host_array_source", "memmap_source",
           "callable_source", "sharded_source", "chunk_hashes",
           "solve_streaming_host", "source_fingerprint"]

# Resume-state phases (the "epoch cursor" of the checkpoint): the solve
# is either still iterating multipliers or inside the finalize pass.
_PHASE_ITER = 0
_PHASE_FIN = 1


class HostChunkSource(NamedTuple):
    """A sparse GKP instance delivered as on-demand *NumPy* chunks.

    The host-side mirror of ``chunked.ChunkSource``: ``fn(i)`` is a
    plain Python callable mapping the int chunk index to ``(p, b)``
    NumPy arrays of shape exactly (chunk, K) — rows at global index
    >= n (the ragged tail) MUST come back as p = b = 0, the same
    inert-row contract as the traced sources. ``fn`` runs on the host
    thread between device dispatches, so anything goes: memmap slices,
    file decoding, RPC fetches. Checkpoint/resume additionally requires
    ``fn`` to be restart-deterministic (same bytes for the same index
    across process restarts).
    """

    n: int                 # virtual user count
    k: int                 # knapsacks (== items, sparse form)
    chunk: int             # rows per chunk
    budgets: np.ndarray    # (K,) global budgets
    fn: Callable           # i -> (p (chunk, K), b (chunk, K)) numpy


def _pad_chunk(a, chunk, dtype):
    a = np.asarray(a, dtype=dtype)
    if a.shape[0] < chunk:
        a = np.concatenate(
            [a, np.zeros((chunk - a.shape[0],) + a.shape[1:], dtype)])
    return a


def host_array_source(p, b, budgets, chunk: int) -> HostChunkSource:
    """Wrap host-resident (n, K) arrays — incl. ``np.memmap`` — as chunks.

    Slicing a memmap only touches the pages of the requested chunk, so
    this is the out-of-core path for instances that exist as files: the
    (n, K) arrays are never resident in process memory, only the
    O(chunk·K) working slice (plus page cache at the OS's discretion).
    The ragged tail is zero-padded per the inert-row contract.
    """
    p = np.asarray(p) if not isinstance(p, np.memmap) else p
    b = np.asarray(b) if not isinstance(b, np.memmap) else b
    n, k = p.shape
    dtype = np.float32

    def fn(i):
        lo = i * chunk
        hi = min(lo + chunk, n)
        return (_pad_chunk(p[lo:hi], chunk, dtype),
                _pad_chunk(b[lo:hi], chunk, dtype))

    return HostChunkSource(n=n, k=k, chunk=chunk,
                           budgets=np.asarray(budgets, dtype), fn=fn)


def memmap_source(p_path, b_path, n: int, k: int, budgets,
                  chunk: int, dtype=np.float32) -> HostChunkSource:
    """Memory-mapped on-disk instance: raw row-major (n, K) p/b files.

    Opens both files with ``np.memmap(mode="r")`` and serves them
    through :func:`host_array_source`; nothing O(n) is ever read into
    memory — the epoch loop faults in exactly the chunks it streams,
    overlapped with device compute when double buffering is on.
    """
    p = np.memmap(p_path, dtype=dtype, mode="r", shape=(n, k))
    b = np.memmap(b_path, dtype=dtype, mode="r", shape=(n, k))
    return host_array_source(p, b, budgets, chunk)


def chunk_hashes(source: HostChunkSource, chunks=None) -> np.ndarray:
    """Per-chunk sha256 content digests of a host source, as (c, 32) uint8.

    Hashes the exact float32 payload bytes (``p`` then ``b``) each chunk
    index serves — the same bytes the solver consumes and the
    fingerprint's chunk-0 probe hashes — so two sources whose digests
    match for a chunk are byte-identical there. This is the identity a
    *real* (file-backed, non-synthetic) source brings to delta refresh:
    :func:`repro.serve.engine.content_chunk_diff` compares the previous
    generation's digests to the new ones and re-streams only chunks
    whose content actually changed (DESIGN.md §11). ``chunks`` restricts
    the scan to specific indices (returned in that order); the default
    hashes all of them — one sequential O(n·K) read, the price of not
    having a generator's closed-form diff.
    """
    if chunks is None:
        chunks = range(-(-source.n // source.chunk))
    out = np.zeros((len(chunks), 32), np.uint8)
    for j, i in enumerate(chunks):
        p, b = source.fn(int(i))
        h = hashlib.sha256(np.asarray(p, np.float32).tobytes())
        h.update(np.asarray(b, np.float32).tobytes())
        out[j] = np.frombuffer(h.digest(), np.uint8)
    return out


def callable_source(fn, n: int, k: int, budgets, chunk: int) -> HostChunkSource:
    """HostChunkSource from any chunk-producing callable.

    ``fn(i)`` must honour the inert-row contract (rows past n come back
    zero); the produced arrays are converted/padded defensively.
    """
    def wrapped(i):
        p, b = fn(i)
        return (_pad_chunk(p, chunk, np.float32),
                _pad_chunk(b, chunk, np.float32))

    return HostChunkSource(n=n, k=k, chunk=chunk,
                           budgets=np.asarray(budgets, np.float32),
                           fn=wrapped)


def sharded_source(source: HostChunkSource, slots: int):
    """Split a host source into ``slots`` disjoint chunk-range sub-sources.

    Slot ``s`` owns global chunks [s*cps, (s+1)*cps), cps = ceil(c/slots)
    — the same contiguous chunk partition the traced sharded driver
    hands shard ``s`` (``stream_solve_fn``'s ``i0 = shard * cpl``), so a
    slot's carry-seeded accumulation reproduces that shard's partial
    bit-for-bit. Sub-source ``fn(j)`` serves the global chunk
    ``s*cps + j``, or an all-zero (inert) chunk for indices past the
    last real chunk — mirroring the traced sources' padded-index
    contract, which matters bitwise: the traced scan *does* run those
    inert chunks (e.g. their invalid candidates still raise the running
    top from -inf), so the host slots must too. Works over every source
    family — memmap, callable, in-memory arrays, and the ``data/synth``
    generators.
    """
    if slots < 1:
        raise ValueError(f"slots must be >= 1, got {slots}")
    c = _num_chunks(source.n, source.chunk)
    cps = -(-c // slots)
    subs = []
    for s in range(slots):
        def fn(j, _s=s):
            i = _s * cps + j
            if i >= c:
                z = np.zeros((source.chunk, source.k), np.float32)
                return z, z.copy()
            return source.fn(i)

        lo = min(s * cps * source.chunk, source.n)
        hi = min((s + 1) * cps * source.chunk, source.n)
        subs.append(HostChunkSource(n=hi - lo, k=source.k,
                                    chunk=source.chunk,
                                    budgets=source.budgets, fn=fn))
    return subs


# --------------------------------------------------------------------------
# The double-buffered epoch driver.
# --------------------------------------------------------------------------

def _put_chunk(source, i, dtype, acc=None):
    # ``acc`` is the per-epoch ingest accumulator ([fetch_s, h2d_s,
    # chunks]): timings are bare perf_counter pairs on the host and are
    # emitted as ONE ingest.fetch + ONE ingest.h2d record per epoch —
    # per-chunk span objects on the streaming critical path would
    # dominate the cost they measure. Nothing here reads a clock inside
    # traced code, so the produced bytes are identical either way.
    if acc is not None:
        t0 = time.perf_counter()
        p, b = source.fn(i)
        t1 = time.perf_counter()
        out = (jax.device_put(np.asarray(p, dtype)),
               jax.device_put(np.asarray(b, dtype)))
        acc[0] += t1 - t0
        acc[1] += time.perf_counter() - t1
        acc[2] += 1
        return out
    p, b = source.fn(i)
    return (jax.device_put(np.asarray(p, dtype)),
            jax.device_put(np.asarray(b, dtype)))


def _epoch(source, step, state, extra, dtype, double_buffer,
           start=0, on_step=None, indices=None, tracer=NULL_TRACER):
    """One pass over chunks [start, c): ``state = step(state, p, b, *extra)``.

    Double-buffered mode dispatches the step (async) and only then
    produces + uploads the next chunk, so host work and H2D overlap the
    device compute; the carry pytree is donated by ``step`` so the
    constant-size state is updated in place. Synchronous mode blocks on
    the transfer and on the step — one chunk fully in flight at a time —
    and is kept as the benchmark baseline. ``on_step(i, state)``, when
    given, observes the post-chunk-i state (the checkpoint hook; reading
    it synchronizes, which is the measured checkpoint overhead).
    ``indices`` overrides the index range with an explicit ascending
    chunk list — the active-set screening pass (core/screening.py)
    streams only surviving chunks through exactly this loop.
    """
    c = _num_chunks(source.n, source.chunk)
    idxs = list(range(start, c)) if indices is None else list(indices)
    acc = [0.0, 0.0, 0] if tracer.enabled else None
    t_epoch = time.time() if tracer.enabled else 0.0
    if not double_buffer:
        for i in idxs:
            cur = _put_chunk(source, i, dtype, acc)
            jax.block_until_ready(cur)
            state = step(state, *cur, *extra)
            jax.block_until_ready(state)
            if on_step is not None:
                on_step(i, state)
        _emit_ingest(tracer, t_epoch, acc)
        return state
    if not idxs:
        return state
    nxt = _put_chunk(source, idxs[0], dtype, acc)
    for t, i in enumerate(idxs):
        cur, nxt = nxt, None
        state = step(state, *cur, *extra)
        if t + 1 < len(idxs):
            nxt = _put_chunk(source, idxs[t + 1], dtype, acc)
        if on_step is not None:
            on_step(i, state)
    _emit_ingest(tracer, t_epoch, acc)
    return state


def _emit_ingest(tracer, t_epoch, acc):
    """One ingest.fetch + one ingest.h2d record for a finished epoch."""
    if acc is not None and acc[2]:
        tracer.record("ingest.fetch", t_epoch, acc[0], chunks=acc[2])
        tracer.record("ingest.h2d", t_epoch, acc[1], chunks=acc[2])


def _observing_source(source, scr, base=0):
    """Wrap a source so every fetched chunk also records its screening
    certificate (:meth:`HostScreen.note_bound`). The bound is computed
    from exactly the bytes the accumulate consumes — after the fault
    layer's retries — so a certificate always describes the data that
    actually entered the histogram."""
    def fn(i):
        p, b = source.fn(i)
        scr.note_bound(base + i, p, b)
        return p, b
    return source._replace(fn=fn)


def _presolve_host(source, lam0, q, cfg):
    """§5.3 warm start: materialise the leading chunks, solve scaled."""
    if cfg.presolve_samples <= 0:
        return lam0
    s = min(cfg.presolve_samples, source.n)
    m = -(-s // source.chunk)
    parts = [source.fn(i) for i in range(m)]
    p = np.concatenate([pp for pp, _ in parts])[:s]
    b = np.concatenate([bb for _, bb in parts])[:s]
    frac = s / source.n
    small = SparseKP(p=jnp.asarray(p), b=jnp.asarray(b),
                     budgets=jnp.asarray(source.budgets) * frac)
    sub_cfg = cfg.replace(presolve_samples=0, record_history=False,
                          postprocess=False, chunk_size=None)
    return solve(small, sub_cfg, q=q, lam0=lam0).lam


def _legacy_finalize_host(source, lam, q, cfg, budgets, st, dtype,
                          double_buffer):
    """The three-pass legacy finalize, host-fed (benchmark baseline)."""
    metrics_step, hist_step, apply_step = (
        st["metrics_step"], st["hist_step"], st["apply_step"])
    r, primal, dual_sum, lo, hi = _epoch(
        source, metrics_step, _metrics_init(source.k, lam.dtype),
        (lam,), dtype, double_buffer)
    dual = dual_sum + _pinned_dot(lam, budgets)
    if not cfg.postprocess:
        return StreamResult(lam, None, r, primal, dual,
                            jnp.asarray(-jnp.inf, lam.dtype))
    edges = profit_edges(lo, hi, cfg.profit_buckets)
    hist = _epoch(
        source, hist_step,
        jnp.zeros((source.k, cfg.profit_buckets + 1), lam.dtype),
        (lam, edges), dtype, double_buffer)
    tau = threshold_from_removable_hist(hist, edges, r, budgets)
    r2, primal2 = _epoch(
        source, apply_step,
        (jnp.zeros_like(r), jnp.zeros((), lam.dtype)),
        (lam, tau), dtype, double_buffer)
    return StreamResult(lam, None, r2, primal2, dual, tau)


# --------------------------------------------------------------------------
# Checkpoint state (constant size): save / restore / fingerprint.
# --------------------------------------------------------------------------

_FIN_KEYS = ["fin_r", "fin_primal", "fin_dual", "fin_lo", "fin_hi",
             "fin_ch", "fin_gh"]


# The SolverConfig fields whose values steer the multiplier trajectory
# or the finalize arithmetic: they are hashed (in this order — the byte
# layout is load-bearing for existing checkpoints) into the resume-state
# fingerprint. ``dtype`` is hashed too, as ``str(cfg.dtype)``.
_FINGERPRINT_CFG_FIELDS = (
    "algo", "cd_mode", "reduce", "tol", "cd_damping", "dd_lr",
    "bucket_half", "bucket_delta", "bucket_growth", "presolve_samples",
    "partial_fraction", "stream_finalize", "profit_buckets",
    "profit_ladder_lo", "profit_ladder_hi", "use_kernels", "kernel_tile",
    "postprocess",
)

# Fields deliberately EXCLUDED from the fingerprint: changing any of
# them across a restart is legitimate because none of them alters the
# accepted multiplier trajectory or the finalize results — iteration
# budget / save cadence / retention, analysis sampling, the fault-retry
# policy, the resident-solver chunking (ignored when streaming), and
# active-set screening (trajectory-neutral by construction — a resumed
# solve rebuilds its screening state from all-active; DESIGN.md §11).
# Every SolverConfig field must appear in exactly one of these two sets
# (tests/test_fingerprint_fields.py enumerates the dataclass and fails
# on a field that is neither fingerprinted nor explicitly exempted).
FINGERPRINT_EXEMPT_FIELDS = frozenset({
    "max_iters", "metrics_every", "record_history",
    "checkpoint_every", "checkpoint_keep",
    "fetch_retries", "fetch_backoff", "fetch_backoff_growth",
    "fetch_backoff_cap", "fetch_jitter", "fetch_timeout",
    "verify_refetch",
    "chunk_size",
    "screening", "screening_floor",
})


def _fingerprint(source, cfg, q, lam_init):
    """Identity hash of (instance, solver arithmetic): workload shape,
    budgets bytes, the warm-start multipliers, the bytes of chunk 0,
    and every cfg field that steers the trajectory
    (``_FINGERPRINT_CFG_FIELDS``). Saved in the resume state; a mismatch
    on resume means the checkpoint belongs to a different solve and is
    refused. ``FINGERPRINT_EXEMPT_FIELDS`` are deliberately excluded —
    extending the iteration budget, changing the save cadence or fault
    policy, or toggling screening across a restart is legitimate.
    """
    h = hashlib.sha256()
    h.update(repr(
        (source.n, source.k, source.chunk, int(q))
        + tuple(getattr(cfg, f) for f in _FINGERPRINT_CFG_FIELDS)
        + (str(cfg.dtype),)).encode())
    h.update(np.asarray(source.budgets, np.float32).tobytes())
    h.update(np.asarray(lam_init, np.float32).tobytes())
    p0, b0 = source.fn(0)
    h.update(np.asarray(p0, np.float32).tobytes())
    h.update(np.asarray(b0, np.float32).tobytes())
    # Stored as raw bytes: an int64 scalar would be silently truncated
    # to int32 by dtype canonicalization on the restore device_put.
    return np.frombuffer(h.digest()[:8], np.uint8).copy()


def source_fingerprint(source: HostChunkSource, cfg: SolverConfig, q: int,
                       lam0=None) -> np.ndarray:
    """Public identity hash of one (source, cfg, q, lam0) solve — (8,) uint8.

    Exactly the fingerprint ``solve_streaming_host`` stores in its resume
    state and refuses to resume across, exposed so higher layers can
    stamp *published* artifacts with the same identity: the serving
    refresh engine (:mod:`repro.serve.engine`) records it in every
    generation, which lets a decision service verify it is answering
    lookups against the workload the generation was actually solved on.
    ``lam0`` defaults to the all-ones cold start like the solver.
    """
    lam0 = (np.ones((source.k,), np.float32) if lam0 is None
            else np.asarray(lam0, np.float32))
    # The chunk-0 probe fetches like any other read: under the cfg's
    # fault policy, so a transient fault during stamping retries instead
    # of failing a refresh whose solve already survived it.
    policy = policy_from_cfg(cfg)
    if policy is not None:
        source = resilient_source(source, policy, verify=cfg.verify_refetch)
    return _fingerprint(source, cfg, q, lam0)


def _save_state(directory, step, phase, iters, cursor, slots, fp, lam,
                dprev, fin, keep=3):
    """Write one StreamCheckpointState atomically; prune old steps.

    ``fin`` is the per-slot fused-finalize partial tuple (leading axis =
    slots; 5 or 7 leaves) — zeros while still iterating. Everything is
    host-gathered NumPy, constant size in n. ``keep`` is the retention
    passed through to ``ckpt.prune`` (``cfg.checkpoint_keep``).
    """
    state = {
        "phase": np.int32(phase),
        "iters": np.int32(iters),
        "cursor": np.int32(cursor),
        "slots": np.int32(slots),
        "fingerprint": np.asarray(fp, np.uint8),
        "lam": np.asarray(lam),
        "dprev": np.asarray(dprev),
    }
    for name, arr in zip(_FIN_KEYS, fin):
        state[name] = np.asarray(arr)
    ckpt.save(directory, step, state)
    ckpt.prune(directory, keep=keep)


def _load_state(resume_from, mesh, axes):
    """Latest resume state, or None when the directory has none (fresh
    start). With a mesh, the per-slot ``fin_*`` leaves are placed
    straight onto it through the elastic re-sharding path
    (``ckpt.restore_auto`` + ``sharding_tree``) and stay device-resident
    for the finalize to continue from; scalars and the replicated
    multiplier state come back as host NumPy for the driver."""
    step = ckpt.latest_step(resume_from)
    if step is None:
        return None
    sharding_tree = None
    if mesh is not None:
        slot_sh = NamedSharding(mesh, P(axes))
        sharding_tree = {name: slot_sh for name in _FIN_KEYS}
    try:
        state = ckpt.restore_auto(resume_from, step,
                                  sharding_tree=sharding_tree)
    except ValueError as e:
        # Chain the original error: this also catches e.g. a corrupt
        # manifest, not just a re-placement failure.
        raise ValueError(
            f"could not restore checkpoint {resume_from!r} step {step}: "
            f"{e} (if the mesh changed, note the checkpoint's slot count "
            "must be a multiple of the device count)") from e
    return {k: (v if k in _FIN_KEYS else np.asarray(v))
            for k, v in state.items()}


def _fin_zeros_np(slots, k, nb, postprocess, dtype=np.float32):
    """ITER-phase placeholder for the finalize partials (constant shape)."""
    dtype = np.dtype(dtype)
    fin = (np.zeros((slots, k), dtype), np.zeros((slots,), dtype),
           np.zeros((slots,), dtype),
           np.full((slots,), np.inf, dtype),
           np.full((slots,), -np.inf, dtype))
    if postprocess:
        fin = fin + (np.zeros((slots, k, nb), dtype),
                     np.zeros((slots, nb), dtype))
    return fin


# --------------------------------------------------------------------------
# Jitted per-chunk steps: single-device family.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _jit_steps(cfg, q):
    """Jitted per-chunk steps and update tails for one (cfg, q).

    Cached on the (hashable) config so repeated host-fed solves — and
    the benchmark's warm-up solve — reuse the compiled programs instead
    of re-jitting per call. Every step donates its carry (argument 0):
    the constant-size accumulators are updated in place chunk by chunk.
    """
    @functools.partial(jax.jit, donate_argnums=(0,))
    def dd_step(r, p_c, b_c, lam):
        x = select_sparse(p_c, b_c, lam, q)
        return r + jnp.sum(b_c * x.astype(b_c.dtype), axis=0)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scd_step(carry, p_c, b_c, lam, edges):
        # No straggler keep/scale: the single-device driver has one
        # shard, so the traced path's mask is identically 1.0 there —
        # and f32 multiplication by 1.0 is exact, so omitting it is
        # bitwise equivalent (the parity tests pin this).
        hist, top = carry
        return scd_chunk_accumulate(p_c, b_c, lam, edges, q, cfg, hist, top)

    @jax.jit
    def scd_tail(hist, top, lam, dprev, budgets, edges):
        prop = threshold_from_hist(hist, edges, budgets, top)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    @jax.jit
    def scd_tail_scr(hist, top, lam, dprev, budgets, edges):
        # The screened-iteration tail: same threshold + damped step,
        # plus the crossing guard — computed in the SAME compiled
        # program, so the guard's in_bucket tensor is (CSE) the one the
        # threshold recovery selects from: trusted here *means* the
        # accepted step never read bucket 0 (core/screening.py §3).
        prop = threshold_from_hist(hist, edges, budgets, top)
        out = damped_multiplier_step(lam, dprev, prop, cfg)
        return out + (crossing_trusted(hist, budgets),)

    @jax.jit
    def dd_tail(r, lam, dprev, budgets):
        prop = jnp.maximum(lam + cfg.dd_lr * (r - budgets), 0.0)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    pedges = profit_edges_fixed(cfg.profit_buckets, cfg.profit_ladder_lo,
                                cfg.profit_ladder_hi, cfg.dtype)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def fused_step(carry, p_c, b_c, lam):
        return finalize_chunk_accumulate(
            p_c, b_c, lam, q, cfg, carry,
            pedges if cfg.postprocess else None)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def metrics_step(carry, p_c, b_c, lam):
        return finalize_chunk_accumulate(p_c, b_c, lam, q, cfg, carry)

    @jax.jit
    def metrics_tail(r, primal, dual_sum, lam, budgets):
        # The same lines _history_metrics_fn runs on the psum'd partials
        # (axis=None here), so sampled host history rows are bitwise the
        # traced ones.
        dual = dual_sum + _pinned_dot(lam, budgets)
        viol = jnp.max(jnp.maximum(r - budgets, 0.0) / budgets)
        return {"lam": lam, "primal": primal, "dual": dual,
                "gap": dual - primal, "max_violation": viol}

    def _pt(p_c, b_c, lam, x):
        # The pinned row reduction of chunked._chunk_primal.
        return jax.lax.optimization_barrier(jnp.sum(
            jnp.where(x, adjusted_profit_chunk(p_c, b_c, lam), 0.0),
            axis=-1))

    @functools.partial(jax.jit, donate_argnums=(0,))
    def hist_step(hist, p_c, b_c, lam, edges):
        x = select_sparse(p_c, b_c, lam, q)
        cons = b_c * x.astype(b_c.dtype)
        return removable_hist(_pt(p_c, b_c, lam, x), cons, edges, init=hist)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def apply_step(carry, p_c, b_c, lam, tau):
        r2, primal2 = carry
        x = select_sparse(p_c, b_c, lam, q)
        cons = b_c * x.astype(b_c.dtype)
        keep_row = _pt(p_c, b_c, lam, x) > tau
        x = x & keep_row[:, None]
        cons = cons * keep_row[:, None].astype(cons.dtype)
        return (r2 + jnp.sum(cons, axis=0),
                primal2 + jnp.sum(jnp.where(x, p_c, 0.0)))

    return {"dd_step": dd_step, "scd_step": scd_step, "scd_tail": scd_tail,
            "scd_tail_scr": scd_tail_scr,
            "dd_tail": dd_tail, "fused_step": fused_step,
            "metrics_step": metrics_step, "metrics_tail": metrics_tail,
            "hist_step": hist_step, "apply_step": apply_step,
            "pedges": pedges}


# --------------------------------------------------------------------------
# Jitted per-column steps: sharded (virtual-slot) family.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _jit_steps_sharded(cfg, q, mesh, spd):
    """Per-column shard_map steps + ordered-fold combines for one
    (cfg, q, mesh, slots-per-device).

    Every step carries per-slot accumulators (leading axis S = spd *
    devices, sharded over all mesh axes) and one chunk per slot
    ((S, chunk, K) batches); inside shard_map each device loops its
    ``spd`` local slots, running the *same* accumulate bodies as the
    traced scan. No collectives in the steps — the combines host-gather
    the S constant-size partials and fold them in slot order
    (``ordered_fold``), which coincides with the traced driver's psum on
    CPU (slots == devices) and never depends on the physical device
    count (elastic resume).
    """
    axes = tuple(mesh.axis_names)
    spec0 = P(axes)
    slots = spd * mesh.devices.size
    pedges = profit_edges_fixed(cfg.profit_buckets, cfg.profit_ladder_lo,
                                cfg.profit_ladder_hi, cfg.dtype)

    # Straggler mask per *slot*, mirroring solver._straggler_mask with
    # size = slots (the flat shard index of the traced driver): keyed on
    # the virtual shard, not the physical device, so degraded meshes
    # drop the same slots.
    if cfg.partial_fraction < 1.0:
        idx = np.arange(slots, dtype=np.float32)
        keep_np = ((idx + 1.0) <= np.float32(cfg.partial_fraction)
                   * np.float32(slots)).astype(np.float32)
        frac = np.maximum(np.float32(cfg.partial_fraction),
                          np.float32(1.0) / np.float32(slots))
        scale_np = np.float32(1.0) / frac
    else:
        keep_np, scale_np = np.ones((slots,), np.float32), np.float32(1.0)

    def _rows(carry, t):
        return tuple(a[t] for a in carry)

    def _stack(rows):
        return tuple(jnp.stack(parts) for parts in zip(*rows))

    def scd_body(hist, top, pb, bb, lam, edges, keep):
        rows = []
        for t in range(spd):
            if cfg.use_kernels or cfg.partial_fraction >= 1.0:
                rows.append(scd_chunk_accumulate(
                    pb[t], bb[t], lam, edges, q, cfg, hist[t], top[t]))
            else:
                rows.append(scd_chunk_accumulate(
                    pb[t], bb[t], lam, edges, q, cfg, hist[t], top[t],
                    keep[t], jnp.float32(scale_np)))
        return _stack(rows)

    # keep is per-slot and must arrive sharded like the carries, so each
    # device indexes its *local* slots' mask values.
    scd_step = jax.jit(shard_map(
        scd_body, mesh=mesh,
        in_specs=(spec0, spec0, spec0, spec0, P(), P(), spec0),
        out_specs=(spec0, spec0), check_vma=False),
        donate_argnums=(0, 1))

    def dd_body(r, pb, bb, lam):
        rows = []
        for t in range(spd):
            x = select_sparse(pb[t], bb[t], lam, q)
            rows.append(r[t] + jnp.sum(bb[t] * x.astype(bb[t].dtype),
                                       axis=0))
        return jnp.stack(rows)

    dd_step = jax.jit(shard_map(
        dd_body, mesh=mesh,
        in_specs=(spec0, spec0, spec0, P()),
        out_specs=spec0, check_vma=False),
        donate_argnums=(0,))

    def fin_body(pedges_or_none, carry, pb, bb, lam):
        rows = []
        for t in range(spd):
            rows.append(finalize_chunk_accumulate(
                pb[t], bb[t], lam, q, cfg, _rows(carry, t), pedges_or_none))
        return _stack(rows)

    n_fin = 7 if cfg.postprocess else 5
    fin_step = jax.jit(shard_map(
        lambda *a: fin_body(pedges if cfg.postprocess else None,
                            a[:n_fin], a[n_fin], a[n_fin + 1], a[n_fin + 2]),
        mesh=mesh,
        in_specs=(spec0,) * n_fin + (spec0, spec0, P()),
        out_specs=(spec0,) * n_fin, check_vma=False),
        donate_argnums=tuple(range(n_fin)))

    metrics_step = jax.jit(shard_map(
        lambda *a: fin_body(None, a[:5], a[5], a[6], a[7]),
        mesh=mesh,
        in_specs=(spec0,) * 5 + (spec0, spec0, P()),
        out_specs=(spec0,) * 5, check_vma=False),
        donate_argnums=(0, 1, 2, 3, 4))

    # Combines: host-gathered slot partials in, replicated results out.
    # ordered_fold = the psum-in-rank-order addition chain, pinned.
    @jax.jit
    def scd_combine(hist, top, lam, dprev, budgets, edges):
        if cfg.use_kernels and cfg.partial_fraction < 1.0:
            # Traced kernel path scales each shard's accumulated
            # histogram once (linear in v2), before the reduce.
            hist = hist * (jnp.asarray(keep_np)[:, None, None]
                           * jnp.float32(scale_np))
        h = ordered_fold(hist)
        t = jnp.max(top, axis=0)               # pmax: order-invariant
        prop = threshold_from_hist(h, edges, budgets, t)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    @jax.jit
    def scd_combine_scr(hist, top, lam, dprev, budgets, edges):
        # Screened-iteration combine: scd_combine's fold + threshold +
        # damped step with the bucket->=1 crossing guard in the same
        # program (see _jit_steps.scd_tail_scr).
        if cfg.use_kernels and cfg.partial_fraction < 1.0:
            hist = hist * (jnp.asarray(keep_np)[:, None, None]
                           * jnp.float32(scale_np))
        h = ordered_fold(hist)
        t = jnp.max(top, axis=0)
        prop = threshold_from_hist(h, edges, budgets, t)
        out = damped_multiplier_step(lam, dprev, prop, cfg)
        return out + (crossing_trusted(h, budgets),)

    @jax.jit
    def dd_combine(r, lam, dprev, budgets):
        rk = ordered_fold(r * jnp.asarray(keep_np)[:, None])
        rk = rk * jnp.float32(scale_np)
        prop = jnp.maximum(lam + cfg.dd_lr * (rk - budgets), 0.0)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    @jax.jit
    def fin_combine(carry, lam, budgets):
        r = ordered_fold(carry[0])
        primal = ordered_fold(carry[1])
        dual = ordered_fold(carry[2]) + _pinned_dot(lam, budgets)
        if not cfg.postprocess:
            return (r, primal, dual, jnp.asarray(-jnp.inf, lam.dtype),
                    None, None)
        ch = ordered_fold(carry[5])
        gh = ordered_fold(carry[6])
        tau, removed_cons, removed_gain = threshold_and_removed(
            ch, gh, pedges, r, budgets)
        return r - removed_cons, primal - removed_gain, dual, tau, ch, gh

    @jax.jit
    def metrics_combine(carry, lam, budgets):
        r = ordered_fold(carry[0])
        primal = ordered_fold(carry[1])
        dual = ordered_fold(carry[2]) + _pinned_dot(lam, budgets)
        viol = jnp.max(jnp.maximum(r - budgets, 0.0) / budgets)
        return {"lam": lam, "primal": primal, "dual": dual,
                "gap": dual - primal, "max_violation": viol}

    return {"scd_step": scd_step, "dd_step": dd_step, "fin_step": fin_step,
            "metrics_step": metrics_step, "scd_combine": scd_combine,
            "scd_combine_scr": scd_combine_scr,
            "dd_combine": dd_combine, "fin_combine": fin_combine,
            "metrics_combine": metrics_combine, "pedges": pedges,
            "keep_np": keep_np}


# --------------------------------------------------------------------------
# Runtimes: the epoch/finalize machinery behind the phase driver.
# --------------------------------------------------------------------------

class _SingleRuntime:
    """Mesh-less host feeding (slots == 1): the original per-chunk jits.

    Kept as its own code path (rather than a 1-device shard_map) so the
    compiled programs — and therefore the f32 rounding contexts the
    PR-3 bitwise host==traced contract was pinned against — are exactly
    the ones the parity tests already cover.
    """

    def __init__(self, source, cfg, q, double_buffer):
        self.source, self.cfg, self.q = source, cfg, q
        self.double_buffer = double_buffer
        self.dtype = cfg.dtype
        self.budgets = jnp.asarray(source.budgets, cfg.dtype)
        self.st = _jit_steps(cfg, q)
        self.fin_cols = _num_chunks(source.n, source.chunk)
        self.real_c = self.fin_cols
        self.slots = 1
        self.scr = None   # HostScreen, installed by the driver
        self.tracer = NULL_TRACER   # phase-span tracer, installed likewise

    def iter_epoch(self, lam, dprev):
        st, cfg, src = self.st, self.cfg, self.source
        if cfg.algo == "dd":
            r = _epoch(src, st["dd_step"], jnp.zeros_like(lam), (lam,),
                       self.dtype, self.double_buffer, tracer=self.tracer)
            return st["dd_tail"](r, lam, dprev, self.budgets)
        edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth,
                           cfg.bucket_half)
        if self.scr is not None:
            return self._iter_epoch_screened(lam, dprev, edges)
        hist0 = jnp.zeros((src.k, edges.shape[-1] + 1), jnp.float32)
        top0 = jnp.full((src.k,), -jnp.inf, lam.dtype)
        hist, top = _epoch(src, st["scd_step"], (hist0, top0),
                           (lam, edges), self.dtype, self.double_buffer,
                           tracer=self.tracer)
        return st["scd_tail"](hist, top, lam, dprev, self.budgets, edges)

    def _iter_epoch_screened(self, lam, dprev, edges):
        """One SCD epoch over the active chunk set only; the crossing
        guard (core/screening.py §3) either certifies the screened
        histogram or triggers one full unscreened pass."""
        st, src, scr = self.st, self.source, self.scr
        scr.begin_iter(np.asarray(lam))
        idx = scr.active_indices()
        obs = _observing_source(src, scr)

        def run(over, indices=None):
            hist0 = jnp.zeros((src.k, edges.shape[-1] + 1), jnp.float32)
            top0 = jnp.full((src.k,), -jnp.inf, lam.dtype)
            hist, top = _epoch(over, st["scd_step"], (hist0, top0),
                               (lam, edges), self.dtype,
                               self.double_buffer, indices=indices,
                               tracer=self.tracer)
            return st["scd_tail_scr"](hist, top, lam, dprev, self.budgets,
                                      edges)

        lam_n, d_n, moved, trusted = run(obs, indices=idx)
        scr.record_streamed(len(idx))
        if self.tracer.enabled:
            self.tracer.event("screen.skip", streamed=len(idx),
                              skipped=self.real_c - len(idx))
        if scr.any_retired() and not bool(trusted):
            lam_n, d_n, moved, _ = run(src)
            scr.record_streamed(self.real_c, fallback=True)
        scr.retire()
        return lam_n, d_n, moved

    def metrics_record(self, lam):
        out = _epoch(self.source, self.st["metrics_step"],
                     _metrics_init(self.source.k, lam.dtype), (lam,),
                     self.dtype, self.double_buffer, tracer=self.tracer)
        return self.st["metrics_tail"](out[0], out[1], out[2], lam,
                                       self.budgets)

    def fin_init(self):
        init = _metrics_init(self.source.k, self.cfg.dtype)
        if self.cfg.postprocess:
            nb = self.st["pedges"].shape[0] + 1
            init = init + (jnp.zeros((self.source.k, nb), self.cfg.dtype),
                           jnp.zeros((nb,), self.cfg.dtype))
        return init

    def fin_run(self, carry, lam, start, on_col):
        return _epoch(self.source, self.st["fused_step"], carry, (lam,),
                      self.dtype, self.double_buffer, start=start,
                      on_step=on_col, tracer=self.tracer)

    def fin_result(self, out, lam, iters):
        r, primal, dual_sum = out[0], out[1], out[2]
        dual = dual_sum + _pinned_dot(lam, self.budgets)
        fin_hist = None
        if self.cfg.postprocess:
            tau, removed_cons, removed_gain = threshold_and_removed(
                out[5], out[6], self.st["pedges"], r, self.budgets)
            r = r - removed_cons
            primal = primal - removed_gain
            fin_hist = (out[5], out[6])
        else:
            tau = jnp.asarray(-jnp.inf, lam.dtype)
        return StreamResult(lam, jnp.int32(iters), r, primal, dual, tau,
                            None, fin_hist)

    def fin_to_np(self, carry):
        return tuple(np.asarray(a)[None] for a in carry)

    def fin_from_np(self, fin):
        return tuple(jnp.asarray(a[0]) for a in fin)

    def legacy_result(self, lam, iters):
        res = _legacy_finalize_host(self.source, lam, self.q, self.cfg,
                                    self.budgets, self.st, self.dtype,
                                    self.double_buffer)
        return res._replace(iters=jnp.int32(iters))


class _ShardedRuntime:
    """Virtual-slot shard_map feeding: S slots over D devices (S % D == 0).

    Each column step uploads one chunk per slot ((S, chunk, K), sharded
    over the mesh) and advances every slot's carry under shard_map; the
    constant-size slot partials are host-gathered once per epoch and
    combined in fixed slot order. Nothing downstream of the per-slot
    accumulation depends on D, which is what makes a checkpoint written
    on one mesh resume bitwise on another.
    """

    def __init__(self, source, cfg, q, mesh, slots, double_buffer):
        self.source, self.cfg, self.q = source, cfg, q
        self.double_buffer = double_buffer
        self.slots = slots
        self.subs = sharded_source(source, slots)
        c = _num_chunks(source.n, source.chunk)
        self.real_c = c
        self.cps = -(-c // slots)
        self.fin_cols = self.cps
        self.scr = None   # HostScreen over slots*cps padded chunk slots
        self.tracer = NULL_TRACER   # phase-span tracer, driver-installed
        spd = slots // mesh.devices.size
        self.st = _jit_steps_sharded(cfg, q, mesh, spd)
        self.slot_sh = NamedSharding(mesh, P(tuple(mesh.axis_names)))
        self.budgets = jnp.asarray(source.budgets, cfg.dtype)
        self.keep = jax.device_put(self.st["keep_np"], self.slot_sh)

    def _fetch_cols(self, j, screen, dt):
        if not screen:
            ps, bs = zip(*(sub.fn(j) for sub in self.subs))
            return ps, bs
        # Screened column: fetch only slots whose chunk (global slot
        # index s*cps + j) is still active; retired slots are fed
        # zeros — bitwise-neutral by the inert-row contract (their
        # scatter-adds contribute +0.0 and their candidate values
        # sit below ``max(top, edges[:, -1])``, screening.py §4).
        scr, cps = self.scr, self.cps
        zero = np.zeros((self.source.chunk, self.source.k), dt)
        ps, bs = [], []
        for s, sub in enumerate(self.subs):
            g = s * cps + j
            if scr.active[g]:
                p, b = sub.fn(j)
                scr.note_bound(g, p, b)
            else:
                p = b = zero
            ps.append(p)
            bs.append(b)
        return ps, bs

    def _produce(self, j, screen=False):
        # Same cfg.dtype cast as the single-device _put_chunk, so a
        # source producing wider arrays feeds both runtimes identically.
        dt = np.dtype(self.cfg.dtype)
        tracer = self.tracer
        if tracer.enabled:
            with tracer.span("ingest.fetch", col=int(j)):
                ps, bs = self._fetch_cols(j, screen, dt)
            with tracer.span("ingest.h2d", col=int(j)):
                pb = np.ascontiguousarray(np.stack(ps), dtype=dt)
                bb = np.ascontiguousarray(np.stack(bs), dtype=dt)
                return (jax.device_put(pb, self.slot_sh),
                        jax.device_put(bb, self.slot_sh))
        ps, bs = self._fetch_cols(j, screen, dt)
        pb = np.ascontiguousarray(np.stack(ps), dtype=dt)
        bb = np.ascontiguousarray(np.stack(bs), dtype=dt)
        return (jax.device_put(pb, self.slot_sh),
                jax.device_put(bb, self.slot_sh))

    def _epoch_cols(self, step, state, extra, start=0, on_col=None,
                    indices=None, screen=False):
        """One pass over columns [start, cps): every slot advances one
        chunk per column. Same double-buffering contract as ``_epoch``.
        ``indices`` restricts the pass to an explicit ascending column
        list (the screening path: columns whose slots are all retired
        are skipped outright)."""
        cols = self.cps
        idxs = list(range(start, cols)) if indices is None else list(indices)

        def call(state, cur):
            out = step(*state, *cur, *extra)
            return out if isinstance(out, tuple) else (out,)

        if not self.double_buffer:
            for j in idxs:
                cur = self._produce(j, screen)
                jax.block_until_ready(cur)
                state = call(state, cur)
                jax.block_until_ready(state)
                if on_col is not None:
                    on_col(j, state)
            return state
        if not idxs:
            return state
        nxt = self._produce(idxs[0], screen)
        for t, j in enumerate(idxs):
            cur, nxt = nxt, None
            state = call(state, cur)
            if t + 1 < len(idxs):
                nxt = self._produce(idxs[t + 1], screen)
            if on_col is not None:
                on_col(j, state)
        return state

    def iter_epoch(self, lam, dprev):
        cfg, st, S, k = self.cfg, self.st, self.slots, self.source.k
        dt = np.dtype(cfg.dtype)
        if cfg.algo == "dd":
            r0 = jax.device_put(np.zeros((S, k), dt), self.slot_sh)
            (r,) = self._epoch_cols(st["dd_step"], (r0,), (lam,))
            return st["dd_combine"](np.asarray(r), lam, dprev, self.budgets)
        edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth,
                           cfg.bucket_half)
        if self.scr is not None:
            return self._iter_epoch_screened(lam, dprev, edges)
        # The histogram is f32 by design (traced convention); top carries
        # the multiplier dtype.
        hist0 = jax.device_put(
            np.zeros((S, k, edges.shape[-1] + 1), np.float32), self.slot_sh)
        top0 = jax.device_put(np.full((S, k), -np.inf, dt), self.slot_sh)
        hist, top = self._epoch_cols(st["scd_step"], (hist0, top0),
                                     (lam, edges, self.keep))
        return st["scd_combine"](np.asarray(hist), np.asarray(top), lam,
                                 dprev, self.budgets, edges)

    def _iter_epoch_screened(self, lam, dprev, edges):
        """Screened SCD epoch: retired slots feed zeros, columns with no
        active slot are skipped; the crossing guard runs on the folded
        histogram inside the same program as the multiplier step."""
        cfg, st, S, k = self.cfg, self.st, self.slots, self.source.k
        dt = np.dtype(cfg.dtype)
        scr, cps = self.scr, self.cps
        scr.begin_iter(np.asarray(lam))
        act = scr.active.reshape(S, cps)
        cols = [int(j) for j in np.flatnonzero(act.any(axis=0))]
        streamed = int(np.count_nonzero(scr.active[:self.real_c]))

        def run(indices=None, screen=False):
            hist0 = jax.device_put(
                np.zeros((S, k, edges.shape[-1] + 1), np.float32),
                self.slot_sh)
            top0 = jax.device_put(np.full((S, k), -np.inf, dt),
                                  self.slot_sh)
            hist, top = self._epoch_cols(st["scd_step"], (hist0, top0),
                                         (lam, edges, self.keep),
                                         indices=indices, screen=screen)
            return st["scd_combine_scr"](np.asarray(hist), np.asarray(top),
                                         lam, dprev, self.budgets, edges)

        lam_n, d_n, moved, trusted = run(indices=cols, screen=True)
        scr.record_streamed(streamed)
        if self.tracer.enabled:
            self.tracer.event("screen.skip", streamed=streamed,
                              skipped=self.real_c - streamed)
        if scr.any_retired() and not bool(trusted):
            lam_n, d_n, moved, _ = run()
            scr.record_streamed(self.real_c, fallback=True)
        scr.retire()
        return lam_n, d_n, moved

    def metrics_record(self, lam):
        init = _fin_zeros_np(self.slots, self.source.k, 0, False,
                             self.cfg.dtype)
        carry = tuple(jax.device_put(a, self.slot_sh) for a in init)
        out = self._epoch_cols(self.st["metrics_step"], carry, (lam,))
        return self.st["metrics_combine"](
            tuple(np.asarray(a) for a in out[:3]), lam, self.budgets)

    def fin_init(self):
        fin = _fin_zeros_np(self.slots, self.source.k,
                            self.st["pedges"].shape[0] + 1,
                            self.cfg.postprocess, self.cfg.dtype)
        return tuple(jax.device_put(a, self.slot_sh) for a in fin)

    def fin_run(self, carry, lam, start, on_col):
        return self._epoch_cols(self.st["fin_step"], carry, (lam,),
                                start=start, on_col=on_col)

    def fin_result(self, carry, lam, iters):
        vals = tuple(np.asarray(a) for a in carry)
        r, primal, dual, tau, ch, gh = self.st["fin_combine"](
            vals, lam, self.budgets)
        fin_hist = (ch, gh) if self.cfg.postprocess else None
        return StreamResult(lam, jnp.int32(iters), r, primal, dual, tau,
                            None, fin_hist)

    def fin_to_np(self, carry):
        return tuple(np.asarray(a) for a in carry)

    def fin_from_np(self, fin):
        return tuple(jax.device_put(a, self.slot_sh) for a in fin)


# --------------------------------------------------------------------------
# The driver: presolve -> iterate -> finalize, with checkpoint/resume.
# --------------------------------------------------------------------------

def solve_streaming_host(source: HostChunkSource,
                         cfg: SolverConfig = SolverConfig(), q: int = 1,
                         lam0=None, double_buffer: bool = True, mesh=None,
                         slots: Optional[int] = None, checkpoint_dir=None,
                         resume_from=None,
                         screen_init: Optional[dict] = None,
                         tracer=None) -> StreamResult:
    """Solve a host-fed sparse GKP, chunks uploaded as they are consumed.

    The host-side twin of ``chunked.solve_streaming``: the iteration
    loop runs in Python (one *epoch* over the chunks per SCD/DD
    iteration, early exit at convergence), every per-chunk device step
    is the same accumulation the traced scan performs — carry-seeded
    histogram, donated buffers — and the finalize follows
    ``cfg.stream_finalize`` ("fused": one epoch; "legacy": three,
    single-device only). With ``double_buffer`` (default) the next
    chunk's production and H2D transfer overlap the current chunk's
    compute.

    Results are bit-identical to ``solve_streaming`` over an
    ``array_source`` holding the same rows and chunking (same
    accumulation functions, same update arithmetic, same finalize), so
    the traced driver remains this one's oracle — single-device and,
    with ``mesh``, under ``shard_map`` field-for-field (tests pin both).

    Sharding: ``mesh`` splits the chunk range into ``slots`` virtual
    shards (default: one per device) fed with per-device shardings; see
    the module docstring. ``slots`` may exceed the device count (each
    device then works several slots per column), which is what lets a
    checkpoint resume on a *smaller* mesh bitwise.

    Preemption safety: with ``cfg.checkpoint_every = N`` and a
    ``checkpoint_dir``, a constant-size resume state is written
    atomically every N iterations, and every N chunk columns inside the
    fused finalize pass. ``resume_from=<dir>`` restores the latest state
    (fingerprint-checked against this source/cfg; torn writes ignored)
    and continues; an interrupted-and-resumed solve returns bitwise the
    uninterrupted ``lam/iters/r/primal/dual/tau`` — and the same
    ``fin_hist`` — on the same mesh or any mesh whose device count
    divides the checkpoint's slot count. An empty/missing ``resume_from``
    directory starts fresh (the standard relaunch loop: always pass
    ``--resume``).

    Restrictions (each raises ValueError): sparse SCD (sync) and DD only
    — ``cd_mode="cyclic"`` would re-feed the source K times per
    iteration; the sharded runtime requires the fused finalize;
    ``record_history`` needs ``cfg.metrics_every`` sampling (one extra
    metrics epoch per sample, bitwise the traced sampled history) and
    cannot be combined with checkpoint/resume.

    Observability: ``tracer`` (a :class:`repro.obs.Tracer`; default the
    shared no-op) emits host-side phase spans — ``solve.iterate``,
    ``solve.finalize``, ``ingest.fetch``, ``ingest.h2d``, ``screen.skip``
    — to its JSONL journal. Tracing is *not* a ``SolverConfig`` field:
    it never enters the resume fingerprint, and because spans bracket
    only host Python (never a value inside a jitted program), a traced
    solve is bitwise identical to an untraced one (``tests/test_obs.py``
    and ``benchmarks/bench_obs.py`` gate this).
    """
    _validate_stream_cfg(cfg)
    if cfg.algo == "scd" and cfg.cd_mode != "sync":
        raise ValueError(
            "solve_streaming_host supports cd_mode='sync' (cyclic CD "
            "re-feeds the whole source K times per iteration)")
    # Fault layer: wrap the source once, here, so every downstream fetch
    # site (epochs, sharded sub-sources, presolve, fingerprint) retries
    # transient failures under cfg's policy. Retries re-run only the
    # pure fetch — the accumulate consumes exactly the bytes a clean
    # fetch returns, which is what keeps a fault-surviving solve bitwise
    # equal to the fault-free one.
    fault_policy = policy_from_cfg(cfg)
    if fault_policy is not None:
        source = resilient_source(source, fault_policy,
                                  verify=cfg.verify_refetch)
    # cfg.checkpoint_every is the cadence; the directory is the enable
    # switch. A cadence with no directory runs unprotected (so reference
    # runs can share the exact cfg of a checkpointed job); the launcher
    # rejects that combination for production jobs.
    ckpt_every = cfg.checkpoint_every
    if checkpoint_dir is None:
        checkpoint_dir = resume_from
    checkpointing = ckpt_every > 0 and checkpoint_dir is not None
    if checkpointing and cfg.checkpoint_keep < 1:
        raise ValueError(
            f"checkpoint_keep must be >= 1 (got {cfg.checkpoint_keep}): "
            "retaining zero resume states would leave nothing to resume "
            "from")
    if (checkpointing or resume_from is not None) and cfg.record_history:
        raise ValueError(
            "record_history is an analysis mode and cannot be combined "
            "with checkpoint/resume (the sampled rows are not part of "
            "the constant-size resume state)")

    restored = (_load_state(resume_from,
                            mesh, tuple(mesh.axis_names) if mesh else None)
                if resume_from is not None else None)
    if restored is not None:
        S = int(restored["slots"])
        if slots is not None and slots != S:
            raise ValueError(
                f"checkpoint was written with slots={S}; asked for "
                f"slots={slots} (the slot count is fixed at first launch)")
    else:
        S = slots if slots is not None else (
            mesh.devices.size if mesh is not None else 1)
    if mesh is None and S > 1:
        # Degraded all the way down to one process-default device: run
        # the same slot structure on an internal single-device mesh.
        mesh = jax.make_mesh((1,), ("slots",))
    if mesh is not None:
        d = mesh.devices.size
        if S < d or S % d != 0:
            raise ValueError(
                f"slots={S} must be a positive multiple of the mesh "
                f"device count {d} (elastic resume divides slots over "
                f"devices)")
    sharded = mesh is not None
    if sharded and cfg.stream_finalize == "legacy":
        raise ValueError(
            "sharded host feeding supports stream_finalize='fused' only "
            "(the legacy three-pass finalize remains on the single-device "
            "driver as the oracle/benchmark baseline)")

    dtype = cfg.dtype
    lam = (jnp.ones((source.k,), dtype) if lam0 is None
           else jnp.asarray(lam0, dtype))
    fp = (_fingerprint(source, cfg, q, np.asarray(lam))
          if (checkpointing or restored is not None) else None)
    if restored is not None and not np.array_equal(
            np.asarray(restored["fingerprint"], np.uint8), fp):
        raise ValueError(
            "resume state fingerprint mismatch: the checkpoint in "
            f"{resume_from!r} was written for a different "
            "(source, cfg, q, lam0) — refusing to resume")

    tracer = NULL_TRACER if tracer is None else tracer
    rt = (_ShardedRuntime(source, cfg, q, mesh, S, double_buffer) if sharded
          else _SingleRuntime(source, cfg, q, double_buffer))
    rt.tracer = tracer
    dprev = jnp.zeros_like(lam)
    iters, phase, cursor, fin_carry = 0, _PHASE_ITER, 0, None
    if restored is not None:
        lam = jnp.asarray(restored["lam"], dtype)
        dprev = jnp.asarray(restored["dprev"], dtype)
        iters = int(restored["iters"])
        phase = int(restored["phase"])
        cursor = int(restored["cursor"])
        if phase == _PHASE_FIN and cursor > 0:
            fin_carry = rt.fin_from_np(tuple(
                restored[k] for k in _FIN_KEYS if k in restored))
    else:
        lam = _presolve_host(source, lam, q, cfg)

    scr = None
    if cfg.screening:   # _validate_stream_cfg pinned algo/cd_mode/reduce
        # Screening state is rebuilt fresh on every (re)start — it is
        # not part of the checkpoint (see HostScreen: it never steers
        # the trajectory). ``screen_init`` seeds it from a previous
        # solve's stats for the serving layer's delta refresh.
        scr = HostScreen(rt.slots * rt.fin_cols, source.k, cfg,
                         np.asarray(lam), seed=screen_init)
        rt.scr = scr

    rows = [] if cfg.record_history else None
    every = max(cfg.metrics_every, 1)
    fin_zeros = functools.partial(_fin_zeros_np, S, source.k,
                                  cfg.profit_buckets + 1, cfg.postprocess,
                                  cfg.dtype)

    if phase == _PHASE_ITER:
        while iters < cfg.max_iters:
            if tracer.enabled:
                with tracer.span("solve.iterate", iter=iters):
                    lam, dprev, moved = rt.iter_epoch(lam, dprev)
            else:
                lam, dprev, moved = rt.iter_epoch(lam, dprev)
            iters += 1
            if rows is not None:
                if (iters - 1) % every == 0:
                    rows.append(rt.metrics_record(lam))
                else:
                    nan = jnp.asarray(jnp.nan, lam.dtype)
                    rows.append({"lam": lam, "primal": nan, "dual": nan,
                                 "gap": nan, "max_violation": nan})
            if not bool(moved):
                break
            if (checkpointing and iters % ckpt_every == 0
                    and iters < cfg.max_iters):
                _save_state(checkpoint_dir, iters, _PHASE_ITER, iters, 0,
                            S, fp, lam, dprev, fin_zeros(),
                            keep=cfg.checkpoint_keep)
        phase, cursor = _PHASE_FIN, 0
        if checkpointing:
            # Finalize-entry state: without it, a kill during the
            # finalize would force replaying multiplier iterations.
            _save_state(checkpoint_dir, cfg.max_iters + 1, _PHASE_FIN,
                        iters, 0, S, fp, lam, dprev, fin_zeros(),
                        keep=cfg.checkpoint_keep)

    history = None
    if rows is not None:
        # The traced scan driver freezes converged iterations: every row
        # past convergence re-records the final iteration's sample —
        # which is exactly a copy of the last live row (the sampling
        # predicate is keyed on the frozen iteration number).
        while len(rows) < cfg.max_iters:
            rows.append(rows[-1])
        history = {k: jnp.stack([r[k] for r in rows]) for k in rows[0]}

    scr_stats = scr.stats() if scr is not None else None
    if cfg.stream_finalize == "legacy":
        if tracer.enabled:
            with tracer.span("solve.finalize", mode="legacy", iters=iters):
                res = rt.legacy_result(lam, iters)
        else:
            res = rt.legacy_result(lam, iters)
        return res._replace(history=history, screen=scr_stats)

    on_col = None
    if checkpointing:
        def on_col(j, state):
            done = j + 1
            if done % ckpt_every == 0 and done < rt.fin_cols:
                _save_state(checkpoint_dir, cfg.max_iters + 1 + done,
                            _PHASE_FIN, iters, done, S, fp, lam, dprev,
                            rt.fin_to_np(state), keep=cfg.checkpoint_keep)

    carry = rt.fin_init() if fin_carry is None else fin_carry
    if tracer.enabled:
        with tracer.span("solve.finalize", mode="fused", iters=iters):
            carry = rt.fin_run(carry, lam, cursor, on_col)
            res = rt.fin_result(carry, lam, iters)
    else:
        carry = rt.fin_run(carry, lam, cursor, on_col)
        res = rt.fin_result(carry, lam, iters)
    return res._replace(history=history, screen=scr_stats)

