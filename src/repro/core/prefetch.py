"""Host-fed streaming solves: chunks that live on disk, not in a trace.

``core.chunked`` streams instances whose chunks are *traceable* — a
generated function of the chunk index, or slices of device-resident
arrays. Real datasets are neither: they sit in files on the host. This
module adds the third source family the repo was missing — a
:class:`HostChunkSource` producing NumPy chunks (memory-mapped files,
in-memory arrays, or any callable) — and a Python-level epoch driver,
:func:`solve_streaming_host`, that feeds them through the *same*
accumulation kernels as the traced driver with the next chunk's
host-to-device transfer overlapped against the current chunk's compute:

* **Double buffering.** Each per-chunk step is dispatched
  asynchronously; while the device works, the host produces chunk i+1
  (memmap page-in, decompression, whatever ``fn`` does) and issues its
  ``jax.device_put``, so H2D rides under the kernel. The synchronous
  mode (``double_buffer=False``) blocks on every transfer and every
  step — the naive feeding loop — and exists as the benchmark baseline
  (BENCH_stream_passes.json measures the gap).
* **Donated carries.** The running (histogram, top) / finalize
  accumulators are donated back to each step, so the constant-size
  carry state is updated in place rather than reallocated per chunk.

Bit-identity: every per-chunk step runs ``solver.scd_chunk_accumulate``
and ``chunked.finalize_chunk_accumulate`` — the exact functions the
traced scan bodies run — and the multiplier update replays the
``iterate_multipliers`` step arithmetic, so a host-fed solve over the
same rows and chunking is bit-identical to ``solve_streaming`` over an
``array_source``, fields for fields (tests pin this). The epoch loop is
single-process/single-device by construction; multi-host deployments
shard the *file*, not the loop (each host feeds its own shard — the
psum wiring for that lives with the traced driver).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .bucketing import make_edges, threshold_from_hist
from .chunked import (
    StreamResult,
    _metrics_init,
    _num_chunks,
    _pinned_dot,
    _validate_stream_cfg,
    adjusted_profit_chunk,
    finalize_chunk_accumulate,
)
from .postprocess import (
    profit_edges,
    profit_edges_fixed,
    removable_hist,
    threshold_and_removed,
    threshold_from_removable_hist,
)
from .solver import damped_multiplier_step, scd_chunk_accumulate, solve
from .sparse_scd import select_sparse
from .types import SolverConfig, SparseKP

__all__ = ["HostChunkSource", "host_array_source", "memmap_source",
           "callable_source", "solve_streaming_host"]


class HostChunkSource(NamedTuple):
    """A sparse GKP instance delivered as on-demand *NumPy* chunks.

    The host-side mirror of ``chunked.ChunkSource``: ``fn(i)`` is a
    plain Python callable mapping the int chunk index to ``(p, b)``
    NumPy arrays of shape exactly (chunk, K) — rows at global index
    >= n (the ragged tail) MUST come back as p = b = 0, the same
    inert-row contract as the traced sources. ``fn`` runs on the host
    thread between device dispatches, so anything goes: memmap slices,
    file decoding, RPC fetches.
    """

    n: int                 # virtual user count
    k: int                 # knapsacks (== items, sparse form)
    chunk: int             # rows per chunk
    budgets: np.ndarray    # (K,) global budgets
    fn: Callable           # i -> (p (chunk, K), b (chunk, K)) numpy


def _pad_chunk(a, chunk, dtype):
    a = np.asarray(a, dtype=dtype)
    if a.shape[0] < chunk:
        a = np.concatenate(
            [a, np.zeros((chunk - a.shape[0],) + a.shape[1:], dtype)])
    return a


def host_array_source(p, b, budgets, chunk: int) -> HostChunkSource:
    """Wrap host-resident (n, K) arrays — incl. ``np.memmap`` — as chunks.

    Slicing a memmap only touches the pages of the requested chunk, so
    this is the out-of-core path for instances that exist as files: the
    (n, K) arrays are never resident in process memory, only the
    O(chunk·K) working slice (plus page cache at the OS's discretion).
    The ragged tail is zero-padded per the inert-row contract.
    """
    p = np.asarray(p) if not isinstance(p, np.memmap) else p
    b = np.asarray(b) if not isinstance(b, np.memmap) else b
    n, k = p.shape
    dtype = np.float32

    def fn(i):
        lo = i * chunk
        hi = min(lo + chunk, n)
        return (_pad_chunk(p[lo:hi], chunk, dtype),
                _pad_chunk(b[lo:hi], chunk, dtype))

    return HostChunkSource(n=n, k=k, chunk=chunk,
                           budgets=np.asarray(budgets, dtype), fn=fn)


def memmap_source(p_path, b_path, n: int, k: int, budgets,
                  chunk: int, dtype=np.float32) -> HostChunkSource:
    """Memory-mapped on-disk instance: raw row-major (n, K) p/b files.

    Opens both files with ``np.memmap(mode="r")`` and serves them
    through :func:`host_array_source`; nothing O(n) is ever read into
    memory — the epoch loop faults in exactly the chunks it streams,
    overlapped with device compute when double buffering is on.
    """
    p = np.memmap(p_path, dtype=dtype, mode="r", shape=(n, k))
    b = np.memmap(b_path, dtype=dtype, mode="r", shape=(n, k))
    return host_array_source(p, b, budgets, chunk)


def callable_source(fn, n: int, k: int, budgets, chunk: int) -> HostChunkSource:
    """HostChunkSource from any chunk-producing callable.

    ``fn(i)`` must honour the inert-row contract (rows past n come back
    zero); the produced arrays are converted/padded defensively.
    """
    def wrapped(i):
        p, b = fn(i)
        return (_pad_chunk(p, chunk, np.float32),
                _pad_chunk(b, chunk, np.float32))

    return HostChunkSource(n=n, k=k, chunk=chunk,
                           budgets=np.asarray(budgets, np.float32),
                           fn=wrapped)


# --------------------------------------------------------------------------
# The double-buffered epoch driver.
# --------------------------------------------------------------------------

def _put_chunk(source, i, dtype):
    p, b = source.fn(i)
    return (jax.device_put(np.asarray(p, dtype)),
            jax.device_put(np.asarray(b, dtype)))


def _epoch(source, step, state, extra, dtype, double_buffer):
    """One pass over all chunks: ``state = step(state, p, b, *extra)``.

    Double-buffered mode dispatches the step (async) and only then
    produces + uploads the next chunk, so host work and H2D overlap the
    device compute; the carry pytree is donated by ``step`` so the
    constant-size state is updated in place. Synchronous mode blocks on
    the transfer and on the step — one chunk fully in flight at a time —
    and is kept as the benchmark baseline.
    """
    c = _num_chunks(source.n, source.chunk)
    if not double_buffer:
        for i in range(c):
            cur = _put_chunk(source, i, dtype)
            jax.block_until_ready(cur)
            state = step(state, *cur, *extra)
            jax.block_until_ready(state)
        return state
    nxt = _put_chunk(source, 0, dtype)
    for i in range(c):
        cur, nxt = nxt, None
        state = step(state, *cur, *extra)
        if i + 1 < c:
            nxt = _put_chunk(source, i + 1, dtype)
    return state


def _presolve_host(source, lam0, q, cfg):
    """§5.3 warm start: materialise the leading chunks, solve scaled."""
    if cfg.presolve_samples <= 0:
        return lam0
    s = min(cfg.presolve_samples, source.n)
    m = -(-s // source.chunk)
    parts = [source.fn(i) for i in range(m)]
    p = np.concatenate([pp for pp, _ in parts])[:s]
    b = np.concatenate([bb for _, bb in parts])[:s]
    frac = s / source.n
    small = SparseKP(p=jnp.asarray(p), b=jnp.asarray(b),
                     budgets=jnp.asarray(source.budgets) * frac)
    sub_cfg = cfg.replace(presolve_samples=0, record_history=False,
                          postprocess=False, chunk_size=None)
    return solve(small, sub_cfg, q=q, lam0=lam0).lam


def _legacy_finalize_host(source, lam, q, cfg, budgets, st, dtype,
                          double_buffer):
    """The three-pass legacy finalize, host-fed (benchmark baseline)."""
    metrics_step, hist_step, apply_step = (
        st["metrics_step"], st["hist_step"], st["apply_step"])
    r, primal, dual_sum, lo, hi = _epoch(
        source, metrics_step, _metrics_init(source.k, lam.dtype),
        (lam,), dtype, double_buffer)
    dual = dual_sum + _pinned_dot(lam, budgets)
    if not cfg.postprocess:
        return StreamResult(lam, None, r, primal, dual,
                            jnp.asarray(-jnp.inf, lam.dtype))
    edges = profit_edges(lo, hi, cfg.profit_buckets)
    hist = _epoch(
        source, hist_step,
        jnp.zeros((source.k, cfg.profit_buckets + 1), lam.dtype),
        (lam, edges), dtype, double_buffer)
    tau = threshold_from_removable_hist(hist, edges, r, budgets)
    r2, primal2 = _epoch(
        source, apply_step,
        (jnp.zeros_like(r), jnp.zeros((), lam.dtype)),
        (lam, tau), dtype, double_buffer)
    return StreamResult(lam, None, r2, primal2, dual, tau)


def solve_streaming_host(source: HostChunkSource,
                         cfg: SolverConfig = SolverConfig(), q: int = 1,
                         lam0=None, double_buffer: bool = True) -> StreamResult:
    """Solve a host-fed sparse GKP, chunks uploaded as they are consumed.

    The host-side twin of ``chunked.solve_streaming``: the iteration
    loop runs in Python (one *epoch* over the chunks per SCD/DD
    iteration, early exit at convergence), every per-chunk device step
    is the same accumulation the traced scan performs — carry-seeded
    histogram, donated buffers — and the finalize follows
    ``cfg.stream_finalize`` ("fused": one epoch; "legacy": three). With
    ``double_buffer`` (default) the next chunk's production and H2D
    transfer overlap the current chunk's compute.

    Results are bit-identical to ``solve_streaming`` over an
    ``array_source`` holding the same rows and chunking (same
    accumulation functions, same update arithmetic, same finalize), so
    the traced driver remains this one's oracle. Restrictions: sparse
    SCD (sync) and DD only — ``cd_mode="cyclic"`` would re-feed the
    source K times per iteration and is rejected — and the same
    ``record_history`` rule as the traced driver (resident solves or
    ``cfg.metrics_every`` sampling; sampling is not implemented host-side
    yet, so any ``record_history=True`` raises here).
    """
    # Host-specific rejections come first: _validate_stream_cfg's
    # record_history message recommends cfg.metrics_every sampling, which
    # only the traced driver implements — following that advice here
    # would just trade one error for another.
    if cfg.record_history:
        raise ValueError(
            "record_history is not supported by the host-fed driver; use "
            "the traced solve_streaming with cfg.metrics_every sampling, "
            "or a resident solve")
    _validate_stream_cfg(cfg)
    if cfg.algo == "scd" and cfg.cd_mode != "sync":
        raise ValueError(
            "solve_streaming_host supports cd_mode='sync' (cyclic CD "
            "re-feeds the whole source K times per iteration)")
    dtype = cfg.dtype
    budgets = jnp.asarray(source.budgets, dtype)
    lam = (jnp.ones((source.k,), dtype) if lam0 is None
           else jnp.asarray(lam0, dtype))
    lam = _presolve_host(source, lam, q, cfg)
    st = _jit_steps(cfg, q)

    dprev = jnp.zeros_like(lam)
    iters = 0
    for _ in range(cfg.max_iters):
        if cfg.algo == "dd":
            r = _epoch(source, st["dd_step"], jnp.zeros_like(lam), (lam,),
                       dtype, double_buffer)
            lam, dprev, moved = st["dd_tail"](r, lam, dprev, budgets)
        else:
            edges = make_edges(lam, cfg.bucket_delta, cfg.bucket_growth,
                               cfg.bucket_half)
            hist0 = jnp.zeros((source.k, edges.shape[-1] + 1), jnp.float32)
            top0 = jnp.full((source.k,), -jnp.inf, lam.dtype)
            hist, top = _epoch(source, st["scd_step"], (hist0, top0),
                               (lam, edges), dtype, double_buffer)
            lam, dprev, moved = st["scd_tail"](hist, top, lam, dprev,
                                               budgets, edges)
        iters += 1
        if not bool(moved):
            break

    if cfg.stream_finalize == "legacy":
        res = _legacy_finalize_host(source, lam, q, cfg, budgets, st, dtype,
                                    double_buffer)
        return res._replace(iters=jnp.int32(iters))

    pedges = st["pedges"]
    init = _metrics_init(source.k, lam.dtype)
    if cfg.postprocess:
        init = init + (jnp.zeros((source.k, pedges.shape[0] + 1), lam.dtype),
                       jnp.zeros((pedges.shape[0] + 1,), lam.dtype))
    out = _epoch(source, st["fused_step"], init, (lam,), dtype, double_buffer)
    r, primal, dual_sum = out[0], out[1], out[2]
    dual = dual_sum + _pinned_dot(lam, budgets)
    if cfg.postprocess:
        tau, removed_cons, removed_gain = threshold_and_removed(
            out[5], out[6], pedges, r, budgets)
        r = r - removed_cons
        primal = primal - removed_gain
    else:
        tau = jnp.asarray(-jnp.inf, lam.dtype)
    return StreamResult(lam, jnp.int32(iters), r, primal, dual, tau)


@functools.lru_cache(maxsize=64)
def _jit_steps(cfg, q):
    """Jitted per-chunk steps and update tails for one (cfg, q).

    Cached on the (hashable) config so repeated host-fed solves — and
    the benchmark's warm-up solve — reuse the compiled programs instead
    of re-jitting per call. Every step donates its carry (argument 0):
    the constant-size accumulators are updated in place chunk by chunk.
    """
    @functools.partial(jax.jit, donate_argnums=(0,))
    def dd_step(r, p_c, b_c, lam):
        x = select_sparse(p_c, b_c, lam, q)
        return r + jnp.sum(b_c * x.astype(b_c.dtype), axis=0)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scd_step(carry, p_c, b_c, lam, edges):
        # No straggler keep/scale: the host driver is single-process, so
        # the traced path's mask is identically 1.0 there — and f32
        # multiplication by 1.0 is exact, so omitting it is bitwise
        # equivalent (the parity tests pin this).
        hist, top = carry
        return scd_chunk_accumulate(p_c, b_c, lam, edges, q, cfg, hist, top)

    @jax.jit
    def scd_tail(hist, top, lam, dprev, budgets, edges):
        prop = threshold_from_hist(hist, edges, budgets, top)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    @jax.jit
    def dd_tail(r, lam, dprev, budgets):
        prop = jnp.maximum(lam + cfg.dd_lr * (r - budgets), 0.0)
        return damped_multiplier_step(lam, dprev, prop, cfg)

    pedges = profit_edges_fixed(cfg.profit_buckets, cfg.profit_ladder_lo,
                                cfg.profit_ladder_hi, cfg.dtype)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def fused_step(carry, p_c, b_c, lam):
        return finalize_chunk_accumulate(
            p_c, b_c, lam, q, cfg, carry,
            pedges if cfg.postprocess else None)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def metrics_step(carry, p_c, b_c, lam):
        return finalize_chunk_accumulate(p_c, b_c, lam, q, cfg, carry)

    def _pt(p_c, b_c, lam, x):
        # The pinned row reduction of chunked._chunk_primal.
        return jax.lax.optimization_barrier(jnp.sum(
            jnp.where(x, adjusted_profit_chunk(p_c, b_c, lam), 0.0),
            axis=-1))

    @functools.partial(jax.jit, donate_argnums=(0,))
    def hist_step(hist, p_c, b_c, lam, edges):
        x = select_sparse(p_c, b_c, lam, q)
        cons = b_c * x.astype(b_c.dtype)
        return removable_hist(_pt(p_c, b_c, lam, x), cons, edges, init=hist)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def apply_step(carry, p_c, b_c, lam, tau):
        r2, primal2 = carry
        x = select_sparse(p_c, b_c, lam, q)
        cons = b_c * x.astype(b_c.dtype)
        keep_row = _pt(p_c, b_c, lam, x) > tau
        x = x & keep_row[:, None]
        cons = cons * keep_row[:, None].astype(cons.dtype)
        return (r2 + jnp.sum(cons, axis=0),
                primal2 + jnp.sum(jnp.where(x, p_c, 0.0)))

    return {"dd_step": dd_step, "scd_step": scd_step, "scd_tail": scd_tail,
            "dd_tail": dd_tail, "fused_step": fused_step,
            "metrics_step": metrics_step, "hist_step": hist_step,
            "apply_step": apply_step, "pedges": pedges}
