"""Capacity-constrained MoE routing as a sparse GKP (the paper inside the LM).

The Section 5.1 sparse knapsack IS expert routing:

    users  = tokens            items = experts (M == K, diagonal costs b=1)
    p_ik   = router affinity   B_k  = expert k's token capacity
    Q      = top-k per token   x_ik = token i routed to expert k

A few synchronous-coordinate-descent iterations (Alg 5 map + §5.2 bucketed
reduce, both pure jnp so GSPMD partitions them across the token shards)
price each expert with a multiplier lam_k such that realised load respects
capacity *globally and by construction* — replacing heuristic aux-loss
balancing. lam is computed under stop_gradient (prices are a dual quantity,
not a learned parameter); gradients flow through the chosen experts'
combine weights exactly as in standard top-k routing.

The final assignment applies Alg 1 for the sparse instance (top-Q positive
adjusted affinities) followed by the §5.4 projection *per expert*: among
tokens assigned to expert k, keep the capacity-many with the largest
adjusted affinity (deterministic, fixed shapes).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bucketing import bucket_histogram, make_edges, threshold_from_hist
from .sparse_scd import candidates_sparse

__all__ = ["RouterOut", "scd_route", "topk_route"]


class RouterOut(NamedTuple):
    combine: jnp.ndarray   # (T, E) combine weights (0 where not routed)
    mask: jnp.ndarray      # (T, E) bool assignment
    lam: jnp.ndarray       # (E,) expert prices
    load: jnp.ndarray      # (E,) realised token counts (pre-projection)


def _scd_prices(p, capacity, q, iters, bucket_half, bucket_delta, bucket_growth,
                axis=None):
    """SCD iterations on the routing GKP. p: (T, E) >= 0, capacity: (E,).

    ``axis``: mesh axis name(s) the token dim is sharded over (inside
    shard_map); the histogram reduce becomes a psum so expert prices are
    global even though each shard only sees its own tokens.
    """
    ones = jnp.ones_like(p)

    def step(lam, _):
        v1, v2 = candidates_sparse(p, ones, lam, q)
        edges = make_edges(lam, bucket_delta, bucket_growth, bucket_half)
        hist = bucket_histogram(v1, v2, edges)
        top = jnp.max(v1, axis=0)
        if axis is not None:
            hist = jax.lax.psum(hist, axis)
            top = jax.lax.pmax(top, axis)
        lam_new = threshold_from_hist(hist, edges, capacity, top)
        return lam_new, None

    lam0 = jnp.zeros((p.shape[-1],), p.dtype)
    lam, _ = jax.lax.scan(step, lam0, None, length=iters)
    return lam


@functools.partial(
    jax.jit,
    static_argnames=("q", "capacity_factor", "iters", "bucket_half"),
)
def scd_route(logits, q=2, capacity_factor=1.25, iters=4, bucket_half=16,
              bucket_delta=1e-4, bucket_growth=1.8):
    """Knapsack-priced top-Q routing with exact expert capacity.

    logits: (T, E). Returns RouterOut with sum(mask, axis=0) <= capacity
    and sum(mask, axis=1) <= q.
    """
    t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # p_ik >= 0
    capacity = jnp.full((e,), capacity_factor * q * t / e, jnp.float32)

    lam = _scd_prices(jax.lax.stop_gradient(probs), capacity, q, iters,
                      bucket_half, bucket_delta, bucket_growth)
    adj = jax.lax.stop_gradient(probs - lam[None, :])
    # Alg 1 (sparse): top-Q positive adjusted affinities per token.
    # (ranks are integer decisions: keep sorts out of the grad graph)
    order = jnp.argsort(-adj, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (adj > 0) & (ranks < q)

    # §5.4 per-expert projection to hard capacity: keep the capacity-many
    # best adjusted affinities among assigned tokens (floor: capacity is a
    # count, an integer rank must stay strictly below it).
    score = jnp.where(mask, adj, -jnp.inf)
    erank = jnp.argsort(jnp.argsort(-score, axis=0, stable=True), axis=0, stable=True)
    mask = mask & (erank < jnp.floor(capacity)[None, :])

    load = jnp.sum(mask, axis=0).astype(jnp.float32)
    combine = jnp.where(mask, probs, 0.0).astype(logits.dtype)
    return RouterOut(combine=combine, mask=mask, lam=lam, load=load)


def scd_route_shmap(logits, q, capacity_factor, iters, axis):
    """shard_map variant: logits (T_local, E); capacity and prices are
    global across ``axis``. Returns (combine, mask) with combine weights
    renormalised over the chosen experts."""
    t_local, e = logits.shape
    n_shards = jax.lax.psum(1, axis)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    capacity = jnp.full((e,), capacity_factor * q * t_local / e, jnp.float32)
    capacity = capacity * n_shards                          # global budget
    # stop_gradient on the INPUT too: prices must be entirely off the AD
    # path (pmax/psum inside the scan have no/expensive transpose rules)
    lam = _scd_prices(jax.lax.stop_gradient(probs), capacity, q, iters,
                      16, 1e-4, 1.8, axis=axis)
    adj = jax.lax.stop_gradient(probs - lam[None, :])
    order = jnp.argsort(-adj, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (adj > 0) & (ranks < q)
    combine = jnp.where(mask, probs, 0.0)
    denom = jnp.maximum(combine.sum(-1, keepdims=True), 1e-9)
    return (combine / denom).astype(logits.dtype), mask


@functools.partial(jax.jit, static_argnames=("q",))
def topk_route(logits, q=2):
    """Baseline heuristic top-k routing (no capacity guarantee)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    order = jnp.argsort(jax.lax.stop_gradient(-probs), axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = ranks < q
    load = jnp.sum(mask, axis=0).astype(jnp.float32)
    combine = jnp.where(mask, probs, 0.0).astype(logits.dtype)
    return RouterOut(
        combine=combine, mask=mask,
        lam=jnp.zeros((logits.shape[-1],), jnp.float32), load=load,
    )
