"""Synthetic GKP instance generators matching the paper's experiment setup.

Section 6: profits p ~ U[0, 1]; costs b ~ U[0, 1] ("sparse"/default) or a
50/50 mixture of U[0, 1] and U[0, 10] (Figure 1's diverse items); budgets
scaled with N, M, L "to ensure tightness"; local caps C_l = 1 by default.

Generation is deterministic per (seed, shard): callers fold the shard index
into the key, so the data pipeline needs no host-side state and any worker
can regenerate any shard after a restart (fault-tolerance requirement).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import DenseKP, SparseKP, cardinality_set, disjoint_partition_sets

__all__ = ["sparse_instance", "dense_instance", "shard_key"]


def shard_key(seed: int, shard: int = 0) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), shard)


def sparse_instance(key, n, k, q=1, tightness=0.5, b_high=1.0):
    """Section 5.1 sparse instance: one item per knapsack, cap Q per user.

    Budgets: with no global constraint each user takes its top-Q items, so
    the unconstrained expected use of knapsack k is ~ n * Q/(2K) * E[b].
    ``tightness`` scales that down so constraints bind (paper §6: budgets
    scaled to ensure tightness).
    """
    kp_, kb = jax.random.split(key)
    p = jax.random.uniform(kp_, (n, k), jnp.float32)
    b = jax.random.uniform(kb, (n, k), jnp.float32, 0.0, b_high)
    budgets = jnp.full((k,), tightness * n * q * (b_high / 2.0) / k, jnp.float32)
    return SparseKP(p=p, b=b, budgets=budgets), q


def dense_instance(key, n, m, k, local="C1", tightness=0.25, mixed_b=False):
    """General instance (Figure 1 setup).

    local: "C1" (cap 1 over all items), "C2" (cap 2), or "C223"
    (hierarchical: two disjoint halves capped at 2, root capped at 3).
    mixed_b: b ~ U[0,1] or U[0,10] with equal probability (Fig 1).
    """
    kp_, kb, km = jax.random.split(key, 3)
    p = jax.random.uniform(kp_, (n, m), jnp.float32)
    b = jax.random.uniform(kb, (n, m, k), jnp.float32)
    if mixed_b:
        wide = jax.random.bernoulli(km, 0.5, (n, m, k))
        b = jnp.where(wide, b * 10.0, b)
    if local == "C1":
        sets = cardinality_set(m, 1)
        cap_total = 1
    elif local == "C2":
        sets = cardinality_set(m, 2)
        cap_total = 2
    elif local == "C223":
        h = m // 2
        base = disjoint_partition_sets([h, m - h], [2, 2], m)
        root = cardinality_set(m, 3)
        sets = type(base)(
            sets=jnp.concatenate([base.sets, root.sets]),
            caps=jnp.concatenate([base.caps, root.caps]),
        )
        cap_total = 3
    else:
        raise ValueError(local)
    eb = jnp.mean(b)
    budgets = jnp.full(
        (k,), tightness * n * cap_total * float(eb) / 1.0, jnp.float32
    )
    return DenseKP(p=p, b=b, budgets=budgets, sets=sets.sets, caps=sets.caps)
