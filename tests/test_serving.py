"""Serving loop with KP admission control (launch/serve.py)."""
import jax
import numpy as np

from repro.configs import registry
from repro.launch.serve import Request, admission_solve, serve_loop

jax.config.update("jax_platform_name", "cpu")


def test_admission_respects_budget_and_slots():
    reqs = [Request(rid=i, prompt_len=10 * (i + 1), max_new=10)
            for i in range(6)]
    adm = admission_solve(reqs, kv_budget=90.0, free_slots=3)
    assert len(adm.picked) <= 3
    kv = {r.rid: r.prompt_len + r.max_new for r in reqs}
    assert sum(kv[i] for i in adm.picked) <= 90.0 + 1e-6
    assert adm.picked, "budget admits at least one request"
    assert adm.lam is not None and adm.lam.shape == (1,)
    assert adm.iters > 0


def test_admission_prefers_short_requests():
    short = Request(rid=0, prompt_len=8, max_new=4)
    long_ = Request(rid=1, prompt_len=8, max_new=100)
    adm = admission_solve([short, long_], kv_budget=20.0, free_slots=2)
    assert adm.picked == [0]


def test_admission_empty_queue_no_solve():
    adm = admission_solve([], kv_budget=100.0, free_slots=2)
    assert adm == ([], None, 0)
    adm = admission_solve([Request(rid=0, prompt_len=8, max_new=4)],
                          kv_budget=100.0, free_slots=0)
    assert adm.picked == [] and adm.lam is None


def test_warm_admission_same_sets_as_cold():
    """Satellite contract: warm-starting each tick's exact KP from the
    previous tick's multipliers changes no admission decision — the
    whole request schedule (admitted sets tick for tick, completion
    order) is identical to solving cold every tick."""
    cfg = registry.get("gemma-2b").smoke()
    done_w, sets_w, stats_w = serve_loop(
        cfg, n_requests=6, cache_len=128, kv_budget=400.0, max_batch=3,
        max_ticks=220, warm=True)
    done_c, sets_c, stats_c = serve_loop(
        cfg, n_requests=6, cache_len=128, kv_budget=400.0, max_batch=3,
        max_ticks=220, warm=False)
    assert sets_w == sets_c
    assert [r.rid for r in done_w] == [r.rid for r in done_c]
    # Both ran real multi-solve schedules, and warm threading was live.
    assert len(stats_w["admission_iters"]) >= 2
    assert stats_w["warm"] and not stats_c["warm"]


def test_warm_admission_threads_multiplier():
    """The warm path actually reuses lam: re-solving the identical queue
    from the converged multipliers terminates in fewer sweeps."""
    reqs = [Request(rid=i, prompt_len=10 + 3 * i, max_new=8 + i)
            for i in range(8)]
    cold = admission_solve(reqs, kv_budget=120.0, free_slots=4)
    warm = admission_solve(reqs, kv_budget=120.0, free_slots=4,
                           lam0=cold.lam)
    assert warm.picked == cold.picked
    assert warm.iters <= cold.iters
    np.testing.assert_allclose(warm.lam, cold.lam, rtol=1e-5)


def test_serve_loop_completes_all_requests():
    cfg = registry.get("gemma-2b").smoke()
    done, admitted_sets, _ = serve_loop(
        cfg, n_requests=6, cache_len=128, kv_budget=400.0, max_batch=3,
        max_ticks=220)
    assert len(done) == 6, [r.rid for r in done]
    assert all(r.done >= r.max_new for r in done)
    assert len(admitted_sets) >= 2  # scheduler actually ran multiple solves
