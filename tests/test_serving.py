"""Serving loop with KP admission control (launch/serve.py)."""
import jax
import numpy as np

from repro.configs import registry
from repro.launch.serve import Request, admission_solve, serve_loop

jax.config.update("jax_platform_name", "cpu")


def test_admission_respects_budget_and_slots():
    reqs = [Request(rid=i, prompt_len=10 * (i + 1), max_new=10)
            for i in range(6)]
    picked = admission_solve(reqs, kv_budget=90.0, free_slots=3)
    assert len(picked) <= 3
    kv = {r.rid: r.prompt_len + r.max_new for r in reqs}
    assert sum(kv[i] for i in picked) <= 90.0 + 1e-6
    assert picked, "budget admits at least one request"


def test_admission_prefers_short_requests():
    short = Request(rid=0, prompt_len=8, max_new=4)
    long_ = Request(rid=1, prompt_len=8, max_new=100)
    picked = admission_solve([short, long_], kv_budget=20.0, free_slots=2)
    assert picked == [0]


def test_serve_loop_completes_all_requests():
    cfg = registry.get("gemma-2b").smoke()
    done, admitted_sets, _ = serve_loop(
        cfg, n_requests=6, cache_len=128, kv_budget=400.0, max_batch=3,
        max_ticks=220)
    assert len(done) == 6, [r.rid for r in done]
    assert all(r.done >= r.max_new for r in done)
    assert len(admitted_sets) >= 2  # scheduler actually ran multiple solves
