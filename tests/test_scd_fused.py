"""Fused SCD map+reduce kernel (scd_fused_hist) vs the unfused paths.

The fused kernel must be bit-compatible (up to float accumulation order)
with the composition it replaces — ``bucket_histogram(candidates_sparse)``
on the jnp side and ``bucket_hist(scd_candidates(...))`` on the kernel
side — including tie cases exactly on bucket edges, all-invalid tiles and
the ragged-n padding path. The solve driver's while_loop fast path must
reproduce the scan path's trajectory exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverConfig, solve
from repro.core.bucketing import bucket_histogram, make_edges
from repro.core.instances import shard_key, sparse_instance
from repro.core.sparse_scd import candidates_sparse
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(128, 8), (512, 16), (384, 10), (383, 8), (1021, 8), (7, 4)]


def _inst(n, k, dtype=jnp.float32, seed=0):
    kp, kb, kl = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.uniform(kp, (n, k), jnp.float32)
    b = jax.random.uniform(kb, (n, k), jnp.float32, 0.05, 1.0)
    lam = jax.random.uniform(kl, (k,), jnp.float32, 0.0, 1.5)
    return p.astype(dtype), b.astype(dtype), lam.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("q", [1, 2, 4])
def test_fused_matches_unfused_jnp_composition(shape, q):
    """Parity vs bucket_histogram(candidates_sparse(...)), incl. ragged n."""
    n, k = shape
    p, b, lam = _inst(n, k, seed=n + q)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    h_f, top_f = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=128,
                                    interpret=True)
    v1, v2 = candidates_sparse(p, b, lam, q)
    h_u = bucket_histogram(v1, v2, edges)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(top_f),
                               np.asarray(jnp.max(v1, axis=0)), rtol=1e-6)
    # mass conservation: every unit of v2 lands in exactly one bucket
    np.testing.assert_allclose(float(h_f.sum()), float(v2.sum()), rtol=1e-5)


@pytest.mark.parametrize("shape", [(256, 8), (383, 16)])
def test_fused_matches_unfused_kernel_composition(shape):
    """Parity vs the two-kernel path it replaces in the solver."""
    n, k = shape
    q = 2
    p, b, lam = _inst(n, k, seed=5)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    h_f, top_f = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=128,
                                    interpret=True)
    v1, v2 = ops.scd_candidates(p, b, lam, q, tile_n=128, interpret=True)
    h_u = ops.bucket_hist(v1, v2, edges, tile_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(top_f),
                               np.asarray(jnp.max(v1, axis=0)), rtol=1e-6)


def test_fused_ties_exactly_on_bucket_edges():
    """Candidates landing exactly on an edge bin identically in all paths.

    q >= K makes pbar = 0 so v1 = p/b = p (b = 1): rows are placed
    exactly on the edge ladder. searchsorted-left convention: a candidate
    at edges[j] belongs to bucket j, not j+1.
    """
    k = 4
    edges = jnp.tile(jnp.array([[0.5, 1.0, 1.5]]), (k, 1))
    vals = jnp.array([0.5, 1.0, 1.5, 0.25, 1.75, 1.0])
    p = jnp.tile(vals[:, None], (1, k))
    b = jnp.ones_like(p)
    lam = jnp.zeros((k,))
    q = k  # local constraint never binds -> v1 = p
    h_f, top_f = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=4,
                                    interpret=True)
    v1, v2 = candidates_sparse(p, b, lam, q)
    h_u = bucket_histogram(v1, v2, edges)
    h_r, top_r = ref.scd_fused_hist_ref(p, b, lam, edges, q)
    np.testing.assert_array_equal(np.asarray(h_f), np.asarray(h_u))
    np.testing.assert_array_equal(np.asarray(h_f), np.asarray(h_r))
    # explicit tie placement: bucket j = (edges[j-1], edges[j]]
    np.testing.assert_array_equal(np.asarray(h_f[0]),
                                  np.array([2.0, 2.0, 1.0, 1.0]))
    np.testing.assert_allclose(np.asarray(top_f), np.full(k, 1.75), rtol=0)


def test_fused_all_invalid_tiles():
    """p = 0 emits no candidates anywhere: zero mass, top = -1 sentinel."""
    n, k, q = 256, 8, 2
    p = jnp.zeros((n, k))
    b = jnp.ones((n, k))
    lam = jnp.full((k,), 0.7)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    h_f, top_f = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=64,
                                    interpret=True)
    assert float(jnp.abs(h_f).sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(top_f), np.full(k, -1.0))


def test_fused_ragged_padding_is_invisible():
    """A ragged tail must change nothing: fused(n) == fused on exact tiles
    of the same rows, and padded rows contribute no mass."""
    n, k, q = 301, 8, 2  # 301 = 7 * 43: no ladder tile divides it
    p, b, lam = _inst(n, k, seed=9)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    h_rag, top_rag = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=128,
                                        interpret=True)
    h_one, top_one = ops.scd_fused_hist(p, b, lam, edges, q, tile_n=301,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(h_rag), np.asarray(h_one),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(top_rag), np.asarray(top_one),
                               rtol=1e-6)
    v1, v2 = candidates_sparse(p, b, lam, q)
    np.testing.assert_allclose(float(h_rag.sum()), float(v2.sum()), rtol=1e-5)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_while_loop_driver_matches_scan(use_kernels):
    """record_history toggles scan <-> while_loop; lam and iters must be
    identical (the early exit only skips frozen iterations)."""
    kp, q = sparse_instance(shard_key(17), n=512, k=8, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=30,
                       use_kernels=use_kernels)
    scan = solve(kp, cfg.replace(record_history=True), q=q)
    wl = solve(kp, cfg.replace(record_history=False), q=q)
    assert int(scan.iters) < cfg.max_iters, "instance must converge early"
    assert int(scan.iters) == int(wl.iters)
    np.testing.assert_array_equal(np.asarray(scan.lam), np.asarray(wl.lam))
    np.testing.assert_allclose(float(scan.primal), float(wl.primal), rtol=0)


def test_solver_fused_path_matches_jnp_path_ragged():
    """End-to-end kernel path on a prime-ish n (exercises pad+mask)."""
    kp, q = sparse_instance(shard_key(7), n=509, k=8, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=8)
    a = solve(kp, cfg, q=q)
    b = solve(kp, cfg.replace(use_kernels=True), q=q)
    np.testing.assert_allclose(np.asarray(a.lam), np.asarray(b.lam),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.primal), float(b.primal), rtol=1e-5)


try:  # jax.core.Jaxpr moved to jax.extend.core in newer jax
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except (ImportError, AttributeError):
    _Jaxpr, _ClosedJaxpr = jax.core.Jaxpr, jax.core.ClosedJaxpr


def _sub_jaxprs(params):
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if isinstance(x, _Jaxpr):
                yield x
            elif isinstance(x, _ClosedJaxpr):
                yield x.jaxpr


def _walk_eqns(jaxpr):
    """All eqns, recursing into subjaxprs EXCEPT pallas_call kernel bodies
    (whose intermediates live in VMEM, which is exactly the point)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from _walk_eqns(sub)


def test_fused_reduce_is_single_pallas_call_no_candidate_intermediates():
    """The jaxpr of the fused solver reduce contains exactly one
    pallas_call and no (n, K) intermediate — v1/v2 never reach HBM."""
    from repro.core import solver as S
    from repro.core.types import SparseKP

    n, k, q = 512, 8, 2
    p, b, lam = _inst(n, k, seed=3)
    kp = SparseKP(p=p, b=b, budgets=jnp.full((k,), 10.0))
    cfg = SolverConfig(reduce="bucketed", use_kernels=True)

    def fused_reduce(kp, lam):
        return S._scd_step_fused(kp, lam, q, 1.0, 1.0, cfg, None)

    jaxpr = jax.make_jaxpr(fused_reduce)(kp, lam).jaxpr
    eqns = list(_walk_eqns(jaxpr))
    n_pallas = sum(e.primitive.name == "pallas_call" for e in eqns)
    assert n_pallas == 1, f"expected 1 pallas_call, got {n_pallas}"
    big = [
        v.aval.shape
        for e in eqns
        if e.primitive.name != "pallas_call"
        for v in e.outvars
        if getattr(v.aval, "shape", ()) and v.aval.shape[:1] == (n,)
    ]
    assert not big, f"(n, K) intermediates escaped the kernel: {big}"
