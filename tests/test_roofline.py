"""Roofline machinery: HLO collective parser, analytic model, report."""
import json
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_collective_parser_counts_ops():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(%x), replica_groups={...}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = f32[2,8]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[16,128,64]{2,1,0} all-to-all(%w)
  %cp = u8[32]{0} collective-permute(%v)
  %mm = f32[128,128]{1,0} dot(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["counts"]["all-gather"] == 1
    assert out["bytes"]["all-gather"] == 4 * 1024 * 2
    assert out["bytes"]["all-reduce"] == 256 * 4
    assert out["bytes"]["reduce-scatter"] == 16 * 4
    assert out["bytes"]["all-to-all"] == 16 * 128 * 64 * 2
    assert out["bytes"]["collective-permute"] == 32
    assert out["total_bytes"] == sum(out["bytes"].values())


def test_analytic_terms_sane():
    from benchmarks.analytic import cell_terms
    from repro.configs import registry
    from repro.models import model as M

    cfg = registry.get("yi-34b")
    cell = M.SHAPES["train_4k"]
    n_params = 34_000_000_000
    t = cell_terms(cfg, cell, n_params, 256)
    # 6*N*T/chips as the floor; remat+attention push above it
    floor = 6.0 * n_params * cell.global_batch * cell.seq_len / 256
    assert t.flops_per_chip >= floor
    assert t.flops_per_chip < 3 * floor
    # decode flops are ~ 2*N_active*B/chips
    d = cell_terms(cfg, M.SHAPES["decode_32k"], n_params, 256)
    assert d.flops_per_chip < t.flops_per_chip / 1e3
    # train memory traffic dominated by 3x full weight reads per chip
    assert t.bytes_per_chip > 3 * n_params * 2


def test_active_params_moe():
    from benchmarks.roofline import active_params
    from repro.configs import registry

    cfg = registry.get("deepseek-v2-236b")
    total = 239_713_551_360
    act = active_params(cfg, total)
    # DeepSeek-V2 reports ~21B active of 236B total
    assert 15e9 < act < 35e9, act


@pytest.mark.skipif(
    not (REPO / "reports/dryrun_full.json").exists(),
    reason="dry-run report not generated")
def test_full_report_complete_and_clean():
    recs = json.load(open(REPO / "reports/dryrun_full.json"))
    assert len(recs) == 80  # 10 archs x 4 shapes x 2 meshes
    assert all(r["status"] in ("ok", "skipped") for r in recs)
    oks = [r for r in recs if r["status"] == "ok"]
    assert len(oks) == 64
    for r in oks:
        assert r["cost"]["flops"] > 0, r["arch"]
        assert r["memory"]["fits_16gb_hbm"], (r["arch"], r["shape"], r["mesh"],
                                              r["memory"])
        assert r["collectives"]["total_bytes"] > 0
