"""Fault tolerance: atomic checkpoints, restart-resume equivalence,
failure injection, elastic re-sharding, deterministic data."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.data.synth import lm_batch
from repro.launch.train import train
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent


def _tree_allclose(a, b, rtol=0, atol=0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol, atol=atol)


def test_checkpoint_roundtrip(tmp_path):
    cfg = registry.get("starcoder2-3b").smoke()
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, OptConfig())
    tree = {"params": params, "opt": opt}
    path = ckpt.save(tmp_path, 7, tree)
    assert pathlib.Path(path).name == "step_00000007"
    assert ckpt.latest_step(tmp_path) == 7
    like = jax.eval_shape(lambda: tree)
    back = ckpt.restore(tmp_path, 7, like)
    _tree_allclose(tree, back)


def test_checkpoint_atomicity_ignores_torn_writes(tmp_path):
    cfg = registry.get("gemma-2b").smoke()
    params = M.init(cfg, jax.random.PRNGKey(1))
    ckpt.save(tmp_path, 1, {"p": params})
    # simulate a crash mid-save of step 2: only a .tmp dir exists
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "arr_00000.npy").write_bytes(b"torn")
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.prune(tmp_path, keep=3)
    assert not (tmp_path / "step_00000002.tmp").exists()


def test_restart_resume_bit_identical(tmp_path):
    """Train 6 steps straight vs 3 steps + restart + 3 steps: identical
    final parameters (deterministic data + donated-step purity)."""
    cfg = registry.get("starcoder2-3b").smoke()
    opt_cfg = OptConfig(lr=1e-3, warmup=2)
    p_full, o_full, losses_full = train(
        cfg, opt_cfg, steps=6, ckpt_dir=None, seed=3, batch_shape=(2, 64),
        log_every=0)
    d1 = tmp_path / "ck"
    train(cfg, opt_cfg, steps=3, ckpt_dir=str(d1), ckpt_every=3, seed=3,
          batch_shape=(2, 64), log_every=0)
    p_res, o_res, losses_res = train(
        cfg, opt_cfg, steps=6, ckpt_dir=str(d1), ckpt_every=3, seed=3,
        batch_shape=(2, 64), log_every=0)
    _tree_allclose(p_full, p_res, rtol=1e-6, atol=1e-7)


@pytest.mark.slow
def test_failure_injection_subprocess(tmp_path):
    """Kill the trainer mid-run (os._exit), relaunch, and verify it resumes
    from the checkpoint and finishes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    args = [
        sys.executable, "-m", "repro.launch.train", "--arch", "gemma-2b",
        "--smoke", "--steps", "8", "--batch", "2", "--seq", "64",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ]
    first = subprocess.run(args + ["--simulate-failure-at", "5"],
                           env=env, capture_output=True, text=True, timeout=600)
    assert first.returncode == 42, first.stdout + first.stderr
    assert ckpt.latest_step(tmp_path) == 4
    second = subprocess.run(args, env=env, capture_output=True, text=True,
                            timeout=600)
    assert second.returncode == 0, second.stdout + second.stderr
    assert "resumed from step 4" in second.stdout
    assert "done" in second.stdout
    assert ckpt.latest_step(tmp_path) == 8


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    """Save sharded on a 4-device mesh, restore onto a 2-device mesh
    (degraded after 'node loss') and onto 8 devices (scale-up)."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import ckpt

tree = {{"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}}
m4 = jax.make_mesh((4,), ("d",), devices=jax.devices()[:4])
t4 = jax.device_put(tree, NamedSharding(m4, P("d", None)))
ckpt.save(r"{tmp_path}", 1, t4)

for nd in (2, 8):
    m = jax.make_mesh((nd,), ("d",), devices=jax.devices()[:nd])
    sh = {{"w": NamedSharding(m, P("d", None))}}
    like = jax.eval_shape(lambda: tree)
    back = ckpt.restore(r"{tmp_path}", 1, like, sharding_tree=sh)
    assert back["w"].sharding.num_devices == nd
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
print("ELASTIC-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ELASTIC-OK" in out.stdout


def test_data_determinism():
    cfg = registry.get("qwen3-4b").smoke()
    b1 = lm_batch(cfg, (4, 64), step=17, seed=5)
    b2 = lm_batch(cfg, (4, 64), step=17, seed=5)
    b3 = lm_batch(cfg, (4, 64), step=18, seed=5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_grad_compression_error_feedback():
    """int8 EF compression: biased per step, unbiased over steps (the error
    accumulator re-injects what quantisation dropped)."""
    from repro.optim.adamw import compress_decompress

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(50):
        sent, err = compress_decompress(g, err)
        total_sent = total_sent + sent
    # cumulative transmitted ~= cumulative true gradient
    np.testing.assert_allclose(np.asarray(total_sent), np.asarray(g) * 50,
                               rtol=0.05, atol=1e-5)


def test_train_loss_decreases():
    """End-to-end learnability: loss on the synthetic stream drops."""
    cfg = registry.get("starcoder2-3b").smoke()
    _, _, losses = train(cfg, OptConfig(lr=3e-3, warmup=5), steps=30,
                         batch_shape=(4, 64), log_every=0, seed=11)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
