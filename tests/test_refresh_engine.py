"""Generation-based serving: refresh engine + decision service contracts.

The contracts under test (repro/serve/, DESIGN.md §9):

* a warm-started refresh of a budget-perturbed generation converges in
  strictly fewer iterations than the cold solve of the same workload,
  and publishes exactly the solve ``solve_streaming_host`` would
  produce (the engine adds durability, not arithmetic);
* publication is atomic — a crash at ANY point (mid-solve, between the
  record save and the pointer flip) leaves the previous generation
  live, and the re-entrant refresh/recover path publishes a record
  bitwise-identical to the uninterrupted one (the subprocess test at
  the bottom really SIGKILLs an 8-virtual-device refresh);
* DecisionService lookups — single and batched, through the LRU chunk
  cache — are bitwise-equal to full ``decisions_chunk``
  materialisation for every queried user.
"""
import os
import pathlib
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import SolverConfig, SparseKP
from repro.core.chunked import array_source, decisions_chunk
from repro.core.prefetch import solve_streaming_host
from repro.serve import (
    DecisionService,
    RefreshEngine,
    WorkloadSpec,
    synthetic_source,
)

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent

SPEC = WorkloadSpec(seed=3, n=4096, k=8, chunk=256, q=2, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=60, checkpoint_every=4)

RESULT_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]


def _assert_gen_equal(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    assert (a.fin_hist is None) == (b.fin_hist is None)
    if a.fin_hist is not None:
        for x, y in zip(a.fin_hist, b.fin_hist):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class _Kill(Exception):
    """In-process stand-in for preemption, raised from the source fn."""


def _killing_factory(after):
    calls = {"n": 0}

    def make(spec):
        src = synthetic_source(spec)
        inner = src.fn

        def fn(i):
            calls["n"] += 1
            if calls["n"] > after:
                raise _Kill()
            return inner(i)

        return src._replace(fn=fn)

    return make, calls


def _materialise(spec, lam, tau):
    """Full decisions via decisions_chunk over the same rows (the oracle)."""
    src = synthetic_source(spec)
    c = -(-src.n // src.chunk)
    p = np.concatenate([src.fn(i)[0] for i in range(c)])[:src.n]
    b = np.concatenate([src.fn(i)[1] for i in range(c)])[:src.n]
    kp = SparseKP(p=jnp.asarray(p), b=jnp.asarray(b),
                  budgets=jnp.asarray(src.budgets))
    asrc = array_source(kp, src.chunk)
    rows = []
    for i in range(c):
        x, valid = decisions_chunk(asrc, lam, spec.q, i, tau=tau)
        rows.append(np.asarray(x)[np.asarray(valid)])
    return np.concatenate(rows), asrc


# ---------------------------------------------------------------------------
# Refresh: warm beats cold, and the engine publishes the solver's bits.
# ---------------------------------------------------------------------------

def test_warm_refresh_strictly_fewer_iters_than_cold(tmp_path):
    """Acceptance bar: on a budget-perturbed generation the warm-started
    refresh converges in strictly fewer iterations than cold."""
    eng = RefreshEngine(tmp_path / "warm", SPEC, cfg=CFG)
    g0 = eng.refresh()
    assert not g0.warm and g0.gen == 0
    g1 = eng.refresh(budget_scale=0.9)
    assert g1.warm and g1.gen == 1

    cold = RefreshEngine(tmp_path / "cold", SPEC.replace(budget_scale=0.9),
                         cfg=CFG).refresh()
    assert not cold.warm
    assert g1.iters < cold.iters, (g1.iters, cold.iters)
    # Same workload, same solution quality: both trajectories stop at
    # tol, so the fixed points (and primals) agree to tol-level noise —
    # the warm start buys iterations, not a different answer.
    assert abs(float(g1.primal) - float(cold.primal)) \
        <= 2e-2 * abs(float(cold.primal))


def test_refresh_is_exactly_the_streaming_solve(tmp_path):
    """The engine adds durability, not arithmetic: a published generation
    is field-for-field the direct solve_streaming_host result under the
    same lam0, and the fingerprint is the solver's own."""
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    g0 = eng.refresh()
    g1 = eng.refresh(budget_scale=0.9)

    spec1 = SPEC.replace(budget_scale=0.9)
    direct = solve_streaming_host(
        synthetic_source(spec1), CFG.replace(checkpoint_every=0), q=SPEC.q,
        lam0=jnp.asarray(g0.lam))
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(g1, f)),
                                      np.asarray(getattr(direct, f)),
                                      err_msg=f)
    for x, y in zip(g1.fin_hist, direct.fin_hist):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert g1.fingerprint.shape == (8,) and g1.fingerprint.dtype == np.uint8
    assert not np.array_equal(g0.fingerprint, g1.fingerprint)


def test_refresh_deltas_churn_and_growth(tmp_path):
    """Traffic churn (seed) and growth (n, more chunks) are refresh
    deltas like budget scaling; the spec is immutable per generation."""
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    g1 = eng.refresh(seed=11)                  # churn: new population
    assert g1.spec.seed == 11 and g1.warm
    g2 = eng.refresh(n=SPEC.n * 2)             # growth: 16 -> 32 chunks
    assert g2.spec.n == SPEC.n * 2 and g2.spec.seed == 11
    assert eng.live().gen == 2
    # Records of past generations stay immutable and loadable.
    assert eng.generation(1).spec == g1.spec
    svc = eng.decision_service()
    assert svc.decide(SPEC.n * 2 - 1).shape == (SPEC.k,)


# ---------------------------------------------------------------------------
# Atomic publication and preemption.
# ---------------------------------------------------------------------------

def test_kill_mid_refresh_resume_bitwise(tmp_path):
    """A refresh killed mid-solve leaves the old generation live; the
    re-entrant refresh (same deltas) resumes from the generation's
    checkpoints and publishes bitwise the uninterrupted record."""
    ref_root = tmp_path / "ref"
    era = RefreshEngine(ref_root, SPEC, cfg=CFG)
    era.refresh()
    ref = era.refresh(budget_scale=0.9)

    root = tmp_path / "killed"
    eng = RefreshEngine(root, SPEC, cfg=CFG)
    eng.refresh()
    make, _ = _killing_factory(40)             # mid epoch ~3 of 16-chunk passes
    with pytest.raises(_Kill):
        RefreshEngine(root, SPEC, make_source=make, cfg=CFG).refresh(
            budget_scale=0.9)
    assert eng.live().gen == 0                 # publication never half-done
    assert eng._pending() is not None

    got = RefreshEngine(root, SPEC, cfg=CFG).refresh(budget_scale=0.9)
    _assert_gen_equal(got, ref)
    assert eng.live().gen == 1


def test_crash_between_record_and_flip_recovered(tmp_path):
    """The record lands, the process dies before the pointer flip: the
    old generation stays live; recover() re-flips without re-solving."""
    import repro.serve.engine as engine_mod

    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    real = ckpt.write_json
    state = {"fail": True}

    def failing(d, name, payload):
        if name == "LIVE.json" and state["fail"]:
            state["fail"] = False
            raise OSError("simulated crash before pointer flip")
        return real(d, name, payload)

    engine_mod.ckpt.write_json = failing
    try:
        with pytest.raises(OSError, match="pointer flip"):
            eng.refresh(budget_scale=0.9)
    finally:
        engine_mod.ckpt.write_json = real
    assert eng.live().gen == 0

    make, calls = _killing_factory(10 ** 9)
    rec = RefreshEngine(tmp_path, SPEC, make_source=make, cfg=CFG).recover()
    assert rec.gen == 1
    assert calls["n"] == 0, "recover() must not re-solve a landed record"
    assert eng.live().gen == 1


def test_pending_spec_mismatch_refused(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    make, _ = _killing_factory(40)
    with pytest.raises(_Kill):
        RefreshEngine(tmp_path, SPEC, make_source=make, cfg=CFG).refresh(
            budget_scale=0.9)
    with pytest.raises(ValueError, match="pending"):
        eng.refresh(budget_scale=1.1)
    assert eng.recover().gen == 1              # the pending one, finished


def test_recover_without_pending_is_none(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    assert eng.recover() is None
    eng.refresh()
    assert eng.recover() is None
    assert eng.live().gen == 0


def test_invalid_refresh_leaves_nothing_pending(tmp_path):
    """An invalid refresh call (warm across a K change, a make_source
    that rejects the spec) fails BEFORE its intent becomes durable — it
    must not wedge the engine behind an uncompletable pending
    generation."""
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    with pytest.raises(ValueError, match="knapsack-count"):
        eng.refresh(k=SPEC.k * 2)              # warm across K change
    assert eng._pending() is None

    def rejecting(spec):
        raise ValueError("make_source rejects this spec")

    bad = RefreshEngine(tmp_path, SPEC, make_source=rejecting, cfg=CFG)
    with pytest.raises(ValueError, match="rejects"):
        bad.refresh(budget_scale=0.9)
    assert eng._pending() is None
    # The engine is not wedged: the next valid refresh publishes.
    assert eng.refresh(budget_scale=0.9).gen == 1
    # Cold across a K change is a legitimate refresh.
    g2 = eng.refresh(k=SPEC.k * 2, warm=False)
    assert g2.gen == 2 and not g2.warm and g2.lam.shape == (SPEC.k * 2,)


def test_run_scenario_without_warm_refreshes_is_ok(tmp_path):
    """Satellite CLI accounting: a single-generation scenario and a
    --resume relaunch that finds everything published must not report a
    spurious warm-vs-cold failure (there was nothing warm to account)."""
    from repro.launch.refresh import run_scenario

    cfg = CFG.replace(checkpoint_every=0)
    out = run_scenario(SPEC, 1, tmp_path / "one", cfg, lookups=32,
                       verify=True)
    assert out["warm_refreshes"] == 0 and out["lookups_bitwise"]

    root = tmp_path / "resumed"
    first = run_scenario(SPEC, 2, root, cfg, lookups=32, verify=False)
    assert first["warm_refreshes"] == 1
    again = run_scenario(SPEC, 2, root, cfg, lookups=32, verify=True,
                         resume=True)
    assert again["warm_refreshes"] == 0 and again["lookups_bitwise"]
    assert again["per_generation"] == []


# ---------------------------------------------------------------------------
# DecisionService: O(chunk) lookups, bitwise the materialised solution.
# ---------------------------------------------------------------------------

def test_decision_service_bitwise_vs_materialisation(tmp_path):
    """Acceptance bar: every queried user's decision — single or batched,
    cache hit or fill — equals the corresponding row of the full
    decisions_chunk materialisation."""
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    gen = eng.refresh(budget_scale=0.9)
    full, asrc = _materialise(gen.spec, gen.lam, gen.tau)
    assert full.any(), "degenerate: nobody selected"

    svc = eng.decision_service(cache_chunks=4)  # forces evictions
    rng = np.random.default_rng(0)
    users = rng.integers(0, SPEC.n, 600)
    np.testing.assert_array_equal(svc.decide_batch(users), full[users])
    singles = np.stack([svc.decide(int(u)) for u in users[:100]])
    np.testing.assert_array_equal(singles, full[users[:100]])
    assert svc.stats["fills"] >= 4 and svc.stats["evictions"] > 0
    assert svc.stats["hits"] > 0

    # The traced-source family answers identically (same decisions_rows).
    svc2 = DecisionService(asrc, gen, cache_chunks=4)
    np.testing.assert_array_equal(svc2.decide_batch(users[:200]),
                                  full[users[:200]])


def test_decision_service_validation(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    gen = eng.refresh()
    svc = eng.decision_service()
    with pytest.raises(IndexError, match="outside"):
        svc.decide(SPEC.n)
    with pytest.raises(IndexError, match="outside"):
        svc.decide_batch([0, -1])
    with pytest.raises(ValueError, match="cache_chunks"):
        eng.decision_service(cache_chunks=0)
    wrong = synthetic_source(SPEC.replace(n=SPEC.n * 2))
    with pytest.raises(ValueError, match="does not match"):
        DecisionService(wrong, gen)
    with pytest.raises(ValueError, match="no live generation"):
        RefreshEngine(tmp_path / "empty", SPEC, cfg=CFG).decision_service()


# ---------------------------------------------------------------------------
# The acceptance bar, for real: SIGKILL an 8-virtual-device refresh in a
# subprocess, resume, and compare the published generation bitwise.
# ---------------------------------------------------------------------------

_SIGKILL_SCRIPT = textwrap.dedent("""
    import os, signal, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import SolverConfig
    from repro.serve import RefreshEngine, WorkloadSpec, synthetic_source

    mode, kill_after, root, out = (sys.argv[1], int(sys.argv[2]),
                                   sys.argv[3], sys.argv[4])
    spec = WorkloadSpec(seed=3, n=2048, k=8, chunk=64, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=40, checkpoint_every=1)
    mesh = jax.make_mesh((8,), ("users",))

    make = synthetic_source
    if mode == "kill":
        calls = {"n": 0}
        def make(s):
            src = synthetic_source(s)
            inner = src.fn
            def fn(i):
                calls["n"] += 1
                if calls["n"] > kill_after:
                    os.kill(os.getpid(), signal.SIGKILL)
                return inner(i)
            return src._replace(fn=fn)

    eng = RefreshEngine(root, spec, make_source=make, cfg=cfg,
                        mesh=mesh, slots=8)
    if eng.live_gen_id() is None:
        eng = RefreshEngine(root, spec, make_source=synthetic_source,
                            cfg=cfg, mesh=mesh, slots=8)
        eng.refresh()                         # gen 0, uninterrupted
        eng = RefreshEngine(root, spec, make_source=make, cfg=cfg,
                            mesh=mesh, slots=8)
    gen = eng.refresh(budget_scale=0.9)       # gen 1 (killed in "kill")
    np.savez(out, lam=gen.lam, tau=gen.tau, iters=gen.iters, r=gen.r,
             primal=gen.primal, dual=gen.dual, ch=gen.fin_hist[0],
             gh=gen.fin_hist[1], warm=gen.warm)
    print("GEN-OK", gen.gen, int(gen.iters))
""")


def _run_script(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run([sys.executable, "-c", _SIGKILL_SCRIPT] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=str(REPO))


@pytest.mark.slow
def test_sigkill_refresh_resume_publishes_bitwise(tmp_path):
    """An 8-virtual-device sharded refresh SIGKILLed mid-solve and
    re-driven publishes a generation bitwise-identical to the
    uninterrupted run (lam/tau/iters/r/primal/dual + both fused-finalize
    histograms), and the pointer never exposes the half-done solve."""
    ref = tmp_path / "ref.npz"
    out = _run_script(["ref", "0", str(tmp_path / "ref_root"), str(ref)])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "GEN-OK 1" in out.stdout

    root = tmp_path / "killed_root"
    killed = _run_script(["kill", "120", str(root), "x"])
    assert killed.returncode == -signal.SIGKILL, (
        killed.returncode, killed.stdout, killed.stderr)
    # gen 0 is live, gen 1 pending with resume states on disk.
    assert json_ptr_gen(root) == 0
    assert ckpt.latest_step(root / "gen_000001" / "ckpt") is not None

    got_path = tmp_path / "resumed.npz"
    res = _run_script(["resume", "0", str(root), str(got_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    want, got = np.load(ref), np.load(got_path)
    for key in ["lam", "tau", "iters", "r", "primal", "dual", "ch", "gh",
                "warm"]:
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


def json_ptr_gen(root):
    ptr = ckpt.read_json(root, "LIVE.json")
    return None if ptr is None else int(ptr["gen"])
