"""Preemption-safe sharded host streaming: checkpoint/resume equivalence.

The contract under test (core/prefetch.py, DESIGN.md §7): a host-fed
solve with ``cfg.checkpoint_every`` writes a constant-size resume state
atomically; killing the process at ANY point — mid iterate epoch, mid
save (torn ``.tmp``), between finalize chunks — and relaunching with
``resume_from=`` yields bitwise the uninterrupted ``lam/iters/r/primal/
dual/tau`` and the same fused-finalize histograms, on the same mesh or
any mesh whose device count divides the checkpoint's virtual-slot
count. The subprocess test at the bottom actually SIGKILLs the first
process on 8 virtual devices and resumes on 8 and on 4.
"""
import os
import pathlib
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.core.chunked import ordered_fold
from repro.core.instances import shard_key, sparse_instance
from repro.core.prefetch import (
    host_array_source,
    sharded_source,
    solve_streaming_host,
)

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent

RESULT_FIELDS = ["lam", "iters", "r", "primal", "dual", "tau"]


def _instance(n=2048, k=8, chunk=128, seed=4):
    kp, q = sparse_instance(shard_key(seed), n=n, k=k, q=2, tightness=0.4)
    p, b = np.asarray(kp.p), np.asarray(kp.b)
    bud = np.asarray(kp.budgets)
    return (lambda: host_array_source(p, b, bud, chunk)), q


class _Kill(Exception):
    """In-process stand-in for preemption: raised from the source fn."""


def _killing(make_source, after):
    """Source whose fn raises _Kill after ``after`` chunk productions."""
    src = make_source()
    calls = {"n": 0}
    inner = src.fn

    def fn(i):
        calls["n"] += 1
        if calls["n"] > after:
            raise _Kill()
        return inner(i)

    return src._replace(fn=fn), calls


def _counting(make_source):
    src = make_source()
    calls = {"n": 0}
    inner = src.fn

    def fn(i):
        calls["n"] += 1
        return inner(i)

    return src._replace(fn=fn), calls


def _assert_bitwise(a, b, hists=True):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    if hists:
        assert (a.fin_hist is None) == (b.fin_hist is None)
        if a.fin_hist is not None:
            for x, y in zip(a.fin_hist, b.fin_hist):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# sharded_source: the per-slot chunk-range splitter.
# ---------------------------------------------------------------------------

def test_sharded_source_splits_chunk_ranges():
    make, _ = _instance(n=1000, chunk=128)      # c = 8 ragged chunks
    src = make()
    subs = sharded_source(src, 4)               # cps = 2
    assert len(subs) == 4
    for s, sub in enumerate(subs):
        assert sub.chunk == 128 and sub.k == src.k
        np.testing.assert_array_equal(sub.budgets, src.budgets)
        for j in range(2):
            p, b = sub.fn(j)
            pg, bg = src.fn(2 * s + j)
            np.testing.assert_array_equal(p, pg)
            np.testing.assert_array_equal(b, bg)
    # Row ownership covers n exactly, in order.
    assert sum(sub.n for sub in subs) == 1000
    # Past the last real chunk: inert zeros (the traced padded-index
    # contract — those chunks still run, so they must exist).
    over = sharded_source(src, 8)               # cps = 1, slot 7 empty... c=8
    p, b = over[7].fn(1)                        # global chunk 8 >= c
    assert not p.any() and not b.any() and p.shape == (128, src.k)
    with pytest.raises(ValueError, match="slots"):
        sharded_source(src, 0)


# ---------------------------------------------------------------------------
# Validation: config/topology errors are actionable.
# ---------------------------------------------------------------------------

def test_checkpoint_and_slot_validation(tmp_path):
    make, q = _instance()
    with pytest.raises(ValueError, match="record_history"):
        solve_streaming_host(
            make(), SolverConfig(checkpoint_every=2, record_history=True,
                                 metrics_every=2),
            q=q, checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="multiple"):
        solve_streaming_host(
            make(), SolverConfig(),
            q=q, mesh=jax.make_mesh((1,), ("d",)), slots=0)
    with pytest.raises(ValueError, match="fused"):
        solve_streaming_host(make(), SolverConfig(stream_finalize="legacy"),
                             q=q, slots=4)


def test_resume_empty_dir_is_fresh_start(tmp_path):
    make, q = _instance()
    cfg = SolverConfig(reduce="bucketed", max_iters=15, checkpoint_every=2)
    base = solve_streaming_host(make(), cfg.replace(checkpoint_every=0),
                                q=q, slots=4)
    res = solve_streaming_host(make(), cfg, q=q, slots=4,
                               resume_from=str(tmp_path))
    _assert_bitwise(res, base)
    assert ckpt.latest_step(tmp_path) is not None   # and it checkpoints there


def test_resume_fingerprint_mismatch_refused(tmp_path):
    make, q = _instance(seed=4)
    other, _ = _instance(seed=5)
    cfg = SolverConfig(reduce="bucketed", max_iters=15, checkpoint_every=2)
    solve_streaming_host(make(), cfg, q=q, slots=4,
                         checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="fingerprint"):
        solve_streaming_host(other(), cfg, q=q, resume_from=str(tmp_path))
    with pytest.raises(ValueError, match="slots"):
        solve_streaming_host(make(), cfg, q=q, slots=8,
                             resume_from=str(tmp_path))


def test_checkpoint_keep_is_configurable(tmp_path):
    """Satellite: cfg.checkpoint_keep reaches ckpt.prune — the retention
    is a knob, not the hardcoded 3 — and a pruned-to-one directory still
    resumes (the newest state is always complete before pruning)."""
    make, q = _instance()
    base = solve_streaming_host(make(), SolverConfig(reduce="bucketed",
                                                     max_iters=20),
                                q=q, slots=4)

    def steps(d):
        return sorted(p.name for p in pathlib.Path(d).iterdir()
                      if p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    for keep in (1, 2):
        d = tmp_path / f"keep{keep}"
        cfg = SolverConfig(reduce="bucketed", max_iters=20,
                           checkpoint_every=1, checkpoint_keep=keep)
        res = solve_streaming_host(make(), cfg, q=q, slots=4,
                                   checkpoint_dir=str(d))
        _assert_bitwise(res, base)
        assert len(steps(d)) == keep, steps(d)
    # Default retention unchanged: 3 states on disk.
    d3 = tmp_path / "default"
    solve_streaming_host(
        make(), SolverConfig(reduce="bucketed", max_iters=20,
                             checkpoint_every=1),
        q=q, slots=4, checkpoint_dir=str(d3))
    assert len(steps(d3)) == 3, steps(d3)
    with pytest.raises(ValueError, match="checkpoint_keep"):
        solve_streaming_host(
            make(), SolverConfig(checkpoint_every=1, checkpoint_keep=0),
            q=q, checkpoint_dir=str(tmp_path / "zero"))
    # Killed mid-solve with keep=1: the single retained state resumes
    # bitwise (pruning never races the newest complete step away).
    dk = tmp_path / "keep1_kill"
    cfgk = SolverConfig(reduce="bucketed", max_iters=20,
                        checkpoint_every=2, checkpoint_keep=1)
    src, _ = _killing(make, 70)
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfgk, q=q, slots=4,
                             checkpoint_dir=str(dk))
    assert len(steps(dk)) == 1
    res = solve_streaming_host(make(), cfgk, q=q, resume_from=str(dk))
    _assert_bitwise(res, base)


# ---------------------------------------------------------------------------
# Corrupted checkpoint directories: loud, actionable, never a silent
# fresh start when a manifest exists.
# ---------------------------------------------------------------------------

def _checkpointed_dir(make, q, d):
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=2)
    solve_streaming_host(make(), cfg, q=q, slots=4, checkpoint_dir=str(d))
    return cfg


def test_truncated_manifest_raises_actionable(tmp_path):
    """A present-but-unparseable manifest is corruption, not 'no
    checkpoint': latest_step still reports the step and the restore
    raises an error naming the file — resuming must never silently
    discard the run."""
    make, q = _instance()
    cfg = _checkpointed_dir(make, q, tmp_path)
    latest = ckpt.latest_step(tmp_path)
    mpath = tmp_path / f"step_{latest:08d}" / "manifest.json"
    mpath.write_text(mpath.read_text()[: len(mpath.read_text()) // 2])
    assert ckpt.latest_step(tmp_path) == latest      # still visible
    with pytest.raises(ValueError, match="manifest.*corrupt|truncated"):
        ckpt.restore_auto(tmp_path, latest)
    with pytest.raises(ValueError, match="could not restore"):
        solve_streaming_host(make(), cfg, q=q, resume_from=str(tmp_path))


def test_missing_leaf_file_raises_actionable(tmp_path):
    make, q = _instance()
    cfg = _checkpointed_dir(make, q, tmp_path)
    latest = ckpt.latest_step(tmp_path)
    step_dir = tmp_path / f"step_{latest:08d}"
    victim = sorted(step_dir.glob("arr_*.npy"))[2]
    victim.unlink()
    with pytest.raises(ValueError, match=victim.name):
        ckpt.restore_auto(tmp_path, latest)
    with pytest.raises(ValueError, match="could not restore"):
        solve_streaming_host(make(), cfg, q=q, resume_from=str(tmp_path))


def test_corrupt_leaf_bytes_raise_actionable(tmp_path):
    make, q = _instance()
    _checkpointed_dir(make, q, tmp_path)
    latest = ckpt.latest_step(tmp_path)
    step_dir = tmp_path / f"step_{latest:08d}"
    victim = sorted(step_dir.glob("arr_*.npy"))[0]
    victim.write_bytes(victim.read_bytes()[:16])     # truncated .npy
    with pytest.raises(ValueError, match="unreadable"):
        ckpt.restore_auto(tmp_path, latest)


def test_stale_tmp_only_is_fresh_start(tmp_path):
    """A directory holding nothing but .tmp debris (killed first save)
    genuinely has no checkpoint: latest_step is None and the solve
    starts fresh — and the stale .tmp is pruned by the next save."""
    make, q = _instance()
    stale = tmp_path / "step_00000004.tmp"
    stale.mkdir(parents=True)
    (stale / "manifest.json").write_text('{"truncat')
    assert ckpt.latest_step(tmp_path) is None
    cfg = SolverConfig(reduce="bucketed", max_iters=15, checkpoint_every=2)
    base = solve_streaming_host(make(), cfg.replace(checkpoint_every=0),
                                q=q, slots=4)
    res = solve_streaming_host(make(), cfg, q=q, slots=4,
                               resume_from=str(tmp_path))
    _assert_bitwise(res, base)
    assert not stale.exists(), "prune should sweep stale .tmp debris"


def test_missing_manifest_dir_is_not_a_step(tmp_path):
    """A step-named directory without any manifest was not written by
    this layer (the atomic rename publishes the manifest with the step):
    it is ignored by latest_step, and restoring it by explicit step
    number says why."""
    bogus = tmp_path / "step_00000007"
    bogus.mkdir(parents=True)
    assert ckpt.latest_step(tmp_path) is None
    with pytest.raises(ValueError, match="no manifest.json"):
        ckpt.restore_auto(tmp_path, 7)


def test_pointer_document_corruption_raises(tmp_path):
    assert ckpt.read_json(tmp_path, "LIVE.json") is None
    ckpt.write_json(tmp_path, "LIVE.json", {"gen": 3})
    assert ckpt.read_json(tmp_path, "LIVE.json") == {"gen": 3}
    (tmp_path / "LIVE.json").write_text('{"gen"')
    with pytest.raises(ValueError, match="corrupt"):
        ckpt.read_json(tmp_path, "LIVE.json")


# ---------------------------------------------------------------------------
# Kill + resume: bitwise equivalence at every interruption point.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slots", [1, 8])
def test_kill_mid_iterate_resume_bitwise(tmp_path, slots):
    """Interrupt inside an iterate epoch (accumulators half-built) and
    resume: the replayed iteration re-runs from the last iteration
    boundary, so the final result is bitwise the uninterrupted one."""
    make, q = _instance()
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=2)
    base = solve_streaming_host(make(), cfg, q=q, slots=slots)
    src, _ = _killing(make, 70)                  # mid epoch ~3 of 16-chunk passes
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfg, q=q, slots=slots,
                             checkpoint_dir=str(tmp_path))
    assert ckpt.latest_step(tmp_path) is not None
    res = solve_streaming_host(make(), cfg, q=q, resume_from=str(tmp_path))
    _assert_bitwise(res, base)


def test_kill_between_finalize_chunks_no_double_count(tmp_path):
    """Satellite: kill between chunks of the fused finalize pass, resume
    from the mid-pass cursor, and verify no chunk's contribution is
    double-counted — the resumed run consumes exactly the not-yet-folded
    columns and reproduces the histograms bit for bit."""
    make, q = _instance(n=2048, chunk=64)        # c = 32, cps = 4 at slots=8
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=1)
    base = solve_streaming_host(make(), cfg, q=q, slots=8)
    iters = int(base.iters)
    cols = 4                                     # cps
    # land between finalize columns: after 2.5 columns of the last pass
    kill_at = 1 + iters * 32 + 2 * 8 + 4         # fp probe + epochs + 2.5 cols
    src, _ = _killing(make, kill_at)
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfg, q=q, slots=8,
                             checkpoint_dir=str(tmp_path))
    latest = ckpt.latest_step(tmp_path)
    assert latest > cfg.max_iters + 1            # a MID-finalize state
    state = ckpt.restore_auto(tmp_path, latest)
    cursor = int(np.asarray(state["cursor"]))
    assert 0 < cursor < cols
    src2, calls = _counting(make)
    res = solve_streaming_host(src2, cfg, q=q, resume_from=str(tmp_path))
    _assert_bitwise(res, base)
    # fingerprint probe + exactly the remaining columns, nothing replayed
    assert calls["n"] == 1 + (cols - cursor) * 8


def test_torn_save_ignored_and_resume_from_previous(tmp_path):
    """Satellite: crash mid-save. os.replace raises after the .tmp write,
    leaving a torn directory; restore ignores it and resumes from the
    previous step to a bitwise-identical result."""
    make, q = _instance()
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=2)
    base = solve_streaming_host(make(), cfg, q=q, slots=8)

    real_replace = os.replace
    n_ok = {"n": 0}

    def torn_replace(a, b):
        if n_ok["n"] >= 2:                      # third save dies mid-rename
            raise OSError("simulated crash during atomic rename")
        n_ok["n"] += 1
        return real_replace(a, b)

    ckpt.os.replace = torn_replace
    try:
        with pytest.raises(OSError, match="simulated crash"):
            solve_streaming_host(make(), cfg, q=q, slots=8,
                                 checkpoint_dir=str(tmp_path))
    finally:
        ckpt.os.replace = real_replace
    # The torn step exists only as .tmp; latest_step skips it.
    torn = [p.name for p in tmp_path.iterdir() if p.name.endswith(".tmp")]
    assert torn, "the interrupted save should have left a .tmp directory"
    latest = ckpt.latest_step(tmp_path)
    assert f"step_{latest:08d}.tmp" not in torn  # torn step > restored step
    res = solve_streaming_host(make(), cfg, q=q, resume_from=str(tmp_path))
    _assert_bitwise(res, base)


def test_resume_on_one_device_mesh_from_slots8(tmp_path):
    """Degraded-to-one-device resume in process: the slot partials are
    mesh-independent, so even D=1 reproduces the slots=8 run bitwise."""
    make, q = _instance()
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=3)
    base = solve_streaming_host(make(), cfg, q=q, slots=8)
    src, _ = _killing(make, 100)
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfg, q=q, slots=8,
                             checkpoint_dir=str(tmp_path))
    res = solve_streaming_host(
        make(), cfg, q=q, resume_from=str(tmp_path),
        mesh=jax.make_mesh((1,), ("d",)))
    _assert_bitwise(res, base)


def test_checkpointed_run_matches_uncheckpointed_bitwise(tmp_path):
    """Checkpointing itself (the save synchronisation points) must not
    perturb the solve."""
    make, q = _instance()
    for slots in (1, 8):
        cfg = SolverConfig(reduce="bucketed", max_iters=20)
        base = solve_streaming_host(make(), cfg, q=q, slots=slots)
        res = solve_streaming_host(
            make(), cfg.replace(checkpoint_every=1), q=q, slots=slots,
            checkpoint_dir=str(tmp_path / f"s{slots}"))
        _assert_bitwise(res, base)


def test_ordered_fold_pins_addition_order():
    rng = np.random.default_rng(0)
    x = np.asarray(rng.uniform(0.1, 1.0, (8, 10, 50)), np.float32) * 1.000123
    acc = x[0].copy()
    for i in range(1, 8):
        acc = (acc + x[i]).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(jax.jit(ordered_fold)(x)), acc)


# ---------------------------------------------------------------------------
# The acceptance bar: SIGKILL a real 8-virtual-device solve, resume on the
# same mesh and on a degraded 4-device mesh (subprocess).
# ---------------------------------------------------------------------------

_KILL_RESUME_SCRIPT = textwrap.dedent("""
    import os, signal, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import SolverConfig
    from repro.core.instances import shard_key, sparse_instance
    from repro.core.prefetch import host_array_source, solve_streaming_host

    mode, ndev, kill_after, ckpt_dir, out = (
        sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), sys.argv[4],
        sys.argv[5])
    kp, q = sparse_instance(shard_key(4), n=2048, k=8, q=2, tightness=0.4)
    p, b = np.asarray(kp.p), np.asarray(kp.b)
    bud = np.asarray(kp.budgets)
    src = host_array_source(p, b, bud, 64)          # c = 32, cps = 4
    if mode == "kill":
        calls = {"n": 0}
        inner = src.fn
        def fn(i):
            calls["n"] += 1
            if calls["n"] > kill_after:
                os.kill(os.getpid(), signal.SIGKILL)
            return inner(i)
        src = src._replace(fn=fn)
    mesh = jax.make_mesh((ndev,), ("users",), devices=jax.devices()[:ndev])
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=1)
    res = solve_streaming_host(
        src, cfg, q=q, mesh=mesh, slots=8,
        checkpoint_dir=ckpt_dir if mode != "resume" else None,
        resume_from=ckpt_dir if mode == "resume" else None)
    np.savez(out, lam=np.asarray(res.lam), iters=np.asarray(res.iters),
             dual=np.asarray(res.dual), r=np.asarray(res.r),
             primal=np.asarray(res.primal), tau=np.asarray(res.tau),
             ch=np.asarray(res.fin_hist[0]), gh=np.asarray(res.fin_hist[1]))
    print("RESULT-OK", int(res.iters))
""")


def _run_script(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run([sys.executable, "-c", _KILL_RESUME_SCRIPT] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=str(REPO))


@pytest.mark.slow
@pytest.mark.parametrize("kill_after", [50, 300])   # mid-iterate / late
def test_sigkill_and_resume_subprocess(tmp_path, kill_after):
    """An 8-virtual-device host-fed solve SIGKILLed at an arbitrary point
    and resumed — on the same mesh AND on a 4-device degraded mesh —
    returns bitwise-identical lam/iters/dual (and every other field, and
    the fused-finalize histograms) to the uninterrupted run."""
    ref = tmp_path / "ref.npz"
    out = _run_script(["ref", "8", "0", str(tmp_path / "unused"), str(ref)])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "RESULT-OK" in out.stdout

    ck = tmp_path / "ck"
    killed = _run_script(["kill", "8", str(kill_after), str(ck), "x"])
    assert killed.returncode == -signal.SIGKILL, (
        killed.returncode, killed.stdout, killed.stderr)
    assert ckpt.latest_step(ck) is not None

    want = np.load(ref)
    for ndev in (8, 4):
        got_path = tmp_path / f"resumed_{ndev}.npz"
        res = _run_script(["resume", str(ndev), "0", str(ck), str(got_path)])
        assert res.returncode == 0, res.stdout + res.stderr
        got = np.load(got_path)
        for key in ["lam", "iters", "dual", "r", "primal", "tau", "ch", "gh"]:
            np.testing.assert_array_equal(got[key], want[key],
                                          err_msg=f"ndev={ndev} {key}")


_TRACED_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import SolverConfig
    from repro.core.chunked import array_source, solve_streaming
    from repro.core.instances import shard_key, sparse_instance
    from repro.core.prefetch import host_array_source, solve_streaming_host

    kp, q = sparse_instance(shard_key(4), n=2048, k=8, q=2, tightness=0.4)
    p, b = np.asarray(kp.p), np.asarray(kp.b)
    bud = np.asarray(kp.budgets)
    mesh = jax.make_mesh((8,), ("users",))
    FIELDS = ["lam", "iters", "r", "primal", "dual", "tau"]

    for cfg in [SolverConfig(reduce="bucketed", max_iters=20),
                SolverConfig(algo="dd", max_iters=10, dd_lr=2e-3),
                SolverConfig(reduce="bucketed", max_iters=12,
                             partial_fraction=0.5),
                SolverConfig(reduce="bucketed", max_iters=20,
                             record_history=True, metrics_every=3)]:
        traced = solve_streaming(array_source(kp, 128), cfg, q=q, mesh=mesh)
        host = solve_streaming_host(host_array_source(p, b, bud, 128), cfg,
                                    q=q, mesh=mesh)
        for f in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(host, f)), np.asarray(getattr(traced, f)),
                err_msg=f"{cfg.algo}/{cfg.partial_fraction} {f}")
        if cfg.record_history:
            for key in traced.history:
                np.testing.assert_array_equal(
                    np.asarray(host.history[key]),
                    np.asarray(traced.history[key]), err_msg=key)
    print("PARITY-OK")
""")


@pytest.mark.slow
def test_host_sharded_matches_traced_sharded_subprocess(tmp_path):
    """Tentpole contract: the host-fed sharded driver is bit-identical
    field-for-field to the traced shard_map driver on 8 virtual devices —
    SCD, DD, straggler scaling and sampled history alike."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", _TRACED_PARITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900, cwd=str(REPO))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "PARITY-OK" in out.stdout


# ---------------------------------------------------------------------------
# Pre-screening regression pin + the screening/resume interplay.
# ---------------------------------------------------------------------------

# sha256 over RESULT_FIELDS of the 8-virtual-device sharded solve on the
# seeded fixture below, recorded immediately BEFORE active-set screening
# (core/screening.py) landed. cfg.screening=False must keep producing
# these exact bytes; screening=True must too on this uniform workload
# (its chunk ratio maxima never clear the bucket ladder, so the active
# set never shrinks and every epoch streams everything).
_GOLDEN_SHARDED = \
    "072a1ca1a405c827933ca8b387870d5415114bca09a220aefa027d47aa060f52"

_GOLDEN_SHARDED_SCRIPT = textwrap.dedent("""
    import hashlib, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import SolverConfig
    from repro.core.prefetch import solve_streaming_host
    from repro.data.synth import sparse_host_chunk_source

    def digest(res):
        h = hashlib.sha256()
        for f in ("lam", "iters", "r", "primal", "dual", "tau"):
            h.update(np.asarray(getattr(res, f)).tobytes())
        return h.hexdigest()

    src = sparse_host_chunk_source(4, 2048, 8, 128, q=2, tightness=0.5)
    cfg = SolverConfig(reduce="bucketed", max_iters=40)
    mesh = jax.make_mesh((8,), ("users",))
    res = solve_streaming_host(src, cfg, q=2, mesh=mesh, slots=8)
    print("PLAIN", digest(res))
    scr = solve_streaming_host(src, cfg.replace(screening=True), q=2,
                               mesh=mesh, slots=8)
    assert bool(scr.screen["active"].all())
    print("SCREENED", digest(scr))
""")


@pytest.mark.slow
def test_sharded_golden_digest_unchanged():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", _GOLDEN_SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900, cwd=str(REPO))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert f"PLAIN {_GOLDEN_SHARDED}" in out.stdout, out.stdout
    assert f"SCREENED {_GOLDEN_SHARDED}" in out.stdout, out.stdout


def test_resume_across_screening_toggle_bitwise(tmp_path):
    """cfg.screening is resume-fingerprint-EXEMPT (it never steers the
    trajectory): a checkpoint written unscreened resumes under
    screening=True — and vice versa — bitwise. The end-to-end twin of
    test_fingerprint_fields.py's field-coverage guard."""
    make, q = _instance()
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=2)
    base = solve_streaming_host(make(), cfg, q=q, slots=4)

    d1 = tmp_path / "off_to_on"
    src, _ = _killing(make, 70)
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfg, q=q, slots=4,
                             checkpoint_dir=str(d1))
    res = solve_streaming_host(make(), cfg.replace(screening=True), q=q,
                               resume_from=str(d1))
    _assert_bitwise(res, base)
    assert res.screen is not None

    d2 = tmp_path / "on_to_off"
    src, _ = _killing(make, 70)
    with pytest.raises(_Kill):
        solve_streaming_host(src, cfg.replace(screening=True), q=q,
                             slots=4, checkpoint_dir=str(d2))
    res = solve_streaming_host(make(), cfg, q=q, resume_from=str(d2))
    _assert_bitwise(res, base)
    assert res.screen is None
