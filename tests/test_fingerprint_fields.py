"""Resume-fingerprint field-coverage guard.

The checkpoint resume fingerprint (core/prefetch.py) hashes exactly the
SolverConfig fields that change the multiplier trajectory or the
finalize arithmetic; operational knobs (checkpoint cadence, fault
policy, screening, ...) are deliberately exempt so they can change
across a restart. The failure mode this file guards against is silent:
someone adds a SolverConfig field and *forgets to decide* — the field is
neither hashed nor exempted, and a checkpoint written before the change
resumes against a semantically different solve (or a legitimate
restart-time knob change spuriously refuses to resume). Here every
field must be accounted for in exactly one of the two lists, and the
hashed layout itself is pinned byte-for-byte.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.prefetch import (
    _FINGERPRINT_CFG_FIELDS,
    FINGERPRINT_EXEMPT_FIELDS,
    source_fingerprint,
)
from repro.core.types import SolverConfig
from repro.data.synth import sparse_host_chunk_source


def test_every_field_fingerprinted_or_exempt():
    fields = {f.name for f in dataclasses.fields(SolverConfig)}
    hashed = set(_FINGERPRINT_CFG_FIELDS) | {"dtype"}
    exempt = set(FINGERPRINT_EXEMPT_FIELDS)
    overlap = hashed & exempt
    assert not overlap, f"fields both hashed and exempt: {sorted(overlap)}"
    missing = fields - hashed - exempt
    assert not missing, (
        f"SolverConfig fields neither fingerprinted nor exempted: "
        f"{sorted(missing)} — add each to _FINGERPRINT_CFG_FIELDS if it "
        "changes the solve, or to FINGERPRINT_EXEMPT_FIELDS if changing "
        "it across a restart is legitimate")
    phantom = (hashed | exempt) - fields
    assert not phantom, (
        f"fingerprint lists name non-existent fields: {sorted(phantom)}")


def test_hashed_fields_exist_and_are_ordered_tuple():
    # The hash layout depends on tuple order; a set would silently
    # change the fingerprint across interpreter runs.
    assert isinstance(_FINGERPRINT_CFG_FIELDS, tuple)
    assert len(set(_FINGERPRINT_CFG_FIELDS)) == len(_FINGERPRINT_CFG_FIELDS)


@pytest.fixture(scope="module")
def _src():
    return sparse_host_chunk_source(0, 1000, 4, 256)


def test_exempt_fields_do_not_change_fingerprint(_src):
    lam0 = np.ones((4,), np.float32)
    base = source_fingerprint(_src, SolverConfig(), 1, lam0)
    changed = SolverConfig(
        max_iters=7, metrics_every=3, record_history=True,
        checkpoint_every=5, checkpoint_keep=9, fetch_retries=2,
        fetch_backoff=0.1, fetch_backoff_growth=3.0, fetch_backoff_cap=9.0,
        fetch_jitter=0.5, fetch_timeout=1.0, verify_refetch=True,
        chunk_size=128, screening=True, screening_floor=0.25)
    assert np.array_equal(
        base, source_fingerprint(_src, changed, 1, lam0)), (
        "an exempt field perturbed the resume fingerprint — a restart "
        "that legitimately changes it would refuse to resume")


def test_hashed_fields_do_change_fingerprint(_src):
    lam0 = np.ones((4,), np.float32)
    base = source_fingerprint(_src, SolverConfig(), 1, lam0)
    for field, value in [("bucket_half", 12), ("cd_damping", 0.25),
                         ("tol", 1e-5), ("postprocess", False)]:
        cfg = SolverConfig(**{field: value})
        assert not np.array_equal(
            base, source_fingerprint(_src, cfg, 1, lam0)), (
            f"changing hashed field {field} left the fingerprint "
            "unchanged")
