"""System-level tests of the GKP solver against independent oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    SolverConfig,
    SparseKP,
    solve,
)
from repro.core.bucketing import (
    bucket_histogram,
    exact_threshold,
    make_edges,
    threshold_from_hist,
)
from repro.core.exact import (
    brute_force,
    brute_force_subproblem,
    lp_upper_bound,
    lp_upper_bound_sparse,
)
from repro.core.greedy import greedy_solve
from repro.core.instances import dense_instance, shard_key, sparse_instance
from repro.core.sparse_scd import candidates_sparse, select_sparse

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Algorithm 1: greedy == brute force on laminar subproblems (Prop 4.1).
# ---------------------------------------------------------------------------

LAMINAR_CASES = [
    # (sets, caps) over M=6 items
    (np.ones((1, 6), bool), [2]),
    (np.array([[1, 1, 1, 0, 0, 0], [0, 0, 0, 1, 1, 1]], bool), [1, 2]),
    (
        np.array(
            [[1, 1, 1, 0, 0, 0], [0, 0, 0, 1, 1, 1], [1, 1, 1, 1, 1, 1]], bool
        ),
        [2, 2, 3],
    ),
    (
        np.array(
            [[1, 1, 0, 0, 0, 0], [1, 1, 1, 0, 0, 0], [1, 1, 1, 1, 1, 1]], bool
        ),
        [1, 2, 4],
    ),
]


@pytest.mark.parametrize("case", range(len(LAMINAR_CASES)))
def test_greedy_matches_brute_force(case):
    sets, caps = LAMINAR_CASES[case]
    rng = np.random.default_rng(case)
    for _ in range(100):
        pa = rng.normal(size=6).astype(np.float32)
        x = np.asarray(
            greedy_solve(jnp.asarray(pa), jnp.asarray(sets), jnp.asarray(np.asarray(caps, np.int32)))
        )
        bv, _ = brute_force_subproblem(pa, sets, caps)
        np.testing.assert_allclose(pa[x].sum(), bv, rtol=1e-5, atol=1e-6)
        # constraints hold
        for s, c in zip(sets, caps):
            assert x[s].sum() <= c


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(2, 8),
)
@settings(max_examples=40, deadline=None)
def test_greedy_laminar_property(seed, m):
    """Random laminar family: greedy is optimal and feasible."""
    rng = np.random.default_rng(seed)
    # random laminar family: nested prefixes + disjoint blocks
    h = max(1, m // 2)
    sets = np.zeros((3, m), bool)
    sets[0, :h] = True
    sets[1, h:] = True
    sets[2, :] = True
    caps = rng.integers(1, m + 1, size=3)
    pa = rng.normal(size=m).astype(np.float32)
    x = np.asarray(greedy_solve(jnp.asarray(pa), jnp.asarray(sets), jnp.asarray(caps.astype(np.int32))))
    bv, _ = brute_force_subproblem(pa, sets, caps)
    np.testing.assert_allclose(pa[x].sum(), bv, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Algorithm 5 candidates + reduce-side threshold search.
# ---------------------------------------------------------------------------

def _naive_threshold(v1, v2, budget):
    """Reference for exact_threshold: scan candidate thresholds directly."""
    vals = np.unique(v1[v2 > 0])[::-1]
    for v in vals:  # descending
        tot = v2[(v1 >= v) & (v2 > 0)].sum()
        if tot > budget:
            # previous value was minimal feasible; if none, above max
            idx = np.where(vals == v)[0][0]
            if idx == 0:
                return float(vals[0]) * (1 + 1e-6) + 1e-6
            return float(vals[idx - 1])
    return 0.0


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_exact_threshold_matches_naive(seed):
    rng = np.random.default_rng(seed)
    z = 50
    v1 = rng.uniform(0, 2, z).astype(np.float32)
    v2 = rng.uniform(0, 1, z).astype(np.float32)
    dead = rng.random(z) < 0.2
    v1[dead], v2[dead] = -1.0, 0.0
    budget = float(rng.uniform(0.1, v2.sum() + 1))
    got = float(exact_threshold(jnp.asarray(v1), jnp.asarray(v2), jnp.asarray(budget)))
    want = _naive_threshold(v1, v2, budget)
    # both must satisfy the defining property
    assert v2[(v1 >= got) & (v2 > 0)].sum() <= budget + 1e-4
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bucketed_threshold_feasible(seed):
    """Bucketed reduce must return a lam whose consumption fits the budget
    (up to interpolation error within one bucket)."""
    rng = np.random.default_rng(seed)
    n, k = 400, 4
    v1 = rng.uniform(0, 3, (n, k)).astype(np.float32)
    v2 = rng.uniform(0, 1, (n, k)).astype(np.float32)
    budgets = jnp.asarray(rng.uniform(5, 50, k).astype(np.float32))
    lam_t = jnp.asarray(rng.uniform(0.5, 2.0, k).astype(np.float32))
    edges = make_edges(lam_t, 1e-4, 1.7, 24)
    hist = bucket_histogram(jnp.asarray(v1), jnp.asarray(v2), edges)
    top = jnp.max(jnp.asarray(v1), axis=0)
    lam = np.asarray(threshold_from_hist(hist, edges, budgets, top))
    edges_np = np.asarray(edges)
    hist_np = np.asarray(hist)
    for kk in range(k):
        cons_at = v2[:, kk][v1[:, kk] >= lam[kk]].sum()
        budget = float(budgets[kk])
        # The single-iteration guarantee: the returned lam lands inside the
        # crossing bucket, so |consumption - budget| <= that bucket's mass.
        # (Iteration re-centres the edge ladder at lam, shrinking the bucket.)
        j = int(np.searchsorted(edges_np[kk], lam[kk]))
        mass = float(hist_np[kk, j])
        assert cons_at <= budget + mass + 1e-3
        if lam[kk] > 0:
            assert cons_at >= budget - mass - 1e-3


@given(seed=st.integers(0, 2**31 - 1), q=st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_alg5_candidates_are_selection_boundaries(seed, q):
    """Property: raising lam_k just above an emitted candidate v1 deselects
    item k for that user; just below keeps/selects it (Alg 5 correctness)."""
    rng = np.random.default_rng(seed)
    k = 8
    p = jnp.asarray(rng.uniform(0, 1, (1, k)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0.1, 1, (1, k)).astype(np.float32))
    lam = jnp.asarray(rng.uniform(0, 0.5, k).astype(np.float32))
    v1, v2 = candidates_sparse(p, b, lam, q)
    v1, v2 = np.asarray(v1)[0], np.asarray(v2)[0]
    for kk in range(k):
        if v2[kk] <= 0:
            continue
        eps = 1e-3 * (1 + abs(v1[kk]))
        lam_hi = lam.at[kk].set(v1[kk] + eps)
        lam_lo = lam.at[kk].set(max(v1[kk] - eps, 0.0))
        x_hi = np.asarray(select_sparse(p, b, lam_hi, q))[0, kk]
        x_lo = np.asarray(select_sparse(p, b, lam_lo, q))[0, kk]
        assert not x_hi, "item must be deselected just above its candidate"
        if v1[kk] > eps:
            assert x_lo, "item must be selected just below its candidate"


# ---------------------------------------------------------------------------
# End-to-end solves vs oracles (paper §6.1 quality claims).
# ---------------------------------------------------------------------------

def test_tiny_dense_bounded_by_brute_force():
    """At tiny N the duality gap is real (§4.4): assert the Lagrangian
    sandwich primal <= IP optimum <= dual, and feasibility."""
    kp = dense_instance(shard_key(3), n=4, m=4, k=2, local="C2", tightness=0.15)
    res = solve(kp, SolverConfig(reduce="exact", cd_mode="cyclic", max_iters=30), q=0)
    bv, _ = brute_force(
        np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets),
        np.asarray(kp.sets), np.asarray(kp.caps),
    )
    assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) + 1e-5)
    assert float(res.primal) <= bv + 1e-5
    assert float(res.dual) >= bv - 1e-5


def test_n100_dense_near_milp_optimum():
    """§4.4/§6.1: gap shrinks with N — at N=100 SCD is within 3% of the
    exact MILP optimum (HiGHS branch and bound)."""
    from repro.core.exact import milp_optimum

    kp = dense_instance(shard_key(21), n=100, m=6, k=3, local="C2", tightness=0.25)
    res = solve(kp, SolverConfig(reduce="exact", cd_mode="cyclic", max_iters=30), q=0)
    opt = milp_optimum(
        np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets),
        np.asarray(kp.sets), np.asarray(kp.caps),
    )
    assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) + 1e-5)
    ratio = float(res.primal) / opt
    assert ratio >= 0.97, f"ratio {ratio:.4f} vs exact MILP optimum"
    assert ratio <= 1.0 + 1e-5


@pytest.mark.parametrize("local", ["C1", "C2", "C223"])
def test_dense_optimality_ratio_above_paper_band(local):
    """Figure 1: optimality ratio vs LP relaxation >= 98.6% at N=1000."""
    kp = dense_instance(shard_key(11), n=1000, m=10, k=5, local=local,
                        tightness=0.25, mixed_b=True)
    res = solve(kp, SolverConfig(reduce="exact", cd_mode="cyclic", max_iters=25), q=0)
    lpv = lp_upper_bound(
        np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets),
        np.asarray(kp.sets), np.asarray(kp.caps),
    )
    ratio = float(res.primal) / lpv
    assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
    assert ratio >= 0.986, f"optimality ratio {ratio:.4f} below paper's 98.6%"


def test_sparse_optimality_ratio_n10000():
    """Figure 1 band at N=10,000: >= 99.8%."""
    kp, q = sparse_instance(shard_key(5), n=10000, k=10, q=1, tightness=0.4)
    res = solve(kp, SolverConfig(reduce="bucketed", max_iters=40), q=q)
    lpv = lp_upper_bound_sparse(
        np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets), q
    )
    ratio = float(res.primal) / lpv
    assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
    assert ratio >= 0.998, f"optimality ratio {ratio:.4f} below paper's 99.8%"


def test_k1_dantzig_bound():
    """§4.4: for K=1 the solution is within max_ij p_ij of optimal."""
    kp, q = sparse_instance(shard_key(7), n=500, k=1, q=1, tightness=0.3)
    res = solve(kp, SolverConfig(reduce="exact", max_iters=30), q=q)
    lpv = lp_upper_bound_sparse(
        np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets), q
    )
    # Dantzig rounding loses at most one item's profit; our left-limit
    # threshold convention (never overshoot the budget) can leave up to one
    # more item of slack, hence the factor 2.
    assert float(res.primal) >= lpv - 2 * float(jnp.max(kp.p)) - 1e-4


def test_duality_gap_small_and_positive():
    kp, q = sparse_instance(shard_key(8), n=5000, k=10, q=2, tightness=0.4)
    res = solve(kp, SolverConfig(reduce="bucketed", max_iters=30), q=q)
    gap = float(res.dual - res.primal)
    assert gap >= -1e-2  # dual upper-bounds primal
    assert gap <= 0.02 * float(res.primal), "gap should be ~ tiny vs primal (Table 1)"


def test_dd_vs_scd_violations():
    """Figures 5/6: SCD's max constraint violation is far smaller than DD's
    along the trajectory, at comparable iteration counts."""
    kp, q = sparse_instance(shard_key(9), n=2000, k=10, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=15, record_history=True,
                       postprocess=False)
    scd = solve(kp, cfg, q=q)
    dd = solve(kp, cfg.replace(algo="dd", dd_lr=2e-3), q=q)
    scd_viol = np.asarray(scd.history["max_violation"])
    dd_viol = np.asarray(dd.history["max_violation"])
    # Fig 6's claim: DD spikes into infeasibility along the way; SCD's
    # trajectory stays near-feasible ("much smaller and way more smooth").
    assert scd_viol.max() <= dd_viol.max() / 2
    assert scd_viol.std() <= dd_viol.std() + 1e-6


def test_presolve_reduces_iterations():
    """Table 2: warm-starting from a sampled solve cuts iterations."""
    kp, q = sparse_instance(shard_key(10), n=20000, k=10, q=1, tightness=0.4)
    cold = solve(kp, SolverConfig(reduce="bucketed", max_iters=40), q=q)
    warm = solve(
        kp, SolverConfig(reduce="bucketed", max_iters=40, presolve_samples=1000), q=q
    )
    assert int(warm.iters) <= int(cold.iters)
    # and solution quality is preserved
    np.testing.assert_allclose(
        float(warm.primal), float(cold.primal), rtol=2e-2
    )


def test_postprocess_guarantees_feasibility():
    """§5.4: returned solutions never violate global constraints."""
    for seed in range(5):
        kp, q = sparse_instance(shard_key(100 + seed), n=1000, k=8, q=2,
                                tightness=0.3)
        res = solve(kp, SolverConfig(reduce="bucketed", max_iters=8), q=q)
        assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) + 1e-4), seed


def test_categorical_extension_via_dense():
    """§2: categorical variables = disjoint one-hot groups (MCKP reduction)."""
    # M=6 items in 3 groups of 2; exactly-one relaxed to at-most-one.
    kp = dense_instance(shard_key(12), n=50, m=6, k=3, local="C223", tightness=0.2)
    res = solve(kp, SolverConfig(reduce="exact", cd_mode="cyclic", max_iters=20), q=0)
    x = np.asarray(res.x)
    sets = np.asarray(kp.sets)
    caps = np.asarray(kp.caps)
    for l in range(sets.shape[0]):
        assert np.all(x[:, sets[l]].sum(-1) <= caps[l])
