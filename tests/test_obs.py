"""The observability layer: registry, tracing, parity, /metrics.

The contracts under test (DESIGN.md §14, repro/obs/):

* the typed registry — monotone counters, set/computed gauges,
  fixed-ladder histograms — is get-or-create keyed by (name, labels)
  and refuses kind collisions; the null registry is inert;
* Prometheus text round-trips through ``render_prometheus`` /
  ``parse_prometheus``; snapshot merging sums counters/gauges and adds
  histogram counts elementwise; ``label_snapshot`` stamps labels;
* the phase-span tracer journals host-side spans to fsync-batched
  JSONL; request ids ride a contextvar into every span emitted inside
  ``request(rid)``; ``read_trace`` tolerates a torn tail (a SIGKILLed
  writer loses at most the buffered spans, never a reader) but flags
  mid-file corruption;
* **the host-side-only rule**: a chunked solve, a sharded (virtual
  slot) solve and an engine refresh with observability ON publish
  results **bitwise identical** to the same runs with it OFF;
* ``/metrics`` on the replica RPC and the front aggregates the same
  numbers ``/health`` reports, the fleet aggregate is the sum of the
  per-replica labeled series, and one request id correlates the
  ``front.decide`` span with the replica-side ``serve.fill`` spans;
* the degraded bit is the *current* binding's state — a rebind onto a
  healed generation clears it while ``stale_serves`` stays monotone;
* SUPERVISOR.json goes through ``ckpt.write_json`` (fsync'd tmp +
  atomic rename), never a bare ``open().write``.
"""
import json
import os
import signal
import subprocess
import sys
import time
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.core.prefetch import solve_streaming_host
from repro.data.synth import sparse_host_chunk_source
from repro.launch.supervisor import Supervisor, SupervisorConfig
from repro.obs import (
    LATENCY_BUCKETS,
    MetricsRegistry,
    NULL_REGISTRY,
    NULL_TRACER,
    Tracer,
    current_rid,
    label_snapshot,
    make_obs,
    merge_snapshots,
    null_obs,
    parse_prometheus,
    read_trace,
    render_prometheus,
    request,
    trace_path,
)
from repro.serve import (
    Front,
    RefreshEngine,
    ReplicaClient,
    ReplicaServer,
    WorkloadSpec,
    synthetic_source,
)

jax.config.update("jax_platform_name", "cpu")

SPEC = WorkloadSpec(seed=5, n=1024, k=4, chunk=128, q=1, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=25, checkpoint_every=0)
SCALES = [1.0, 0.9]
CHUNKS = SPEC.n // SPEC.chunk
RESULT_FIELDS = ("lam", "iters", "r", "primal", "dual", "tau")
GEN_FIELDS = ("lam", "tau", "r", "primal", "dual")


# ---------------------------------------------------------------------------
# Metrics registry: typed instruments, get-or-create, null inertness.
# ---------------------------------------------------------------------------

def test_counter_is_monotone_and_snapshots():
    reg = MetricsRegistry()
    c = reg.counter("hits", route="a")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert not hasattr(c, "set")        # counters cannot go down
    (s,) = reg.snapshot()
    assert s == {"kind": "counter", "name": "hits",
                 "labels": {"route": "a"}, "value": 5}


def test_gauge_set_max_and_computed():
    reg = MetricsRegistry()
    g = reg.gauge("lease_age")
    g.set(2.0)
    g.set_max(1.0)                      # lower: ignored
    g.set_max(7.5)
    assert g.value == 7.5
    backing = [1, 2, 3]
    live = reg.gauge("cache_size", fn=lambda: len(backing))
    assert live.value == 3
    backing.append(4)
    assert live.value == 4              # computed at read time


def test_histogram_buckets_and_ladder():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    assert h.buckets == LATENCY_BUCKETS
    for v in (2e-5, 2e-5, 0.3, 99.0):   # two in one bucket, one +Inf
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(99.30004)
    (s,) = reg.snapshot()
    assert sum(s["counts"]) == 4
    assert s["counts"][-1] == 1         # 99.0 lands past the last edge
    assert s["counts"][1] == 2          # both 2e-5 in the 2.5e-5 bucket


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.counter("x", a="1") is not reg.counter("x", a="2")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


def test_null_registry_is_inert():
    inst = NULL_REGISTRY.counter("anything")
    inst.inc()
    inst.set(9)
    inst.observe(1.0)
    assert inst.value == 0
    assert NULL_REGISTRY.snapshot() == []
    assert NULL_REGISTRY.gauge("g") is inst       # one shared instrument
    assert null_obs() is null_obs()               # and one shared bundle


# ---------------------------------------------------------------------------
# Prometheus text: render/parse round-trip, merge, labeling.
# ---------------------------------------------------------------------------

def test_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("req", route="decide").inc(3)
    reg.gauge("up").set(1)
    reg.histogram("lat").observe(3e-5)
    series = parse_prometheus(render_prometheus(reg.snapshot()))
    assert series[("req", (("route", "decide"),))] == 3
    assert series[("up", ())] == 1
    assert series[("lat_count", ())] == 1
    assert series[("lat_sum", ())] == pytest.approx(3e-5)
    # Cumulative buckets: the +Inf bucket equals the count.
    assert series[("lat_bucket", (("le", "+Inf"),))] == 1


def test_merge_snapshots_sums_and_adds_histograms():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("q").inc(2)
    b.counter("q").inc(5)
    a.histogram("lat").observe(1e-4)
    b.histogram("lat").observe(2.0)
    m = merge_snapshots([a.snapshot(), b.snapshot()])
    by_name = {s["name"]: s for s in m}
    assert by_name["q"]["value"] == 7
    assert by_name["lat"]["count"] == 2
    assert sum(by_name["lat"]["counts"]) == 2
    assert by_name["lat"]["sum"] == pytest.approx(2.0001)


def test_label_snapshot_stamps_and_merge_keeps_labels_apart():
    reg = MetricsRegistry()
    reg.counter("q").inc(3)
    s0 = label_snapshot(reg.snapshot(), replica="0")
    s1 = label_snapshot(reg.snapshot(), replica="1")
    m = merge_snapshots([s0, s1, reg.snapshot()])
    vals = {tuple(sorted(s["labels"].items())): s["value"] for s in m}
    # Distinct label sets never merge; the unlabeled entry is separate.
    assert vals == {(("replica", "0"),): 3, (("replica", "1"),): 3, (): 3}


# ---------------------------------------------------------------------------
# Tracing: spans to JSONL, rid propagation, torn-tail-proof reader.
# ---------------------------------------------------------------------------

def test_tracer_spans_events_records_and_rid(tmp_path):
    path = trace_path(tmp_path, "t")
    with Tracer(path) as tr:
        with tr.span("solve.iterate", iter=3):
            pass
        tr.event("screen.skip", chunk=7)
        tr.record("ingest.fetch", 123.0, 0.25, chunks=8)
        with request("abc-1"):
            assert current_rid() == "abc-1"
            tr.event("serve.fill", chunk=0)
        assert current_rid() is None
    spans = read_trace(path)
    by_phase = {s["phase"]: s for s in spans}
    assert by_phase["solve.iterate"]["iter"] == 3
    assert by_phase["solve.iterate"]["dur_s"] >= 0
    assert by_phase["screen.skip"]["dur_s"] == 0.0
    assert by_phase["ingest.fetch"]["t"] == 123.0
    assert by_phase["ingest.fetch"]["dur_s"] == 0.25
    assert by_phase["serve.fill"]["rid"] == "abc-1"
    assert "rid" not in by_phase["screen.skip"]
    assert all(s["pid"] == os.getpid() for s in spans)


def test_tracer_batches_fsyncs(tmp_path):
    path = trace_path(tmp_path, "b")
    tr = Tracer(path, fsync_every=4)
    for i in range(3):
        tr.event("e", i=i)
    assert read_trace(path) == []       # still buffered, nothing on disk
    tr.event("e", i=3)                  # 4th: batch-flushed + fsync'd
    assert len(read_trace(path)) == 4
    tr.close()


def test_read_trace_torn_tail_and_corruption(tmp_path):
    p = tmp_path / "j.jsonl"
    rec = json.dumps({"phase": "x", "t": 0, "dur_s": 0, "pid": 1})
    p.write_text(rec + "\n" + rec + "\n" + rec[: len(rec) // 2])
    assert len(read_trace(p)) == 2          # torn tail dropped, no raise
    p.write_text(rec + "\n{bad}\n" + rec + "\n")
    with pytest.raises(ValueError, match="corrupt trace line 2"):
        read_trace(p)                       # mid-file damage is loud
    assert read_trace(tmp_path / "missing.jsonl") == []


def test_trace_journal_survives_sigkill(tmp_path):
    """A writer SIGKILLed mid-journal leaves a readable trace: every
    fsync'd span survives and the reader never crashes on the tail."""
    prog = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.obs import Tracer, trace_path\n"
        "tr = Tracer(trace_path({root!r}, 'victim'), fsync_every=1)\n"
        "tr.event('warmup')\n"
        "tr.flush()\n"
        "print('ready', flush=True)\n"
        "import time\n"
        "i = 0\n"
        "while True:\n"
        "    tr.event('tick', i=i); i += 1; time.sleep(0.001)\n"
    ).format(src=str((os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))) + "/src"), root=str(tmp_path))
    proc = subprocess.Popen([sys.executable, "-c", prog],
                            stdout=subprocess.PIPE, text=True)
    try:
        assert proc.stdout.readline().strip() == "ready"
        # The journal is pid-stamped by the *writer* process.
        path = os.path.join(tmp_path, "obs", f"victim-{proc.pid}.jsonl")
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if os.path.exists(path) and \
                    len(open(path, "rb").read().splitlines()) > 20:
                break
            time.sleep(0.01)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait()
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    spans = read_trace(path)                # must not raise, ever
    ticks = [s for s in spans if s["phase"] == "tick"]
    assert len(ticks) >= 10
    # What survived is a prefix: fsync order == emission order.
    assert [s["i"] for s in ticks] == list(range(len(ticks)))


# ---------------------------------------------------------------------------
# The host-side-only rule: obs on == obs off, bitwise.
# ---------------------------------------------------------------------------

def _bitwise_result(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def _source():
    return sparse_host_chunk_source(3, SPEC.n, 6, SPEC.chunk,
                                    q=2, tightness=0.3)


def test_chunked_solve_bitwise_identical_obs_on_off(tmp_path):
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=0)
    base = solve_streaming_host(_source(), cfg, q=2)
    with Tracer(trace_path(tmp_path, "solve")) as tr:
        traced = solve_streaming_host(_source(), cfg, q=2, tracer=tr)
    _bitwise_result(base, traced)
    phases = {s["phase"] for s in read_trace(tr.path)}
    assert {"solve.iterate", "solve.finalize",
            "ingest.fetch", "ingest.h2d"} <= phases


def test_sharded_solve_bitwise_identical_obs_on_off(tmp_path):
    cfg = SolverConfig(reduce="bucketed", max_iters=20, checkpoint_every=0)
    base = solve_streaming_host(_source(), cfg, q=2, slots=4)
    with Tracer(trace_path(tmp_path, "shard")) as tr:
        traced = solve_streaming_host(_source(), cfg, q=2, slots=4,
                                      tracer=tr)
    _bitwise_result(base, traced)
    phases = {s["phase"] for s in read_trace(tr.path)}
    assert {"solve.iterate", "solve.finalize", "ingest.fetch"} <= phases


def test_refresh_bitwise_identical_obs_on_off(tmp_path):
    plain = RefreshEngine(tmp_path / "off", SPEC, cfg=CFG)
    obs = make_obs(tmp_path / "on", role="engine")
    traced = RefreshEngine(tmp_path / "on", SPEC, cfg=CFG, obs=obs)
    for scale in SCALES:
        a = plain.refresh(budget_scale=scale)
        b = traced.refresh(budget_scale=scale)
        for f in GEN_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)),
                                          err_msg=f)
        # Same solver identity hash: the traced solve IS the same solve.
        assert a.fingerprint.tobytes() == b.fingerprint.tobytes()
        assert a.iters == b.iters
    obs.close()
    phases = [s["phase"] for s in read_trace(obs.tracer.path)]
    # The refresh journal holds the solve spans AND the publish steps.
    assert "solve.iterate" in phases and "solve.finalize" in phases
    assert phases.count("refresh.publish") == 2 * len(SCALES)


# ---------------------------------------------------------------------------
# /metrics over the wire: replica RPC, front aggregation, rid correlation.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """Two obs-enabled replicas behind a traced front, ready to query."""
    path = tmp_path_factory.mktemp("obs_front")
    eng = RefreshEngine(path, SPEC, cfg=CFG)
    refs = []
    for s in SCALES:
        g = eng.refresh(budget_scale=s)
        refs.append(eng.decision_service(
            generation=g, fallback=False).decide_batch(np.arange(SPEC.n)))

    reps, clients = [], []
    for i in range(2):
        e = RefreshEngine.attach(path, cfg=CFG,
                                 obs=make_obs(path, role=f"replica{i}"))
        rep = ReplicaServer(e, index=i, cache_chunks=CHUNKS, poll_s=0.02)
        port = rep.start()
        reps.append(rep)
        clients.append(ReplicaClient("127.0.0.1", port))
    front_obs = make_obs(path, role="front")
    front = Front(clients, tracer=front_obs.tracer)
    yield SimpleNamespace(path=path, reps=reps, clients=clients,
                          front=front, front_obs=front_obs, refs=refs)
    for c in clients:
        c.close()
    front.shutdown()
    for r in reps:
        r.stop()
    front_obs.close()
    for r in reps:
        r.engine.obs.close()


def test_replica_metrics_op_matches_health(served):
    rc = served.clients[0]
    for u in (3, 700, 3):
        rc.call({"op": "lookup", "user": u})
    h = rc.call({"op": "health"})
    m = rc.call({"op": "metrics"})
    assert m["replica"] == 0
    series = parse_prometheus(m["text"])
    assert series[("serve_queries", ())] == h["queries"]
    assert series[("serve_fills", ())] == h["fills"]
    assert series[("serve_hits", ())] == h["hits"]
    assert series[("serve_stale_serves", ())] == h["stale_serves"] == 0
    assert series[("replica_rebinds", ())] == served.reps[0].rebinds
    # The fill latencies landed in the shared-ladder histogram.
    assert series[("serve_fill_seconds_count", ())] == h["fills"]
    # The snapshot in the payload renders to the same text.
    assert render_prometheus(m["snapshot"]) == m["text"]


def test_front_metrics_aggregate_is_sum_of_replicas(served):
    front = served.front
    for u in (1, 2, 3, 4, 5):
        r = front.decide(u)
        assert not r["stale"]
        assert (np.asarray(r["x"]) == served.refs[-1][u]).all()
    front.decide_batch([7, 8, 9])
    series = parse_prometheus(front.metrics_text())
    assert series[("front_requests", ())] == front.stats["requests"]
    for name in ("serve_queries", "serve_fills", "replica_rebinds"):
        per = [series.get((name, (("replica", str(i)),)), 0.0)
               for i in range(2)]
        assert series[(name, ())] == sum(per), name
    # Both replicas actually answered traffic (round-robin works).
    per_q = [series.get(("serve_queries", (("replica", str(i)),)), 0.0)
             for i in range(2)]
    assert all(q > 0 for q in per_q)


def test_request_id_correlates_front_and_replica_spans(served):
    # User 513 lives in chunk 4 — untouched by the earlier tests, so
    # this decide provably misses the cache and fills under its rid.
    served.front.decide(513)
    served.front_obs.tracer.flush()
    for rep in served.reps:
        rep.engine.obs.tracer.flush()
    fronts = [s for s in read_trace(trace_path(served.path, "front"))
              if s["phase"] == "front.decide"]
    assert fronts, "front.decide spans missing"
    rids = {s["rid"] for s in fronts}
    fills = []
    for i in range(2):
        fills += [s for s in
                  read_trace(trace_path(served.path, f"replica{i}"))
                  if s["phase"] == "serve.fill" and "rid" in s]
    # Every front rid that caused a fill shows up replica-side; the
    # decide(42) above certainly missed the cache at least once overall.
    assert rids & {s["rid"] for s in fills}
    assert all("-" in r for r in rids)      # pid-qualified ids


# ---------------------------------------------------------------------------
# Supervisor status durability: SUPERVISOR.json via ckpt.write_json.
# ---------------------------------------------------------------------------

def test_supervisor_publish_routes_through_write_json(tmp_path, monkeypatch):
    calls = []
    real = ckpt.write_json

    def spy(root, name, doc):
        calls.append((name, dict(doc)))
        return real(root, name, doc)

    monkeypatch.setattr(ckpt, "write_json", spy)
    sup = Supervisor(tmp_path, {"kind": "solve"}, cfg=SupervisorConfig(),
                     devices=2)
    sup._publish("watching")
    assert calls and calls[-1][0] == "SUPERVISOR.json"
    doc = calls[-1][1]
    assert doc["state"] == "watching" and doc["devices"] == 2
    assert set(doc) == {"ok", "state", "spawns", "crash_restarts",
                        "hang_takeovers", "restarts", "kills_injected",
                        "stops_injected", "degraded_spawns",
                        "max_lease_age", "term", "devices", "last_rc",
                        "updated_wall"}
    # And the durable file is what health() will read back.
    on_disk = json.loads((tmp_path / "SUPERVISOR.json").read_text())
    assert on_disk["state"] == "watching"
