"""Property suite for safe λ-interval active-set screening (DESIGN.md §11).

The claim under test is absolute: a screened solve — which *skips*
retired chunks in every iteration pass — returns **bitwise** the
unscreened solve's every field, on both streaming drivers. Three
property families, each with a deterministic twin that always runs and a
hypothesis sweep (gated by ``REQUIRE_HYPOTHESIS`` in CI like the other
property suites):

* **oracle parity** — screened vs unscreened host-fed and traced solves
  agree field-for-field (lam/iters/r/primal/dual/tau and the finalize
  histograms), across instance seeds, budget tightness, cold-band
  widths, damping and floor factors; and the per-row decisions derived
  from (lam, tau) — the thing production serves — match row-for-row.
* **safe-elimination soundness** — every retired chunk's stored
  certificate dominates an independently recomputed f64 bound of its
  actual bytes AND clears the floor ladder's lowest edge: retirement
  never rests on an understated bound.
* **monotone shrinkage** — with no floor escapes, the traced driver's
  per-iteration active-chunk telemetry never grows.

The workloads are ratio-banded (``data.synth.banded_host_chunk_source``)
with a narrowed bucket ladder: uniform-[0,1]/[0,1] data has heavy-tailed
chunk ratio maxima and retires nothing (that degenerate case is pinned
too — screening must still be bitwise when it never fires).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.chunked import (
    ChunkSource,
    decisions_chunk,
    solve_streaming,
)
from repro.core.prefetch import solve_streaming_host
from repro.core.screening import HostScreen, chunk_bound, lowest_edges
from repro.core.types import SolverConfig
from repro.data.synth import banded_host_chunk_source, sparse_host_chunk_source

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

jax.config.update("jax_platform_name", "cpu")

RESULT_FIELDS = ["lam", "iters", "r", "primal", "dual", "tau"]

# One fixed shape family across every example so XLA programs compile
# once per driver; the hypothesis sweep varies data, not shapes.
N, K, CHUNK, Q, ITERS, HALF = 3000, 5, 250, 2, 18, 12


def _cfg(floor=0.5, damping=0.5, screening=False):
    return SolverConfig(reduce="bucketed", max_iters=ITERS,
                        bucket_half=HALF, cd_damping=damping,
                        screening=screening, screening_floor=floor)


def _banded(seed, tightness, band):
    return banded_host_chunk_source(seed, N, K, CHUNK, q=Q,
                                    tightness=tightness, band=band)


def _traced_source(host_src):
    """The traced twin of a host source: same bytes, jnp-delivered."""
    c = -(-host_src.n // host_src.chunk)
    chunks = [host_src.fn(i) for i in range(c)]
    ps = jnp.asarray(np.stack([p for p, _ in chunks]))
    bs = jnp.asarray(np.stack([b for _, b in chunks]))

    def fn(i):
        j = jnp.minimum(i, c - 1)
        live = i < c
        p = jax.lax.dynamic_index_in_dim(ps, j, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(bs, j, keepdims=False)
        return (jnp.where(live, p, 0.0), jnp.where(live, b, 0.0))

    return ChunkSource(n=host_src.n, k=host_src.k, chunk=host_src.chunk,
                       budgets=jnp.asarray(host_src.budgets), fn=fn)


def _assert_bitwise(a, b, what):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{what}: field {f} diverged")
    # Same-driver pairs carry matching fin_hist structure; the
    # cross-driver pair legitimately differs (the traced driver only
    # materialises finalize histograms on the postprocess path).
    if a.fin_hist is not None and b.fin_hist is not None:
        for x, y in zip(a.fin_hist, b.fin_hist):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{what}: fin_hist")


# ---------------------------------------------------------------------------
# Check bodies.
# ---------------------------------------------------------------------------

def check_host_parity(seed, tightness, band, floor=0.5, damping=0.5):
    """Screened host solve is bitwise the unscreened host solve; the
    derived per-row decisions match; soundness holds on the retired set."""
    src = _banded(seed, tightness, band)
    base = solve_streaming_host(src, _cfg(floor, damping), q=Q)
    scr = solve_streaming_host(src, _cfg(floor, damping, screening=True),
                               q=Q)
    _assert_bitwise(base, scr, f"host seed={seed}")
    assert scr.screen is not None and base.screen is None

    # Decisions — the served artifact — row-for-row via the shared
    # decision kernel (lam/tau being bitwise makes this a pinned
    # consequence; assert it directly anyway on a couple of chunks).
    tsrc = _traced_source(src)
    for i in (0, 1):
        xb, _ = decisions_chunk(tsrc, base.lam, Q, i, tau=base.tau)
        xs, _ = decisions_chunk(tsrc, scr.lam, Q, i, tau=scr.tau)
        np.testing.assert_array_equal(np.asarray(xb), np.asarray(xs))
    return scr


def check_soundness(scr_res, src, cfg):
    """Retired certificates (a) clear the floor ladder's lowest edge and
    (b) dominate an independent f64 recomputation of the chunk bytes."""
    stats = scr_res.screen
    active, bmax = stats["active"], stats["bmax"]
    e0 = lowest_edges(stats["lam_lo"], cfg)
    retired = np.flatnonzero(~active)
    assert retired.size, "workload retired nothing — check is vacuous"
    c = -(-src.n // src.chunk)
    for g in retired:
        assert np.all(bmax[g] <= e0), (g, bmax[g], e0)
        if g >= c:
            continue                       # padded slot: zero bytes
        p, b = src.fn(int(g))
        with np.errstate(divide="ignore", invalid="ignore"):
            true64 = np.where(b > 0, p.astype(np.float64)
                              / b.astype(np.float64), -np.inf).max(axis=0)
        # The stored f32 certificate must not understate the true ratio
        # by more than one f32 rounding step of the division (round to
        # nearest: |fl(x) - x| <= 0.5 ulp, so one f32 step up covers x).
        up32 = np.nextafter(bmax[g], np.float32(np.inf))
        assert np.all(up32.astype(np.float64) >= true64), (
            g, bmax[g], true64)
        kernel = np.asarray(chunk_bound(jnp.asarray(p), jnp.asarray(b)))
        np.testing.assert_array_equal(kernel, bmax[g])


def check_traced_parity_and_shrinkage(seed, tightness, band):
    """Traced screened == traced unscreened bitwise; active counts are
    non-increasing across iterations when no floor escape happened."""
    src = _traced_source(_banded(seed, tightness, band))
    base = solve_streaming(src, _cfg(), q=Q)
    scr = solve_streaming(src, _cfg(screening=True), q=Q)
    _assert_bitwise(base, scr, f"traced seed={seed}")
    counts = np.asarray(scr.screen["active_chunks"])
    counts = counts[counts >= 0]
    assert counts.size >= int(scr.iters)
    if int(np.asarray(scr.screen["resets"])) == 0:
        assert np.all(np.diff(counts) <= 0), counts
    return scr


# ---------------------------------------------------------------------------
# Deterministic twins (always run).
# ---------------------------------------------------------------------------

def test_host_parity_banded():
    cfg = _cfg(screening=True)
    src = _banded(11, 0.08, 0.05)
    scr = check_host_parity(11, 0.08, 0.05)
    # The workload is built to retire most chunks — the claim is not
    # vacuously "screening never fired".
    assert int(scr.screen["active"].sum()) < scr.screen["active"].size
    check_soundness(scr, src, cfg)


def test_host_parity_uniform_never_retires():
    """Uniform data: certificates never clear the ladder; screening must
    stream everything and stay bitwise (the degenerate no-op case)."""
    src = sparse_host_chunk_source(3, N, K, CHUNK, q=Q, tightness=0.4)
    base = solve_streaming_host(src, _cfg(), q=Q)
    scr = solve_streaming_host(src, _cfg(screening=True), q=Q)
    _assert_bitwise(base, scr, "uniform host")
    assert bool(scr.screen["active"].all())
    streamed = np.asarray(scr.screen["streamed_chunks"])
    c = -(-N // CHUNK)
    assert np.all(streamed == c), streamed


def test_traced_parity_and_monotone_shrinkage():
    scr = check_traced_parity_and_shrinkage(11, 0.08, 0.05)
    counts = np.asarray(scr.screen["active_chunks"])
    counts = counts[counts >= 0]
    assert counts[-1] < counts[0]          # really shrank


def test_host_traced_cross_driver_bitwise():
    """Screened host == screened traced == unscreened either: one
    equality chain across both drivers on the same bytes."""
    hsrc = _banded(5, 0.1, 0.05)
    tsrc = _traced_source(hsrc)
    rh = solve_streaming_host(hsrc, _cfg(screening=True), q=Q)
    rt = solve_streaming(tsrc, _cfg(screening=True), q=Q)
    _assert_bitwise(rh, rt, "host vs traced screened")


def test_seeded_screen_floor_never_lowers():
    """A delta-refresh seed must not drop the floor below the seed's —
    the inherited certificates were only certified down to it."""
    cfg = _cfg(screening=True)
    k = 3
    seed = {"active": np.array([False, True]),
            "bmax": np.zeros((2, k), np.float32),
            "lam_lo": np.full((k,), 2.0, np.float32)}
    hs = HostScreen(2, k, cfg, np.ones((k,), np.float32), seed=seed)
    assert np.all(hs.lam_lo >= 2.0)
    # ... and a warm start below that floor escapes (reactivates all)
    # rather than trusting the inherited retirement.
    ok = hs.begin_iter(np.ones((k,), np.float32))
    assert not ok and bool(hs.active.all()) and hs.resets == 1


# ---------------------------------------------------------------------------
# Hypothesis sweeps.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestScreeningSweep:
    @given(seed=st.integers(0, 2**31 - 1),
           tightness=st.floats(0.04, 0.15),
           band=st.floats(0.02, 0.2),
           floor=st.floats(0.25, 0.9),
           damping=st.sampled_from([1.0, 0.5, 0.25]))
    @settings(max_examples=8, deadline=None)
    def test_host_parity_sweep(self, seed, tightness, band, floor, damping):
        check_host_parity(seed, tightness, band, floor, damping)

    @given(seed=st.integers(0, 2**31 - 1),
           tightness=st.floats(0.04, 0.15),
           band=st.floats(0.02, 0.12))
    @settings(max_examples=5, deadline=None)
    def test_traced_parity_sweep(self, seed, tightness, band):
        check_traced_parity_and_shrinkage(seed, tightness, band)

    @given(seed=st.integers(0, 2**31 - 1),
           scale=st.floats(0.8, 1.25))
    @settings(max_examples=5, deadline=None)
    def test_budget_scale_parity(self, seed, scale):
        """Budget perturbations move the trajectory (and the crossing
        buckets) — parity must survive wherever the guard lands."""
        src = _banded(seed, 0.08, 0.05)
        src = src._replace(budgets=(src.budgets
                                    * np.float32(scale)).astype(np.float32))
        base = solve_streaming_host(src, _cfg(), q=Q)
        scr = solve_streaming_host(src, _cfg(screening=True), q=Q)
        _assert_bitwise(base, scr, f"budget scale {scale}")
