"""Tests for repro/launch/supervisor.py — the elastic supervision layer.

The coordinator state machine is exercised with *scripted fake workers*
(``worker_cmd`` override): tiny ``python -c`` subprocesses that speak
the lease file format directly without importing jax, so crash
restarts, lease-expiry hang takeovers, chaos injection, device
degradation and crash-loop containment all run in well under a second
of worker time each. One slow end-to-end test runs a real supervised
solve worker and pins the published record bitwise against an
in-process reference; the full soak (kills + stops + bitwise refresh
parity) is the ``--chaos-soak`` CI gate.
"""
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.launch.supervisor import (
    ChaosSchedule,
    Supervisor,
    SupervisorConfig,
    run_solve_task,
)
from repro.serve.engine import WorkloadSpec

# A scripted worker that renews leases without importing repro (or jax):
# argv = [python, -c, _FAKE, root, term, mode]. Modes:
#   ok            beat a few times, exit 0
#   crash-once    exit 5 in term 1, behave like "ok" afterwards
#   hang          beat once, then stop beating (SIGSTOP-shaped) forever
#   crash-always  exit 7 immediately
#   work          bump progress forever (chaos-injection target) in term
#                 1, behave like "ok" afterwards
_FAKE = r"""
import hashlib, json, os, sys, time
root, term, mode = sys.argv[1], int(sys.argv[2]), sys.argv[3]
path = os.path.join(root, "heartbeat.json")
seq = 0
def beat(progress):
    global seq
    seq += 1
    rec = dict(worker="fake", pid=os.getpid(), term=term, seq=seq,
               progress=progress, ttl=0.5, mono=time.monotonic(),
               wall=time.time())
    payload = json.dumps(rec, sort_keys=True).encode()
    data = payload + b"\n" + hashlib.sha256(payload).hexdigest().encode() \
        + b"\n"
    tmp = path + ".wtmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
if mode == "crash-always":
    sys.exit(7)
if mode == "crash-once" and term == 1:
    beat(0)
    sys.exit(5)
if mode == "hang" and term == 1:
    beat(0)
    time.sleep(3600)
if mode == "work" and term == 1:
    p = 0
    while True:
        p += 1
        beat(p)
        time.sleep(0.02)
for i in range(3):
    beat(i)
    time.sleep(0.05)
sys.exit(0)
"""


def _fake_cmd(mode):
    def cmd(root, term, devices):
        return [sys.executable, "-c", _FAKE, str(root), str(term), mode]
    return cmd


def _cfg(**kw):
    base = dict(ttl=0.4, poll=0.02, grace=5.0, max_restarts=4)
    base.update(kw)
    return SupervisorConfig(**base)


def test_clean_completion_publishes_done_status(tmp_path):
    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=4,
                     worker_cmd=_fake_cmd("ok"))
    out = sup.run()
    assert out["ok"] and out["spawns"] == 1 and out["restarts"] == 0
    status = ckpt.read_json(tmp_path, "SUPERVISOR.json")
    assert status["state"] == "done" and status["ok"]
    # The durable task intent was written before the first spawn.
    assert ckpt.read_json(tmp_path, "task.json") == {"kind": "noop",
                                                     "ttl": 0.4}


def test_crash_restart_resumes_on_degraded_devices(tmp_path):
    seen = []

    def cmd(root, term, devices):
        seen.append((term, devices))
        return [sys.executable, "-c", _FAKE, str(root), str(term),
                "crash-once"]

    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=4,
                     worker_cmd=cmd)
    out = sup.run()
    assert out["ok"] and out["crash_restarts"] == 1
    assert out["last_rc"] == 5
    assert out["degraded_spawns"] == 1
    assert seen == [(1, 4), (2, 2)], "respawn must halve the devices"
    # The respawn env forces the degraded device count on the child.
    env2 = sup._env(2)
    assert "--xla_force_host_platform_device_count=2" in env2["XLA_FLAGS"]


def test_hang_detected_by_lease_expiry_and_taken_over(tmp_path):
    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=2,
                     worker_cmd=_fake_cmd("hang"))
    t0 = time.monotonic()
    out = sup.run()
    took = time.monotonic() - t0
    assert out["ok"] and out["hang_takeovers"] == 1
    assert out["crash_restarts"] == 0, "a hang is not an exit-code crash"
    # Detected by lease expiry within the deadline, not by luck: the
    # takeover must land shortly after ttl, far under the fake's sleep.
    assert took < 30.0
    # The adoption was exclusively claimed at term 2.
    assert (tmp_path / "heartbeat.json.claim_00000002").exists()


def test_chaos_kill_fires_at_progress_threshold(tmp_path):
    sched = ChaosSchedule(seed=0, events=(("kill", 5),))
    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=2,
                     worker_cmd=_fake_cmd("work"), chaos=sched)
    out = sup.run()
    assert out["ok"]
    assert out["kills_injected"] == 1 and out["crash_restarts"] == 1


def test_chaos_stop_detected_as_hang(tmp_path):
    sched = ChaosSchedule(seed=0, events=(("stop", 5),))
    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=2,
                     worker_cmd=_fake_cmd("work"), chaos=sched)
    out = sup.run()
    assert out["ok"]
    assert out["stops_injected"] == 1
    assert out["hang_takeovers"] == 1, \
        "a SIGSTOPped worker must surface via lease expiry"


def test_crash_loop_budget_stamps_failed_and_stops(tmp_path):
    sup = Supervisor(tmp_path, {"kind": "noop"},
                     cfg=_cfg(max_restarts=2), devices=4,
                     worker_cmd=_fake_cmd("crash-always"))
    out = sup.run()
    assert not out["ok"]
    assert out["crash_restarts"] == 3          # initial + 2 budgeted
    failed = ckpt.read_json(tmp_path, "FAILED.json")
    assert failed is not None
    assert "budget" in failed["reason"]
    status = ckpt.read_json(tmp_path, "SUPERVISOR.json")
    assert status["state"] == "failed"


def test_schedule_plan_is_deterministic_and_interleaved():
    a = ChaosSchedule.plan(7, kills=2, stops=1, lo=10, hi=50)
    b = ChaosSchedule.plan(7, kills=2, stops=1, lo=10, hi=50)
    assert a.events == b.events
    kinds = [k for k, _ in a.events]
    assert kinds == ["kill", "stop", "kill"]
    assert all(10 <= at < 50 for _, at in a.events)
    assert a.events != ChaosSchedule.plan(8, 2, 1, 10, 50).events


def test_poisoned_worker_exits_before_heavy_imports(tmp_path):
    # The real --worker entry point, poisoned: must exit with the poison
    # code fast (it runs before any jax import) and never read task.json.
    env = dict(os.environ)
    env["REPRO_WORKER_POISON"] = "3"
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    rc = subprocess.run(
        [sys.executable, "-m", "repro.launch.supervisor",
         "--worker", str(tmp_path), "--term", "1"],
        env=env, timeout=60).returncode
    assert rc == 3


def test_next_term_skips_debris_from_previous_coordinators(tmp_path):
    sup = Supervisor(tmp_path, {"kind": "noop"}, cfg=_cfg(), devices=1,
                     worker_cmd=_fake_cmd("ok"))
    assert sup._next_term() == 1
    # Claim debris from a dead coordinator advances the term.
    (tmp_path / "heartbeat.json.claim_00000004").write_text("1\n")
    assert sup._next_term() == 5
    out = sup.run()                        # must claim term 5, not term 1
    assert out["ok"] and out["term"] == 5


@pytest.mark.slow
def test_supervised_solve_matches_inprocess_reference(tmp_path):
    """End to end with a real worker subprocess: the supervised result
    record is bitwise the in-process one."""
    spec = WorkloadSpec(seed=3, n=1024, k=4, chunk=256, q=1,
                        tightness=0.5)
    cfg = dict(reduce="bucketed", max_iters=12, checkpoint_every=4,
               bucket_half=16)
    task = {"kind": "solve", "spec": spec.to_json(), "cfg": cfg,
            "slots": 2}
    ref = run_solve_task(tmp_path / "ref", task)
    sup = Supervisor(tmp_path / "sup", task,
                     cfg=SupervisorConfig(ttl=5.0, poll=0.1, grace=300.0,
                                          max_restarts=2),
                     devices=1)
    out = sup.run()
    assert out["ok"], out
    got = ckpt.restore_auto(tmp_path / "sup" / "result", 0)
    for f in ["lam", "tau", "iters", "r", "primal", "dual"]:
        assert np.asarray(ref[f]).tobytes() \
            == np.asarray(got[f]).tobytes(), f
