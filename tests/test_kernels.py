"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus cross-checks of the oracles against the core solver modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(128, 8), (256, 16), (512, 64), (384, 10)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _inst(n, k, dtype, seed=0):
    kp, kb, kl = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.uniform(kp, (n, k), jnp.float32)
    b = jax.random.uniform(kb, (n, k), jnp.float32, 0.05, 1.0)
    lam = jax.random.uniform(kl, (k,), jnp.float32, 0.0, 1.5)
    return p.astype(dtype), b.astype(dtype), lam.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("q", [1, 2, 4])
def test_adjusted_topc_matches_ref(shape, dtype, q):
    n, k = shape
    p, b, lam = _inst(n, k, dtype)
    x_k, v_k = ops.adjusted_topc(p, b, lam, q, tile_n=128, interpret=True)
    x_r, v_r = ref.adjusted_topc_ref(p, b, lam, q)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_array_equal(np.asarray(x_k), np.asarray(x_r))
    np.testing.assert_allclose(
        np.asarray(v_k, np.float32), np.asarray(v_r, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("q", [1, 2, 4])
def test_scd_candidates_matches_ref(shape, dtype, q):
    n, k = shape
    p, b, lam = _inst(n, k, dtype, seed=1)
    v1_k, v2_k = ops.scd_candidates(p, b, lam, q, tile_n=128, interpret=True)
    v1_r, v2_r = ref.scd_candidates_ref(p, b, lam, q)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(v1_k, np.float32), np.asarray(v1_r, np.float32),
        rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(v2_k, np.float32), np.asarray(v2_r, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(256, 8), (512, 16)])
@pytest.mark.parametrize("n_edges", [17, 49])
def test_bucket_hist_matches_ref(shape, n_edges):
    n, k = shape
    p, b, lam = _inst(n, k, jnp.float32, seed=2)
    v1 = p / b
    v2 = b
    edges = jnp.sort(
        jax.random.uniform(jax.random.PRNGKey(5), (k, n_edges), jnp.float32,
                           0.0, 3.0), axis=-1)
    h_k = ops.bucket_hist(v1, v2, edges, tile_n=128, interpret=True)
    h_r = ref.bucket_hist_ref(v1, v2, edges)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)
    # total mass preserved
    np.testing.assert_allclose(float(h_k.sum()), float(v2.sum()), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), q=st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_kernel_refs_match_core_modules(seed, q):
    """The kernel oracles and the core solver must agree (same tie-breaks)."""
    from repro.core.sparse_scd import candidates_sparse, select_sparse

    kp_, kb, kl = jax.random.split(jax.random.PRNGKey(seed), 3)
    n, k = 64, 8
    p = jax.random.uniform(kp_, (n, k))
    b = jax.random.uniform(kb, (n, k), minval=0.05)
    lam = jax.random.uniform(kl, (k,), maxval=1.5)

    x_ref, _ = ref.adjusted_topc_ref(p, b, lam, q)
    x_core = select_sparse(p, b, lam, q)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_core))

    v1_ref, v2_ref = ref.scd_candidates_ref(p, b, lam, q)
    v1_core, v2_core = candidates_sparse(p, b, lam, q)
    np.testing.assert_allclose(np.asarray(v1_ref), np.asarray(v1_core), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2_ref), np.asarray(v2_core), rtol=1e-6)


def test_bucket_hist_accumulates_across_grid():
    """Multi-tile grid: the VMEM accumulator pattern must sum all tiles."""
    n, k, e = 1024, 4, 9
    v1 = jnp.tile(jnp.linspace(0.0, 2.0, n)[:, None], (1, k))
    v2 = jnp.ones((n, k))
    edges = jnp.tile(jnp.linspace(0.25, 1.75, e)[None, :], (k, 1))
    h = ops.bucket_hist(v1, v2, edges, tile_n=128, interpret=True)
    assert float(h.sum()) == pytest.approx(n * k)
    h1 = ops.bucket_hist(v1, v2, edges, tile_n=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h1), rtol=1e-6)


def test_solver_kernel_path_matches_jnp_path():
    """End-to-end: the solver with use_kernels=True (Pallas interpret mode)
    reproduces the jnp path's multipliers and primal."""
    from repro.core import SolverConfig, solve
    from repro.core.instances import shard_key, sparse_instance

    kp, q = sparse_instance(shard_key(33), n=512, k=8, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=6)
    a = solve(kp, cfg, q=q)
    b = solve(kp, cfg.replace(use_kernels=True), q=q)
    np.testing.assert_allclose(np.asarray(a.lam), np.asarray(b.lam),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.primal), float(b.primal), rtol=1e-5)
