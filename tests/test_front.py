"""The serving front: bitwise HTTP answers over replica processes.

The contract under test (DESIGN.md §13): a front answer IS a
DecisionService answer. Single lookups, batches, degraded-``stale``
rows and the cross-generation ``/diff`` must all be **bitwise-equal**
to direct in-process lookups against the same generations — the wire
(base64 of the exact row bytes), the round-robin, the replica RPC and
the pointer watcher may add latency but never change a bit.

The diff endpoint's cost model is pinned by counting fetches at the
source: "which of these users changed since generation g?" is one
grouped chunk pass per generation (lookup_batch's chunk grouping), and
a repeat against cached generations is zero passes.
"""
import json
import socket
import time
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.core import SolverConfig
from repro.launch.front import (_HTTPClient, spawn_replicas, stop_replicas)
from repro.serve import (DecisionService, Front, RefreshEngine,
                         ReplicaClient, ReplicaServer, WorkloadSpec,
                         synthetic_source)
from repro.serve.front import (decision_diff, pack_array, poisoned_factory,
                               recv_msg, send_msg, unpack_array)

jax.config.update("jax_platform_name", "cpu")

SPEC = WorkloadSpec(seed=5, n=1024, k=4, chunk=128, q=1, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=25, checkpoint_every=0)
SCALES = [1.0, 0.9]
CHUNKS = SPEC.n // SPEC.chunk


@pytest.fixture(scope="module")
def root(tmp_path_factory):
    """A generation root with two published generations + references."""
    path = tmp_path_factory.mktemp("front_root")
    eng = RefreshEngine(path, SPEC, cfg=CFG)
    gens, refs = [], []
    for s in SCALES:
        g = eng.refresh(budget_scale=s)
        svc = eng.decision_service(generation=g, fallback=False)
        gens.append(g)
        refs.append(svc.decide_batch(np.arange(SPEC.n)))
    return SimpleNamespace(path=path, engine=eng, gens=gens, refs=refs)


def _counting_source(spec):
    """A synthetic source whose per-chunk fetches are counted."""
    src = synthetic_source(spec)
    calls = []
    inner = src.fn

    def fn(i):
        calls.append(int(i))
        return inner(i)

    return src._replace(fn=fn), calls


# ---------------------------------------------------------------------------
# Wire format: exact bytes across the encoding and the framing.
# ---------------------------------------------------------------------------

def test_pack_array_roundtrip_is_bitwise():
    rng = np.random.default_rng(0)
    arrays = [rng.random(17).astype(np.float32),
              rng.integers(0, 2, (5, 3)).astype(bool),
              np.arange(12, dtype=np.int64)[::2],      # non-contiguous
              np.zeros((0, 4), bool)]                  # empty
    for a in arrays:
        b = unpack_array(json.loads(json.dumps(pack_array(a))))
        assert b.dtype == a.dtype and b.shape == a.shape
        assert b.tobytes() == np.ascontiguousarray(a).tobytes()


def test_framing_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    with a, b:
        msgs = [{"op": "ping"}, {"x": pack_array(np.arange(9) % 2 == 0)}]
        for m in msgs:
            send_msg(a, m)
        assert [recv_msg(b) for _ in msgs] == msgs
        a.close()
        assert recv_msg(b) is None           # clean close between messages


# ---------------------------------------------------------------------------
# decision_diff: brute-force parity, fetch-counted grouped passes.
# ---------------------------------------------------------------------------

def test_decision_diff_matches_brute_force_in_one_pass_per_gen(root):
    g0, g1 = root.gens
    src_new, calls_new = _counting_source(g1.spec)
    src_old, calls_old = _counting_source(g0.spec)
    new_svc = DecisionService(src_new, g1, cache_chunks=CHUNKS)
    old_svc = DecisionService(src_old, g0, cache_chunks=CHUNKS)

    users = np.concatenate([np.arange(0, SPEC.n, 13), [0, 0, 511]])
    spanned = len(np.unique(users // SPEC.chunk))
    d = decision_diff(new_svc, old_svc, users)

    brute = (root.refs[1][users] != root.refs[0][users]).any(axis=1)
    assert d["changed"].tobytes() == brute.tobytes()
    assert d["from_gen"] == g0.gen and d["to_gen"] == g1.gen
    assert d["compared"] == users.size and d["new_users"] == 0
    assert not d["stale"] and not d["k_changed"]
    # The cost claim, counted at the source: each generation regenerated
    # every spanned chunk exactly once — ONE grouped pass, not one fetch
    # per user (users.size >> spanned here).
    assert sorted(calls_new) == sorted(set(calls_new))
    assert len(calls_new) == spanned == len(calls_old)
    # Repeat against the (now cached) generations: zero further fetches.
    d2 = decision_diff(new_svc, old_svc, users)
    assert d2["changed"].tobytes() == brute.tobytes()
    assert len(calls_new) == spanned == len(calls_old)


def test_decision_diff_full_range_costs_exactly_all_chunks(root):
    g0, g1 = root.gens
    src_new, calls_new = _counting_source(g1.spec)
    src_old, calls_old = _counting_source(g0.spec)
    new_svc = DecisionService(src_new, g1, cache_chunks=CHUNKS)
    old_svc = DecisionService(src_old, g0, cache_chunks=CHUNKS)
    d = decision_diff(new_svc, old_svc, range(SPEC.n))
    brute = (root.refs[1] != root.refs[0]).any(axis=1)
    assert d["changed"].tobytes() == brute.tobytes()
    assert len(calls_new) == CHUNKS == len(calls_old)


def test_decision_diff_users_past_old_generation_are_changed(root, tmp_path):
    """Traffic growth: users the old generation never covered diff as
    changed (there is nothing to compare them against)."""
    eng = RefreshEngine(tmp_path / "grow", SPEC.replace(n=SPEC.n // 2),
                        cfg=CFG)
    small = eng.refresh(budget_scale=1.0)            # n = 512
    big = eng.refresh(budget_scale=1.0, n=SPEC.n)    # n = 1024
    new_svc = eng.decision_service(generation=big, fallback=False)
    old_svc = eng.decision_service(generation=small, fallback=False)
    users = np.array([0, 300, 511, 512, 1023])       # last two are new
    d = decision_diff(new_svc, old_svc, users)
    assert d["compared"] == 3 and d["new_users"] == 2
    assert d["changed"][3:].all()
    ref_new = new_svc.decide_batch(users[:3])
    ref_old = old_svc.decide_batch(users[:3])
    assert (d["changed"][:3] == (ref_new != ref_old).any(axis=1)).all()


def test_decision_diff_k_change_marks_everything_changed():
    """No row is comparable across a knapsack-count change — the diff
    short-circuits before any lookup."""
    mk = lambda k, gen: SimpleNamespace(  # noqa: E731
        generation=SimpleNamespace(spec=SimpleNamespace(k=k), gen=gen),
        source=SimpleNamespace(n=100))
    d = decision_diff(mk(8, 1), mk(4, 0), [1, 2, 3])
    assert d["k_changed"] and d["changed"].all()
    assert d["compared"] == 0 and d["new_users"] == 0


# ---------------------------------------------------------------------------
# Replica RPC + degraded-stale provenance over the wire.
# ---------------------------------------------------------------------------

def _start_replica(root_path, make_source=None, retries=0, index=0):
    cfg = SolverConfig(reduce="bucketed", fetch_retries=retries,
                       fetch_backoff=1e-5, fetch_backoff_cap=1e-4)
    kw = {} if make_source is None else {"make_source": make_source}
    eng = RefreshEngine.attach(root_path, cfg=cfg, **kw)
    rep = ReplicaServer(eng, index=index, cache_chunks=CHUNKS,
                        poll_s=0.02)
    port = rep.start()
    return rep, ReplicaClient("127.0.0.1", port)


def test_replica_rpc_lookup_and_batch_are_bitwise(root):
    rep, rc = _start_replica(root.path)
    try:
        live = root.gens[-1]
        r = rc.call({"op": "lookup", "user": 700})
        assert unpack_array(r["x"]).tobytes() == root.refs[-1][700].tobytes()
        assert not r["stale"] and r["gen"] == live.gen
        users = [5, 900, 5, 130, 1023]
        b = rc.call({"op": "decide_batch", "users": users})
        assert unpack_array(b["x"]).tobytes() == \
            root.refs[-1][np.asarray(users)].tobytes()
        assert not unpack_array(b["stale"]).any()
        assert (unpack_array(b["gens"]) == live.gen).all()
        # Out-of-range surfaces as a typed error payload, not a hangup.
        from repro.serve import FrontRPCError
        with pytest.raises(FrontRPCError) as ei:
            rc.call({"op": "lookup", "user": SPEC.n})
        assert ei.value.kind == "IndexError"
    finally:
        rc.close()
        rep.stop()


def test_replica_degraded_stale_answers_match_in_process(root):
    """The degraded path over the wire: the live generation's poisoned
    chunk exhausts its retries and the replica answers those users from
    the fallback generation, stale-flagged — bitwise what a direct
    in-process DecisionService with the same poisoned source serves."""
    poison_chunk = 3
    live_scale = SCALES[-1]
    make_source = poisoned_factory(synthetic_source, live_scale,
                                   poison_chunk)
    rep, rc = _start_replica(root.path, make_source=make_source, retries=1)
    try:
        # The in-process reference: same poisoned factory, same policy.
        ref_svc = rep.engine.decision_service(cache_chunks=CHUNKS)
        poisoned = poison_chunk * SPEC.chunk + 7
        healthy = 10
        ref_p, ref_h = ref_svc.lookup(poisoned), ref_svc.lookup(healthy)
        assert ref_p.stale and ref_p.gen == root.gens[0].gen   # sanity
        for user, ref in ((poisoned, ref_p), (healthy, ref_h)):
            r = rc.call({"op": "lookup", "user": user})
            assert unpack_array(r["x"]).tobytes() == ref.x.tobytes()
            assert r["stale"] == ref.stale and r["gen"] == ref.gen
        # Batched: per-row provenance flags exactly the poisoned chunk.
        users = np.array([healthy, poisoned, poisoned + 1, 999])
        b = rc.call({"op": "decide_batch", "users": users.tolist()})
        stale = unpack_array(b["stale"])
        gens = unpack_array(b["gens"])
        assert stale.tolist() == [False, True, True, False]
        assert gens.tolist() == [root.gens[1].gen, root.gens[0].gen,
                                 root.gens[0].gen, root.gens[1].gen]
        x = unpack_array(b["x"])
        expect = np.where(stale[:, None], root.refs[0][users],
                          root.refs[1][users])
        assert x.tobytes() == expect.tobytes()
        h = rc.call({"op": "health"})
        assert h["degraded"] and h["stale_serves"] >= 3
    finally:
        rc.close()
        rep.stop()


# ---------------------------------------------------------------------------
# Front: routing, aggregated health, failover.
# ---------------------------------------------------------------------------

def test_front_aggregated_health_and_failover(root):
    rep0, rc0 = _start_replica(root.path, index=0)
    rep1, rc1 = _start_replica(root.path, index=1)
    front = Front([rc0, rc1])
    host, port = front.start()
    cli = _HTTPClient(host, port)
    try:
        h = cli.get("/health")
        assert h["ok"] and h["agreement"]
        assert h["generations"] == [root.gens[-1].gen]
        assert [d["replica"]["index"] for d in h["replicas"]] == [0, 1]
        assert all(d["supervisor"] == {"status": "absent"}
                   for d in h["replicas"])
        # Kill replica 0; the round-robin must fail over, health must
        # report the dead replica without taking the endpoint down.
        rep0.stop()
        rc0.close()                     # drop pooled conns to the corpse
        time.sleep(0.05)
        for u in (1, 2, 3, 4):
            r = cli.get(f"/decide?user={u}")
            assert r["x"] == [int(v) for v in root.refs[-1][u]]
        h = cli.get("/health")
        assert not h["ok"]
        assert "error" in h["replicas"][0] and "error" not in h["replicas"][1]
        assert h["front"]["failovers"] >= 1
    finally:
        cli.close()
        front.shutdown()
        rep1.stop()
        rep0.stop()


# ---------------------------------------------------------------------------
# End to end: replica processes, HTTP front, live refresh, diff.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_front_over_replica_processes_tracks_refresh(root, tmp_path):
    """The full request path: spawned replica *processes* attach to a
    copied root, the front serves bitwise answers, a refresh published
    underneath flips every watcher, and /diff answers the
    cross-generation question against brute force."""
    import shutil

    work = tmp_path / "serve_root"
    shutil.copytree(root.path, work)
    eng = RefreshEngine(work, SPEC, cfg=CFG)
    procs, clients = spawn_replicas(work, 2, cache_chunks=CHUNKS)
    front = Front(clients)
    host, port = front.start()
    cli = _HTTPClient(host, port)
    try:
        live = root.gens[-1]
        users = list(range(0, SPEC.n, 7))
        b = cli.post("/decide_batch", {"users": users})
        assert unpack_array(b["x"]).tobytes() == \
            root.refs[-1][np.asarray(users)].tobytes()
        assert not unpack_array(b["stale"]).any()
        assert (unpack_array(b["gens"]) == live.gen).all()
        r = cli.get("/decide?user=321")
        assert r["x"] == [int(v) for v in root.refs[-1][321]]
        assert r["gen"] == live.gen and not r["stale"]

        # Publish a new generation; every replica's watcher must rebind.
        g2 = eng.refresh(budget_scale=0.8)
        ref2 = eng.decision_service(
            generation=g2, fallback=False).decide_batch(np.arange(SPEC.n))
        deadline = time.monotonic() + 30
        while True:
            h = cli.get("/health")
            if h["ok"] and h["generations"] == [g2.gen]:
                break
            assert time.monotonic() < deadline, f"never converged: {h}"
            time.sleep(0.05)
        assert all(d["replica"]["rebinds"] >= 1 for d in h["replicas"])
        b = cli.post("/decide_batch", {"users": users})
        assert unpack_array(b["x"]).tobytes() == \
            ref2[np.asarray(users)].tobytes()
        assert (unpack_array(b["gens"]) == g2.gen).all()

        # /diff against the previous generation, brute-force-checked,
        # on BOTH replicas (round-robin covers each).
        brute = (ref2 != root.refs[-1]).any(axis=1)
        for _ in range(2):
            d = cli.post("/diff", {"gen": live.gen,
                                   "users": list(range(SPEC.n))})
            assert unpack_array(d["changed"]).tobytes() == brute.tobytes()
            assert d["from_gen"] == live.gen and d["to_gen"] == g2.gen
            assert not d["stale"]
            assert d["fills"]["old"] == CHUNKS     # one grouped pass
        errs = cli.post("/diff", {"gen": live.gen,
                                  "users": list(range(SPEC.n))})
        assert errs["fills"] == {"new": 0, "old": 0}   # both cached now
    finally:
        cli.close()
        front.shutdown()
        stop_replicas(procs, clients)


def test_attach_requires_a_published_generation(tmp_path):
    with pytest.raises(ValueError, match="no live generation"):
        RefreshEngine.attach(tmp_path / "empty")
