"""Tests for repro/launch/env.py — environment assembly for workers.

Pure string/dict behaviour is tested directly; the in-process setters'
after-initialisation guard is tested against this process's already-
initialised JAX (every test session imports jax), which is exactly the
footgun the guard exists for.
"""
import os

import pytest

import jax

from repro.launch import env


def test_merged_flags_replaces_in_place_preserving_others():
    existing = "--a=1 --xla_force_host_platform_device_count=8 --b=2"
    out = env.merged_xla_flags(existing, env.DEVICE_COUNT_FLAG, 4)
    assert out == "--a=1 --xla_force_host_platform_device_count=4 --b=2"


def test_merged_flags_appends_when_absent_and_handles_empty():
    out = env.merged_xla_flags(None, env.DEVICE_COUNT_FLAG, 2)
    assert out == "--xla_force_host_platform_device_count=2"
    out = env.merged_xla_flags("--a=1", "--b", "x")
    assert out == "--a=1 --b=x"


def test_host_device_flags_rejects_nonpositive():
    with pytest.raises(ValueError):
        env.host_device_flags(0)


def test_worker_env_pins_platform_and_devices_without_mutating_base():
    base = {"PYTHONPATH": "/x", "XLA_FLAGS": "--a=1"}
    out = env.worker_env(3, base=base, platform="cpu")
    assert out["JAX_PLATFORMS"] == "cpu"
    assert "--a=1" in out["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=3" in out["XLA_FLAGS"]
    assert out["PYTHONPATH"] == "/x"
    assert base == {"PYTHONPATH": "/x", "XLA_FLAGS": "--a=1"}, \
        "worker_env must return a copy"


def test_worker_env_defaults_to_os_environ():
    out = env.worker_env(2)
    assert out["JAX_PLATFORMS"] == "cpu"
    # Inherits unrelated variables from the real environment.
    assert out.get("PATH") == os.environ.get("PATH")


def test_setters_raise_after_jax_initialised():
    jax.devices()                       # force backend initialisation
    with pytest.raises(RuntimeError, match="worker_env"):
        env.set_host_device_count(4)
    with pytest.raises(RuntimeError, match="worker_env"):
        env.set_platform("cpu")


def test_describe_reports_effective_environment():
    jax.devices()
    d = env.describe()
    assert d["jax_imported"] is True
    assert d["pid"] == os.getpid()
    assert d["platform"] == jax.default_backend()
    assert d["device_count"] == jax.device_count()
    assert isinstance(d["x64"], bool)


def test_enable_x64_round_trip():
    try:
        env.enable_x64(True)
        assert jax.config.read("jax_enable_x64") is True
    finally:
        env.enable_x64(False)
    assert jax.config.read("jax_enable_x64") is False
