"""Chunked / out-of-core solve == unchunked oracle, bit for bit.

The contract under test (core/solver.py module docstring): with the SCD
bucketed reduce, chunking the per-iteration map — any chunk size,
including 1, ragged final chunks and chunk >= n — produces a SolveResult
bit-identical to the unchunked solve, because the histogram accumulation
is carry-seeded (same f32 additions in the same order). The kernel path
additionally requires the same tile decomposition on both sides
(cfg.kernel_tile pins it). The streaming driver (core/chunked.py) must
match the same oracle on lam/iters and reconstruct the identical primal
via decisions_chunk. DD chunked is reduce-order-level, not bitwise.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverConfig, solve
from repro.core.bucketing import bucket_histogram, make_edges
from repro.core.chunked import array_source, decisions_chunk, solve_streaming
from repro.core.instances import shard_key, sparse_instance, dense_instance
from repro.core.sparse_scd import candidates_sparse
from repro.data.synth import sparse_chunk_source

jax.config.update("jax_platform_name", "cpu")


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.lam), np.asarray(b.lam))
    assert int(a.iters) == int(b.iters)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    np.testing.assert_array_equal(np.asarray(a.r), np.asarray(b.r))
    assert float(a.primal) == float(b.primal)
    assert float(a.dual) == float(b.dual)


# ---------------------------------------------------------------------------
# bucket_histogram: the carry-seeded scatter is the bitwise foundation.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 7, 64, 500, 1021, 4096])
def test_seeded_histogram_chunking_invariant(chunk):
    """Chunked scatter-add onto the carry == one scatter over all rows."""
    kp, q = sparse_instance(shard_key(3), n=1021, k=8, q=2, tightness=0.4)
    lam = jnp.full((8,), 0.7)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    v1, v2 = candidates_sparse(kp.p, kp.b, lam, q)
    whole = bucket_histogram(v1, v2, edges)
    acc = jnp.zeros_like(whole)
    for i in range(0, 1021, chunk):
        acc = bucket_histogram(v1[i:i + chunk], v2[i:i + chunk], edges,
                               init=acc)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(whole))


# ---------------------------------------------------------------------------
# cfg.chunk_size: in-memory chunked solve vs the unchunked oracle.
# ---------------------------------------------------------------------------

# 1021 is prime: every chunk size below exercises a ragged final chunk.
@pytest.mark.parametrize("chunk", [1, 7, 256, 1021, 4096])
def test_chunked_solve_bit_identical_sparse(chunk):
    """chunk = 1, ragged tails, chunk == n and chunk >= n, all bitwise."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=chunk), q=q),
                        solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_kernels():
    """Kernel path: same tile on both sides -> bitwise, incl. ragged."""
    kp, q = sparse_instance(shard_key(7), n=509, k=8, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=10, use_kernels=True,
                       kernel_tile=128)
    for chunk in [128, 256, 1024]:   # multiples of the pinned tile
        _assert_same_result(solve(kp, cfg.replace(chunk_size=chunk), q=q),
                            solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_kernels_chunk1():
    """chunk = 1 on the kernel path: tile 1 on both sides."""
    kp, q = sparse_instance(shard_key(5), n=48, k=6, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=6, use_kernels=True,
                       kernel_tile=1)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=1), q=q),
                        solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_dense():
    """Dense (Alg 3 map) chunking is bitwise too."""
    kp = dense_instance(shard_key(6), n=130, m=6, k=4, local="C223",
                        tightness=0.25)
    cfg = SolverConfig(reduce="bucketed", max_iters=10)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=32), q=0),
                        solve(kp, cfg, q=0))


def test_chunked_dd_matches_to_reduce_order():
    """DD's consumption sum groups by chunk: allclose, documented non-bitwise."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(algo="dd", max_iters=10, dd_lr=2e-3)
    a = solve(kp, cfg, q=q)
    b = solve(kp, cfg.replace(chunk_size=100), q=q)
    np.testing.assert_allclose(np.asarray(a.lam), np.asarray(b.lam),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.primal), float(b.primal), rtol=1e-5)


def test_chunked_exact_reduce_rejected():
    """The exact reduce must see every candidate: chunking raises."""
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    with pytest.raises(ValueError, match="bucketed"):
        solve(kp, SolverConfig(reduce="exact", chunk_size=16), q=q)


# ---------------------------------------------------------------------------
# Streaming driver: nothing O(n) on device.
# ---------------------------------------------------------------------------

def test_streaming_matches_resident_bitwise():
    """array_source streaming == resident solve on lam/iters, any chunking."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    base = solve(kp, cfg, q=q)
    for chunk in [100, 256, 2048]:   # ragged tail / mid / single chunk
        sr = solve_streaming(array_source(kp, chunk), cfg, q=q)
        np.testing.assert_array_equal(np.asarray(sr.lam), np.asarray(base.lam))
        assert int(sr.iters) == int(base.iters)
        np.testing.assert_allclose(float(sr.dual), float(base.dual),
                                   rtol=1e-6)
        # §5.4 differs by design: bucketed (conservative) vs exact sort.
        assert np.all(np.asarray(sr.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
        np.testing.assert_allclose(float(sr.primal), float(base.primal),
                                   rtol=2e-2)


def test_streaming_kernels_matches_resident_chunked():
    """Fused-kernel streaming == resident chunked kernels, pinned tile."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=10, use_kernels=True,
                       kernel_tile=128)
    res = solve(kp, cfg.replace(chunk_size=256), q=q)
    sr = solve_streaming(array_source(kp, 256), cfg, q=q)
    np.testing.assert_array_equal(np.asarray(sr.lam), np.asarray(res.lam))
    assert int(sr.iters) == int(res.iters)


def test_streaming_decisions_reconstruct_primal():
    """decisions_chunk streams out exactly the solution the solve scored."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    src = array_source(kp, 256)
    sr = solve_streaming(src, cfg, q=q)
    primal, r = 0.0, jnp.zeros((10,))
    for i in range(math.ceil(1021 / 256)):
        x, valid = decisions_chunk(src, sr.lam, q, i, tau=sr.tau)
        p_c, b_c = src.fn(jnp.int32(i))
        primal += float(jnp.sum(jnp.where(x, p_c, 0.0)))
        r = r + jnp.sum(b_c * x.astype(b_c.dtype), axis=0)
    np.testing.assert_allclose(primal, float(sr.primal), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(sr.r), rtol=1e-5)


def test_streaming_synth_source_never_materialises():
    """Generated source solves at quality on n far beyond the chunk size."""
    src = sparse_chunk_source(0, n=100_000, k=8, chunk=4096, q=1,
                              tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=15)
    res = solve_streaming(src, cfg, q=1)
    assert int(res.iters) < 15
    assert np.all(np.asarray(res.r) <= np.asarray(src.budgets) * (1 + 1e-4))
    gap = float((res.dual - res.primal) / res.primal)
    assert 0 <= gap < 0.01


def test_streaming_rejects_exact_and_history():
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    src = array_source(kp, 16)
    with pytest.raises(ValueError, match="bucketed"):
        solve_streaming(src, SolverConfig(reduce="exact"), q=q)
    with pytest.raises(ValueError, match="record_history"):
        solve_streaming(src, SolverConfig(record_history=True), q=q)
