"""Chunked / out-of-core solve == unchunked oracle, bit for bit.

The contract under test (core/solver.py module docstring): with the SCD
bucketed reduce, chunking the per-iteration map — any chunk size,
including 1, ragged final chunks and chunk >= n — produces a SolveResult
bit-identical to the unchunked solve, because the histogram accumulation
is carry-seeded (same f32 additions in the same order). The kernel path
additionally requires the same tile decomposition on both sides
(cfg.kernel_tile pins it). The streaming driver (core/chunked.py) must
match the same oracle on lam/iters and reconstruct the identical primal
via decisions_chunk. DD chunked is reduce-order-level, not bitwise.

Pass accounting (DESIGN.md §5c): a converged streaming solve touches the
source exactly ``iters + 1`` times with the fused finalize and
``iters + 3`` with the legacy one — counted at runtime by a traced
source-call counter (io_callback) — and the host-fed driver
(core/prefetch.py) must be bit-identical to the traced one, double
buffered or not.
"""
import hashlib
import math
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import io_callback

from repro.core import SolverConfig, solve
from repro.core.bucketing import bucket_histogram, make_edges
from repro.core.chunked import array_source, decisions_chunk, solve_streaming
from repro.core.instances import shard_key, sparse_instance, dense_instance
from repro.core.postprocess import profit_edges_fixed
from repro.core.prefetch import (
    host_array_source,
    memmap_source,
    solve_streaming_host,
)
from repro.core.sparse_scd import candidates_sparse
from repro.data.synth import sparse_chunk_source

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent


class CountingSource:
    """Wrap a ChunkSource with a *runtime* source-call counter.

    ``fn`` is traced once, but an (unordered) io_callback fires on every
    execution — including inside lax.scan and lax.while_loop — so
    ``calls`` counts actual chunk fetches, and ``passes`` converts that
    to full sweeps over the source. ``jax.effects_barrier()`` flushes
    in-flight callbacks before reading.
    """

    def __init__(self, src):
        self.calls = 0
        inner = src.fn

        def _bump(_):
            self.calls += 1
            return np.int32(0)

        def fn(i):
            io_callback(_bump, jax.ShapeDtypeStruct((), np.int32), i,
                        ordered=False)
            return inner(i)

        self.source = src._replace(fn=fn)

    def passes(self, n_chunks):
        jax.effects_barrier()
        assert self.calls % n_chunks == 0, (self.calls, n_chunks)
        return self.calls // n_chunks


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.lam), np.asarray(b.lam))
    assert int(a.iters) == int(b.iters)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    np.testing.assert_array_equal(np.asarray(a.r), np.asarray(b.r))
    assert float(a.primal) == float(b.primal)
    assert float(a.dual) == float(b.dual)


# ---------------------------------------------------------------------------
# bucket_histogram: the carry-seeded scatter is the bitwise foundation.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 7, 64, 500, 1021, 4096])
def test_seeded_histogram_chunking_invariant(chunk):
    """Chunked scatter-add onto the carry == one scatter over all rows."""
    kp, q = sparse_instance(shard_key(3), n=1021, k=8, q=2, tightness=0.4)
    lam = jnp.full((8,), 0.7)
    edges = make_edges(lam, 1e-4, 1.6, 24)
    v1, v2 = candidates_sparse(kp.p, kp.b, lam, q)
    whole = bucket_histogram(v1, v2, edges)
    acc = jnp.zeros_like(whole)
    for i in range(0, 1021, chunk):
        acc = bucket_histogram(v1[i:i + chunk], v2[i:i + chunk], edges,
                               init=acc)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(whole))


# ---------------------------------------------------------------------------
# cfg.chunk_size: in-memory chunked solve vs the unchunked oracle.
# ---------------------------------------------------------------------------

# 1021 is prime: every chunk size below exercises a ragged final chunk.
@pytest.mark.parametrize("chunk", [1, 7, 256, 1021, 4096])
def test_chunked_solve_bit_identical_sparse(chunk):
    """chunk = 1, ragged tails, chunk == n and chunk >= n, all bitwise."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=chunk), q=q),
                        solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_kernels():
    """Kernel path: same tile on both sides -> bitwise, incl. ragged."""
    kp, q = sparse_instance(shard_key(7), n=509, k=8, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=10, use_kernels=True,
                       kernel_tile=128)
    for chunk in [128, 256, 1024]:   # multiples of the pinned tile
        _assert_same_result(solve(kp, cfg.replace(chunk_size=chunk), q=q),
                            solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_kernels_chunk1():
    """chunk = 1 on the kernel path: tile 1 on both sides."""
    kp, q = sparse_instance(shard_key(5), n=48, k=6, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=6, use_kernels=True,
                       kernel_tile=1)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=1), q=q),
                        solve(kp, cfg, q=q))


def test_chunked_solve_bit_identical_dense():
    """Dense (Alg 3 map) chunking is bitwise too."""
    kp = dense_instance(shard_key(6), n=130, m=6, k=4, local="C223",
                        tightness=0.25)
    cfg = SolverConfig(reduce="bucketed", max_iters=10)
    _assert_same_result(solve(kp, cfg.replace(chunk_size=32), q=0),
                        solve(kp, cfg, q=0))


def test_chunked_dd_matches_to_reduce_order():
    """DD's consumption sum groups by chunk: allclose, documented non-bitwise."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(algo="dd", max_iters=10, dd_lr=2e-3)
    a = solve(kp, cfg, q=q)
    b = solve(kp, cfg.replace(chunk_size=100), q=q)
    np.testing.assert_allclose(np.asarray(a.lam), np.asarray(b.lam),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.primal), float(b.primal), rtol=1e-5)


def test_chunked_exact_reduce_rejected():
    """The exact reduce must see every candidate: chunking raises."""
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    with pytest.raises(ValueError, match="bucketed"):
        solve(kp, SolverConfig(reduce="exact", chunk_size=16), q=q)


# ---------------------------------------------------------------------------
# Streaming driver: nothing O(n) on device.
# ---------------------------------------------------------------------------

def test_streaming_matches_resident_bitwise():
    """array_source streaming == resident solve on lam/iters, any chunking."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    base = solve(kp, cfg, q=q)
    for chunk in [100, 256, 2048]:   # ragged tail / mid / single chunk
        sr = solve_streaming(array_source(kp, chunk), cfg, q=q)
        np.testing.assert_array_equal(np.asarray(sr.lam), np.asarray(base.lam))
        assert int(sr.iters) == int(base.iters)
        np.testing.assert_allclose(float(sr.dual), float(base.dual),
                                   rtol=1e-6)
        # §5.4 differs by design: bucketed (conservative) vs exact sort.
        assert np.all(np.asarray(sr.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
        np.testing.assert_allclose(float(sr.primal), float(base.primal),
                                   rtol=2e-2)


def test_streaming_kernels_matches_resident_chunked():
    """Fused-kernel streaming == resident chunked kernels, pinned tile."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=10, use_kernels=True,
                       kernel_tile=128)
    res = solve(kp, cfg.replace(chunk_size=256), q=q)
    sr = solve_streaming(array_source(kp, 256), cfg, q=q)
    np.testing.assert_array_equal(np.asarray(sr.lam), np.asarray(res.lam))
    assert int(sr.iters) == int(res.iters)


def test_streaming_decisions_reconstruct_primal():
    """decisions_chunk streams out exactly the solution the solve scored."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    src = array_source(kp, 256)
    sr = solve_streaming(src, cfg, q=q)
    primal, r = 0.0, jnp.zeros((10,))
    for i in range(math.ceil(1021 / 256)):
        x, valid = decisions_chunk(src, sr.lam, q, i, tau=sr.tau)
        p_c, b_c = src.fn(jnp.int32(i))
        primal += float(jnp.sum(jnp.where(x, p_c, 0.0)))
        r = r + jnp.sum(b_c * x.astype(b_c.dtype), axis=0)
    np.testing.assert_allclose(primal, float(sr.primal), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(sr.r), rtol=1e-5)


def test_streaming_synth_source_never_materialises():
    """Generated source solves at quality on n far beyond the chunk size."""
    src = sparse_chunk_source(0, n=100_000, k=8, chunk=4096, q=1,
                              tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=15)
    res = solve_streaming(src, cfg, q=1)
    assert int(res.iters) < 15
    assert np.all(np.asarray(res.r) <= np.asarray(src.budgets) * (1 + 1e-4))
    gap = float((res.dual - res.primal) / res.primal)
    assert 0 <= gap < 0.01


def test_streaming_rejects_exact_and_history():
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    src = array_source(kp, 16)
    with pytest.raises(ValueError, match="bucketed"):
        solve_streaming(src, SolverConfig(reduce="exact"), q=q)
    with pytest.raises(ValueError, match="record_history"):
        solve_streaming(src, SolverConfig(record_history=True), q=q)


# ---------------------------------------------------------------------------
# Pass accounting: iters + 1 fused vs iters + 3 legacy (DESIGN.md §5c).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("finalize,extra", [("fused", 1), ("legacy", 3)])
def test_streaming_pass_counts(finalize, extra):
    """A converged solve touches the source iters + 1 (fused) times."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=8, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20,
                       stream_finalize=finalize)
    cs = CountingSource(array_source(kp, 256))
    res = solve_streaming(cs.source, cfg, q=q)
    iters = int(res.iters)
    assert 0 < iters < 20          # converged: the while_loop exited early
    assert cs.passes(math.ceil(1021 / 256)) == iters + extra


@pytest.mark.parametrize("finalize,extra", [("fused", 1), ("legacy", 3)])
def test_host_streaming_pass_counts(finalize, extra):
    """The host-fed epoch driver performs the same pass counts."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=8, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20,
                       stream_finalize=finalize)
    src = host_array_source(np.asarray(kp.p), np.asarray(kp.b),
                            np.asarray(kp.budgets), 256)
    calls = {"n": 0}
    inner = src.fn

    def fn(i):
        calls["n"] += 1
        return inner(i)

    res = solve_streaming_host(src._replace(fn=fn), cfg, q=q)
    iters = int(res.iters)
    assert 0 < iters < 20
    assert calls["n"] == (iters + extra) * math.ceil(1021 / 256)


# ---------------------------------------------------------------------------
# Fused finalize: parity with the legacy three-pass path and the kernel.
# ---------------------------------------------------------------------------

def test_fused_finalize_metrics_bitwise_vs_legacy():
    """Without §5.4 both finalizes are one metrics reduction: bitwise."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20, postprocess=False)
    fused = solve_streaming(array_source(kp, 256), cfg, q=q)
    legacy = solve_streaming(array_source(kp, 256),
                             cfg.replace(stream_finalize="legacy"), q=q)
    for f, l in zip(fused[:6], legacy[:6]):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(l))


def test_fused_finalize_postprocess_close_to_legacy():
    """With §5.4 the ladders differ (fixed geometric vs data-dependent):
    lam/iters/dual stay bitwise, the projected primal/r agree closely,
    and both projections are feasible."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    fused = solve_streaming(array_source(kp, 256), cfg, q=q)
    legacy = solve_streaming(array_source(kp, 256),
                             cfg.replace(stream_finalize="legacy"), q=q)
    np.testing.assert_array_equal(np.asarray(fused.lam),
                                  np.asarray(legacy.lam))
    assert int(fused.iters) == int(legacy.iters)
    assert float(fused.dual) == float(legacy.dual)
    for res in (fused, legacy):
        assert np.all(np.asarray(res.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
    np.testing.assert_allclose(float(fused.primal), float(legacy.primal),
                               rtol=1e-2)


@pytest.mark.parametrize("chunk", [100, 256, 2048])
def test_fused_finalize_bitwise_across_chunkings(chunk):
    """The fused tau / projected (r, primal) are histogram-prefix derived
    — carry-seeded scatters — so they are bitwise invariant to the
    chunking, unlike the legacy apply-pass re-sums."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    base = solve_streaming(array_source(kp, 256), cfg, q=q)
    other = solve_streaming(array_source(kp, chunk), cfg, q=q)
    np.testing.assert_array_equal(np.asarray(base.lam), np.asarray(other.lam))
    assert float(base.tau) == float(other.tau)


def test_finalize_kernel_matches_ref_ragged():
    """scd_finalize_hist == its jnp oracle on a prime-n (ragged) shard."""
    from repro.kernels import ops as kops
    from repro.kernels import ref

    rng = np.random.default_rng(7)
    n, k, q = 509, 8, 2
    p = jnp.asarray(rng.uniform(size=(n, k)), jnp.float32)
    b = jnp.asarray(rng.uniform(size=(n, k)), jnp.float32)
    lam = jnp.asarray(rng.uniform(0.2, 1.0, size=(k,)), jnp.float32)
    pedges = profit_edges_fixed(64)
    out_k = kops.scd_finalize_hist(p, b, lam, pedges, q, tile_n=128)
    out_r = ref.scd_finalize_ref(p, b, lam, pedges, q)
    for name, a, c in zip(["ch", "gh", "r", "primal", "dual", "lo", "hi"],
                          out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-6,
                                   atol=1e-6, err_msg=name)
    # metrics-only variant
    mk = kops.scd_finalize_hist(p, b, lam, pedges, q, tile_n=128,
                                with_hist=False)
    mr = ref.scd_finalize_ref(p, b, lam, pedges, q, with_hist=False)
    assert mk[0] is None and mk[1] is None
    for name, a, c in zip(["r", "primal", "dual", "lo", "hi"], mk[2:], mr[2:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-6,
                                   err_msg=name)


def test_finalize_kernel_seeded_chunking_bitwise():
    """Seeded finalize accumulation over chunks == one whole-shard call,
    bit for bit (same tile) — the kernel-path §5c contract."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(3)
    n, k, q = 512, 6, 1
    p = jnp.asarray(rng.uniform(size=(n, k)), jnp.float32)
    b = jnp.asarray(rng.uniform(size=(n, k)), jnp.float32)
    lam = jnp.asarray(rng.uniform(0.2, 1.0, size=(k,)), jnp.float32)
    pedges = profit_edges_fixed(64)
    nb = pedges.shape[0] + 1
    acc = (jnp.zeros((k, nb), jnp.float32), jnp.zeros((nb,), jnp.float32),
           jnp.zeros((k,), jnp.float32), jnp.zeros((), jnp.float32),
           jnp.zeros((), jnp.float32), jnp.asarray(jnp.inf),
           jnp.asarray(-jnp.inf))
    ch, gh, r, pr, du, lo, hi = acc
    for i in range(0, n, 128):
        ch, gh, r, pr, du, lo, hi = kops.scd_finalize_hist(
            p[i:i + 128], b[i:i + 128], lam, pedges, q, tile_n=128,
            cons_hist_init=ch, gain_hist_init=gh, r_init=r,
            sums_init=jnp.stack([pr, du]), maxs_init=jnp.stack([hi, -lo]))
    whole = kops.scd_finalize_hist(p, b, lam, pedges, q, tile_n=128)
    for name, a, c in zip(["ch", "gh", "r", "primal", "dual", "lo", "hi"],
                          (ch, gh, r, pr, du, lo, hi), whole):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c),
                                      err_msg=name)


def test_fused_finalize_kernel_path_streaming():
    """use_kernels streaming: lam bitwise vs resident chunked (pinned
    tile), finalize outputs allclose to the jnp streaming path."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=10, use_kernels=True,
                       kernel_tile=128)
    res = solve(kp, cfg.replace(chunk_size=256), q=q)
    sk = solve_streaming(array_source(kp, 256), cfg, q=q)
    np.testing.assert_array_equal(np.asarray(sk.lam), np.asarray(res.lam))
    assert int(sk.iters) == int(res.iters)
    sj = solve_streaming(array_source(kp, 256),
                         cfg.replace(use_kernels=False), q=q)
    np.testing.assert_allclose(np.asarray(sk.r), np.asarray(sj.r), rtol=1e-5)
    np.testing.assert_allclose(float(sk.primal), float(sj.primal), rtol=1e-5)
    assert np.all(np.asarray(sk.r) <= np.asarray(kp.budgets) * (1 + 1e-4))


# ---------------------------------------------------------------------------
# record_history when streaming: actionable error / metrics_every sampling.
# ---------------------------------------------------------------------------

def test_streaming_history_error_names_workarounds():
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    src = array_source(kp, 16)
    with pytest.raises(ValueError) as exc:
        solve_streaming(src, SolverConfig(record_history=True), q=q)
    msg = str(exc.value)
    assert "metrics_every" in msg          # the sampling workaround
    assert "resident" in msg               # ... or solve resident


def test_streaming_metrics_every_samples_history():
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    base = solve_streaming(array_source(kp, 256), cfg, q=q)
    rh = solve_streaming(
        array_source(kp, 256),
        cfg.replace(record_history=True, metrics_every=3), q=q)
    # scan and while drivers share the step fn: trajectories bitwise.
    np.testing.assert_array_equal(np.asarray(rh.lam), np.asarray(base.lam))
    assert int(rh.iters) == int(base.iters)
    h = rh.history
    assert sorted(h) == ["dual", "gap", "lam", "max_violation", "primal"]
    prim = np.asarray(h["primal"])
    assert prim.shape == (20,)
    finite = np.isfinite(prim)
    assert finite[0] and finite[3] and not finite[1]   # every 3rd sampled
    assert np.all(np.isfinite(np.asarray(h["lam"])))   # lam on every row
    # a converged sample evaluates the final metrics
    last = np.flatnonzero(finite)[-1]
    assert np.isfinite(np.asarray(h["dual"])[last])


# ---------------------------------------------------------------------------
# Host-fed sources (core/prefetch.py): bitwise vs the traced driver.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("double_buffer", [True, False])
def test_host_streaming_bitwise_vs_device(double_buffer):
    """Double-buffered or synchronous, the host-fed solve reproduces the
    traced array_source solve bit for bit, field for field."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    dev = solve_streaming(array_source(kp, 256), cfg, q=q)
    host = solve_streaming_host(
        host_array_source(np.asarray(kp.p), np.asarray(kp.b),
                          np.asarray(kp.budgets), 256),
        cfg, q=q, double_buffer=double_buffer)
    for f in ["lam", "iters", "r", "primal", "dual", "tau"]:
        np.testing.assert_array_equal(np.asarray(getattr(host, f)),
                                      np.asarray(getattr(dev, f)), err_msg=f)


def test_host_streaming_dd_and_legacy_bitwise():
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    hsrc = host_array_source(np.asarray(kp.p), np.asarray(kp.b),
                             np.asarray(kp.budgets), 256)
    for cfg in [SolverConfig(algo="dd", max_iters=10, dd_lr=2e-3),
                SolverConfig(reduce="bucketed", max_iters=20,
                             stream_finalize="legacy")]:
        dev = solve_streaming(array_source(kp, 256), cfg, q=q)
        host = solve_streaming_host(hsrc, cfg, q=q)
        for f in ["lam", "iters", "r", "primal", "dual", "tau"]:
            np.testing.assert_array_equal(np.asarray(getattr(host, f)),
                                          np.asarray(getattr(dev, f)),
                                          err_msg=f)


def test_memmap_source_streams_from_disk(tmp_path):
    """Raw on-disk files, memory-mapped: same solve as in-memory host."""
    kp, q = sparse_instance(shard_key(4), n=777, k=6, q=1, tightness=0.4)
    p = np.asarray(kp.p, np.float32)
    b = np.asarray(kp.b, np.float32)
    p_path, b_path = tmp_path / "p.bin", tmp_path / "b.bin"
    p.tofile(p_path)
    b.tofile(b_path)
    src = memmap_source(p_path, b_path, 777, 6, np.asarray(kp.budgets), 128)
    cfg = SolverConfig(reduce="bucketed", max_iters=15)
    res = solve_streaming_host(src, cfg, q=q)
    ref = solve_streaming_host(
        host_array_source(p, b, np.asarray(kp.budgets), 128), cfg, q=q)
    for f in ["lam", "iters", "r", "primal", "dual", "tau"]:
        np.testing.assert_array_equal(np.asarray(getattr(res, f)),
                                      np.asarray(getattr(ref, f)), err_msg=f)


def test_host_streaming_rejects_cyclic_and_unsampled_history():
    kp, q = sparse_instance(shard_key(4), n=64, k=4, q=1, tightness=0.4)
    src = host_array_source(np.asarray(kp.p), np.asarray(kp.b),
                            np.asarray(kp.budgets), 16)
    with pytest.raises(ValueError, match="cyclic"):
        solve_streaming_host(src, SolverConfig(cd_mode="cyclic"), q=q)
    # Unsampled history would re-scan the source every iteration: same
    # rejection as the traced driver. Sampled history works (below).
    with pytest.raises(ValueError, match="record_history"):
        solve_streaming_host(src, SolverConfig(record_history=True), q=q)


def test_host_streaming_metrics_every_matches_traced_bitwise():
    """Host-fed sampled history == the traced solve_streaming history at
    the same cfg.metrics_every, bitwise: live sampled rows, NaN rows and
    the frozen converged tail (ROADMAP leftover, ported in PR 4)."""
    kp, q = sparse_instance(shard_key(4), n=1021, k=10, q=2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20,
                       record_history=True, metrics_every=3)
    dev = solve_streaming(array_source(kp, 256), cfg, q=q)
    host = solve_streaming_host(
        host_array_source(np.asarray(kp.p), np.asarray(kp.b),
                          np.asarray(kp.budgets), 256), cfg, q=q)
    for f in ["lam", "iters", "r", "primal", "dual", "tau"]:
        np.testing.assert_array_equal(np.asarray(getattr(host, f)),
                                      np.asarray(getattr(dev, f)), err_msg=f)
    assert sorted(host.history) == sorted(dev.history)
    for key in dev.history:
        a, b = np.asarray(host.history[key]), np.asarray(dev.history[key])
        assert a.shape == b.shape, key
        np.testing.assert_array_equal(a, b, err_msg=key)


# ---------------------------------------------------------------------------
# Fused finalize under shard_map (8 virtual devices, subprocess).
# ---------------------------------------------------------------------------

_SHARDED_FINALIZE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import solve_sharded
from repro.core.chunked import array_source, solve_streaming
from repro.core.instances import sparse_instance, shard_key
from repro.core.types import SolverConfig

kp, q = sparse_instance(shard_key(4), n=1024, k=10, q=1, tightness=0.4)
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = SolverConfig(reduce="bucketed", max_iters=20)

fused = solve_streaming(array_source(kp, 64), cfg, q=q, mesh=mesh)
legacy = solve_streaming(array_source(kp, 64),
                         cfg.replace(stream_finalize="legacy"), q=q, mesh=mesh)
np.testing.assert_array_equal(np.asarray(fused.lam), np.asarray(legacy.lam))
assert int(fused.iters) == int(legacy.iters)
assert float(fused.dual) == float(legacy.dual), "dual not bitwise"
assert np.all(np.asarray(fused.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
np.testing.assert_allclose(float(fused.primal), float(legacy.primal),
                           rtol=1e-2)

# postprocess off: the two finalizes are the same reduction — bitwise.
f0 = solve_streaming(array_source(kp, 64), cfg.replace(postprocess=False),
                     q=q, mesh=mesh)
l0 = solve_streaming(array_source(kp, 64),
                     cfg.replace(postprocess=False, stream_finalize="legacy"),
                     q=q, mesh=mesh)
for a, b in zip(f0[:6], l0[:6]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# multiplier trajectory still bitwise vs the resident sharded solve.
base = solve_sharded(kp, mesh, cfg, q=q)
np.testing.assert_array_equal(np.asarray(fused.lam), np.asarray(base.lam))
assert int(fused.iters) == int(base.iters)
print("FINALIZE-OK")
"""


@pytest.mark.slow
def test_fused_finalize_sharded_subprocess():
    """Fused vs legacy finalize under shard_map on 8 virtual devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_FINALIZE_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900, cwd=str(REPO))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "FINALIZE-OK" in out.stdout


# ---------------------------------------------------------------------------
# Pre-screening regression pin: the exact bytes, by digest.
# ---------------------------------------------------------------------------

# sha256 over the result fields below, recorded on the seeded fixture
# immediately BEFORE active-set screening (core/screening.py) landed.
# Both streaming drivers must keep producing these bytes with
# cfg.screening=False — the feature must be provably inert when off —
# and, on this uniform fixture (whose chunk ratio maxima never clear
# the bucket ladder), with cfg.screening=True as well.
_GOLDEN_FIELDS = ("lam", "iters", "r", "primal", "dual", "tau")
_GOLDEN_STREAMING = \
    "55910a2f97b1fbf45ea0336352e686b1e64554f51bb624f916fb1ec28868e2d0"


def _result_digest(res):
    h = hashlib.sha256()
    for f in _GOLDEN_FIELDS:
        h.update(np.asarray(getattr(res, f)).tobytes())
    return h.hexdigest()


def test_streaming_golden_digest_unchanged():
    kp, q = sparse_instance(shard_key(4), 1021, 10, 2, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=20)
    src_np = (np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets))

    traced = solve_streaming(array_source(kp, 256), cfg, q=q)
    assert _result_digest(traced) == _GOLDEN_STREAMING
    host = solve_streaming_host(host_array_source(*src_np, 256), cfg, q=q)
    assert _result_digest(host) == _GOLDEN_STREAMING

    # Screening on: retires nothing here, must still not move a bit.
    scfg = cfg.replace(screening=True)
    t_scr = solve_streaming(array_source(kp, 256), scfg, q=q)
    assert _result_digest(t_scr) == _GOLDEN_STREAMING
    assert t_scr.screen is not None
    h_scr = solve_streaming_host(host_array_source(*src_np, 256), scfg, q=q)
    assert _result_digest(h_scr) == _GOLDEN_STREAMING
    assert bool(h_scr.screen["active"].all())
