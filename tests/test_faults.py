"""Fault-domain hardening: retries, chaos parity, containment, GC.

The contracts under test (repro/core/faults.py + the serve layer,
DESIGN.md §10):

* the backoff schedule is deterministic, replayable, and actually slept
  (the injectable ``sleep`` records it); exhaustion raises an error
  naming the chunk and every attempt;
* **chaos parity** — a streaming solve whose source drops, slows,
  corrupts and repeat-offends under a :class:`FaultPlan`, absorbed by
  the retry layer, is *bitwise identical* to the fault-free solve
  (single-device and sharded virtual-slot paths);
* failure containment — a refresh that exhausts its retry budget leaves
  LIVE.json untouched, stamps FAILED.json, and a later re-drive against
  healed storage publishes bitwise the clean record;
* generation GC (``prune``) never deletes the live or pending
  generation;
* degraded serving — lookups that cannot regenerate their chunk answer
  from the previous generation with an explicit ``stale=True``;
* the DecisionService chunk cache is keyed by generation fingerprint —
  flipping generations under a warm cache can never serve yesterday's
  decisions (the regression this PR fixes);
* checkpoint writes fsync data before the rename and the directory
  after it (durability, not just atomicity).
"""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.core.faults import (
    ChunkFetchError,
    ChunkFetchTimeout,
    ChunkIntegrityError,
    FaultPlan,
    FaultPolicy,
    faulty_source,
    fetch_with_retries,
    policy_from_cfg,
    resilient_source,
)
from repro.core.prefetch import solve_streaming_host
from repro.serve import (
    DecisionService,
    RefreshEngine,
    WorkloadSpec,
    synthetic_source,
)

jax.config.update("jax_platform_name", "cpu")

SPEC = WorkloadSpec(seed=3, n=2048, k=8, chunk=256, q=2, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=40)

# The chaos knobs used throughout: rates must keep the per-attempt
# failure probability modest because verify_refetch doubles the reads —
# an attempt succeeds only when BOTH reads come back clean, so
# P(success) = (1 - drop - corrupt)^2 per attempt and the retry budget
# has to cover the compounding across thousands of fetches.
CHAOS_CFG = CFG.replace(fetch_retries=8, fetch_backoff=1e-4,
                        fetch_backoff_cap=1e-3, verify_refetch=True)
CHAOS_PLAN = FaultPlan(seed=0, drop=0.08, slow=0.05, slow_s=0.002,
                       corrupt=0.04, offenders=(1,), offender_failures=2)

RESULT_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]


def _assert_bitwise(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    assert (a.fin_hist is None) == (b.fin_hist is None)
    if a.fin_hist is not None:
        for x, y in zip(a.fin_hist, b.fin_hist):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _flaky(fail_occurrences, payload=("p", "b")):
    """A fetch fn failing on the listed occurrence numbers (0-based)."""
    calls = {"n": 0}

    def fn(i):
        occ = calls["n"]
        calls["n"] += 1
        if occ in fail_occurrences:
            raise IOError(f"transient occurrence {occ}")
        return payload

    return fn, calls


# ---------------------------------------------------------------------------
# fetch_with_retries: the retry loop itself.
# ---------------------------------------------------------------------------

def test_retries_sleep_exactly_the_schedule():
    policy = FaultPolicy(max_retries=4, backoff_base=0.05)
    fn, calls = _flaky({0, 1, 2})
    slept = []
    out = fetch_with_retries(fn, 7, policy, sleep=slept.append)
    assert out == ("p", "b") and calls["n"] == 4
    # The recorded sleeps are exactly the first attempts of the chunk's
    # replayable schedule — no RNG, no wall clock.
    assert slept == list(policy.schedule(7))[:3]


def test_exhaustion_names_chunk_and_history():
    policy = FaultPolicy(max_retries=2, backoff_base=1e-5)
    fn, calls = _flaky(set(range(10)))
    slept = []
    with pytest.raises(ChunkFetchError) as ei:
        fetch_with_retries(fn, 3, policy, sleep=slept.append)
    e = ei.value
    assert e.chunk == 3 and len(e.history) == 3 and calls["n"] == 3
    assert "chunk 3" in str(e) and "3 attempt(s)" in str(e)
    assert "transient occurrence 0" in str(e)
    # The last attempt records no backoff (there is no retry after it).
    assert e.history[-1][2] is None and len(slept) == 2


def test_non_retryable_errors_propagate_immediately():
    def fn(i):
        raise ValueError("a bug, not a fault")

    with pytest.raises(ValueError, match="a bug"):
        fetch_with_retries(fn, 0, FaultPolicy(max_retries=5),
                           sleep=lambda s: None)


def test_on_retry_hook_observes_every_failure():
    policy = FaultPolicy(max_retries=3, backoff_base=1e-5)
    fn, _ = _flaky({0, 1})
    seen = []
    fetch_with_retries(fn, 5, policy, sleep=lambda s: None,
                       on_retry=lambda *a: seen.append(a))
    assert len(seen) == 2
    for chunk, attempt, err, delay in seen:
        assert chunk == 5 and isinstance(err, IOError) and delay > 0


def test_timeout_is_retryable():
    calls = {"n": 0}

    def fn(i):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.5)
        return ("p", "b")

    policy = FaultPolicy(max_retries=2, backoff_base=1e-5, timeout=0.05)
    seen = []
    out = fetch_with_retries(fn, 0, policy, sleep=lambda s: None,
                             on_retry=lambda c, a, e, d: seen.append(e))
    assert out == ("p", "b")
    assert len(seen) == 1 and isinstance(seen[0], ChunkFetchTimeout)


def test_verify_detects_corruption_and_retries_past_it():
    """An occurrence-keyed corrupt payload differs between the two
    verified reads -> ChunkIntegrityError -> retried; once the injected
    corruption stops, the clean double-read passes."""
    src = synthetic_source(SPEC)
    clean = src.fn(0)
    calls = {"n": 0}

    def fn(i):
        occ = calls["n"]
        calls["n"] += 1
        if occ < 2:
            p = np.array(clean[0], copy=True)
            p.flat[0] += np.float32(occ + 1)   # different bytes each time
            return p, clean[1]
        return clean

    policy = FaultPolicy(max_retries=3, backoff_base=1e-5)
    out = fetch_with_retries(fn, 0, policy, verify=True,
                             sleep=lambda s: None)
    assert np.array_equal(out[0], clean[0])

    # Without retries left, the mismatch is terminal and names the check.
    calls["n"] = 0
    with pytest.raises(ChunkFetchError, match="re-read"):
        fetch_with_retries(fn, 0, FaultPolicy(max_retries=0),
                           verify=True, sleep=lambda s: None)


def test_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        FaultPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="jitter"):
        FaultPolicy(jitter=1.0)
    with pytest.raises(ValueError, match="monotone"):
        FaultPolicy(backoff_growth=1.1, jitter=0.25)
    with pytest.raises(ValueError, match="attempt is 1-based"):
        FaultPolicy().backoff(0, 0)
    with pytest.raises(ValueError, match="summing"):
        FaultPlan(drop=0.7, corrupt=0.4)


def test_policy_from_cfg_gates_wrapping():
    assert policy_from_cfg(CFG) is None
    pol = policy_from_cfg(CHAOS_CFG)
    assert pol.max_retries == 8 and pol.timeout == 0.0
    # verify alone still needs the wrapper (retries may be 0).
    assert policy_from_cfg(CFG.replace(verify_refetch=True)) is not None
    assert policy_from_cfg(CFG.replace(fetch_timeout=0.1)) is not None


# ---------------------------------------------------------------------------
# Chaos parity: the key invariant. Faults absorbed -> bitwise the clean solve.
# ---------------------------------------------------------------------------

def test_chaos_solve_bitwise_equals_clean_solve():
    clean = solve_streaming_host(synthetic_source(SPEC), CFG, q=SPEC.q)
    chaotic = solve_streaming_host(
        faulty_source(synthetic_source(SPEC), CHAOS_PLAN),
        CHAOS_CFG, q=SPEC.q)
    _assert_bitwise(chaotic, clean)


def test_chaos_solve_bitwise_sharded_slots():
    """Same invariant under the sharded virtual-slot runtime (threaded
    producers fetching through the retry layer). Slot count changes the
    accumulation grouping, so clean and chaotic must run the SAME
    slots."""
    mesh = jax.make_mesh((1,), ("users",))
    clean = solve_streaming_host(synthetic_source(SPEC), CFG, q=SPEC.q,
                                 mesh=mesh, slots=4)
    chaotic = solve_streaming_host(
        faulty_source(synthetic_source(SPEC), CHAOS_PLAN),
        CHAOS_CFG, q=SPEC.q, mesh=mesh, slots=4)
    _assert_bitwise(chaotic, clean)


def test_timeout_retry_path_bitwise():
    """A chunk that hangs past the per-fetch timeout once is abandoned,
    retried, and the solve is still bitwise clean."""
    src = synthetic_source(SPEC)
    inner = src.fn
    calls = {"n": 0}

    def hang_once(i):
        if int(i) == 2:
            calls["n"] += 1
            if calls["n"] == 1:
                time.sleep(0.5)
        return inner(i)

    cfg = CFG.replace(fetch_retries=3, fetch_backoff=1e-4,
                      fetch_backoff_cap=1e-3, fetch_timeout=0.1)
    clean = solve_streaming_host(synthetic_source(SPEC), CFG, q=SPEC.q)
    got = solve_streaming_host(src._replace(fn=hang_once), cfg, q=SPEC.q)
    assert calls["n"] >= 2           # the timeout really fired + retried
    _assert_bitwise(got, clean)


def test_exhaustion_in_solve_names_the_chunk():
    plan = FaultPlan(seed=0, offenders=(3,), offender_failures=10 ** 6)
    cfg = CFG.replace(fetch_retries=2, fetch_backoff=1e-5,
                      fetch_backoff_cap=1e-4)
    with pytest.raises(ChunkFetchError, match="chunk 3") as ei:
        solve_streaming_host(faulty_source(synthetic_source(SPEC), plan),
                             cfg, q=SPEC.q)
    assert ei.value.chunk == 3 and len(ei.value.history) == 3


def test_resilient_source_composes_over_faulty():
    """The chaos sandwich: faults injected below, retries above, clean
    bytes out — chunk-for-chunk, not just end-to-end."""
    clean = synthetic_source(SPEC)
    wrapped = resilient_source(
        faulty_source(clean, CHAOS_PLAN),
        policy_from_cfg(CHAOS_CFG), verify=True, sleep=lambda s: None)
    for i in range(-(-clean.n // clean.chunk)):
        want, got = clean.fn(i), wrapped.fn(i)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])


# ---------------------------------------------------------------------------
# Failure containment: FAILED.json, LIVE untouched, re-drive heals.
# ---------------------------------------------------------------------------

def _offender_factory(plan):
    def make(spec):
        return faulty_source(synthetic_source(spec), plan)

    return make


def test_failed_refresh_contained_and_redriven(tmp_path):
    ref_root = tmp_path / "ref"
    era = RefreshEngine(ref_root, SPEC, cfg=CFG)
    era.refresh()
    ref = era.refresh(budget_scale=0.9)

    root = tmp_path / "faulty"
    eng = RefreshEngine(root, SPEC, cfg=CFG)
    eng.refresh()

    # gen 1's solve exhausts its retries on a permanently-dead chunk.
    dead = FaultPlan(seed=0, offenders=(5,), offender_failures=10 ** 6)
    cfg_retry = CFG.replace(fetch_retries=2, fetch_backoff=1e-5,
                            fetch_backoff_cap=1e-4)
    broken = RefreshEngine(root, SPEC, make_source=_offender_factory(dead),
                           cfg=cfg_retry)
    with pytest.raises(ChunkFetchError, match="chunk 5"):
        broken.refresh(budget_scale=0.9)

    # Containment: the previous generation still serves; the failure is
    # stamped with the chunk and attempt history.
    assert eng.live().gen == 0
    stamp = eng.failed()
    assert stamp is not None and stamp["chunk"] == 5
    assert stamp["attempts"] == 3 and stamp["gen"] == 1
    assert len(stamp["history"]) == 3
    # Lookups through the engine keep answering from gen 0.
    assert eng.decision_service().decide(0).shape == (SPEC.k,)

    # Storage heals (same spec, clean source): the SAME refresh re-drives
    # the pending generation, clears the stamp, publishes bitwise.
    healed = RefreshEngine(root, SPEC, cfg=CFG).refresh(budget_scale=0.9)
    _assert_bitwise(healed, ref)
    assert eng.live().gen == 1 and eng.failed() is None
    assert not (eng._gen_dir(1) / "FAILED.json").exists()


def test_discard_pending_frees_the_generation_id(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    dead = FaultPlan(seed=0, offenders=(0,), offender_failures=10 ** 6)
    broken = RefreshEngine(tmp_path, SPEC,
                           make_source=_offender_factory(dead),
                           cfg=CFG.replace(fetch_retries=1,
                                           fetch_backoff=1e-5))
    with pytest.raises(ChunkFetchError):
        broken.refresh(budget_scale=0.9)
    assert eng.failed() is not None
    assert eng.discard_pending() == 1
    assert eng._pending() is None and eng.failed() is None
    # The id is claimable afresh, with different deltas this time.
    assert eng.refresh(budget_scale=1.1).gen == 1
    assert eng.discard_pending() is None


# ---------------------------------------------------------------------------
# Generation GC: prune never removes live or pending.
# ---------------------------------------------------------------------------

def test_prune_keeps_newest_and_never_live(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    for scale in [1.0, 0.95, 0.9, 0.85]:
        eng.refresh(budget_scale=scale)
    assert eng.generation_ids() == [0, 1, 2, 3]
    removed = eng.prune(keep=2)
    assert removed == [0, 1] and eng.generation_ids() == [2, 3]
    assert eng.live().gen == 3
    with pytest.raises(ValueError, match="keep >= 1"):
        eng.prune(keep=0)
    with pytest.raises(ValueError, match="keep >= 1"):
        eng.prune()                      # engine has keep=None


def test_auto_prune_after_refresh(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG, keep=2)
    for scale in [1.0, 0.95, 0.9, 0.85]:
        eng.refresh(budget_scale=scale)
    assert eng.generation_ids() == [2, 3] and eng.live().gen == 3
    with pytest.raises(ValueError, match="keep must be >= 1"):
        RefreshEngine(tmp_path, SPEC, cfg=CFG, keep=0)


def test_prune_never_removes_pending(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    eng.refresh()
    eng.refresh(budget_scale=0.95)
    dead = FaultPlan(seed=0, offenders=(0,), offender_failures=10 ** 6)
    broken = RefreshEngine(tmp_path, SPEC,
                           make_source=_offender_factory(dead),
                           cfg=CFG.replace(fetch_retries=1,
                                           fetch_backoff=1e-5))
    with pytest.raises(ChunkFetchError):
        broken.refresh(budget_scale=0.9)          # gen 2 pending (failed)
    removed = eng.prune(keep=1)
    # gen 1 is live, gen 2 pending: both survive; only gen 0 goes.
    assert removed == [0]
    assert eng.generation_ids() == [1, 2]
    assert eng.live().gen == 1 and eng._pending()[0] == 2
    # The pending generation is still re-drivable after the sweep.
    healed = RefreshEngine(tmp_path, SPEC, cfg=CFG).recover()
    assert healed.gen == 2 and eng.live().gen == 2


# ---------------------------------------------------------------------------
# Degraded serving: stale answers beat no answers, and say so.
# ---------------------------------------------------------------------------

def _two_generations(tmp_path):
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    g0 = eng.refresh()
    g1 = eng.refresh(budget_scale=0.7)   # big delta: decisions differ
    return eng, g0, g1


def test_degraded_lookup_serves_previous_generation(tmp_path):
    eng, g0, g1 = _two_generations(tmp_path)

    # The live generation's storage is dead for every chunk; the
    # fallback (gen 0) is healthy.
    dead = FaultPlan(seed=0, offenders=tuple(range(8)),
                     offender_failures=10 ** 6)

    def make(spec):
        src = synthetic_source(spec)
        return faulty_source(src, dead) if spec == g1.spec else src

    cfg_retry = CFG.replace(fetch_retries=1, fetch_backoff=1e-5,
                            fetch_backoff_cap=1e-4)
    svc = RefreshEngine(tmp_path, SPEC, make_source=make,
                        cfg=cfg_retry).decision_service()
    res = svc.lookup(17)
    assert res.stale and res.gen == 0
    # The stale answer is gen 0's decision, bitwise.
    want = DecisionService(synthetic_source(g0.spec), g0).decide(17)
    np.testing.assert_array_equal(res.x, want)
    # decide/decide_batch degrade the same way (per-user).
    np.testing.assert_array_equal(svc.decide(17), want)
    h = svc.health()
    assert h["degraded"] and h["stale_serves"] >= 2
    assert h["fetch_failures"] >= 2 and h["retries"] >= 2
    assert h["generation"] == 1 and h["fallback_generation"] == 0


def test_degraded_lookup_without_fallback_raises(tmp_path):
    eng, g0, g1 = _two_generations(tmp_path)
    dead = FaultPlan(seed=0, offenders=tuple(range(8)),
                     offender_failures=10 ** 6)
    cfg_retry = CFG.replace(fetch_retries=1, fetch_backoff=1e-5)
    svc = RefreshEngine(
        tmp_path, SPEC, make_source=_offender_factory(dead),
        cfg=cfg_retry).decision_service(fallback=False)
    with pytest.raises(ChunkFetchError):
        svc.lookup(17)
    h = svc.health()
    assert h["fetch_failures"] == 1 and h["stale_serves"] == 0
    assert not h["degraded"] and h["fallback_generation"] is None


def test_degraded_clears_on_rebind_to_healed_generation(tmp_path):
    """``degraded`` is the *current* binding's state, not history: a
    rebind onto a generation with healthy storage reports healthy
    again, while the monotone ``stale_serves`` tally keeps the record
    of what happened (the recovery-transition regression)."""
    eng, g0, g1 = _two_generations(tmp_path)
    dead = FaultPlan(seed=0, offenders=tuple(range(8)),
                     offender_failures=10 ** 6)

    def make(spec):
        src = synthetic_source(spec)
        return faulty_source(src, dead) if spec == g1.spec else src

    cfg_retry = CFG.replace(fetch_retries=1, fetch_backoff=1e-5,
                            fetch_backoff_cap=1e-4)
    eng2 = RefreshEngine(tmp_path, SPEC, make_source=make, cfg=cfg_retry)
    svc = eng2.decision_service()
    assert svc.lookup(17).stale
    h = svc.health()
    assert h["degraded"] and h["stale_serves"] >= 1
    stale_before = h["stale_serves"]

    # Publish a healed generation and follow the pointer flip.
    g2 = eng2.refresh(budget_scale=0.85)
    svc.rebind(synthetic_source(g2.spec), g2)
    res = svc.lookup(17)
    assert not res.stale and res.gen == g2.gen
    h = svc.health()
    assert not h["degraded"]                    # current binding: healthy
    assert h["stale_serves"] == stale_before    # history: preserved
    assert h["generation"] == g2.gen and h["fallback_generation"] == g1.gen


def test_healthy_lookups_are_never_marked_stale(tmp_path):
    eng, g0, g1 = _two_generations(tmp_path)
    svc = eng.decision_service()
    res = svc.lookup(17)
    assert not res.stale and res.gen == 1
    h = svc.health()
    assert h["stale_serves"] == 0 and not h["degraded"]
    assert h["fallback_generation"] == 0    # armed, just unused


# ---------------------------------------------------------------------------
# The cache-keying regression: generations flip under a warm cache.
# ---------------------------------------------------------------------------

def test_cache_keyed_by_generation_fingerprint(tmp_path):
    """A service rebound to a new generation with a WARM cache must
    answer from the new generation's multipliers — a chunk-index-only
    cache key would serve yesterday's decisions here."""
    eng, g0, g1 = _two_generations(tmp_path)
    svc = DecisionService(synthetic_source(g0.spec), g0, cache_chunks=16)
    users = np.arange(SPEC.n)
    before = svc.decide_batch(users)          # warms every chunk
    assert svc.stats["fills"] == 8

    oracle = DecisionService(synthetic_source(g1.spec), g1).decide_batch(
        users)
    assert (before != oracle).any(), \
        "degenerate scenario: both generations decide identically"

    svc.rebind(synthetic_source(g1.spec), g1)
    after = svc.decide_batch(users)
    np.testing.assert_array_equal(after, oracle)
    # The new generation filled its own entries; it never hit g0's.
    assert svc.stats["fills"] == 16
    # And the demoted generation's warm entries still answer for it
    # (the degraded path reuses them for free).
    assert svc.generation.gen == 1


def test_engine_decision_service_tracks_pointer_flips(tmp_path):
    """The engine hands out a service per generation; two services built
    around a refresh disagree exactly where the oracle says they
    should."""
    eng = RefreshEngine(tmp_path, SPEC, cfg=CFG)
    g0 = eng.refresh()
    svc0 = eng.decision_service()
    x0 = svc0.decide_batch(np.arange(256))
    g1 = eng.refresh(budget_scale=0.7)
    svc1 = eng.decision_service()
    assert svc1.generation.gen == 1
    x1 = svc1.decide_batch(np.arange(256))
    oracle0 = DecisionService(synthetic_source(g0.spec), g0).decide_batch(
        np.arange(256))
    oracle1 = DecisionService(synthetic_source(g1.spec), g1).decide_batch(
        np.arange(256))
    np.testing.assert_array_equal(x0, oracle0)
    np.testing.assert_array_equal(x1, oracle1)


# ---------------------------------------------------------------------------
# Checkpoint durability: fsync before the rename, directory after it.
# ---------------------------------------------------------------------------

def _counting(monkeypatch):
    events = []
    real_fsync, real_replace = os.fsync, os.replace
    monkeypatch.setattr(
        os, "fsync",
        lambda fd: (events.append("fsync"), real_fsync(fd))[1])
    monkeypatch.setattr(
        os, "replace",
        lambda a, b: (events.append("replace"), real_replace(a, b))[1])
    return events


def test_save_fsyncs_data_before_rename_and_dir_after(tmp_path,
                                                      monkeypatch):
    events = _counting(monkeypatch)
    tree = {"a": np.arange(4, dtype=np.float32),
            "b": np.ones((2, 2), np.float32)}
    ckpt.save(tmp_path, 0, tree)
    assert "replace" in events
    ri = events.index("replace")
    # 2 leaves + manifest + tmp-dir fsync land before the rename...
    assert events[:ri].count("fsync") >= 4
    # ...and the parent directory is fsynced after it.
    assert "fsync" in events[ri + 1:]


def test_write_json_fsyncs_before_and_after_flip(tmp_path, monkeypatch):
    events = _counting(monkeypatch)
    ckpt.write_json(tmp_path, "LIVE.json", {"gen": 1})
    ri = events.index("replace")
    assert events[:ri].count("fsync") >= 1
    assert "fsync" in events[ri + 1:]
    assert ckpt.read_json(tmp_path, "LIVE.json") == {"gen": 1}


# ---------------------------------------------------------------------------
# Abandoned-worker accounting: repeated timeouts leak a bounded number
# of threads, and the leak is observable.
# ---------------------------------------------------------------------------

def _drain_abandoned(release=None, timeout_s=10.0):
    """Release hung fakes (if any) and wait for the live count to reach 0."""
    import repro.core.faults as faults_mod
    if release is not None:
        release.set()
    deadline = time.time() + timeout_s
    while faults_mod.abandoned_workers()["live"] > 0 \
            and time.time() < deadline:
        time.sleep(0.01)
    assert faults_mod.abandoned_workers()["live"] == 0


def test_abandoned_workers_counted_and_reaped():
    import threading

    import repro.core.faults as faults_mod

    _drain_abandoned()
    release = threading.Event()

    def hang(i):
        release.wait(30)
        return ("p", "b")

    policy = FaultPolicy(max_retries=1, backoff_base=1e-5, timeout=0.02)
    before = faults_mod.abandoned_workers()["total"]
    with pytest.raises(ChunkFetchError):
        fetch_with_retries(hang, 0, policy, sleep=lambda s: None)
    stats = faults_mod.abandoned_workers()
    # Two attempts, both timed out and abandoned, both still alive.
    assert stats["total"] == before + 2
    assert stats["live"] == 2
    # Released workers die and are reaped from the live count; the
    # monotone total stays.
    _drain_abandoned(release)
    assert faults_mod.abandoned_workers()["total"] == before + 2


def test_abandoned_cap_fails_fast_and_is_retryable(monkeypatch):
    import threading

    import repro.core.faults as faults_mod
    from repro.core.faults import FetchCapacityError

    _drain_abandoned()
    release = threading.Event()

    def hang(i):
        release.wait(30)
        return ("p", "b")

    try:
        monkeypatch.setattr(faults_mod, "ABANDONED_WORKER_CAP", 2)
        policy = FaultPolicy(max_retries=0, backoff_base=1e-5, timeout=0.02)
        for i in range(2):
            with pytest.raises(ChunkFetchError):
                fetch_with_retries(hang, i, policy, sleep=lambda s: None)
        # At the cap: the next timed fetch refuses to park another
        # thread — fast, retryable, and the exhaustion names the cause.
        with pytest.raises(ChunkFetchError, match="abandoned fetch"):
            fetch_with_retries(hang, 9, policy, sleep=lambda s: None)
        assert issubclass(FetchCapacityError, IOError)   # retryable class
        assert faults_mod.abandoned_workers()["live"] == 2, \
            "the capped call must not have spawned a third worker"
    finally:
        _drain_abandoned(release)


def test_health_surfaces_leaked_workers_and_supervisor_doc(tmp_path):
    eng, g0, g1 = _two_generations(tmp_path)
    svc = eng.decision_service()
    h = svc.health()
    assert {"abandoned_fetch_workers", "abandoned_fetch_total"} <= set(h)
    assert h["abandoned_fetch_workers"] == 0
    # No supervisor has run over this root yet: an explicit "absent"
    # status, distinguishable from a dead supervisor's stale document.
    assert h["supervisor"] == {"status": "absent"}
    # A supervisor status document in the engine root is surfaced as-is.
    ckpt.write_json(tmp_path, "SUPERVISOR.json",
                    {"state": "done", "hang_takeovers": 1, "restarts": 2})
    h = svc.health()
    assert h["supervisor"]["hang_takeovers"] == 1
    assert h["supervisor"]["restarts"] == 2
