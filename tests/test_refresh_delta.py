"""Delta refresh: a screened generation re-streams only changed chunks.

The contract under test (DESIGN.md §11, serve/engine.py): a refresh
whose ``chunk_diff`` proves which chunks' bytes changed seeds the new
solve's active set from the parent generation's published screening
certificates — unchanged retired chunks start retired, changed chunks
start active with unknown bounds — and publishes a record **bitwise
identical** to the full refresh that re-streams everything (same
record fields, same fingerprint, same LIVE pointer). The delta is an
I/O optimisation with a soundness proof, not a different solve.

Also pinned here:

* re-streamed chunk accounting — the first delta epoch fetches exactly
  the parent's surviving active set (budget-only delta) or that set
  plus the changed chunks (growth delta), counted two independent ways
  (the published ``screen_streamed`` record and a counting
  ``make_source`` wrapper);
* ``synthetic_chunk_diff``'s own contract (None / zeros / frontier);
* the acceptance bar, for real: an 8-virtual-device sharded delta
  refresh SIGKILLed mid-solve and re-driven publishes bitwise the
  uninterrupted record (screening state is rebuilt, not checkpointed —
  the seeding is recomputed identically from the immutable parent).
"""
import os
import pathlib
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.serve import (
    RefreshEngine,
    WorkloadSpec,
    synthetic_chunk_diff,
    synthetic_source,
)

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent

# Ratio-banded workload (retirement actually happens) with the narrowed
# ladder; checkpointing on so the SIGKILL path has resume states.
SPEC = WorkloadSpec(seed=7, n=4000, k=6, chunk=250, q=2, tightness=0.08,
                    band=0.05)
CFG = SolverConfig(reduce="bucketed", max_iters=30, bucket_half=12,
                   screening=True, checkpoint_every=4)

RESULT_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]


def _assert_gen_equal(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    np.testing.assert_array_equal(a.fingerprint, b.fingerprint)
    assert (a.fin_hist is None) == (b.fin_hist is None)
    if a.fin_hist is not None:
        for x, y in zip(a.fin_hist, b.fin_hist):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _record(gen):
    return ckpt.restore_auto(pathlib.Path(gen.path) / "record", 0)


def _streamed(gen):
    return np.asarray(_record(gen)["screen_streamed"])


def _counting_factory():
    """synthetic_source with a per-refresh chunk-fetch counter."""
    calls = {"n": 0}

    def make(spec):
        src = synthetic_source(spec)
        inner = src.fn

        def fn(i):
            calls["n"] += 1
            return inner(i)

        return src._replace(fn=fn)

    return make, calls


# ---------------------------------------------------------------------------
# Delta vs full: bitwise record, fewer bytes moved.
# ---------------------------------------------------------------------------

def test_delta_refresh_bitwise_vs_full(tmp_path):
    """Budget-only delta: the delta engine inherits the parent's retired
    set and still publishes the full-restream engine's exact bits."""
    delta_eng = RefreshEngine(tmp_path / "delta", SPEC, cfg=CFG)
    full_eng = RefreshEngine(tmp_path / "full", SPEC, cfg=CFG,
                             chunk_diff=lambda old, new: None)
    assert delta_eng.chunk_diff is synthetic_chunk_diff   # default wiring

    p_delta = delta_eng.refresh()
    p_full = full_eng.refresh()
    _assert_gen_equal(p_delta, p_full)                    # same gen 0

    g_delta = delta_eng.refresh(budget_scale=1.02)
    g_full = full_eng.refresh(budget_scale=1.02)
    _assert_gen_equal(g_delta, g_full)
    assert delta_eng.live().gen == 1 and full_eng.live().gen == 1
    assert g_delta.spec.budget_scale == pytest.approx(1.02)

    # Accounting: the parent retired most chunks; the delta's first
    # epoch streams exactly the survivors, the full restream all of c.
    c = -(-SPEC.n // SPEC.chunk)
    parent_active = int(np.asarray(_record(p_delta)["screen_active"]).sum())
    assert 0 < parent_active < c
    sd, sf = _streamed(g_delta), _streamed(g_full)
    assert sd[0] == parent_active, (sd, parent_active)
    assert sf[0] == c, sf
    assert sd.sum() < sf.sum(), (sd, sf)


def test_delta_restream_counted_at_the_source(tmp_path):
    """Independent count: a wrapping make_source sees the delta refresh
    save exactly the fetches the screen record claims it skipped.

    Both engines pay identical fixed costs (fingerprint probes, the
    fused-finalize full pass); the difference in raw source fetches is
    therefore exactly the difference in iteration-epoch streaming."""
    def run(root, diff):
        make, calls = _counting_factory()
        eng = RefreshEngine(root, SPEC, make_source=make, cfg=CFG,
                            chunk_diff=diff)
        p = eng.refresh()
        calls["n"] = 0
        g = eng.refresh(budget_scale=1.02)
        return p, g, calls["n"]

    p, g_d, fetches_d = run(tmp_path / "delta", synthetic_chunk_diff)
    _, g_f, fetches_f = run(tmp_path / "full", lambda old, new: None)
    _assert_gen_equal(g_d, g_f)
    sd, sf = _streamed(g_d), _streamed(g_f)
    assert fetches_d < fetches_f
    assert fetches_f - fetches_d == int(sf.sum() - sd.sum()), (
        fetches_d, fetches_f, sd, sf)
    parent_active = int(np.asarray(_record(p)["screen_active"]).sum())
    assert sd[0] == parent_active


def test_growth_delta_streams_survivors_plus_frontier(tmp_path):
    """n growth: first delta epoch = parent survivors + the chunks the
    diff marks changed (the ragged frontier and the genuinely new)."""
    eng = RefreshEngine(tmp_path / "delta", SPEC, cfg=CFG)
    p = eng.refresh()
    n2 = SPEC.n + 500                                     # 16 -> 18 chunks
    changed = synthetic_chunk_diff(SPEC, SPEC.replace(n=n2))
    g = eng.refresh(n=n2)

    oracle = RefreshEngine(tmp_path / "full", SPEC, cfg=CFG,
                           chunk_diff=lambda old, new: None)
    oracle.refresh()
    _assert_gen_equal(g, oracle.refresh(n=n2))

    parent_active = np.asarray(_record(p)["screen_active"]).astype(bool)
    c_old = parent_active.shape[0]
    inherited = int(parent_active[~changed[:c_old]].sum())
    expect = inherited + int(changed.sum())
    assert _streamed(g)[0] == expect, (_streamed(g), inherited, changed)


def test_synthetic_chunk_diff_contract():
    base = SPEC
    # Budget-shaped deltas never touch chunk bytes.
    for delta in [dict(budget_scale=0.9), dict(tightness=0.2), dict(q=3)]:
        ch = synthetic_chunk_diff(base, base.replace(**delta))
        assert ch is not None and not ch.any(), delta
    # Identity-shaped deltas invalidate everything.
    for delta in [dict(seed=8), dict(k=7), dict(chunk=200), dict(band=0.1)]:
        assert synthetic_chunk_diff(base, base.replace(**delta)) is None, \
            delta
    # Growth: unchanged iff fully live under BOTH n's.
    ch = synthetic_chunk_diff(base, base.replace(n=base.n + 500))
    c_old = -(-base.n // base.chunk)
    assert ch.shape == (c_old + 2,)
    assert not ch[:c_old].any() and ch[c_old:].all()
    # Shrink: the new frontier chunk is conservatively changed.
    ch = synthetic_chunk_diff(base, base.replace(n=base.n - 100))
    assert ch.shape == (c_old,)
    assert not ch[:-1].any() and ch[-1]


def test_unscreened_parent_solves_delta_cold(tmp_path):
    """A parent published without screening has no certificates to
    inherit; the screened delta refresh must degrade to a full first
    epoch — and still match the all-restream oracle bitwise."""
    cold_cfg = CFG.replace(screening=False)
    eng = RefreshEngine(tmp_path / "a", SPEC, cfg=cold_cfg)
    eng.refresh()
    eng = RefreshEngine(tmp_path / "a", SPEC, cfg=CFG)    # flip screening on
    g = eng.refresh(budget_scale=1.02)
    c = -(-SPEC.n // SPEC.chunk)
    assert _streamed(g)[0] == c                           # nothing inherited

    oracle = RefreshEngine(tmp_path / "b", SPEC, cfg=cold_cfg)
    oracle.refresh()
    o = RefreshEngine(tmp_path / "b", SPEC, cfg=CFG,
                      chunk_diff=lambda old, new: None).refresh(
        budget_scale=1.02)
    _assert_gen_equal(g, o)


# ---------------------------------------------------------------------------
# SIGKILL mid-delta-refresh: resume publishes the same bits.
# ---------------------------------------------------------------------------

_SIGKILL_SCRIPT = textwrap.dedent("""
    import os, pathlib, signal, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.checkpoint import ckpt
    from repro.core import SolverConfig
    from repro.serve import (RefreshEngine, WorkloadSpec,
                             synthetic_chunk_diff, synthetic_source)

    mode, kill_after, root, out = (sys.argv[1], int(sys.argv[2]),
                                   sys.argv[3], sys.argv[4])
    spec = WorkloadSpec(seed=7, n=4000, k=6, chunk=250, q=2,
                        tightness=0.08, band=0.05)
    cfg = SolverConfig(reduce="bucketed", max_iters=30, bucket_half=12,
                       screening=True, checkpoint_every=1)
    mesh = jax.make_mesh((8,), ("users",))

    make = synthetic_source
    if mode == "kill":
        calls = {"n": 0}
        def make(s):
            src = synthetic_source(s)
            inner = src.fn
            def fn(i):
                calls["n"] += 1
                if calls["n"] > kill_after:
                    os.kill(os.getpid(), signal.SIGKILL)
                return inner(i)
            return src._replace(fn=fn)

    # chunk_diff must be explicit: the killing wrapper is not
    # synthetic_source, so the engine's default delta wiring would not
    # engage and the refresh would silently solve full, not delta.
    eng = RefreshEngine(root, spec, make_source=make, cfg=cfg,
                        mesh=mesh, slots=8,
                        chunk_diff=synthetic_chunk_diff)
    if eng.live_gen_id() is None:
        cold = RefreshEngine(root, spec, make_source=synthetic_source,
                             cfg=cfg, mesh=mesh, slots=8)
        cold.refresh()                        # gen 0, uninterrupted
        eng = RefreshEngine(root, spec, make_source=make, cfg=cfg,
                            mesh=mesh, slots=8,
                            chunk_diff=synthetic_chunk_diff)
    gen = eng.refresh(budget_scale=1.02)      # gen 1 delta (killed in "kill")
    rec = ckpt.restore_auto(pathlib.Path(gen.path) / "record", 0)
    np.savez(out, lam=gen.lam, tau=gen.tau, iters=gen.iters, r=gen.r,
             primal=gen.primal, dual=gen.dual, ch=gen.fin_hist[0],
             gh=gen.fin_hist[1], warm=gen.warm,
             active=np.asarray(rec["screen_active"]))
    print("GEN-OK", gen.gen, int(gen.iters))
""")


def _run_script(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run([sys.executable, "-c", _SIGKILL_SCRIPT] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=str(REPO))


@pytest.mark.slow
def test_sigkill_mid_delta_refresh_resume_bitwise(tmp_path):
    """An 8-virtual-device sharded DELTA refresh SIGKILLed mid-solve and
    re-driven publishes bitwise the uninterrupted delta record — the
    screening seed is recomputed from the immutable parent on re-entry,
    never checkpointed — and the pointer never exposes the half-done
    generation."""
    ref = tmp_path / "ref.npz"
    out = _run_script(["ref", "0", str(tmp_path / "ref_root"), str(ref)])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "GEN-OK 1" in out.stdout

    root = tmp_path / "killed_root"
    # Gen 1's delta epochs fetch only the parent's survivors (a handful
    # of chunks per iteration); 6 fetches lands mid-solve, after the
    # first checkpoint but well before convergence.
    killed = _run_script(["kill", "6", str(root), "x"])
    assert killed.returncode == -signal.SIGKILL, (
        killed.returncode, killed.stdout, killed.stderr)
    ptr = ckpt.read_json(pathlib.Path(root), "LIVE.json")
    assert ptr is not None and int(ptr["gen"]) == 0
    assert ckpt.latest_step(pathlib.Path(root) / "gen_000001" / "ckpt") \
        is not None

    got_path = tmp_path / "resumed.npz"
    res = _run_script(["resume", "0", str(root), str(got_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    want, got = np.load(ref), np.load(got_path)
    for key in ["lam", "tau", "iters", "r", "primal", "dual", "ch", "gh",
                "warm", "active"]:
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


# ---------------------------------------------------------------------------
# Real (file-backed) sources: content-hash chunk_diff over memmaps.
# ---------------------------------------------------------------------------

from repro.core.prefetch import chunk_hashes, memmap_source  # noqa: E402
from repro.serve.engine import content_chunk_diff  # noqa: E402

_EDIT_CHUNK = 3


def _write_days(tmp_path):
    """Two on-disk (n, k) f32 extracts: day 1 = day 0 with ONE chunk
    edited. Bytes come from the banded synthetic source so screening
    retires chunks exactly as in the generator-backed tests."""
    base = synthetic_source(SPEC)
    c = -(-SPEC.n // SPEC.chunk)
    p0 = np.concatenate([base.fn(i)[0] for i in range(c)])[:SPEC.n]
    b0 = np.concatenate([base.fn(i)[1] for i in range(c)])[:SPEC.n]
    p1 = p0.copy()
    lo = _EDIT_CHUNK * SPEC.chunk
    p1[lo:lo + SPEC.chunk] *= np.float32(1.25)     # today's edit
    paths = {}
    for day, (p, b) in enumerate([(p0, b0), (p1, b0)]):
        pp = tmp_path / f"day{day}_p.bin"
        bp = tmp_path / f"day{day}_b.bin"
        p.astype(np.float32).tofile(pp)
        b.astype(np.float32).tofile(bp)
        paths[day] = (pp, bp)
    return paths, np.asarray(base.budgets)


def _memmap_factory(paths, budgets):
    """spec -> memmap_source; spec.seed - SPEC.seed picks the day."""
    def make(spec):
        pp, bp = paths[spec.seed - SPEC.seed]
        return memmap_source(pp, bp, spec.n, spec.k, budgets, spec.chunk)

    return make


def test_content_chunk_diff_contract(tmp_path):
    paths, budgets = _write_days(tmp_path)
    make = _memmap_factory(paths, budgets)
    diff = content_chunk_diff(make)
    day0, day1 = SPEC, SPEC.replace(seed=SPEC.seed + 1)

    # Identity: byte-identical sources -> zero changed chunks.
    assert not diff(day0, day0).any()
    # The edited chunk — and only it — is marked changed.
    changed = diff(day0, day1)
    c = -(-SPEC.n // SPEC.chunk)
    assert changed.shape == (c,)
    assert changed[_EDIT_CHUNK] and changed.sum() == 1
    # Layout changes inherit nothing.
    assert diff(day0, day1.replace(chunk=200)) is None
    assert diff(day0, day1.replace(k=SPEC.k + 1)) is None
    # Growth over the same file: the overlap is unchanged, chunks past
    # the old end are changed by definition.
    shrunk = day0.replace(n=SPEC.n - 2 * SPEC.chunk)
    grown = diff(shrunk, day0)
    assert not grown[:-2].any() and grown[-2:].all()


def test_chunk_hashes_match_iff_bytes_match(tmp_path):
    paths, budgets = _write_days(tmp_path)
    make = _memmap_factory(paths, budgets)
    h0 = chunk_hashes(make(SPEC))
    h1 = chunk_hashes(make(SPEC.replace(seed=SPEC.seed + 1)))
    same = (h0 == h1).all(axis=1)
    assert not same[_EDIT_CHUNK] and same.sum() == len(same) - 1
    # Restricted scan returns the requested chunks in order.
    sub = chunk_hashes(make(SPEC), chunks=[_EDIT_CHUNK, 0])
    np.testing.assert_array_equal(sub[0], h0[_EDIT_CHUNK])
    np.testing.assert_array_equal(sub[1], h0[0])


def test_memmap_delta_restreams_only_the_edited_chunk(tmp_path):
    """End to end on a file-backed workload: day-over-day refresh with
    the content diff re-streams the parent's survivors plus exactly the
    one edited chunk, and publishes the full-restream engine's bits."""
    paths, budgets = _write_days(tmp_path)
    raw = _memmap_factory(paths, budgets)

    calls = {"n": 0}

    def counting(spec):
        src = raw(spec)
        inner = src.fn

        def fn(i):
            calls["n"] += 1
            return inner(i)

        return src._replace(fn=fn)

    day1 = SPEC.replace(seed=SPEC.seed + 1)
    delta_eng = RefreshEngine(tmp_path / "delta", SPEC, make_source=counting,
                              cfg=CFG, chunk_diff=content_chunk_diff(raw))
    full_eng = RefreshEngine(tmp_path / "full", SPEC, make_source=raw,
                             cfg=CFG)
    assert full_eng.chunk_diff is None     # custom sources default cold

    p_delta, p_full = delta_eng.refresh(), full_eng.refresh()
    _assert_gen_equal(p_delta, p_full)
    g_delta = delta_eng.refresh(seed=day1.seed)
    g_full = full_eng.refresh(seed=day1.seed)
    _assert_gen_equal(g_delta, g_full)

    changed = content_chunk_diff(raw)(SPEC, day1)
    parent_active = np.asarray(_record(p_delta)["screen_active"]).astype(bool)
    inherited = int(parent_active[~changed].sum())
    expect = inherited + int(changed.sum())
    sd = _streamed(g_delta)
    assert sd[0] == expect, (sd, inherited, changed)
    # The edited chunk was genuinely re-streamed even if the parent had
    # retired it.
    c = -(-SPEC.n // SPEC.chunk)
    assert _streamed(g_full)[0] == c
    assert sd.sum() < _streamed(g_full).sum()
