"""Property tests for heartbeat-lease semantics (repro/core/heartbeat.py).

The properties the supervisor's correctness stands on:

* **renewal monotonicity** — a writer's ``seq`` strictly increases per
  beat and ``progress`` is non-decreasing under ``bump``; the monitor's
  freshness judgement depends only on observing ``(term, seq)`` advance
  against its *own* clock, so with beats arriving within ``ttl`` the
  lease stays fresh and once they cease it expires after exactly
  ``ttl`` of monitor time — never earlier, regardless of the schedule;
* **takeover exclusivity** — for one term, of any number of racing
  coordinators exactly one ``claim_takeover`` wins (O_CREAT|O_EXCL),
  whether raced sequentially or from threads;
* **torn writes carry no liveness** — any truncation or byte corruption
  of a valid lease file classifies as expired (``TornLease`` /
  ``state == "torn"``), never fresh: a damaged record must not keep a
  dead worker looking alive.

Each property has a deterministic twin (always run) and a hypothesis
sweep (skipped without hypothesis unless REQUIRE_HYPOTHESIS is set —
see tests/_hypothesis_compat.py).
"""
import os
import threading

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.heartbeat import (
    HeartbeatWriter,
    LeaseMonitor,
    LeaseRecord,
    TornLease,
    claim_takeover,
    lease_status,
    read_lease,
    write_lease,
)


class _Clock:
    """Injectable monotonic clock."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)


def _writer(tmp_path, clock, ttl=3.0, term=1):
    return HeartbeatWriter(tmp_path / "hb.json", worker="w", term=term,
                           ttl=ttl, now_fn=clock)


# ---------------------------------------------------------------------------
# Renewal monotonicity.
# ---------------------------------------------------------------------------

def test_seq_strictly_increases_and_progress_monotone(tmp_path):
    clock = _Clock()
    hb = _writer(tmp_path, clock)
    seqs, progs = [], []
    for i in range(10):
        hb.bump(i % 3)
        rec = hb.beat()
        seqs.append(rec.seq)
        progs.append(rec.progress)
    assert seqs == sorted(set(seqs)), "seq must strictly increase"
    assert progs == sorted(progs), "progress must be non-decreasing"
    on_disk = read_lease(tmp_path / "hb.json")
    assert on_disk.seq == seqs[-1] and on_disk.progress == progs[-1]


def test_monitor_fresh_while_beating_expired_after_ttl(tmp_path):
    clock = _Clock()
    hb = _writer(tmp_path, clock, ttl=2.0)
    mon = LeaseMonitor(tmp_path / "hb.json", ttl=2.0, grace=5.0,
                       expect_term=1, now_fn=clock)
    for _ in range(8):                    # renewals within ttl: fresh
        hb.beat()
        clock.advance(0.5)
        st_ = mon.poll()
        assert st_["state"] == "fresh" and not st_["expired"]
    clock.advance(1.9)                    # beats cease; inside ttl still
    assert not mon.poll()["expired"]
    clock.advance(0.2)                    # now past ttl since last advance
    st_ = mon.poll()
    assert st_["state"] == "expired" and st_["expired"]


def test_monitor_never_compares_cross_process_clocks(tmp_path):
    # A lease whose *writer* clock is absurdly far in the past/future
    # must not matter: only observed advancement on the monitor's clock.
    clock = _Clock(1000.0)
    mon = LeaseMonitor(tmp_path / "hb.json", ttl=1.0, grace=10.0,
                       expect_term=1, now_fn=clock)
    rec = LeaseRecord(worker="w", pid=1, term=1, seq=1, progress=0,
                      ttl=1.0, mono=-9e9, wall=9e12)
    write_lease(tmp_path / "hb.json", rec)
    assert mon.poll()["state"] == "fresh"
    clock.advance(0.5)
    write_lease(tmp_path / "hb.json",
                LeaseRecord(worker="w", pid=1, term=1, seq=2, progress=0,
                            ttl=1.0, mono=9e9, wall=0.0))
    assert mon.poll()["state"] == "fresh"
    clock.advance(1.1)                    # no further advancement
    assert mon.poll()["state"] == "expired"


def test_monitor_grace_bounds_absent_and_old_terms_are_ghosts(tmp_path):
    clock = _Clock()
    mon = LeaseMonitor(tmp_path / "hb.json", ttl=1.0, grace=3.0,
                       expect_term=2, now_fn=clock)
    assert mon.poll()["state"] == "absent"
    # A dead incarnation's record (term 1 < expect_term 2) is a ghost.
    write_lease(tmp_path / "hb.json",
                LeaseRecord(worker="w", pid=1, term=1, seq=99, progress=9,
                            ttl=1.0, mono=0.0, wall=0.0))
    st_ = mon.poll()
    assert st_["state"] == "absent" and st_["expired"] is False
    clock.advance(3.1)                    # grace elapsed, still no term-2
    assert mon.poll()["expired"]


def test_progress_ttl_detects_stall_with_live_beats(tmp_path):
    clock = _Clock()
    hb = _writer(tmp_path, clock, ttl=2.0)
    mon = LeaseMonitor(tmp_path / "hb.json", ttl=2.0, grace=5.0,
                       expect_term=1, progress_ttl=3.0, now_fn=clock)
    hb.bump()
    hb.beat()
    assert mon.poll()["state"] == "fresh"
    for _ in range(4):                    # beats keep coming, progress frozen
        clock.advance(1.0)
        hb.beat()
        mon.poll()
    st_ = mon.poll()
    assert st_["state"] == "stalled" and st_["expired"]
    hb.bump()                             # progress resumes -> fresh again
    hb.beat()
    assert mon.poll()["state"] == "fresh"


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(steps=st.lists(
    st.tuples(st.booleans(), st.floats(0.01, 1.0)), min_size=1,
    max_size=40))
def test_prop_expiry_iff_no_advancement_for_ttl(tmp_path_factory, steps):
    """expired <=> monitor time since last observed advance > ttl."""
    tmp_path = tmp_path_factory.mktemp("hb")
    clock = _Clock()
    ttl = 1.0
    hb = _writer(tmp_path, clock, ttl=ttl)
    mon = LeaseMonitor(tmp_path / "hb.json", ttl=ttl, grace=100.0,
                       expect_term=1, now_fn=clock)
    hb.beat()
    mon.poll()
    since_advance = 0.0
    for beat, dt in steps:
        if beat:
            hb.beat()
        clock.advance(dt)
        st_ = mon.poll()
        # The monitor observes the beat at this poll, so advancement
        # resets *now* when one happened since the last poll.
        since_advance = 0.0 if beat else since_advance + dt
        if abs(since_advance - ttl) > 1e-9:   # off the float boundary
            assert st_["expired"] == (since_advance > ttl), \
                (steps, since_advance, st_)


# ---------------------------------------------------------------------------
# Takeover exclusivity.
# ---------------------------------------------------------------------------

def test_takeover_exclusive_sequential(tmp_path):
    path = tmp_path / "hb.json"
    assert claim_takeover(path, 2) is True
    assert claim_takeover(path, 2) is False      # second claimant loses
    assert claim_takeover(path, 3) is True       # next term is fresh


def test_takeover_exclusive_racing_threads(tmp_path):
    path = tmp_path / "hb.json"
    wins = []
    barrier = threading.Barrier(8)

    def race():
        barrier.wait()
        wins.append(claim_takeover(path, 7))

    threads = [threading.Thread(target=race) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(wins) == 1, f"exactly one of 8 racers may win, got {wins}"


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(terms=st.lists(st.integers(1, 6), min_size=1, max_size=24))
def test_prop_one_winner_per_term(tmp_path_factory, terms):
    tmp_path = tmp_path_factory.mktemp("claims")
    path = tmp_path / "hb.json"
    winners = {}
    for i, term in enumerate(terms):
        if claim_takeover(path, term):
            assert term not in winners, "a term was claimed twice"
            winners[term] = i
    assert set(winners) == set(terms), "first claim per term must win"


# ---------------------------------------------------------------------------
# Torn writes carry no liveness evidence.
# ---------------------------------------------------------------------------

def _valid_lease_bytes(tmp_path) -> bytes:
    path = tmp_path / "hb.json"
    write_lease(path, LeaseRecord(worker="w", pid=1, term=1, seq=5,
                                  progress=3, ttl=2.0, mono=0.0, wall=0.0))
    return path.read_bytes()


def test_truncated_lease_is_torn_and_expired(tmp_path):
    raw = _valid_lease_bytes(tmp_path)
    path = tmp_path / "hb.json"
    for cut in (0, 1, len(raw) // 2, len(raw) - 2):
        path.write_bytes(raw[:cut])   # 0 = empty-but-existing file
        with pytest.raises(TornLease):
            read_lease(path)
        st_ = lease_status(path, now=0.0)
        assert st_["state"] == "torn" and st_["expired"]


def test_corrupted_lease_byte_is_torn_never_fresh(tmp_path):
    raw = _valid_lease_bytes(tmp_path)
    path = tmp_path / "hb.json"
    clock = _Clock()
    mon = LeaseMonitor(path, ttl=100.0, grace=100.0, expect_term=1,
                       now_fn=clock)
    flipped = bytearray(raw)
    flipped[3] ^= 0xFF                    # damage inside the payload
    path.write_bytes(bytes(flipped))
    st_ = mon.poll()
    assert st_["state"] == "torn" and st_["expired"]


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=80, deadline=None)
@given(data=st.data())
def test_prop_damaged_lease_never_classifies_fresh(tmp_path_factory, data):
    tmp_path = tmp_path_factory.mktemp("torn")
    raw = _valid_lease_bytes(tmp_path)
    path = tmp_path / "hb.json"
    mode = data.draw(st.sampled_from(["truncate", "flip"]))
    if mode == "truncate":
        # Up to len-2: dropping only the trailing newline leaves a
        # complete payload+digest, which is legitimately not torn.
        cut = data.draw(st.integers(0, len(raw) - 2))
        damaged = raw[:cut]
    else:
        pos = data.draw(st.integers(0, len(raw) - 1))
        bit = data.draw(st.integers(0, 7))
        b = bytearray(raw)
        b[pos] ^= 1 << bit
        damaged = bytes(b)
    if damaged == raw:                    # flip landed on trailing newline?
        return                            # (impossible for sha256 hex, but
                                          # keep the property total)
    path.write_bytes(damaged)
    st_ = lease_status(path, now=0.0)
    assert st_["state"] in ("torn", "expired"), st_
    assert st_["expired"], "damaged lease files must never look alive"


def test_writer_context_manager_beats_and_stops(tmp_path):
    path = tmp_path / "hb.json"
    with HeartbeatWriter(path, worker="w", term=1, ttl=0.2) as hb:
        first = read_lease(path)
        assert first is not None and first.seq >= 1
        hb.bump(4)
    rec = read_lease(path)
    assert rec.term == 1
    # Stopped: no renewal thread left running.
    assert hb._thread is None
