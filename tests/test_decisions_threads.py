"""Thread-safety regressions for the decision service (ISSUE 9 bugfixes).

Three bugs, each pinned by a failing-before/passing-after test:

1. ``decide_batch`` used to read ``self._current`` three separate times
   (bounds check, output shape, then again inside every ``lookup()``) —
   a concurrent ``rebind()`` mid-call validated bounds against one
   generation and answered from another, or raised ``IndexError`` for
   users the *new* generation no longer covers. Now the whole batch
   answers from one snapshot (injected-rebind tests below).
2. The serve layer had zero synchronization: LRU mutations and the
   ``stats`` counters raced under threaded lookups, and ``rebind()``'s
   two-step ``_current``/``_fallback`` swap was not atomic with respect
   to an in-flight ``lookup()`` — a fetch failure straddling a rebind
   would retry the very generation that just failed instead of the
   armed fallback. Now a service lock guards cache/stats/binding swap
   (threaded stress with exact counter accounting below).
3. ``health()`` with a configured ``supervisor_root`` but no
   SUPERVISOR.json silently reported ``"supervisor": None`` —
   indistinguishable from a dead supervisor — and a torn/unparseable
   document raised straight through the health endpoint.
"""
import sys
import threading

import numpy as np
import pytest

import jax

from repro.checkpoint import ckpt
from repro.core import SolverConfig
from repro.core.faults import FaultPolicy
from repro.serve import (DecisionService, RefreshEngine, WorkloadSpec,
                         synthetic_source)

jax.config.update("jax_platform_name", "cpu")

SPEC = WorkloadSpec(seed=3, n=1024, k=4, chunk=128, q=1, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=25)
SCALES = [1.0, 0.9, 0.8]


@pytest.fixture(scope="module")
def gens(tmp_path_factory):
    """Three published generations + their full decision matrices."""
    root = tmp_path_factory.mktemp("decisions_threads")
    eng = RefreshEngine(root, SPEC, cfg=CFG)
    out = {"engine": eng, "root": root, "gen": [], "ref": []}
    for s in SCALES:
        g = eng.refresh(budget_scale=s)
        svc = DecisionService(synthetic_source(g.spec), g, cache_chunks=16)
        out["gen"].append(g)
        out["ref"].append(svc.decide_batch(np.arange(SPEC.n)))
    return out


def _svc(gen, **kw) -> DecisionService:
    return DecisionService(synthetic_source(gen.spec), gen, **kw)


# ---------------------------------------------------------------------------
# Bug 1: decide_batch must answer the whole batch from ONE binding.
# ---------------------------------------------------------------------------

class _RebindOnFirstChunk(DecisionService):
    """Injects a rebind between the bounds check and the chunk fills —
    exactly the window the un-snapshotted decide_batch was exposed in."""

    def arm(self, source, generation):
        self._inject = (source, generation)

    def _chunk_decisions(self, bound, ci):
        inject, self._inject = getattr(self, "_inject", None), None
        if inject is not None:
            self.rebind(*inject)
        return super()._chunk_decisions(bound, ci)


def test_decide_batch_rows_come_from_one_generation(gens):
    """A rebind mid-batch must not switch later rows to the new
    generation: bounds were validated and provenance is reported
    against the snapshot."""
    g0, g1 = gens["gen"][0], gens["gen"][1]
    svc = _RebindOnFirstChunk(synthetic_source(g0.spec), g0,
                              cache_chunks=16)
    svc.arm(synthetic_source(g1.spec), g1)
    users = np.arange(0, SPEC.n, 17)          # spans every chunk
    x, stale, gens_served = svc.lookup_batch(users)
    # Pre-fix: rows filled after the injected rebind came from gen 1
    # (different multipliers -> different rows); the fixed batch is
    # bitwise the snapshot generation's materialisation, end to end.
    assert x.tobytes() == gens["ref"][0][users].tobytes()
    assert (gens_served == g0.gen).all() and not stale.any()
    # The service itself DID follow the flip (the injection ran).
    assert svc.generation.gen == g1.gen


def test_decide_batch_bounds_and_fills_use_same_generation(gens, tmp_path):
    """Shrinking traffic (smaller n) mid-batch: users validated against
    the snapshot generation must all be answered, not IndexError'd
    against the rebound one."""
    eng = RefreshEngine(tmp_path / "shrink", SPEC, cfg=CFG)
    big = eng.refresh(budget_scale=1.0)                  # n = 1024
    small = eng.refresh(budget_scale=0.95, n=SPEC.n // 2)  # n = 512
    svc = _RebindOnFirstChunk(synthetic_source(big.spec), big,
                              cache_chunks=16)
    svc.arm(synthetic_source(small.spec), small)
    users = np.array([3, 200, 600, 900, 1023])   # tail outside small's n
    ref = _svc(big, cache_chunks=16).decide_batch(users)
    x, stale, gens_served = svc.lookup_batch(users)   # pre-fix: IndexError
    assert x.tobytes() == ref.tobytes()
    assert (gens_served == big.gen).all() and not stale.any()


# ---------------------------------------------------------------------------
# Bug 2a: the degraded path must use the fallback snapshotted WITH the
# current binding, not whatever a concurrent rebind just demoted.
# ---------------------------------------------------------------------------

_POISON_CHUNK = 2


def _poison(source):
    inner = source.fn

    def fn(i):
        if int(i) == _POISON_CHUNK:
            raise IOError("injected permanent fault")
        return inner(i)

    return source._replace(fn=fn)


class _RebindInFetch(DecisionService):
    """Triggers a rebind inside the failing fetch — the racing window
    between a lookup's current-read and its fallback-read."""

    def arm(self, source, generation):
        self._inject = (source, generation)

    def _fetch(self, bound, ci):
        inject = getattr(self, "_inject", None)
        if inject is not None and int(ci) == _POISON_CHUNK:
            self._inject = None
            self.rebind(*inject)
        return super()._fetch(bound, ci)


def test_degraded_fallback_is_snapshotted_across_rebind(gens):
    g0, g1, g2 = gens["gen"]
    policy = FaultPolicy(max_retries=1, backoff_base=1e-6,
                         backoff_cap=1e-5)
    svc = _RebindInFetch(_poison(synthetic_source(g1.spec)), g1,
                         cache_chunks=16, fault_policy=policy,
                         fallback=(synthetic_source(g0.spec), g0))
    svc.arm(synthetic_source(g2.spec), g2)
    user = _POISON_CHUNK * SPEC.chunk + 5
    res = svc.lookup(user)
    # Pre-fix: the rebind demoted the (poisoned) current generation to
    # fallback before the degraded path read self._fallback — the
    # "fallback" fetch failed identically and the lookup raised.
    # Post-fix the armed fallback pair is part of the snapshot.
    assert res.stale and res.gen == g0.gen
    assert res.x.tobytes() == gens["ref"][0][user].tobytes()
    assert svc.stats["stale_serves"] == 1
    assert svc.stats["fetch_failures"] == 1


# ---------------------------------------------------------------------------
# Bug 2b: threaded lookups + rebind churn — exact counters, bitwise rows.
# ---------------------------------------------------------------------------

def test_threaded_lookups_under_rebind_churn_stay_exact(gens):
    g0, g1 = gens["gen"][0], gens["gen"][1]
    refs = {g0.gen: gens["ref"][0], g1.gen: gens["ref"][1]}
    svc = _svc(g0, cache_chunks=3)        # tiny LRU: eviction churn too
    n_threads, per_thread = 4, 250
    results = [[] for _ in range(n_threads)]
    errors = []
    stop = threading.Event()

    def reader(t):
        rng = np.random.default_rng(100 + t)
        try:
            for j in range(per_thread):
                if j % 5 == 0:
                    users = rng.integers(0, SPEC.n, 8)
                    x, stale, gs = svc.lookup_batch(users)
                    assert not stale.any()
                    for u, row, g in zip(users, x, gs):
                        results[t].append((int(u), row.tobytes(), int(g)))
                else:
                    u = int(rng.integers(0, SPEC.n))
                    r = svc.lookup(u)
                    results[t].append((u, r.x.tobytes(), int(r.gen)))
        except Exception as e:            # noqa: BLE001 — surfaced below
            errors.append(repr(e))

    def rebinder():
        flip = 0
        while not stop.is_set():
            tgt = (g1, g0)[flip % 2]
            svc.rebind(synthetic_source(tgt.spec), tgt)
            flip += 1

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)           # stress the interleavings
    try:
        threads = [threading.Thread(target=reader, args=(t,))
                   for t in range(n_threads)]
        rb = threading.Thread(target=rebinder)
        rb.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        rb.join()
    finally:
        sys.setswitchinterval(old)

    assert errors == []
    total = sum(len(r) for r in results)
    # Every row bitwise-equal to the generation that claims it.
    for rows in results:
        for u, raw, g in rows:
            assert raw == refs[g][u].tobytes()
    # Exact counter accounting under arbitrary interleaving: one query
    # per lookup, each resolving to exactly one hit or fill. Lost
    # updates (the pre-lock races dropped increments) break these
    # equalities. Two threads racing a miss on the same chunk both
    # count a fill while the second insert overwrites the first, so the
    # cache holds at most fills - evictions entries — and never more
    # than its configured capacity.
    s = svc.stats
    assert s["queries"] == total
    assert s["hits"] + s["fills"] == s["queries"]
    assert len(svc._cache) <= svc.cache_chunks
    assert s["fills"] - s["evictions"] >= len(svc._cache)
    assert s["stale_serves"] == 0 and s["fetch_failures"] == 0


# ---------------------------------------------------------------------------
# Bug 3: supervisor health must distinguish absent / present / damaged.
# ---------------------------------------------------------------------------

def test_health_supervisor_absent_is_explicit(gens):
    svc = gens["engine"].decision_service()
    h = svc.health()
    # Pre-fix: None — indistinguishable from "supervisor died and its
    # document vanished". Now an explicit status document.
    assert h["supervisor"] == {"status": "absent"}


def test_health_survives_unreadable_supervisor_doc(gens):
    root = gens["root"]
    svc = gens["engine"].decision_service()
    ckpt.write_json(root, "SUPERVISOR.json", {"state": "running"})
    assert svc.health()["supervisor"]["state"] == "running"
    # External damage: torn/garbage bytes where the document should be.
    (root / "SUPERVISOR.json").write_text("{not json", encoding="utf-8")
    h = svc.health()                      # pre-fix: ValueError escapes
    assert h["supervisor"]["status"] == "unreadable"
    assert "SUPERVISOR.json" in h["supervisor"]["error"]
    assert h["generation"] == gens["gen"][-1].gen
    (root / "SUPERVISOR.json").unlink()   # leave the root clean
