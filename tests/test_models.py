"""Architecture zoo: per-arch smoke tests + decode/prefill equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import registry
from repro.models import model as M
from repro.models import lm
from repro.optim import OptConfig, init_opt_state

jax.config.update("jax_platform_name", "cpu")

ARCHS = registry.names()


def _batch(cfg, key, b=2, s=64):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    tgts = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": tgts}
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(key, (b, 32, cfg.d_model), cfg.dtype)
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke_train_and_decode(name):
    """One reduced-config train step + one decode step: shapes, finiteness."""
    cfg = registry.get(name).smoke()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(7))
    opt_cfg = OptConfig(warmup=10)
    ostate = init_opt_state(params, opt_cfg)
    step = jax.jit(M.make_train_step(cfg, opt_cfg))
    p2, o2, metrics = step(params, ostate, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    # params actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0, name

    caches = M.init_cache(cfg, params, 2, 128, frames=batch.get("frames"))
    dstep = jax.jit(M.make_decode_step(cfg))
    logits, caches2 = dstep(params, caches, jnp.ones((2, 1), jnp.int32),
                            jnp.int32(3))
    assert logits.shape == (2, 1, cfg.vocab), name
    assert bool(jnp.all(jnp.isfinite(logits))), name


@pytest.mark.parametrize("name", ARCHS)
def test_arch_prefill_step(name):
    cfg = registry.get(name).smoke()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(3))
    pf = jax.jit(M.make_prefill_step(cfg))
    logits = pf(params, batch)
    assert logits.shape == (2, cfg.vocab), name
    assert bool(jnp.all(jnp.isfinite(logits))), name


@pytest.mark.parametrize("name", ["yi-34b", "mamba2-370m", "deepseek-v2-236b",
                                  "jamba-v0.1-52b", "gemma-2b"])
def test_decode_matches_prefill(name):
    """Token-by-token decode with cache must reproduce the teacher-forced
    forward logits (validates SSD step vs chunked scan, MLA absorbed decode
    vs materialised attention, GQA cache plumbing)."""
    import dataclasses
    cfg = registry.get(name).smoke().replace(remat=False)
    if cfg.moe.n_experts:
        # decode always routes with plain top-k; align the train path so the
        # equivalence check exercises the cache plumbing, not the router
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, router="topk"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)

    h = lm.forward(params, cfg, toks)
    head = params.get("head", params["embed"])
    from repro.models.layers import unembed
    ref_logits = np.asarray(unembed(head, h).astype(jnp.float32))  # (b,s,V)

    caches = M.init_cache(cfg, params, b, s)
    dstep = jax.jit(M.make_decode_step(cfg))
    outs = []
    for t in range(s):
        logits, caches = dstep(params, caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(np.asarray(logits.astype(jnp.float32))[:, 0])
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref_logits, rtol=2e-2, atol=2e-3)


def test_long_500k_skip_rules():
    """Skip accounting per DESIGN §Arch-applicability."""
    runs, skips = [], []
    cell = M.SHAPES["long_500k"]
    for name in ARCHS:
        cfg = registry.get(name)
        (runs if M.cell_applicable(cfg, cell) is None else skips).append(name)
    assert set(runs) == {"mamba2-370m", "jamba-v0.1-52b"}
    assert len(skips) == 8


@given(seed=st.integers(0, 2**31 - 1), q=st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_scd_router_capacity_property(seed, q):
    """The paper's router: expert load never exceeds capacity; per-token
    choices never exceed Q (hypothesis sweep over logits)."""
    from repro.core.moe_router import scd_route

    key = jax.random.PRNGKey(seed)
    t, e = 128, 8
    logits = jax.random.normal(key, (t, e)) * 3.0
    out = scd_route(logits, q=q, capacity_factor=1.1, iters=4)
    cap = 1.1 * q * t / e
    assert np.all(np.asarray(out.load) <= cap + 1e-6)
    assert np.all(np.asarray(out.mask.sum(1)) <= q)
    # combine weights only on assigned experts
    assert np.all((np.asarray(out.combine) > 0) <= np.asarray(out.mask))


def test_scd_router_balances_better_than_topk():
    """Adversarially skewed logits: SCD pricing caps hot experts; plain
    top-k overflows them."""
    from repro.core.moe_router import scd_route, topk_route

    key = jax.random.PRNGKey(0)
    t, e = 256, 8
    logits = jax.random.normal(key, (t, e))
    logits = logits.at[:, 0].add(4.0)        # everyone loves expert 0
    cap = 1.25 * 2 * t / e
    scd = scd_route(logits, q=2, capacity_factor=1.25, iters=6)
    topk = topk_route(logits, q=2)
    assert float(topk.load.max()) > cap      # heuristic overflows
    assert float(scd.load.max()) <= cap + 1e-6
    # roughly as many total assignments (within the capacity bound)
    assert float(scd.mask.sum()) >= 0.7 * float(topk.mask.sum())
