"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev dependency (see pyproject.toml) but is not baked
into every runtime image. Importing it unconditionally used to fail
*collection* of three whole test modules, hiding their non-property tests.
This shim re-exports the real ``given``/``settings``/``st`` when available
and otherwise substitutes stand-ins that collect the decorated tests and
mark them skipped — so collection always succeeds and only the
property-based subset is lost on minimal images.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_kw):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy constructors only feed @given, never run."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _Strategies()
