"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev dependency (see pyproject.toml) but is not baked
into every runtime image. Importing it unconditionally used to fail
*collection* of three whole test modules, hiding their non-property tests.
This shim re-exports the real ``given``/``settings``/``st`` when available
and otherwise substitutes stand-ins that collect the decorated tests and
mark them skipped — so collection always succeeds and only the
property-based subset is lost on minimal images.

Anti-skip gate: with ``REQUIRE_HYPOTHESIS`` set in the environment (CI
does this) a missing ``hypothesis`` is re-raised instead of silently
downgrading the property suites to skips — the tier-1 job must run
them, not collect them as green-looking skips.
"""
from __future__ import annotations

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_kw):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy constructors only feed @given, never run."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _Strategies()
