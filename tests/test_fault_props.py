"""Property tests for the fault layer's schedule and injection algebra.

The properties (repro/core/faults.py):

* the backoff schedule is a pure function of ``(policy, chunk)`` —
  replaying it yields identical floats (determinism is what lets the
  chaos tests assert bitwise solver parity while faults fire);
* it is monotone non-decreasing until the cap and never exceeds the
  cap — guaranteed structurally by the ``growth >= 1 + jitter``
  constructor constraint, checked here against adversarial policies;
* attempts are bounded: a fetch runs at most ``max_retries + 1`` times
  and its failure history records exactly the attempts made;
* :func:`faulty_source` exhaustion semantics: an offender chunk with
  ``offender_failures <= max_retries`` ALWAYS heals under retries, one
  with ``offender_failures > max_retries`` ALWAYS exhausts — and clean
  payloads pass through bit-identically.

Each property has a deterministic twin (fixed cases, always run) and a
hypothesis sweep (skipped without hypothesis unless REQUIRE_HYPOTHESIS
is set — see tests/_hypothesis_compat.py).
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.faults import (
    ChunkFetchError,
    FaultPlan,
    FaultPolicy,
    faulty_source,
    fetch_with_retries,
    resilient_source,
)


class _Src:
    """Minimal HostChunkSource-shaped stand-in (duck-typed _replace/fn)."""

    def __init__(self, fn):
        self.fn = fn

    def _replace(self, fn):
        return _Src(fn)


def _policies():
    return st.builds(
        FaultPolicy,
        max_retries=st.integers(0, 8),
        backoff_base=st.floats(0.0, 10.0, allow_nan=False),
        backoff_growth=st.floats(2.0, 8.0, allow_nan=False),
        backoff_cap=st.floats(0.0, 100.0, allow_nan=False),
        jitter=st.floats(0.0, 0.99, allow_nan=False),
        timeout=st.just(0.0),
    )


# ---------------------------------------------------------------------------
# Schedule determinism + shape.
# ---------------------------------------------------------------------------

def check_schedule(policy, chunk):
    s1 = policy.schedule(chunk)
    s2 = policy.schedule(chunk)
    # Determinism: bit-identical floats on replay.
    assert s1 == s2 and len(s1) == policy.max_retries
    for a, d in enumerate(s1, start=1):
        assert d == policy.backoff(chunk, a)
        # Bounded: never above the cap (and never negative).
        assert 0.0 <= d <= policy.backoff_cap
    # Monotone non-decreasing until the cap: once below the cap, the
    # next delay is never smaller (growth >= 1 + jitter guarantees it).
    for prev, nxt in zip(s1, s1[1:]):
        if prev < policy.backoff_cap:
            assert nxt >= prev, (prev, nxt, policy)


@pytest.mark.parametrize("policy,chunk", [
    (FaultPolicy(), 0),
    (FaultPolicy(max_retries=8, jitter=0.0), 3),
    (FaultPolicy(max_retries=6, backoff_base=1e-3, backoff_growth=5.0,
                 backoff_cap=0.5, jitter=0.9), 12345),
    (FaultPolicy(max_retries=5, backoff_base=0.0), 7),   # zero base: all 0
    (FaultPolicy(max_retries=4, backoff_cap=0.0), 2),    # cap 0: all 0
])
def test_schedule_deterministic_twin(policy, chunk):
    check_schedule(policy, chunk)


@settings(max_examples=200, deadline=None)
@given(policy=_policies(), chunk=st.integers(0, 2 ** 31 - 1))
def test_schedule_props(policy, chunk):
    check_schedule(policy, chunk)


def test_jitter_decorrelates_chunks():
    """Different chunks get different (deterministic) delays — retry
    storms from co-failing workers spread out instead of thundering."""
    policy = FaultPolicy(max_retries=1, backoff_base=1.0, backoff_cap=100.0,
                         jitter=0.5)
    delays = {policy.backoff(c, 1) for c in range(64)}
    assert len(delays) > 32


# ---------------------------------------------------------------------------
# Attempt accounting.
# ---------------------------------------------------------------------------

def check_attempts(max_retries, failures):
    calls = {"n": 0}

    def fn(i):
        occ = calls["n"]
        calls["n"] += 1
        if occ < failures:
            raise IOError(f"occ {occ}")
        return ("ok",)

    policy = FaultPolicy(max_retries=max_retries, backoff_base=0.0)
    if failures <= max_retries:
        assert fetch_with_retries(fn, 1, policy,
                                  sleep=lambda s: None) == ("ok",)
        assert calls["n"] == failures + 1
    else:
        with pytest.raises(ChunkFetchError) as ei:
            fetch_with_retries(fn, 1, policy, sleep=lambda s: None)
        assert calls["n"] == max_retries + 1          # bounded attempts
        assert len(ei.value.history) == max_retries + 1
        assert ei.value.chunk == 1


@pytest.mark.parametrize("max_retries,failures", [
    (0, 0), (0, 1), (3, 3), (3, 4), (8, 2), (2, 100),
])
def test_attempts_deterministic_twin(max_retries, failures):
    check_attempts(max_retries, failures)


@settings(max_examples=100, deadline=None)
@given(max_retries=st.integers(0, 10), failures=st.integers(0, 15))
def test_attempts_props(max_retries, failures):
    check_attempts(max_retries, failures)


# ---------------------------------------------------------------------------
# faulty_source exhaustion semantics under resilient_source.
# ---------------------------------------------------------------------------

def check_offender(max_retries, offender_failures):
    payload = (np.arange(8, dtype=np.float32).reshape(2, 4),
               np.ones((2, 4), np.float32))
    plan = FaultPlan(seed=0, offenders=(5,),
                     offender_failures=offender_failures)
    policy = FaultPolicy(max_retries=max_retries, backoff_base=0.0)
    src = resilient_source(faulty_source(_Src(lambda i: payload), plan),
                           policy, sleep=lambda s: None)
    if offender_failures <= max_retries:
        p, b = src.fn(5)                              # always heals
        np.testing.assert_array_equal(p, payload[0])
        np.testing.assert_array_equal(b, payload[1])
    else:
        with pytest.raises(ChunkFetchError) as ei:    # always exhausts
            src.fn(5)
        assert ei.value.chunk == 5
        assert len(ei.value.history) == max_retries + 1
    # Non-offender chunks pass through bit-identically either way.
    p, b = src.fn(0)
    np.testing.assert_array_equal(p, payload[0])
    np.testing.assert_array_equal(b, payload[1])


@pytest.mark.parametrize("max_retries,offender_failures", [
    (0, 0), (0, 1), (4, 4), (4, 5), (2, 10 ** 6),
])
def test_offender_deterministic_twin(max_retries, offender_failures):
    check_offender(max_retries, offender_failures)


@settings(max_examples=60, deadline=None)
@given(max_retries=st.integers(0, 6), offender_failures=st.integers(0, 10))
def test_offender_props(max_retries, offender_failures):
    check_offender(max_retries, offender_failures)


def test_injection_replays_identically():
    """Two faulty_source wrappers over the same plan make the same
    decisions call-for-call (hash of (seed, chunk, occurrence) only)."""
    payload = (np.zeros((2, 2), np.float32), np.zeros((2, 2), np.float32))
    plan = FaultPlan(seed=7, drop=0.3, corrupt=0.3)

    def trace():
        src = faulty_source(_Src(lambda i: payload), plan)
        out = []
        for i in range(16):
            for _ in range(3):                        # 3 occurrences each
                try:
                    p, _b = src.fn(i)
                    out.append(p.tobytes())
                except IOError:
                    out.append(b"drop")
        return out

    assert trace() == trace()
