"""Multi-process torn-read stress proof for the serving layer.

The claim (DESIGN.md §10): any number of reader *processes* may follow
the LIVE pointer, load generation records and run DecisionService
lookups while a writer process churns refreshes AND the generation GC
(``keep=2`` auto-prune) deletes old directories under them — and every
single read observes a fully published generation, bitwise.

The proof here is operational, not simulated:

* the main process first solves the whole refresh sequence in a
  *reference* root and saves every generation's record fields and full
  decision matrix;
* N real reader subprocesses then hammer a second *churn* root —
  pointer read, record load, 32 random lookups per round — while the
  main process re-runs the same refresh sequence there with ``keep=2``
  pruning generations behind the readers; the writer paces itself to
  the readers (each refresh waits until every reader has acknowledged
  observing the new generation) so every generation is actually read
  under churn regardless of machine load;
* every record field and every lookup a reader observes must be
  byte-identical to the reference for that generation id (the solver's
  determinism makes the two roots publish identical records, so ANY
  torn/partial/stale read shows up as a byte mismatch);
* a record load that fails is tolerated only when the pointer has
  moved on meanwhile (the documented GC-vs-reader contract: a vanished
  generation means "re-resolve the pointer") — a failed load under a
  stable pointer is a torn read and fails the test.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import jax

from repro.core import SolverConfig
from repro.serve import DecisionService, RefreshEngine, WorkloadSpec, \
    synthetic_source

jax.config.update("jax_platform_name", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent

SPEC = WorkloadSpec(seed=3, n=2048, k=8, chunk=256, q=2, tightness=0.4)
CFG = SolverConfig(reduce="bucketed", max_iters=30)
SCALES = [1.0, 0.95, 0.9, 0.85, 0.8]          # 5 generations of churn
N_READERS = 3
FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]

_READER = textwrap.dedent("""
    import json, os, pathlib, sys, time
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    from repro.checkpoint import ckpt
    from repro.serve import (DecisionService, RefreshEngine, WorkloadSpec,
                             synthetic_source)

    root, refdir, out, ready = map(pathlib.Path, sys.argv[1:5])
    rng = np.random.default_rng(int(sys.argv[5]))
    spec = WorkloadSpec(seed=3, n=2048, k=8, chunk=256, q=2, tightness=0.4)
    eng = RefreshEngine(root, spec)
    fields = ["lam", "tau", "iters", "r", "primal", "dual"]
    errors, gens_seen, reads, lookups = [], set(), 0, 0
    ready.write_text("ok")
    stop = root / "STOP"
    while True:
        done = stop.exists()             # checked BEFORE the read: the
        ptr = ckpt.read_json(root, "LIVE.json")   # last round still runs
        if ptr is None:
            if done:
                break
            time.sleep(0.01)
            continue
        g = int(ptr["gen"])
        try:
            gen = eng.generation(g)
        except (ValueError, OSError) as e:
            ptr2 = ckpt.read_json(root, "LIVE.json")
            if ptr2 is not None and int(ptr2["gen"]) != g:
                continue                 # GC raced us; pointer moved on
            errors.append(f"gen {g}: unreadable under a stable pointer "
                          f"(torn read): {e!r}")
            break
        reads += 1
        gens_seen.add(g)
        ref = np.load(refdir / f"gen_{g}.npz")
        for f in fields:
            if np.asarray(getattr(gen, f)).tobytes() != ref[f].tobytes():
                errors.append(f"gen {g}: field {f} mismatches reference")
        svc = DecisionService(synthetic_source(gen.spec), gen,
                              cache_chunks=4)
        users = rng.integers(0, spec.n, 32)
        x = svc.decide_batch(users)
        if x.tobytes() != ref["decisions"][users].tobytes():
            errors.append(f"gen {g}: lookup decisions mismatch reference")
        lookups += users.size
        ready.write_text(json.dumps(sorted(gens_seen)))   # ack progress
        if done:
            break
    out.write_text(json.dumps({"errors": errors, "reads": reads,
                               "lookups": lookups,
                               "gens": sorted(gens_seen)}))
    print("READER-DONE", reads)
""")


def _publish_reference(root, refdir):
    """Solve the refresh sequence once; persist per-generation truth."""
    refdir.mkdir(parents=True)
    eng = RefreshEngine(root, SPEC, cfg=CFG)
    for scale in SCALES:
        gen = eng.refresh(budget_scale=scale)
        svc = DecisionService(synthetic_source(gen.spec), gen,
                              cache_chunks=16)
        decisions = svc.decide_batch(np.arange(SPEC.n))
        np.savez(refdir / f"gen_{gen.gen}.npz", decisions=decisions,
                 **{f: np.asarray(getattr(gen, f)) for f in FIELDS})


@pytest.mark.slow
def test_multiprocess_readers_never_see_torn_state(tmp_path):
    _publish_reference(tmp_path / "ref_root", tmp_path / "ref")

    churn = tmp_path / "churn"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    readers, outs, readies = [], [], []
    for r in range(N_READERS):
        out = tmp_path / f"reader_{r}.json"
        ready = tmp_path / f"ready_{r}"
        outs.append(out)
        readies.append(ready)
        readers.append(subprocess.Popen(
            [sys.executable, "-c", _READER, str(churn),
             str(tmp_path / "ref"), str(out), str(ready), str(100 + r)],
            env=env, cwd=str(REPO), stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True))
    try:
        deadline = time.time() + 180
        while not all(r.exists() for r in readies):
            assert time.time() < deadline, "readers never became ready"
            assert all(p.poll() is None for p in readers), \
                [p.communicate() for p in readers if p.poll() is not None]
            time.sleep(0.05)

        # The churn: same refresh sequence, generations pruned to 2
        # behind the readers' backs. Publication is paced to the
        # readers — the next refresh waits until every reader has
        # acknowledged the current generation (via its ready file) so
        # that under arbitrary load each generation really is read
        # while the next one is being published and GC'd over.
        eng = RefreshEngine(churn, SPEC, cfg=CFG, keep=2)
        for scale in SCALES:
            g = eng.refresh(budget_scale=scale).gen
            while True:
                acked = 0
                for rd in readies:
                    try:
                        seen = json.loads(rd.read_text())
                    except (OSError, json.JSONDecodeError):
                        seen = []
                    if isinstance(seen, list) and g in seen:
                        acked += 1
                if acked == len(readers):
                    break
                assert time.time() < deadline, \
                    f"readers never observed gen {g}"
                assert all(p.poll() is None for p in readers), \
                    [p.communicate() for p in readers
                     if p.poll() is not None]
                time.sleep(0.02)
        (churn / "STOP").write_text("stop")

        for p in readers:
            stdout, stderr = p.communicate(timeout=180)
            assert p.returncode == 0, stdout + stderr
            assert "READER-DONE" in stdout, stdout + stderr
    finally:
        for p in readers:
            if p.poll() is None:
                p.kill()

    results = [json.loads(o.read_text()) for o in outs]
    for r, res in enumerate(results):
        assert res["errors"] == [], f"reader {r}: {res['errors']}"
        assert res["reads"] > 0 and res["lookups"] > 0, res
        # Pacing guarantees every reader really watched the pointer
        # move through every generation — this was a race, not one
        # quiet generation at the end.
        assert res["gens"] == list(range(len(SCALES))), res["gens"]

    # The GC really ran underneath them and never touched live/pending.
    assert eng.generation_ids() == [3, 4]
    assert eng.live().gen == 4 and eng._pending() is None
