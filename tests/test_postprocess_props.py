"""Property tests for the §5.4 projection arithmetic (postprocess.py).

Two families, each with a deterministic smoke twin (always runs) and a
hypothesis-driven sweep (skipped only when hypothesis is missing AND the
``REQUIRE_HYPOTHESIS`` anti-skip gate is off — CI sets it):

* ``threshold_and_removed``: the prefix-subtraction projection against
  two independent oracles — a NumPy reimplementation of the f32
  histogram/threshold decision (exact match required: same tie
  convention, same edge choice, same fallback) and a float64 brute-force
  row-sum removal oracle (tolerance match on the removed masses; the f32
  histogram groups additions differently). Includes the tau = +inf
  overflow fallback: mass above the ladder still yields a feasible —
  remove-everything — projection.
* ``profit_edges_fixed``: strictly monotone edges, and every
  representable positive f32 profit (subnormals through inf) bins to a
  valid bucket of the default ladder under the repo-wide
  searchsorted-left convention.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.postprocess import (
    profit_edges_fixed,
    removable_hist,
    threshold_and_removed,
)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Check bodies (plain functions of concrete inputs).
# ---------------------------------------------------------------------------

def _random_case(seed, n, k, tight, overflow_frac):
    """A random removal instance: nonneg group profits/consumption/gains,
    budgets scaled to ``tight`` of total consumption, ``overflow_frac``
    of the rows pushed above the ladder's top edge."""
    rng = np.random.default_rng(seed)
    pt = rng.uniform(1e-7, 10.0, n).astype(np.float32)
    over = rng.random(n) < overflow_frac
    pt = np.where(over, pt * np.float32(1e7), pt).astype(np.float32)
    cons = rng.uniform(0.0, 1.0, (n, k)).astype(np.float32)
    gain = rng.uniform(0.0, 1.0, n).astype(np.float32)
    budgets = (np.maximum(cons.sum(0, dtype=np.float64), 1e-3)
               * tight).astype(np.float32)
    return pt, cons, gain, budgets


def check_threshold_and_removed(pt, cons, gain, budgets, n_edges=64):
    """Assert the projection contract on one concrete instance."""
    edges = profit_edges_fixed(n_edges)
    e_np = np.asarray(edges)
    ch = removable_hist(jnp.asarray(pt), jnp.asarray(cons), edges)
    gh = removable_hist(jnp.asarray(pt), jnp.asarray(gain)[:, None], edges)[0]
    r_total = jnp.sum(jnp.asarray(cons), axis=0)
    tau, rc, rg = threshold_and_removed(ch, gh, edges, r_total,
                                        jnp.asarray(budgets))
    tau = float(tau)
    rc, rg = np.asarray(rc), np.asarray(rg)
    r_np = np.asarray(r_total)

    # Independent NumPy reimplementation of the decision: histogram by
    # searchsorted-left with a row-order scatter (np.add.at == the XLA
    # scatter's duplicate-index order: exact match required), then f64
    # prefix sums for the minimal feasible edge. The in-function prefix
    # is an f32 XLA scan whose association differs from a sequential
    # cumsum, so edge choices are only asserted when every deciding
    # comparison clears an ambiguity band wider than that rounding.
    idx = np.searchsorted(e_np, pt, side="left")
    hist = np.zeros((cons.shape[1], n_edges + 1), np.float32)
    for kk in range(cons.shape[1]):
        np.add.at(hist[kk], idx, cons[:, kk])
    np.testing.assert_array_equal(np.asarray(ch), hist)
    excess = np.maximum(r_np - budgets, 0.0).astype(np.float32)
    ccum = np.cumsum(hist, axis=-1, dtype=np.float64)
    feas = np.all(ccum[:, :n_edges] >= excess[:, None].astype(np.float64),
                  axis=0)
    band = 1e-4 * (1.0 + np.abs(excess))[:, None].astype(np.float64)
    unambiguous = not np.any(
        np.abs(ccum[:, :n_edges] - excess[:, None]) < band)
    if not excess.any():
        assert tau == -np.inf and not rc.any() and rg == 0.0
        return
    if feas.any():
        e_star = int(np.argmax(feas))
        if unambiguous:
            assert tau == e_np[e_star], (tau, e_np[e_star])
        removed = pt <= tau if np.isfinite(tau) else np.ones_like(pt, bool)
    else:
        if unambiguous:
            assert tau == np.inf                  # overflow fallback
        removed = (np.ones_like(pt, bool) if tau == np.inf
                   else pt <= tau)
    # Removal restores feasibility exactly in f32.
    assert np.all(r_np - rc <= budgets)
    # float64 brute-force row-sum oracle for the removed masses — over
    # the set the function's own tau selects, so it holds through
    # near-tie edge choices too (the f32 histogram prefix groups the
    # additions differently: tolerance).
    oracle_c = cons[removed].sum(0, dtype=np.float64)
    oracle_g = gain[removed].sum(dtype=np.float64)
    scale_c = max(float(cons.sum(dtype=np.float64)), 1.0)
    np.testing.assert_allclose(rc, oracle_c, rtol=1e-4,
                               atol=1e-5 * scale_c)
    np.testing.assert_allclose(rg, oracle_g, rtol=1e-4,
                               atol=1e-5 * max(oracle_g, 1.0))
    # Minimality: one edge earlier does not cover the excess.
    if unambiguous and feas.any() and e_star > 0:
        assert not np.all(ccum[:, e_star - 1] >= excess)


def check_profit_edges_bins_everything(values, n_edges=512, lo=1e-6, hi=1e6):
    edges = np.asarray(profit_edges_fixed(n_edges, lo, hi))
    assert edges.shape == (n_edges,)
    assert np.all(np.diff(edges) > 0), "edges must be strictly monotone"
    assert edges[0] == np.float32(lo) and edges[-1] == np.float32(hi)
    idx = np.searchsorted(edges, np.asarray(values, np.float32), side="left")
    assert np.all((idx >= 0) & (idx <= n_edges))
    # Below-ladder mass shares bucket 0; above-ladder mass lands in the
    # overflow bucket the tau = +inf fallback can still remove.
    assert np.all(idx[np.asarray(values, np.float32) <= lo] == 0)
    assert np.all(idx[np.asarray(values, np.float32) > hi] == n_edges)


# ---------------------------------------------------------------------------
# Deterministic twins: always run, also on hypothesis-less images.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,tight,overflow", [
    (0, 0.5, 0.0),      # ordinary removal
    (1, 0.95, 0.0),     # barely infeasible
    (2, 2.0, 0.0),      # already feasible: tau = -inf
    (3, 0.5, 0.3),      # some groups above the ladder
    (4, 1e-6, 1.0),     # everything above the ladder: tau = +inf fallback
    (5, 0.01, 0.5),     # huge excess, mixed
])
def test_threshold_and_removed_cases(seed, tight, overflow):
    pt, cons, gain, budgets = _random_case(seed, 300, 5, tight, overflow)
    check_threshold_and_removed(pt, cons, gain, budgets)


def test_threshold_overflow_fallback_removes_everything():
    """All mass above the ladder and budgets ~0: no edge prefix covers
    the excess, tau = +inf, and the prefix subtraction empties the
    solution — feasible by construction."""
    pt, cons, gain, budgets = _random_case(7, 100, 4, 1e-6, 1.0)
    edges = profit_edges_fixed(64)
    ch = removable_hist(jnp.asarray(pt), jnp.asarray(cons), edges)
    gh = removable_hist(jnp.asarray(pt), jnp.asarray(gain)[:, None], edges)[0]
    r = jnp.sum(jnp.asarray(cons), axis=0)
    tau, rc, rg = threshold_and_removed(ch, gh, edges, r,
                                        jnp.asarray(budgets))
    assert float(tau) == np.inf
    np.testing.assert_allclose(np.asarray(rc), np.asarray(r), rtol=1e-6)
    assert np.all(np.asarray(r) - np.asarray(rc) <= budgets)


def test_profit_edges_fixed_bins_representative_floats():
    vals = np.array([np.finfo(np.float32).tiny, 1e-38, 1e-7, 1e-6,
                     1.0000001e-6, 3.14, 1e6, 1.0000001e6, 1e30,
                     np.finfo(np.float32).max, np.inf], np.float32)
    check_profit_edges_bins_everything(vals)
    check_profit_edges_bins_everything(vals, n_edges=2, lo=0.5, hi=2.0)
    check_profit_edges_bins_everything(vals, n_edges=1024, lo=1e-3, hi=1e3)


# ---------------------------------------------------------------------------
# Hypothesis sweeps (CI: REQUIRE_HYPOTHESIS makes absence a failure).
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 400), st.integers(1, 8),
       st.floats(1e-6, 4.0), st.sampled_from([0.0, 0.1, 0.5, 1.0]))
@settings(max_examples=80, deadline=None)
def test_threshold_and_removed_property(seed, n, k, tight, overflow):
    pt, cons, gain, budgets = _random_case(seed, n, k, tight, overflow)
    check_threshold_and_removed(pt, cons, gain, budgets)


@given(st.integers(0, 2**31 - 1), st.integers(2, 1024))
@settings(max_examples=60, deadline=None)
def test_profit_edges_fixed_property(seed, n_edges):
    rng = np.random.default_rng(seed)
    # log-uniform across the full positive f32 range, plus exact edges
    vals = np.exp(rng.uniform(np.log(1e-38), np.log(3e38), 200)
                  ).astype(np.float32)
    edges = np.asarray(profit_edges_fixed(n_edges))
    vals = np.concatenate([vals, edges, [np.inf]]).astype(np.float32)
    check_profit_edges_bins_everything(vals, n_edges=n_edges)
