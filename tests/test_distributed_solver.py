"""Distributed (shard_map) solver == single-device solver, plus
straggler-tolerant reduce. Runs in a subprocess so the 8 fake XLA host
devices never leak into other tests."""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import *
from repro.core.instances import sparse_instance, dense_instance, shard_key
from repro.core.types import SolverConfig

kp, q = sparse_instance(shard_key(4), n=1024, k=10, q=1, tightness=0.4)
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = SolverConfig(reduce="bucketed", max_iters=20)

res_d = solve_sharded(kp, mesh, cfg, q=q)
res_l = solve(kp, cfg, q=q)

np.testing.assert_allclose(np.asarray(res_d.lam), np.asarray(res_l.lam),
                           rtol=2e-2, atol=2e-3)
np.testing.assert_allclose(float(res_d.dual), float(res_l.dual), rtol=1e-2)
assert np.all(np.asarray(res_d.r) <= np.asarray(kp.budgets) * (1 + 1e-4)), "dist feasibility"
# primal within 2% (postprocess differs: bucketed vs exact projection)
np.testing.assert_allclose(float(res_d.primal), float(res_l.primal), rtol=2e-2)

# exact reduce distributed == local bit-for-bit on lam
cfg_e = SolverConfig(reduce="exact", max_iters=10, postprocess=False)
rd = solve_sharded(kp, mesh, cfg_e, q=q)
rl = solve(kp, cfg_e, q=q)
np.testing.assert_allclose(np.asarray(rd.lam), np.asarray(rl.lam), rtol=1e-5, atol=1e-6)

# straggler mitigation: proceed with 75% of shards, still feasible + close
cfg_s = SolverConfig(reduce="bucketed", max_iters=20, partial_fraction=0.75)
rs = solve_sharded(kp, mesh, cfg_s, q=q)
assert np.all(np.asarray(rs.r) <= np.asarray(kp.budgets) * (1 + 1e-4)), "straggler feasibility"
np.testing.assert_allclose(float(rs.primal), float(res_l.primal), rtol=0.08)

# dense instance distributed
kpd = dense_instance(shard_key(6), n=512, m=8, k=4, local="C223", tightness=0.25)
rdd = solve_sharded(kpd, mesh, SolverConfig(reduce="bucketed", max_iters=15), q=0)
assert np.all(np.asarray(rdd.r) <= np.asarray(kpd.budgets) * (1 + 1e-4))
rdl = solve(kpd, SolverConfig(reduce="bucketed", max_iters=15), q=0)
# distributed feasibility projection is bucketed (conservative): allow 4%
np.testing.assert_allclose(float(rdd.primal), float(rdl.primal), rtol=4e-2)

print("DISTRIBUTED-OK")
"""


PRESOLVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import *
from repro.core.instances import sparse_instance, shard_key
from repro.core.types import SolverConfig

kp, q = sparse_instance(shard_key(4), n=1024, k=10, q=1, tightness=0.4)
mesh = jax.make_mesh((4, 2), ("data", "model"))

# presolve warm start in distributed mode converges in fewer iters; the
# cold solve must itself converge before max_iters (the damped update
# breaks the old period-2 limit cycle that made this test an xfail).
cfg_p = SolverConfig(reduce="bucketed", max_iters=30, presolve_samples=64)
rp = solve_sharded(kp, mesh, cfg_p, q=q)
rc = solve_sharded(kp, mesh, cfg_p.replace(presolve_samples=0), q=q)
assert int(rc.iters) < 30, f"cold solve still cycling: {int(rc.iters)}"
assert int(rp.iters) <= int(rc.iters), (int(rp.iters), int(rc.iters))

print("PRESOLVE-OK")
"""


CHUNKED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import *
from repro.core.chunked import array_source, solve_streaming
from repro.core.instances import sparse_instance, shard_key
from repro.core.types import SolverConfig

kp, q = sparse_instance(shard_key(4), n=1024, k=10, q=1, tightness=0.4)
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = SolverConfig(reduce="bucketed", max_iters=20)
base = solve_sharded(kp, mesh, cfg, q=q)

# chunk_size under shard_map: every field bitwise, incl. ragged local
# tails (128 rows/shard, chunk 100) and chunk >= local n (chunk 4096).
for c in [1, 100, 128, 4096]:
    rc = solve_sharded(kp, mesh, cfg.replace(chunk_size=c), q=q)
    np.testing.assert_array_equal(np.asarray(rc.lam), np.asarray(base.lam)), c
    np.testing.assert_array_equal(np.asarray(rc.x), np.asarray(base.x)), c
    assert int(rc.iters) == int(base.iters), c
    assert float(rc.primal) == float(base.primal), c
    assert float(rc.dual) == float(base.dual), c

# streaming under shard_map: 16 chunks of 64 rows over 8 shards; the
# multiplier trajectory matches the resident sharded solve bitwise.
ss = solve_streaming(array_source(kp, 64), cfg, q=q, mesh=mesh)
np.testing.assert_array_equal(np.asarray(ss.lam), np.asarray(base.lam))
assert int(ss.iters) == int(base.iters)
assert np.all(np.asarray(ss.r) <= np.asarray(kp.budgets) * (1 + 1e-4))
np.testing.assert_allclose(float(ss.primal), float(base.primal), rtol=2e-2)

print("CHUNKED-OK")
"""


def _run_script(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900, cwd=str(REPO),
    )


@pytest.mark.slow
def test_distributed_solver_subprocess():
    out = _run_script(SCRIPT)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "DISTRIBUTED-OK" in out.stdout


@pytest.mark.slow
def test_distributed_presolve_cuts_iterations():
    """Was an xfail (sync-CD period-2 limit cycle kept per-iteration
    movement just above tol); the reversal-damped update (cfg.cd_damping)
    shrinks the cycle geometrically, so warm <= cold holds and both
    converge before max_iters."""
    out = _run_script(PRESOLVE_SCRIPT)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "PRESOLVE-OK" in out.stdout


@pytest.mark.slow
def test_distributed_chunked_bit_identical():
    """cfg.chunk_size and the streaming driver under shard_map on 8
    virtual devices: bit-identical to the unchunked sharded solve."""
    out = _run_script(CHUNKED_SCRIPT)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "CHUNKED-OK" in out.stdout
