"""CLI launchers: solve.py end-to-end, one dry-run cell, examples."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(args, timeout=560, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable] + args, env=env, capture_output=True, text=True,
        timeout=timeout, cwd=str(cwd or REPO))


def test_solve_cli():
    out = _run(["-m", "repro.launch.solve", "--n", "50000", "--k", "8",
                "--max-iters", "20"])
    assert out.returncode == 0, out.stdout + out.stderr
    lines = dict(l.split(": ") for l in out.stdout.strip().splitlines())
    assert int(lines["iterations"]) <= 20
    assert float(lines["max_violation"]) <= 1e-4
    gap = float(lines["duality_gap"])
    assert 0 <= gap < 0.01 * float(lines["primal"])


@pytest.mark.slow
def test_dryrun_single_cell_cli(tmp_path):
    out = _run(["-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
                "--shape", "decode_32k", "--no-probe",
                "--out", str(tmp_path / "r.json")])
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(tmp_path / "r.json"))[0]
    assert rec["status"] == "ok"
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["fits_16gb_hbm"]


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert out.returncode == 0, out.stderr
    assert "duality gap" in out.stdout
    # feasible
    viol_line = [l for l in out.stdout.splitlines() if "max violation" in l][0]
    assert float(viol_line.split(":")[1].split("%")[0]) <= 1e-3
