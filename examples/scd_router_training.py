"""The paper inside the LM: train a small MoE with the SCD knapsack router.

Trains the reduced moonshot-v1-16b-a3b config twice — heuristic top-k
router vs the paper's SCD capacity-priced router — and reports loss and
expert-load balance. The SCD router holds every expert at or under its
capacity by construction (core/moe_router.py), which is the property the
heuristic router needs an auxiliary loss to approximate.

    PYTHONPATH=src python examples/scd_router_training.py [--steps 60]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.moe_router import scd_route, topk_route
from repro.launch.train import train
from repro.optim import OptConfig


def load_stats(router, seed=0, t=512, e=8, q=2, skew=2.5):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (t, e))
    logits = logits.at[:, 0].add(skew)          # a popular expert
    out = (scd_route(logits, q=q, iters=6) if router == "scd"
           else topk_route(logits, q=q))
    return np.asarray(out.load)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    for router in ("topk", "scd"):
        cfg = registry.get("moonshot-v1-16b-a3b").smoke()
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, router=router))
        _, _, losses = train(cfg, OptConfig(lr=3e-3, warmup=10),
                             steps=args.steps, batch_shape=(4, 64),
                             log_every=0, seed=5)
        load = load_stats(router)
        cap = 1.25 * 2 * 512 / 8
        print(f"router={router:5s} loss {losses[0]:.3f} -> "
              f"{np.mean(losses[-5:]):.3f} | skewed-load max={load.max():.0f} "
              f"(capacity {cap:.0f}) imbalance={load.max() / load.mean():.2f}x")


if __name__ == "__main__":
    main()
