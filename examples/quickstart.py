"""Quickstart: solve a generalized knapsack problem in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import SolverConfig, solve
from repro.core.instances import shard_key, sparse_instance

# 100k users, 10 knapsacks, pick at most 2 items per user (§5.1 sparse form)
kp, q = sparse_instance(shard_key(0), n=100_000, k=10, q=2, tightness=0.4)

res = solve(kp, SolverConfig(algo="scd", reduce="bucketed", max_iters=30), q=q)

print(f"iterations      : {int(res.iters)}")
print(f"primal objective: {float(res.primal):,.2f}")
print(f"dual bound      : {float(res.dual):,.2f}")
print(f"duality gap     : {float(res.dual - res.primal):,.2f} "
      f"({float((res.dual - res.primal) / res.primal) * 100:.3f}%)")
viol = jnp.max((res.r - kp.budgets) / kp.budgets)
print(f"max violation   : {float(viol) * 100:.4f}%  (<= 0 means feasible)")
print(f"selected items  : {int(res.x.sum()):,} / {kp.p.size:,}")
