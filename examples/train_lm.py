"""End-to-end LM training driver with checkpoint/restart.

Trains a reduced-config model from the zoo for a few hundred steps on the
synthetic stream, checkpointing every 50 steps; re-running the same
command resumes from the newest checkpoint (kill it mid-run to see).

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 200
"""
import argparse

from repro.configs import registry
from repro.launch.train import train
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = registry.get(args.arch).smoke()
    _, _, losses = train(
        cfg, OptConfig(lr=3e-3, warmup=20), steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, batch_shape=(4, 128),
        log_every=20,
    )
    print(f"loss: {losses[0]:.3f} -> {sum(losses[-10:]) / 10:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
