"""End-to-end driver: the paper's headline workload, scaled to this host.

The paper solves N = 1e9 users / 1e9 constraints on 200 Spark executors in
under an hour. This driver runs the SAME jitted program (one lax.scan of
SCD iterations: Alg 5 map -> §5.2 bucketed psum reduce -> replicated
multiplier update -> §5.4 projection) over as many devices as exist, and
reports Table-1-style metrics plus the measured per-iteration throughput
extrapolated to the billion-user mesh footprint.

    PYTHONPATH=src python examples/billion_scale_solve.py --users 4000000

On a 256-chip pod the identical program (see launch/dryrun.py --paper-kp
billion) shards 1e9 users at ~3.9M per chip — the size this driver runs on
ONE device — so the printed per-iteration wall time is, to first order,
the per-iteration time of the full billion-user solve (the reduce is a
constant-size psum).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import SolverConfig, solve
from repro.core.instances import shard_key, sparse_instance


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=2_000_000)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()

    print(f"building {args.users:,}-user sparse GKP (K={args.k}, Q={args.q})")
    kp, q = sparse_instance(shard_key(0), args.users, args.k, args.q,
                            tightness=0.5)
    cfg = SolverConfig(reduce="bucketed", max_iters=args.iters,
                       presolve_samples=10_000)

    t0 = time.time()
    res = jax.block_until_ready(solve(kp, cfg, q=q))
    dt = time.time() - t0

    gap = float(res.dual - res.primal)
    print(f"iterations   : {int(res.iters)} (+presolve)")
    print(f"primal       : {float(res.primal):,.2f}")
    print(f"duality gap  : {gap:,.2f} ({gap / float(res.primal) * 100:.4f}%)")
    print(f"max violation: "
          f"{float(jnp.max((res.r - kp.budgets) / kp.budgets)) * 100:+.4f}%")
    print(f"wall         : {dt:.1f}s "
          f"({dt / max(int(res.iters), 1):.2f} s/iter at "
          f"{args.users:,} users/device)")
    per_chip = 1_000_000_000 / 256
    print(f"\n[extrapolation] 1e9 users on a 16x16 pod = {per_chip:,.0f} "
          f"users/chip ({per_chip / args.users:.2f}x this run); the reduce "
          "is a constant-size (K x buckets) psum, so per-iteration time "
          "scales with the map shard only.")


if __name__ == "__main__":
    main()
