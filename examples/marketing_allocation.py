"""The paper's production scenario: daily marketing-budget allocation.

2 million users; 8 campaign channels (items) with hierarchical caps —
at most 1 push notification, at most 2 app banners, at most 3 contacts
overall (Definition 2.1 laminar family) — and 5 global budget pools the
channels draw from. Demonstrates the full production recipe:

  1. §5.3 pre-solve on a 10k-user sample to warm-start the prices,
  2. Alg 4 SCD with the §5.2 bucketed reduce,
  3. §5.4 post-processing so no budget pool is ever exceeded,
  4. DD (Alg 2) comparison run — the paper's Figure 5/6 story,
  5. the §6 deployment epilogue: budgets move day over day, so the
     allocation is re-solved warm through the serving refresh engine
     (repro/serve) and single users' next-day plans are answered by the
     decision service without materialising anyone else's.

    PYTHONPATH=src python examples/marketing_allocation.py [--users 2000000]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DenseKP, SolverConfig, hierarchy_from_lists, solve
from repro.core.instances import shard_key
import jax


def build_instance(n_users, seed=0):
    key = shard_key(seed)
    m, k = 8, 5
    kp_, kb = jax.random.split(key)
    # expected conversion lift per (user, channel)
    p = jax.random.uniform(kp_, (n_users, m), jnp.float32)
    # cost of channel j against budget pool k (sparse-ish: each channel
    # draws mainly from 1-2 pools)
    b = jax.random.uniform(kb, (n_users, m, k), jnp.float32) * 0.2
    main_pool = jnp.arange(m) % k
    b = b.at[:, jnp.arange(m), main_pool].add(
        jax.random.uniform(jax.random.fold_in(key, 3), (n_users, m)))
    # laminar caps: channels 0-1 = push (cap 1), 2-4 = banners (cap 2),
    # root cap 3 contacts per user
    local = hierarchy_from_lists(
        [[0, 1], [2, 3, 4], list(range(m))], [1, 2, 3], m)
    budgets = jnp.full((k,), 0.12 * n_users, jnp.float32)
    return DenseKP(p=p, b=b, budgets=budgets, sets=local.sets,
                   caps=local.caps)


def refresh_epilogue(kp, n_users, days=3, seed=0):
    """Daily budget refresh: the dense campaign re-priced warm, per §6.

    The daily loop works the sparse per-channel view of the same users
    (channel j's cost for user i = its total pool draw, budgets per
    channel, root cap 3 contacts — the laminar sub-caps stay with the
    dense solve above): each day's budget shift is a `WorkloadSpec`
    delta, the refresh engine re-solves warm from yesterday's channel
    prices, and tomorrow's plan for any single user is an O(chunk)
    lookup against the published generation.
    """
    import tempfile

    from repro.core.prefetch import host_array_source
    from repro.serve import RefreshEngine, WorkloadSpec

    m = kp.p.shape[1]
    p = np.asarray(kp.p, np.float32)
    b = np.asarray(jnp.sum(kp.b, axis=-1), np.float32)  # per-channel cost
    base_budgets = np.full((m,), 0.15 * n_users, np.float32)
    chunk = 16384

    def make_source(spec):
        budgets = (base_budgets * np.float32(spec.budget_scale)
                   ).astype(np.float32)
        return host_array_source(p, b, budgets, spec.chunk)

    spec = WorkloadSpec(seed=seed, n=n_users, k=m, chunk=chunk, q=3)
    eng = RefreshEngine(tempfile.mkdtemp(prefix="marketing_gens_"), spec,
                        make_source=make_source,
                        cfg=SolverConfig(reduce="bucketed", max_iters=40))
    print("\ndaily refresh (per-channel budgets, warm-started):")
    for day, scale in enumerate([1.0, 0.9, 1.08][:days]):
        gen = eng.refresh(budget_scale=scale)
        print(f"  day {day}: budgets x{scale:.2f} -> "
              f"{gen.iters:2d} iters ({'warm' if gen.warm else 'cold'}), "
              f"primal {float(gen.primal):14,.1f}")
    svc = eng.decision_service()
    for user in (0, n_users // 2, n_users - 1):
        channels = np.flatnonzero(svc.decide(user))
        print(f"  user {user:>9,}: contact via channels {channels.tolist()}")
    print(f"  lookups touched {svc.stats['fills']} chunk(s) "
          f"of {-(-n_users // chunk)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=200_000)
    args = ap.parse_args()

    kp = build_instance(args.users)
    base = SolverConfig(reduce="bucketed", max_iters=30)

    for name, cfg in [
        ("SCD cold", base),
        ("SCD + presolve", base.replace(presolve_samples=10_000)),
        ("DD  lr=1e-3", base.replace(algo="dd", dd_lr=1e-3, max_iters=30)),
    ]:
        t0 = time.time()
        res = solve(kp, cfg, q=0)
        dt = time.time() - t0
        viol = float(jnp.max((res.r - kp.budgets) / kp.budgets))
        print(f"{name:16s} iters={int(res.iters):3d} "
              f"primal={float(res.primal):14,.1f} "
              f"gap={float(res.dual - res.primal):10,.1f} "
              f"viol={viol * 100:+.3f}%  wall={dt:.1f}s")

    res = solve(kp, base.replace(presolve_samples=10_000), q=0)
    x = np.asarray(res.x)
    print("\nper-channel allocation:", x.sum(0))
    print("contacts per user      :", float(x.sum(1).mean()))
    print("all local caps hold    :",
          bool((x[:, :2].sum(1) <= 1).all()
               and (x[:, 2:5].sum(1) <= 2).all()
               and (x.sum(1) <= 3).all()))

    refresh_epilogue(kp, args.users)


if __name__ == "__main__":
    main()
