"""Roofline derivation from the dry-run report (§Roofline deliverable).

Reads reports/dryrun_full.json (written by launch/dryrun.py) and computes,
per (arch x shape x mesh):

    compute    = FLOPs_per_chip  / 197 TF/s          (bf16 peak, v5e)
    memory     = bytes_per_chip  / 819 GB/s          (HBM)
    collective = coll_bytes_per_chip / 50 GB/s       (ICI per link)

Scan correction: XLA's cost model visits a while-loop body once, so the
full-program numbers are (program) + (n_periods - 1) x (single-period
probe program). All quantities are per-chip (the SPMD module's shapes are
per-device; see dryrun.py).

MODEL_FLOPS (the "useful" numerator, attention excluded by convention):
    train:   6 * N_active * tokens      prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch       (one token per sequence)

The headline score per cell is mfu_proxy = useful-FLOPs-time / dominant
term — the MFU an execution achieving the roofline bound would get.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def active_params(cfg, n_params: int) -> int:
    """Active (per-token) parameter count: total minus unrouted experts."""
    m = cfg.moe
    if not m.n_experts:
        return n_params
    # routed expert params per moe layer
    per_expert = cfg.d_model * 2 * m.d_ff + m.d_ff * cfg.d_model
    n_moe_layers = sum(
        1 for i in range(cfg.n_layers)
        if cfg.ffn_pattern[i % len(cfg.ffn_pattern)] == "moe"
    )
    if cfg.first_dense_ff:
        n_moe_layers = max(n_moe_layers - 0, 0)  # layer0 override is dense
        n_moe_layers = n_moe_layers - (1 if cfg.ffn_pattern[0] == "moe" else 0)
    routed = n_moe_layers * m.n_experts * per_expert
    inactive = routed * (1.0 - m.topk / m.n_experts)
    return int(n_params - inactive)


def model_flops_per_chip(cfg, cell, n_params, chips):
    na = active_params(cfg, n_params)
    if cell["kind"] == "train":
        tokens = cell["global_batch"] * cell["text_len"]
        return 6.0 * na * tokens / chips
    if cell["kind"] == "prefill":
        tokens = cell["global_batch"] * cell["text_len"]
        return 2.0 * na * tokens / chips
    return 2.0 * na * cell["global_batch"] / chips


def corrected(rec):
    """(flops, bytes, coll_bytes) per chip with the scan-probe correction."""
    flops = rec["cost"]["flops"]
    byts = rec["cost"]["bytes_accessed"]
    coll = rec["collectives"]["total_bytes"]
    probe = rec.get("scan_probe")
    if probe and probe.get("flops", -1) > 0:
        extra = probe["n_periods"] - 1
        flops += extra * probe["flops"]
        byts += extra * probe["bytes_accessed"]
        coll += extra * probe["collectives"]["total_bytes"]
    return flops, byts, coll


def analyse(report_path="reports/dryrun_full.json"):
    from repro.configs import registry
    from repro.models import model as M

    recs = json.load(open(report_path))
    rows = []
    for r in recs:
        if r["status"] != "ok":
            rows.append({**{k: r.get(k) for k in ("arch", "shape", "mesh",
                                                  "status")},
                         "reason": r.get("reason", r.get("error", ""))[:90]})
            continue
        chips = 512 if r["mesh"] == "2x16x16" else 256
        cfg = registry.get(r["arch"])
        cell = M.SHAPES[r["shape"]]
        flops, byts, coll = corrected(r)
        # Analytic model (benchmarks/analytic.py): the primary compute /
        # memory terms — XLA's cost model undercounts inner scan bodies
        # even after the layer-probe correction, so HLO terms are reported
        # as secondary reference columns.
        from benchmarks.analytic import cell_terms
        ana = cell_terms(cfg, cell, r["n_params"], chips)
        t_c = ana.compute_s(PEAK)
        t_m = ana.memory_s(HBM)
        t_x = coll / ICI
        dom = max(t_c, t_m, t_x)
        which = {t_c: "compute", t_m: "memory", t_x: "collective"}[dom]
        mf = model_flops_per_chip(
            cfg,
            {"kind": cell.kind, "global_batch": cell.global_batch,
             "text_len": M._text_len(cfg, cell.seq_len)},
            r["n_params"], chips)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "hlo_compute_s": flops / PEAK, "hlo_memory_s": byts / HBM,
            "dominant": which,
            "useful_ratio": mf / ana.flops_per_chip if ana.flops_per_chip else 0.0,
            "mfu_proxy": (mf / PEAK) / dom if dom else 0.0,
            "hbm_gb": r["memory"].get("per_device_bytes_est", 0) / 1e9,
            "n_params": r["n_params"],
        })
    return rows


def markdown(rows):
    out = ["| arch | shape | mesh | compute s | memory s | coll s | bound | useful | MFU-proxy | HBM GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r.get('arch')} | {r.get('shape')} | {r.get('mesh')} "
                       f"| — | — | — | {r.get('status')} | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['mfu_proxy'] * 100:.1f}% | {r['hbm_gb']:.1f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun_full.json"
    if not pathlib.Path(path).exists():
        print(f"roofline: no report at {path} (run launch/dryrun.py --all)")
        return
    rows = analyse(path)
    print(markdown(rows))
    with open("reports/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
