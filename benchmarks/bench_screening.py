"""Active-set screening bench: items streamed per iteration + parity.

``PYTHONPATH=src python -m benchmarks.bench_screening [--smoke] [--out P]``

The screening claim (DESIGN.md §11) in numbers: on the ratio-banded
workload (``data.synth.banded_host_chunk_source`` — hot cohorts every
``period`` chunks, cold cohorts whose profit ratios provably bin below
the narrowed bucket ladder) the screened host-fed solve retires most
chunks after the first epochs, so the per-iteration streamed-item curve
collapses geometrically while the published result stays **bitwise**
the unscreened oracle's.

What the report claims, and how it is gated:

* **Streamed-chunk profiles are the hardware-independent number**: the
  solve is deterministic, so the screened per-iteration counts (and the
  unscreened ``iters × c`` baseline) reproduce everywhere. The bench
  itself exits 1 unless (a) every screened result field is bitwise the
  unscreened one and (b) the screened solve streamed at most as many
  chunks in total; ``tools/bench_diff.py`` then gates the committed
  items-reduction ratio against CI's measurement.
* **Wall time is recorded, not gated here** — the smoke instances are
  small enough that dispatch overhead dominates; the streamed-item
  accounting is the honest proxy for the I/O a billion-row deployment
  saves.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SolverConfig  # noqa: E402
from repro.core.prefetch import solve_streaming_host  # noqa: E402
from repro.data.synth import banded_host_chunk_source  # noqa: E402

K, Q, TIGHTNESS, BAND = 6, 2, 0.08, 0.05
RESULT_FIELDS = ("lam", "iters", "r", "primal", "dual", "tau")

# (n, chunk): the smoke point is shared with CI so bench_diff can match
# points by n against the committed report.
GRID = [(4000, 250), (16000, 500)]
SMOKE_GRID = [(4000, 250)]


def _cfg(screening):
    return SolverConfig(reduce="bucketed", max_iters=30, bucket_half=12,
                        screening=screening)


def _bitwise(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in RESULT_FIELDS)


def bench_point(n, chunk, seed=7):
    src = banded_host_chunk_source(seed, n, K, chunk, q=Q,
                                   tightness=TIGHTNESS, band=BAND)
    c = -(-n // chunk)

    t0 = time.time()
    base = solve_streaming_host(src, _cfg(False), q=Q)
    wall_base = time.time() - t0
    t0 = time.time()
    scr = solve_streaming_host(src, _cfg(True), q=Q)
    wall_scr = time.time() - t0

    iters = int(base.iters)
    # Iteration-epoch accounting only: the fused finalize pass streams
    # all c chunks in both modes and is excluded from both sides.
    base_profile = [c] * iters
    scr_profile = [int(x) for x in scr.screen["streamed_chunks"]]
    base_items = sum(base_profile) * chunk
    scr_items = sum(scr_profile) * chunk
    return {
        "n": n, "chunk": chunk, "chunks": c, "k": K, "q": Q,
        "tightness": TIGHTNESS, "band": BAND, "iterations": iters,
        "unscreened": {"chunks_per_iter": base_profile,
                       "items_streamed": base_items,
                       "wall_s": round(wall_base, 3)},
        "screened": {"chunks_per_iter": scr_profile,
                     "items_streamed": scr_items,
                     "wall_s": round(wall_scr, 3),
                     "final_active": int(scr.screen["active"].sum()),
                     "resets": int(scr.screen["resets"]),
                     "fallbacks": int(scr.screen["fallbacks"])},
        "items_reduction": round(base_items / max(scr_items, 1), 3),
        "identical": _bitwise(base, scr),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_screening.json")
    args = ap.parse_args()

    points = []
    print("n,iterations,unscreened_items,screened_items,reduction,identical")
    for n, chunk in (SMOKE_GRID if args.smoke else GRID):
        p = bench_point(n, chunk)
        points.append(p)
        print(f"{n},{p['iterations']},"
              f"{p['unscreened']['items_streamed']},"
              f"{p['screened']['items_streamed']},"
              f"{p['items_reduction']},{p['identical']}")

    report = {
        "bench": "screening",
        "backend": jax.default_backend(),
        "points": points,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p["n"] for p in points
           if not p["identical"]
           or p["screened"]["items_streamed"]
           > p["unscreened"]["items_streamed"]]
    if bad:
        print(f"REGRESSION: screened solve diverged from the unscreened "
              f"oracle (or streamed more) at n={bad}")
        sys.exit(1)


if __name__ == "__main__":
    main()
