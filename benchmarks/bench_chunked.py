"""Out-of-core chunked solve: device memory flat in n, past the HBM cap.

``PYTHONPATH=src python -m benchmarks.bench_chunked [--smoke] [--out PATH]``

The paper's billion-scale claim holds only if per-worker state is
O(items processed at a time), not O(local items). This benchmark
demonstrates that for the streaming driver (core/chunked.py):

* **solves** the §6 sparse workload through the fused Pallas kernel at
  n from the largest unchunked BENCH_scd.json point (32768) up to 8-16x
  past it, chunks synthesized on demand — the (n, K) instance never
  exists;
* **AOT memory analysis** (same probe as launch/dryrun.py) of the
  compiled streaming program at each n: argument + temp bytes must be
  flat in n (the scan carries O(chunk·K + K·E) state and a loop
  counter), while the resident ``solve`` program's bytes grow as
  8·n·K + intermediates — its device-memory ceiling is exactly what the
  streaming path removes;
* **pass accounting** (DESIGN.md §5c, ``BENCH_stream_passes.json``):
  measured source passes and per-pass wall time for the fused
  (``iters + 1``) vs legacy (``iters + 3``) finalize, and for the
  host-fed pipeline (core/prefetch.py) with double-buffered vs
  synchronous ``device_put`` — the combined fused+double-buffered
  speedup over legacy+synchronous is the headline number
  ``tools/bench_diff.py`` gates against. A ``checkpointed_fused``
  entry measures the preemption-safety premium (DESIGN.md §7):
  ``cfg.checkpoint_every=2`` atomic resume-state saves on the same
  solve, reported as ``overhead_frac`` against the unprotected run
  (the pass count must stay ``iters + 1`` — checkpointing never
  re-reads the source beyond the one-chunk fingerprint probe).

The CI smoke gate fails if the streaming program's footprint is not flat
(<= 1% drift across n), if the big-n solve regresses infeasible, or if
a measured pass count deviates from the §5c accounting. Writes
``BENCH_chunked.json`` next to ``BENCH_scd.json`` so later PRs can diff
the trajectory.
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import SolverConfig, SparseKP  # noqa: E402
from repro.core.chunked import stream_solve_fn  # noqa: E402
from repro.core.prefetch import solve_streaming_host  # noqa: E402
from repro.core.solver import _solve_entry  # noqa: E402
from repro.data.synth import (  # noqa: E402
    sparse_chunk_source,
    sparse_host_chunk_source,
)

K, Q, CHUNK = 8, 1, 8192
# Largest unchunked point in BENCH_scd.json is n=32768; the acceptance
# bar is a solve at >= 8x that with flat peak device memory.
GRID = [32768, 65536, 131072, 262144, 524288]
SMOKE_GRID = [32768, 65536]
# Pass-accounting grid: the smoke size (shared with CI so bench_diff can
# match points) plus the largest solve.
PASSES_GRID = [65536, 524288]
PASSES_SMOKE_GRID = [65536]


def _cfg(use_kernels=True, max_iters=12):
    return SolverConfig(reduce="bucketed", max_iters=max_iters,
                        use_kernels=use_kernels)


def _streaming_fn(src, cfg):
    return stream_solve_fn(src, cfg, Q)


def _aot_bytes(lowered):
    """argument + temp bytes of a compiled program (dryrun.py calibration:
    both are per-device on this backend); -1 when the backend can't say."""
    try:
        ma = lowered.compile().memory_analysis()
        arg = int(getattr(ma, "argument_size_in_bytes", -1))
        temp = int(getattr(ma, "temp_size_in_bytes", -1))
        return {"argument_bytes": arg, "temp_bytes": temp,
                "total_bytes": arg + temp}
    except Exception as e:  # pragma: no cover - CPU backend quirks
        return {"error": str(e), "total_bytes": -1}


def bench_point(n, seed=0, use_kernels=True, max_iters=12):
    """Solve the n-user workload streaming; report wall time + AOT bytes."""
    cfg = _cfg(use_kernels, max_iters)
    src = sparse_chunk_source(seed, n, K, CHUNK, q=Q, tightness=0.4)
    fn = _streaming_fn(src, cfg)
    lam0 = jnp.ones((K,), jnp.float32)

    stream_mem = _aot_bytes(fn.lower(src.budgets, lam0))
    # Resident-solve footprint at the same n: the ceiling being removed.
    resident = jax.jit(functools.partial(
        _solve_entry, q=Q, cfg=cfg.replace(use_kernels=False), axis=None))
    abstract = SparseKP(
        p=jax.ShapeDtypeStruct((n, K), jnp.float32),
        b=jax.ShapeDtypeStruct((n, K), jnp.float32),
        budgets=jax.ShapeDtypeStruct((K,), jnp.float32),
    )
    resident_mem = _aot_bytes(resident.lower(abstract, lam0))

    t0 = time.perf_counter()
    res = jax.block_until_ready(fn(src.budgets, lam0))
    wall = time.perf_counter() - t0
    feasible = bool(jnp.all(res.r <= src.budgets * (1 + 1e-4)))
    return {
        "n": n, "k": K, "q": Q, "chunk": CHUNK,
        "use_kernels": use_kernels,
        "iterations": int(res.iters),
        "duality_gap_frac": float((res.dual - res.primal) / res.primal),
        "feasible": feasible,
        "wall_s": round(wall, 4),
        "streaming_memory": stream_mem,
        "resident_memory": resident_mem,
    }


def _count_device_passes(src):
    """Wrap a traced ChunkSource with a runtime fetch counter."""
    from jax.experimental import io_callback

    calls = {"n": 0}
    inner = src.fn

    def _bump(_):
        calls["n"] += 1
        return np.int32(0)

    def fn(i):
        io_callback(_bump, jax.ShapeDtypeStruct((), np.int32), i,
                    ordered=False)
        return inner(i)

    return src._replace(fn=fn), calls


# Timed solves repeat this many times and keep the fastest wall: the
# container's CPU shares are throttled in bursts, and min-of-N is the
# standard way to read a stable number through that.
REPEATS = 3


def _timed_device_solve(n, cfg, seed=0):
    """Streamed device solve with measured wall time and source passes."""
    src = sparse_chunk_source(seed, n, K, CHUNK, q=Q, tightness=0.4)
    src, calls = _count_device_passes(src)
    fn = stream_solve_fn(src, cfg, Q)
    lam0 = jnp.ones((K,), jnp.float32)
    # AOT-compile and time the executable itself: compile time excluded.
    compiled = fn.lower(src.budgets, lam0).compile()
    wall = float("inf")
    for _ in range(REPEATS):
        # Drain in-flight (unordered) io_callbacks before resetting, or a
        # straggler from the previous repeat lands after the reset.
        jax.effects_barrier()
        calls["n"] = 0
        t0 = time.perf_counter()
        res = jax.block_until_ready(compiled(src.budgets, lam0))
        wall = min(wall, time.perf_counter() - t0)
    jax.effects_barrier()
    n_chunks = -(-n // CHUNK)
    assert calls["n"] % n_chunks == 0, (calls["n"], n_chunks)
    return res, wall, calls["n"] // n_chunks


def _timed_host_solve(n, cfg, double_buffer, seed=0):
    """Host-fed streamed solve (numpy chunk producer) with pass counts."""
    src = sparse_host_chunk_source(seed, n, K, CHUNK, q=Q, tightness=0.4)
    calls = {"n": 0}
    inner = src.fn

    def fn(i):
        calls["n"] += 1
        return inner(i)

    src = src._replace(fn=fn)
    # Warm the jit caches with one tiny solve on the same shapes.
    warm = src._replace(n=CHUNK)
    solve_streaming_host(warm, cfg, q=Q, double_buffer=double_buffer)
    wall = float("inf")
    for _ in range(REPEATS):
        calls["n"] = 0
        t0 = time.perf_counter()
        res = solve_streaming_host(src, cfg, q=Q,
                                   double_buffer=double_buffer)
        jax.block_until_ready(res)
        wall = min(wall, time.perf_counter() - t0)
    n_chunks = -(-n // CHUNK)
    assert calls["n"] % n_chunks == 0, (calls["n"], n_chunks)
    return res, wall, calls["n"] // n_chunks


def _timed_host_ckpt_solve(n, cfg, seed=0):
    """Double-buffered host solve with checkpointing on: the preemption
    insurance premium. The fingerprint probe reads one extra chunk per
    solve (not a pass); every save synchronises the carry and writes the
    constant-size state atomically."""
    import shutil
    import tempfile

    src = sparse_host_chunk_source(seed, n, K, CHUNK, q=Q, tightness=0.4)
    calls = {"n": 0}
    inner = src.fn

    def fn(i):
        calls["n"] += 1
        return inner(i)

    src = src._replace(fn=fn)
    ckpt_cfg = cfg.replace(checkpoint_every=2)
    warm = src._replace(n=CHUNK)
    ckdir = tempfile.mkdtemp(prefix="bench_ckpt_")
    from repro.checkpoint import ckpt as _ckpt

    # Count save calls directly: the driver prunes the directory to the
    # newest few steps, so globbing undercounts what the overhead paid for.
    saves = {"n": 0}
    real_save = _ckpt.save

    def counting_save(*a, **kw):
        saves["n"] += 1
        return real_save(*a, **kw)

    _ckpt.save = counting_save
    try:
        solve_streaming_host(warm, ckpt_cfg, q=Q, checkpoint_dir=ckdir)
        wall = float("inf")
        for _ in range(REPEATS):
            shutil.rmtree(ckdir, ignore_errors=True)
            calls["n"] = 0
            saves["n"] = 0
            t0 = time.perf_counter()
            res = solve_streaming_host(src, ckpt_cfg, q=Q,
                                       checkpoint_dir=ckdir)
            jax.block_until_ready(res)
            wall = min(wall, time.perf_counter() - t0)
        n_ckpts = saves["n"]
        latest = _ckpt.latest_step(ckdir)
    finally:
        _ckpt.save = real_save
        shutil.rmtree(ckdir, ignore_errors=True)
    n_chunks = -(-n // CHUNK)
    fetches = calls["n"] - 1            # minus the fingerprint probe
    assert fetches % n_chunks == 0, (calls["n"], n_chunks)
    assert latest is not None
    return res, wall, fetches // n_chunks, n_ckpts


def _entry(wall, passes, res, budgets):
    return {"wall_s": round(wall, 4), "passes": passes,
            "wall_per_pass_s": round(wall / passes, 4),
            "iterations": int(res.iters),
            "feasible": bool(jnp.all(res.r <= jnp.asarray(budgets)
                                     * (1 + 1e-4))),
            "primal": float(res.primal)}


def bench_passes_point(n, use_kernels=True, max_iters=12):
    """Pass accounting at one n: fused vs legacy, double-buffered vs sync.

    Five solves of the same workload: traced device source with the
    fused and legacy finalize (pass-count delta), and the host-fed
    pipeline double-buffered+fused / synchronous+fused /
    synchronous+legacy. ``combined_speedup`` (sync+legacy over
    double-buffered+fused) is the end-to-end win of the fused finalize
    and the prefetch pipeline together; the pass counts are asserted
    against the §5c accounting.

    Runs the kernel (production) path like the memory section: the
    fused finalize is a VMEM-resident accumulation there
    (scd_finalize_hist), whereas on the pure-jnp path the two
    carry-seeded scatter histograms of the single fused pass cost about
    what the three legacy passes do on CPU — the pass-count win is
    path-independent (test-asserted on both), the wall-clock win rides
    on the kernel. Numbers on this CPU backend run the kernels under
    the interpreter; on TPU the gap widens (HBM traffic per §5).
    """
    fused = _cfg(use_kernels, max_iters)
    legacy = fused.replace(stream_finalize="legacy")
    out = {"n": n, "n_chunks": -(-n // CHUNK)}
    budgets = sparse_chunk_source(0, n, K, CHUNK, q=Q, tightness=0.4).budgets

    res_f, wall_f, passes_f = _timed_device_solve(n, fused)
    res_l, wall_l, passes_l = _timed_device_solve(n, legacy)
    assert int(res_f.iters) == int(res_l.iters)
    out["device"] = {
        "fused": _entry(wall_f, passes_f, res_f, budgets),
        "legacy": _entry(wall_l, passes_l, res_l, budgets),
        "finalize_speedup": round(wall_l / wall_f, 3),
        "passes_ok": (passes_f == int(res_f.iters) + 1
                      and passes_l == int(res_l.iters) + 3),
    }

    res_db, wall_db, passes_db = _timed_host_solve(n, fused, True)
    res_sf, wall_sf, passes_sf = _timed_host_solve(n, fused, False)
    res_sl, wall_sl, passes_sl = _timed_host_solve(n, legacy, False)
    res_ck, wall_ck, passes_ck, n_ckpts = _timed_host_ckpt_solve(n, fused)
    ckpt_entry = _entry(wall_ck, passes_ck, res_ck, budgets)
    ckpt_entry["n_checkpoints"] = n_ckpts
    ckpt_entry["overhead_frac"] = round(wall_ck / wall_db - 1.0, 4)
    out["host"] = {
        "double_buffered_fused": _entry(wall_db, passes_db, res_db, budgets),
        "synchronous_fused": _entry(wall_sf, passes_sf, res_sf, budgets),
        "synchronous_legacy": _entry(wall_sl, passes_sl, res_sl, budgets),
        # Preemption-safety premium: the same double-buffered fused
        # solve with cfg.checkpoint_every=2 writing atomic resume
        # states (constant size; each save synchronises the carry).
        "checkpointed_fused": ckpt_entry,
        "pipeline_speedup": round(wall_sf / wall_db, 3),
        "combined_speedup": round(wall_sl / wall_db, 3),
        "checkpoint_overhead": ckpt_entry["overhead_frac"],
        "passes_ok": (passes_db == int(res_db.iters) + 1
                      and passes_sf == int(res_sf.iters) + 1
                      and passes_sl == int(res_sl.iters) + 3
                      and passes_ck == int(res_ck.iters) + 1),
    }
    return out


def main() -> None:
    """Run the grids, write the JSON reports, gate on the contracts."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two small points (CI-friendly)")
    ap.add_argument("--out", default="BENCH_chunked.json")
    ap.add_argument("--passes-out", default="BENCH_stream_passes.json",
                    help="pass-accounting report (empty string to skip)")
    ap.add_argument("--no-kernels", action="store_true",
                    help="jnp map instead of the fused Pallas kernel")
    args = ap.parse_args()
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)

    points = []
    print("n,iters,wall_s,stream_bytes,resident_bytes,feasible")
    for n in (SMOKE_GRID if args.smoke else GRID):
        r = bench_point(n, use_kernels=not args.no_kernels)
        points.append(r)
        print(f"{n},{r['iterations']},{r['wall_s']},"
              f"{r['streaming_memory']['total_bytes']},"
              f"{r['resident_memory']['total_bytes']},{r['feasible']}")

    totals = [p["streaming_memory"]["total_bytes"] for p in points]
    flat = (min(totals) > 0 and max(totals) / min(totals) <= 1.01)
    report = {
        "backend": jax.default_backend(),
        "chunk": CHUNK,
        "largest_unchunked_n": 32768,   # BENCH_scd.json ceiling
        "memory_flat_in_n": flat,
        "points": points,
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    passes_ok = True
    if args.passes_out:
        ppoints = []
        print("n,fused_passes,legacy_passes,finalize_x,pipeline_x,combined_x,"
              "ckpt_overhead")
        for n in (PASSES_SMOKE_GRID if args.smoke else PASSES_GRID):
            p = bench_passes_point(n, use_kernels=not args.no_kernels)
            ppoints.append(p)
            print(f"{n},{p['device']['fused']['passes']},"
                  f"{p['device']['legacy']['passes']},"
                  f"{p['device']['finalize_speedup']},"
                  f"{p['host']['pipeline_speedup']},"
                  f"{p['host']['combined_speedup']},"
                  f"{p['host']['checkpoint_overhead']}")
        passes_ok = all(p["device"]["passes_ok"] and p["host"]["passes_ok"]
                        for p in ppoints)
        preport = {
            "backend": jax.default_backend(),
            "k": K, "q": Q, "chunk": CHUNK,
            "points": ppoints,
        }
        pathlib.Path(args.passes_out).write_text(
            json.dumps(preport, indent=2) + "\n")
        print(f"wrote {args.passes_out}")

    bad = [p for p in points if not p["feasible"]]
    if bad or not flat or not passes_ok:
        print(f"REGRESSION: feasible={not bad}, memory_flat_in_n={flat}, "
              f"pass_counts_ok={passes_ok}")
        sys.exit(1)


if __name__ == "__main__":
    main()
