"""Out-of-core chunked solve: device memory flat in n, past the HBM cap.

``PYTHONPATH=src python -m benchmarks.bench_chunked [--smoke] [--out PATH]``

The paper's billion-scale claim holds only if per-worker state is
O(items processed at a time), not O(local items). This benchmark
demonstrates that for the streaming driver (core/chunked.py):

* **solves** the §6 sparse workload through the fused Pallas kernel at
  n from the largest unchunked BENCH_scd.json point (32768) up to 8-16x
  past it, chunks synthesized on demand — the (n, K) instance never
  exists;
* **AOT memory analysis** (same probe as launch/dryrun.py) of the
  compiled streaming program at each n: argument + temp bytes must be
  flat in n (the scan carries O(chunk·K + K·E) state and a loop
  counter), while the resident ``solve`` program's bytes grow as
  8·n·K + intermediates — its device-memory ceiling is exactly what the
  streaming path removes.

The CI smoke gate fails if the streaming program's footprint is not flat
(<= 1% drift across n) or if the big-n solve regresses infeasible.
Writes ``BENCH_chunked.json`` next to ``BENCH_scd.json`` so later PRs
can diff the trajectory.
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import SolverConfig, SparseKP  # noqa: E402
from repro.core.chunked import stream_solve_fn  # noqa: E402
from repro.core.solver import _solve_entry  # noqa: E402
from repro.data.synth import sparse_chunk_source  # noqa: E402

K, Q, CHUNK = 8, 1, 8192
# Largest unchunked point in BENCH_scd.json is n=32768; the acceptance
# bar is a solve at >= 8x that with flat peak device memory.
GRID = [32768, 65536, 131072, 262144, 524288]
SMOKE_GRID = [32768, 65536]


def _cfg(use_kernels=True, max_iters=12):
    return SolverConfig(reduce="bucketed", max_iters=max_iters,
                        use_kernels=use_kernels)


def _streaming_fn(src, cfg):
    return stream_solve_fn(src, cfg, Q)


def _aot_bytes(lowered):
    """argument + temp bytes of a compiled program (dryrun.py calibration:
    both are per-device on this backend); -1 when the backend can't say."""
    try:
        ma = lowered.compile().memory_analysis()
        arg = int(getattr(ma, "argument_size_in_bytes", -1))
        temp = int(getattr(ma, "temp_size_in_bytes", -1))
        return {"argument_bytes": arg, "temp_bytes": temp,
                "total_bytes": arg + temp}
    except Exception as e:  # pragma: no cover - CPU backend quirks
        return {"error": str(e), "total_bytes": -1}


def bench_point(n, seed=0, use_kernels=True, max_iters=12):
    """Solve the n-user workload streaming; report wall time + AOT bytes."""
    cfg = _cfg(use_kernels, max_iters)
    src = sparse_chunk_source(seed, n, K, CHUNK, q=Q, tightness=0.4)
    fn = _streaming_fn(src, cfg)
    lam0 = jnp.ones((K,), jnp.float32)

    stream_mem = _aot_bytes(fn.lower(src.budgets, lam0))
    # Resident-solve footprint at the same n: the ceiling being removed.
    resident = jax.jit(functools.partial(
        _solve_entry, q=Q, cfg=cfg.replace(use_kernels=False), axis=None))
    abstract = SparseKP(
        p=jax.ShapeDtypeStruct((n, K), jnp.float32),
        b=jax.ShapeDtypeStruct((n, K), jnp.float32),
        budgets=jax.ShapeDtypeStruct((K,), jnp.float32),
    )
    resident_mem = _aot_bytes(resident.lower(abstract, lam0))

    t0 = time.perf_counter()
    res = jax.block_until_ready(fn(src.budgets, lam0))
    wall = time.perf_counter() - t0
    feasible = bool(jnp.all(res.r <= src.budgets * (1 + 1e-4)))
    return {
        "n": n, "k": K, "q": Q, "chunk": CHUNK,
        "use_kernels": use_kernels,
        "iterations": int(res.iters),
        "duality_gap_frac": float((res.dual - res.primal) / res.primal),
        "feasible": feasible,
        "wall_s": round(wall, 4),
        "streaming_memory": stream_mem,
        "resident_memory": resident_mem,
    }


def main() -> None:
    """Run the grid, write the JSON report, gate on flat memory."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two small points (CI-friendly)")
    ap.add_argument("--out", default="BENCH_chunked.json")
    ap.add_argument("--no-kernels", action="store_true",
                    help="jnp map instead of the fused Pallas kernel")
    args = ap.parse_args()
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)

    points = []
    print("n,iters,wall_s,stream_bytes,resident_bytes,feasible")
    for n in (SMOKE_GRID if args.smoke else GRID):
        r = bench_point(n, use_kernels=not args.no_kernels)
        points.append(r)
        print(f"{n},{r['iterations']},{r['wall_s']},"
              f"{r['streaming_memory']['total_bytes']},"
              f"{r['resident_memory']['total_bytes']},{r['feasible']}")

    totals = [p["streaming_memory"]["total_bytes"] for p in points]
    flat = (min(totals) > 0 and max(totals) / min(totals) <= 1.01)
    report = {
        "backend": jax.default_backend(),
        "chunk": CHUNK,
        "largest_unchunked_n": 32768,   # BENCH_scd.json ceiling
        "memory_flat_in_n": flat,
        "points": points,
    }
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p for p in points if not p["feasible"]]
    if bad or not flat:
        print(f"REGRESSION: feasible={not bad}, memory_flat_in_n={flat}")
        sys.exit(1)


if __name__ == "__main__":
    main()
