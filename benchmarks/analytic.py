"""Analytic roofline model per (arch x shape cell).

XLA's HloCostAnalysis visits every while-loop body exactly once, so any
flow inside lax.scan (layer stack, attention chunk scans, the chunked
loss) is undercounted in the dry-run's cost_analysis. The scan-probe
correction (dryrun.py) fixes the *layer* scan; this module supplies the
full analytic counts — derived from the architecture config, not the HLO —
for compute and HBM-byte terms. Collective bytes remain HLO-derived (the
optimized-HLO collective ops are explicit and reliable).

Counting conventions (documented for §Roofline):
  * matmul flops = 2 * m * n * k; train = fwd + backward (2x) + remat
    re-forward (1x) = 4x fwd for the layer stack, 3x for the unremat'd
    loss head; prefill = 1x fwd; decode = 1x fwd per token.
  * attention context flops count the FULL S (not S/2): the portable
    chunked-causal implementation computes masked pairs (the 2x causal
    waste is reported and attacked in §Perf, not hidden).
  * HBM bytes: parameters are read at full size per chip (FSDP gathers
    materialise them locally) 1x/2x/3x for decode/prefill/train; optimizer
    moments (f32, sharded) r/w; activations ~ c_act * D bytes/token/layer;
    decode additionally reads the KV/state cache once per step.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Terms:
    flops_per_chip: float
    bytes_per_chip: float

    def compute_s(self, peak=197e12):
        return self.flops_per_chip / peak

    def memory_s(self, bw=819e9):
        return self.bytes_per_chip / bw


def _layer_param_flops_per_token(cfg, slot: str, ffn: str) -> float:
    """2 * (active params touched) for one layer's projections."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    f = 0.0
    if slot == "attn" and not cfg.use_mla:
        f += 2 * (d * h * hd + 2 * d * kv * hd + h * hd * d)
    elif slot == "attn" and cfg.use_mla:
        m = cfg.mla
        qd = m.nope_head_dim + m.rope_head_dim
        f += 2 * (d * m.q_lora + m.q_lora * h * qd
                  + d * (m.kv_lora + m.rope_head_dim)
                  + m.kv_lora * h * (m.nope_head_dim + m.v_head_dim)
                  + h * m.v_head_dim * d)
    elif slot == "mamba":
        mm = cfg.mamba
        di = mm.d_inner(d)
        proj = 2 * di + 2 * mm.n_groups * mm.d_state + mm.n_heads(d)
        f += 2 * (d * proj + di * d)
    if ffn == "dense":
        f += 2 * 3 * d * cfg.d_ff
    elif ffn == "moe":
        mo = cfg.moe
        f += 2 * d * mo.n_experts                      # router
        f += mo.topk * 2 * 3 * d * mo.d_ff             # routed experts
        f += mo.n_shared * 2 * 3 * d * mo.d_ff         # shared experts
    return f


def _ctx_flops_per_token(cfg, slot: str, s_ctx: int) -> float:
    """Attention/SSD context mixing flops for one token at context s_ctx."""
    d = cfg.d_model
    if slot == "attn" and not cfg.use_mla:
        return 4.0 * s_ctx * cfg.n_heads * cfg.hd
    if slot == "attn" and cfg.use_mla:
        m = cfg.mla
        return 2.0 * s_ctx * cfg.n_heads * (
            m.nope_head_dim + m.rope_head_dim + m.v_head_dim)
    mm = cfg.mamba
    h, p, n, q = mm.n_heads(d), mm.head_dim, mm.d_state, mm.chunk
    g = mm.n_groups
    # SSD: intra-chunk quadratic + state path (arXiv 2405.21060 chunked form)
    return 2.0 * q * g * n + 2.0 * q * h * p + 4.0 * h * p * n


def _slots(cfg):
    reps = cfg.n_layers // len(cfg.pattern)
    out = list(zip(cfg.pattern, cfg.ffn_pattern)) * reps
    if cfg.first_dense_ff:
        out[0] = (cfg.pattern[0], "dense")
    return out


def _param_bytes(cfg, n_params: int) -> float:
    import numpy as np
    return n_params * np.dtype(cfg.param_dtype).itemsize


def cell_terms(cfg, cell, n_params: int, chips: int, act_bytes_factor=16.0,
               fsdp_mode: str = None):
    """Analytic (flops, bytes) per chip for the cell's step function.

    fsdp_mode affects the weight-read traffic: "full"/"fsdp_only" gather
    and read FULL weights per chip per pass; "zero1"/"none" read only the
    1/16 TP shard (weights stay resident).
    """
    fsdp_mode = fsdp_mode or cfg.fsdp_mode
    kind = cell.kind
    b = cell.global_batch
    from repro.models import model as M
    s = M._text_len(cfg, cell.seq_len)
    d, v = cfg.d_model, cfg.vocab
    slots = _slots(cfg)

    if kind in ("train", "prefill"):
        tokens = b * s
        f_layers = sum(_layer_param_flops_per_token(cfg, sl, ff)
                       + _ctx_flops_per_token(cfg, sl, s)
                       for sl, ff in slots) * tokens
        if cfg.kind == "encdec":
            f_layers += sum(
                (_layer_param_flops_per_token(cfg, "attn", "dense")
                 + _ctx_flops_per_token(cfg, "attn", s)) * tokens
                for _ in range(cfg.n_enc_layers))
        f_head = 2.0 * d * v * tokens
        if kind == "train":
            flops = 4.0 * f_layers + 3.0 * f_head
        else:
            flops = f_layers + f_head
        pbytes = _param_bytes(cfg, n_params)
        reads = 3.0 if kind == "train" else 1.0
        if fsdp_mode in ("zero1", "none"):
            pbytes = pbytes / 16.0                      # resident TP shard
        w_traffic = reads * pbytes                      # full when gathered
        opt_traffic = (16.0 * n_params / chips) if kind == "train" else 0.0
        grad_traffic = (4.0 * pbytes / chips) if kind == "train" else 0.0
        act = act_bytes_factor * d * tokens * len(slots) * 2.0 / chips
        kv_reread = 0.0
        for sl, _ in slots:
            if sl != "attn":
                continue
            nq = max(s // cfg.attn_chunk, 1)
            kv_heads_bytes = 2 * cfg.n_kv_heads * cfg.hd * 2  # k+v bf16
            kv_reread += nq * tokens * kv_heads_bytes / chips
        byts = w_traffic + opt_traffic + grad_traffic + act + kv_reread
        return Terms(flops / chips, byts)

    # decode: one token per sequence, context = full cache
    s_cache = cell.seq_len
    f = sum(_layer_param_flops_per_token(cfg, sl, ff)
            + _ctx_flops_per_token(cfg, sl, s_cache)
            for sl, ff in slots) * b
    f += 2.0 * d * v * b
    # cache bytes: attention layers read k+v (or c_kv) for the whole cache
    cache_bytes = 0.0
    for sl, _ in slots:
        if sl == "attn" and not cfg.use_mla:
            cache_bytes += b * s_cache * cfg.n_kv_heads * cfg.hd * 2 * 2
        elif sl == "attn" and cfg.use_mla:
            cache_bytes += b * s_cache * (
                cfg.mla.kv_lora + cfg.mla.rope_head_dim) * 2
        else:
            mm = cfg.mamba
            cache_bytes += b * mm.n_heads(d) * mm.head_dim * mm.d_state * 4 * 2
    if cfg.kind == "encdec":
        cache_bytes += cfg.n_layers * b * (cell.seq_len // 2) * \
            cfg.n_kv_heads * cfg.hd * 2 * 2
    # Weight reads at decode: with TP over the 16-way model axis each chip
    # reads 1/16 of the ACTIVE weights once per step. (The FSDP baseline
    # instead all-gathers full weights — that cost shows up in the HLO
    # collective term, which is where §Perf attacks it.)
    from benchmarks.roofline import active_params
    act_p = active_params(cfg, n_params)
    w_read = act_p * 2.0 / 16.0
    byts = w_read + cache_bytes / chips + 4.0 * d * b * len(slots)
    return Terms(f / chips, byts)
