"""Supervision overhead bench: supervised vs direct solve + lease traffic.

``PYTHONPATH=src python -m benchmarks.bench_supervisor [--smoke] [--out P]``

The elastic supervision layer (launch/supervisor.py) is only free if
the paper's numbers survive it: a supervised solve runs in a worker
subprocess that re-imports jax, renews a fsync'd heartbeat lease every
``ttl/4`` seconds, and checkpoints for re-drive — all of which costs
wall clock the in-process solve does not pay. This bench prices that.

Each grid point solves the same workload twice:

* **direct** — ``run_solve_task`` in this process (no subprocess, no
  lease, same checkpoint cadence), the baseline;
* **supervised** — a real ``Supervisor.run()`` with one worker
  subprocess, chaos-free.

What the report claims, and how it is gated:

* **The supervised record is bitwise the direct one** (lam, tau, iters,
  r, primal, dual) and completes in one spawn with zero restarts — the
  bench exits 1 otherwise. Supervision must not perturb results.
* **Overhead is recorded, not gated**: ``overhead_s`` is dominated by
  the worker's one-time interpreter + jax import (~seconds), constant
  in n, so it amortises to noise at paper scale; wall clock on shared
  CPU is too noisy to gate. The deterministic numbers next to it —
  lease beats written and beats per checkpoint interval — are the
  fsync-traffic accounting.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.core.heartbeat import read_lease  # noqa: E402
from repro.launch.supervisor import (  # noqa: E402
    Supervisor,
    SupervisorConfig,
    run_solve_task,
)
from repro.serve.engine import WorkloadSpec  # noqa: E402

K, Q, SLOTS = 8, 2, 4
_FIELDS = ["lam", "tau", "iters", "r", "primal", "dual"]
# (n, chunk, max_iters): smoke is the CI point.
GRID = [(16384, 1024, 24), (65536, 2048, 40)]
SMOKE_GRID = [(16384, 1024, 24)]


def bench_point(n, chunk, max_iters, seed=0):
    """Solve one workload direct and supervised; return the comparison."""
    spec = WorkloadSpec(seed=seed, n=n, k=K, chunk=chunk, q=Q,
                        tightness=0.4)
    task = {"kind": "solve", "spec": spec.to_json(),
            "cfg": dict(reduce="bucketed", max_iters=max_iters,
                        checkpoint_every=4, bucket_half=16),
            "slots": SLOTS, "ttl": 2.0}
    with tempfile.TemporaryDirectory(prefix="bench_sup_") as tmp:
        root = pathlib.Path(tmp)
        t0 = time.perf_counter()
        ref = run_solve_task(root / "direct", task)
        direct_s = time.perf_counter() - t0

        sup = Supervisor(root / "sup", task,
                         cfg=SupervisorConfig(ttl=2.0, poll=0.05,
                                              grace=300.0, max_restarts=2),
                         devices=1)
        t0 = time.perf_counter()
        out = sup.run()
        supervised_s = time.perf_counter() - t0

        got = ckpt.restore_auto(root / "sup" / "result", 0)
        bitwise = all(np.asarray(ref[f]).tobytes()
                      == np.asarray(got[f]).tobytes() for f in _FIELDS)
        lease = read_lease(root / "sup" / "heartbeat.json")
    return {
        "n": n, "chunk": chunk, "max_iters": max_iters,
        "k": K, "q": Q, "slots": SLOTS,
        "direct_s": round(direct_s, 3),
        "supervised_s": round(supervised_s, 3),
        "overhead_s": round(supervised_s - direct_s, 3),
        "overhead_ratio": round(supervised_s / max(direct_s, 1e-9), 3),
        "lease_beats": lease.seq,
        "final_progress": lease.progress,
        "bitwise": bitwise,
        "spawns": out["spawns"],
        "restarts": out["restarts"],
        "ok": out["ok"],
    }


def main() -> None:
    """CLI: run the grid, write the JSON report, gate bitwise identity."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_supervisor.json")
    args = ap.parse_args()

    points = []
    print("n,direct_s,supervised_s,overhead_s,lease_beats,bitwise")
    for n, chunk, max_iters in (SMOKE_GRID if args.smoke else GRID):
        p = bench_point(n, chunk, max_iters)
        points.append(p)
        print(f"{n},{p['direct_s']},{p['supervised_s']},"
              f"{p['overhead_s']},{p['lease_beats']},{p['bitwise']}")

    report = {
        "bench": "supervisor",
        "backend": jax.default_backend(),
        "points": points,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p["n"] for p in points
           if not (p["bitwise"] and p["ok"]
                   and p["spawns"] == 1 and p["restarts"] == 0)]
    if bad:
        print(f"REGRESSION: supervised solve diverged from direct "
              f"(or needed restarts) at n={bad}")
        sys.exit(1)


if __name__ == "__main__":
    main()
