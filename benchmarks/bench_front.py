"""Serving-front bench: sustained HTTP lookup QPS under refresh churn.

``PYTHONPATH=src python -m benchmarks.bench_front [--smoke] [--out PATH]``

The request-path extension of ``bench_serve`` (which measures
*in-process* lookups — 659k single-lookup QPS on this container): here
the lookups cross a real HTTP front into DecisionService **replica
processes** (``repro/serve/front.py``) while the generation engine
refreshes and prunes underneath, so the number is the end-to-end
serving figure: wire encoding + round-robin + the replica's service
lock + live rebinds, all included.

What the report claims, and how it is gated:

* **Bitwise parity is the hard claim**: every answered row is compared
  against the full materialisation of the generation that answered it,
  and the ``/diff`` endpoint against the brute-force comparison of two
  generations' decision matrices. The bench exits 1 on any mismatch;
  ``tools/bench_diff.py`` re-checks the committed flags.
* **Diff pass accounting is deterministic**: the first diff against a
  baseline costs exactly one grouped chunk pass (``chunks`` fills) on
  the baseline generation, and repeats cost zero on both (two cached
  generations) — gated exactly by ``bench_diff``.
* **Sustained batched QPS** is gated within the usual generous wall
  tolerance (CI wall clocks are noisy; a front that serialises on a
  global lock shows up far beyond it). Single-lookup QPS is recorded,
  not gated.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import SolverConfig  # noqa: E402
from repro.launch.front import run_front_scenario  # noqa: E402
from repro.serve import WorkloadSpec  # noqa: E402

K, Q, REPLICAS = 8, 2, 2
# (n, chunk, generations): the smoke point is shared with CI so
# bench_diff can match points by n against the committed report.
GRID = [(8192, 512, 3), (32768, 2048, 3)]
SMOKE_GRID = [(8192, 512, 3)]


def bench_point(n, chunk, generations, seed=0, max_iters=60):
    spec = WorkloadSpec(seed=seed, n=n, k=K, chunk=chunk, q=Q,
                        tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=max_iters,
                       checkpoint_every=0)
    with tempfile.TemporaryDirectory(prefix="bench_front_") as root:
        return run_front_scenario(spec, generations, root, cfg,
                                  replicas=REPLICAS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_front.json")
    args = ap.parse_args()

    points = []
    print("n,replicas,batched_qps,single_qps,parity,diff_parity")
    for n, chunk, generations in (SMOKE_GRID if args.smoke else GRID):
        p = bench_point(n, chunk, generations)
        points.append(p)
        print(f"{n},{p['replicas']},{p['sustained']['batched_qps']},"
              f"{p['sustained']['single_qps']},{p['parity']},"
              f"{p['diff']['parity']}")

    report = {
        "bench": "front",
        "backend": jax.default_backend(),
        "points": points,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p["n"] for p in points
           if not p["parity"] or not p["diff"]["parity"]
           or p["stale_rows"] != 0
           or not all(r >= 1 for r in p["rebinds"])]
    if bad:
        print(f"REGRESSION: front parity/diff/rebind failure at n={bad}")
        sys.exit(1)


if __name__ == "__main__":
    main()
