"""Benchmarks reproducing each table/figure of the paper (CPU-scaled).

Figure 1  — optimality ratio vs LP bound across K and local-constraint
            scenarios (paper: >98.6% at N=1e3, >99.8% at N=1e4).
Table 1   — SCD iterations + primal + duality gap as M grows (sparse).
Table 2   — presolve iteration reduction (paper: 40-75%).
Figure 2  — wall time vs N (fixed K).
Figure 3  — wall time vs K (fixed N).
Figure 4  — Alg 5 linear-time map ("speedup") vs the general Alg 3 map
            ("regular") on the same diagonal instances.
Figure 5/6— DD vs SCD duality-gap and max-violation trajectories.

Sizes are scaled to a single CPU device; every function prints
``name,us_per_call,derived`` CSV rows (benchmarks/run.py drives them all).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import SolverConfig, solve
from repro.core.exact import lp_upper_bound, lp_upper_bound_sparse
from repro.core.instances import dense_instance, shard_key, sparse_instance

from .common import emit, timeit


def fig1_optimality(n=1000, ks=(1, 5, 10, 15, 20)):
    for local in ("C1", "C2", "C223"):
        for k in ks:
            kp = dense_instance(shard_key(42 + k), n=n, m=10, k=k,
                                local=local, tightness=0.25, mixed_b=True)
            cfg = SolverConfig(reduce="exact", cd_mode="cyclic", max_iters=25)
            sec = timeit(lambda: solve(kp, cfg, q=0), warmup=0, iters=1)
            res = solve(kp, cfg, q=0)
            lpv = lp_upper_bound(
                np.asarray(kp.p), np.asarray(kp.b), np.asarray(kp.budgets),
                np.asarray(kp.sets), np.asarray(kp.caps))
            emit(f"fig1/{local}/K{k}", sec,
                 ratio=round(float(res.primal) / lpv, 5),
                 iters=int(res.iters))


def tab1_duality(n=200_000, ms=(1, 5, 10, 20)):
    for m in ms:
        kp, q = sparse_instance(shard_key(7 + m), n=n, k=max(m, 2), q=1,
                                tightness=0.5)
        cfg = SolverConfig(reduce="bucketed", max_iters=40)
        sec = timeit(lambda: solve(kp, cfg, q=q), warmup=1, iters=1)
        res = solve(kp, cfg, q=q)
        emit(f"tab1/M{m}", sec,
             iters=int(res.iters),
             primal=round(float(res.primal), 2),
             gap=round(float(res.dual - res.primal), 2),
             viol=round(float(jnp.max((res.r - kp.budgets) / kp.budgets)), 5))


def tab2_presolve(ns=(100_000, 1_000_000)):
    for n in ns:
        kp, q = sparse_instance(shard_key(77), n=n, k=10, q=1, tightness=0.4)
        cold = solve(kp, SolverConfig(reduce="bucketed", max_iters=40), q=q)
        warm = solve(kp, SolverConfig(reduce="bucketed", max_iters=40,
                                      presolve_samples=10_000), q=q)
        red = 1.0 - int(warm.iters) / max(int(cold.iters), 1)
        emit(f"tab2/N{n}", 0.0, cold_iters=int(cold.iters),
             presolve_iters=int(warm.iters),
             reduction=f"{100 * red:.0f}%")


def fig2_scaling_n(ns=(100_000, 200_000, 400_000, 800_000), k=10):
    cfg = SolverConfig(reduce="bucketed", max_iters=8, postprocess=False)
    for n in ns:
        kp, q = sparse_instance(shard_key(9), n=n, k=k, q=1, tightness=0.4)
        sec = timeit(lambda: solve(kp, cfg, q=q), warmup=1, iters=2)
        emit(f"fig2/N{n}", sec, per_iter_ms=round(sec / 8 * 1e3, 2))


def fig3_scaling_k(ks=(4, 6, 8, 10, 15, 20), n=200_000):
    cfg = SolverConfig(reduce="bucketed", max_iters=8, postprocess=False)
    for k in ks:
        kp, q = sparse_instance(shard_key(10), n=n, k=k, q=1, tightness=0.4)
        sec = timeit(lambda: solve(kp, cfg, q=q), warmup=1, iters=2)
        emit(f"fig3/K{k}", sec, per_iter_ms=round(sec / 8 * 1e3, 2))


def fig4_speedup(n=20_000, k=10, q=1):
    """Alg 5 map vs general Alg 3 map on the SAME diagonal instance."""
    from repro.core.types import DenseKP, SparseKP, cardinality_set

    kp, _ = sparse_instance(shard_key(11), n=n, k=k, q=q, tightness=0.4)
    # equivalent dense encoding: b diagonal, single cardinality constraint
    b_dense = jnp.zeros((n, k, k)).at[:, jnp.arange(k), jnp.arange(k)].set(kp.b)
    sets = cardinality_set(k, q)
    kpd = DenseKP(p=kp.p, b=b_dense, budgets=kp.budgets,
                  sets=sets.sets, caps=sets.caps)
    cfg = SolverConfig(reduce="bucketed", max_iters=6, postprocess=False)
    sec_sparse = timeit(lambda: solve(kp, cfg, q=q), warmup=1, iters=2)
    sec_dense = timeit(lambda: solve(kpd, cfg, q=0), warmup=1, iters=2)
    emit("fig4/speedup_alg5", sec_sparse, per_iter_ms=round(sec_sparse / 6 * 1e3, 2))
    emit("fig4/regular_alg3", sec_dense, per_iter_ms=round(sec_dense / 6 * 1e3, 2))
    emit("fig4/ratio", 0.0, speedup=round(sec_dense / sec_sparse, 1))


def fig56_dd_vs_scd(n=10_000, k=10):
    kp, q = sparse_instance(shard_key(12), n=n, k=k, q=1, tightness=0.4)
    cfg = SolverConfig(reduce="bucketed", max_iters=15, record_history=True,
                       postprocess=False)
    scd = solve(kp, cfg, q=q)
    for lr, tag in ((1e-3, "dd_lr1e-3"), (2e-3, "dd_lr2e-3")):
        dd = solve(kp, cfg.replace(algo="dd", dd_lr=lr), q=q)
        emit(f"fig56/{tag}", 0.0,
             final_gap=round(float(dd.history["gap"][-1]), 2),
             max_viol=round(float(np.max(dd.history["max_violation"])), 4))
    emit("fig56/scd", 0.0,
         final_gap=round(float(scd.history["gap"][-1]), 2),
         max_viol=round(float(np.max(scd.history["max_violation"])), 4))


def all_benchmarks():
    fig1_optimality()
    tab1_duality()
    tab2_presolve()
    fig2_scaling_n()
    fig3_scaling_k()
    fig4_speedup()
    fig56_dd_vs_scd()
