"""Per-iteration SCD hot path: fused vs unfused map+reduce wall-time.

``PYTHONPATH=src python -m benchmarks.bench_scd [--smoke] [--out PATH]``

Times one SCD iteration's map+reduce — candidates + bucketed histogram +
per-knapsack top — through the two-kernel path (scd_candidates ->
bucket_hist, (n, K) v1/v2 round-tripping through HBM) and the fused
single-kernel path (kernels/scd_fused.py, candidates never leave VMEM)
across an (n, K) grid, and writes ``BENCH_scd.json`` so later PRs can
diff the perf trajectory. On CPU both run the Pallas interpreter: the
measured win there is the deleted second grid pass; the HBM-traffic win
on top of it only shows on real TPU.
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import timeit  # noqa: E402
from repro.core.bucketing import make_edges  # noqa: E402
from repro.kernels import ops  # noqa: E402

# Per-device user shards at production scale (a billion users over a pod
# is ~1e4-1e5 per core). Below ~4k rows the interpret-mode dispatch
# overhead drowns the fusion win on CPU, so CI measures from 8k up.
GRID = [(8192, 8), (8192, 32), (32768, 8), (32768, 32)]
# Smoke gates CI: one point with the widest fused-vs-unfused margin
# (~1.5x on CPU interpret), so host noise can't flip the comparison.
SMOKE_GRID = [(32768, 8)]


@functools.partial(jax.jit, static_argnames=("q", "tile"))
def _unfused(p, b, lam, edges, q, tile):
    v1, v2 = ops.scd_candidates(p, b, lam, q, tile_n=tile)
    hist = ops.bucket_hist(v1, v2, edges, tile_n=tile)
    return hist, jnp.max(v1, axis=0)


@functools.partial(jax.jit, static_argnames=("q", "tile"))
def _fused(p, b, lam, edges, q, tile):
    return ops.scd_fused_hist(p, b, lam, edges, q, tile_n=tile)


def bench_point(n, k, q=2, half=24, seed=0, samples=16):
    kp, kb, kl = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.uniform(kp, (n, k), jnp.float32)
    b = jax.random.uniform(kb, (n, k), jnp.float32, 0.05, 1.0)
    lam = jax.random.uniform(kl, (k,), jnp.float32, 0.0, 1.5)
    edges = make_edges(lam, 1e-4, 1.6, half)
    tile = ops.pick_tile(n)
    # Compile both variants up front, then take the min over many short
    # interleaved samples: best-case time is the standard noise-robust
    # estimator, and interleaving keeps scheduler/load drift on a shared
    # host from biasing whichever variant runs second.
    jax.block_until_ready(_unfused(p, b, lam, edges, q, tile))
    jax.block_until_ready(_fused(p, b, lam, edges, q, tile))
    ts_u, ts_f = [], []
    for _ in range(samples):
        ts_u.append(timeit(_unfused, p, b, lam, edges, q, tile,
                           warmup=0, iters=1))
        ts_f.append(timeit(_fused, p, b, lam, edges, q, tile,
                           warmup=0, iters=1))
    t_unfused = min(ts_u)
    t_fused = min(ts_f)
    return {
        "n": n,
        "k": k,
        "q": q,
        "tile": tile,
        "unfused_s": t_unfused,
        "fused_s": t_fused,
        "speedup": t_unfused / t_fused,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_scd.json")
    args = ap.parse_args()
    # Fail on an unwritable destination BEFORE the minutes-long measurement.
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)

    points = []
    print("n,k,unfused_us,fused_us,speedup")
    for n, k in (SMOKE_GRID if args.smoke else GRID):
        r = bench_point(n, k)
        points.append(r)
        print(f"{n},{k},{r['unfused_s'] * 1e6:.1f},"
              f"{r['fused_s'] * 1e6:.1f},{r['speedup']:.2f}x")

    report = {"backend": jax.default_backend(), "points": points}
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    slow = [r for r in points if r["fused_s"] > r["unfused_s"]]
    if slow:
        print(f"REGRESSION: fused slower on {len(slow)} point(s)")
        sys.exit(1)


if __name__ == "__main__":
    main()
