"""Observability overhead bench: obs-on vs obs-off, bitwise + priced.

``PYTHONPATH=src python -m benchmarks.bench_obs [--smoke] [--out P]``

The obs contract (DESIGN.md §14) in numbers: instrumentation is
strictly host-side of the jit boundary, so a streamed solve with a
live tracer + registry must publish a result **bitwise identical** to
the uninstrumented run — the bench itself exits 1 on any field
mismatch. On top of parity it prices the two paths:

* **enabled overhead** — wall-time ratio of the traced run (spans to a
  real fsync'd journal) over the baseline. Gated here at <10% per the
  acceptance bar and by ``tools/bench_diff.py`` within ``--tol``
  against the committed report (wall noise aware: both runs are warm,
  median-of-3).
* **null-path overhead** — the default ``tracer=None`` run against the
  same baseline, priced so a regression that sneaks dict-building or
  span objects onto the disabled path shows up as a ratio drift.

Span counts are recorded and checked for shape (one ``solve.iterate``
per iteration, exactly one ``solve.finalize``, ``ingest.fetch`` ≥
chunks) — a tracer that silently stopped firing cannot pass.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SolverConfig  # noqa: E402
from repro.core.prefetch import solve_streaming_host  # noqa: E402
from repro.data.synth import sparse_host_chunk_source  # noqa: E402
from repro.obs import NULL_TRACER, Tracer, read_trace, trace_path  # noqa: E402

K, Q, TIGHTNESS = 6, 2, 0.3
RESULT_FIELDS = ("lam", "iters", "r", "primal", "dual", "tau")

# (n, chunk): the smoke point is shared with CI so bench_diff can match
# points by n against the committed report.
GRID = [(4000, 250), (16000, 500)]
SMOKE_GRID = [(4000, 250)]
REPEATS = 5


def _cfg():
    return SolverConfig(reduce="bucketed", max_iters=30, bucket_half=12,
                        checkpoint_every=0)


def _bitwise(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in RESULT_FIELDS)


def _timed(src, tracers):
    """Best-of-REPEATS wall + last result per variant, interleaved.

    The variants run round-robin (off, null, on, off, null, on, ...) so
    slow machine drift hits all of them equally, and the minimum is
    taken per variant: both paths run the identical deterministic work,
    so the fastest observations bound the true cost and scheduler noise
    only inflates the other samples.
    """
    walls = {k: [] for k in tracers}
    res = {}
    for _ in range(REPEATS):
        for k, tracer in tracers.items():
            t0 = time.perf_counter()
            res[k] = solve_streaming_host(src, _cfg(), q=Q, tracer=tracer)
            walls[k].append(time.perf_counter() - t0)
    return {k: min(w) for k, w in walls.items()}, res


def bench_point(n, chunk, seed=7):
    src = sparse_host_chunk_source(seed, n, K, chunk, q=Q,
                                   tightness=TIGHTNESS)
    c = -(-n // chunk)

    # Warm the jit caches once so all three variants price dispatch,
    # not compilation.
    solve_streaming_host(src, _cfg(), q=Q)

    with tempfile.TemporaryDirectory(prefix="bench_obs_") as td:
        tracer = Tracer(trace_path(td, "bench"))
        with tracer:
            walls, results = _timed(
                src, {"off": NULL_TRACER, "null": None, "on": tracer})
        spans = read_trace(tracer.path)
    wall_off, wall_null, wall_on = \
        walls["off"], walls["null"], walls["on"]
    base, null_res, traced = \
        results["off"], results["null"], results["on"]

    phases = {}
    for s in spans:
        phases[s["phase"]] = phases.get(s["phase"], 0) + 1
    iters = int(base.iters)
    # One ingest.fetch/h2d record per epoch (per-chunk timings are
    # accumulated host-side); every iterate epoch emits one.
    spans_ok = (phases.get("solve.iterate", 0) == REPEATS * iters
                and phases.get("solve.finalize", 0) == REPEATS
                and phases.get("ingest.fetch", 0) >= REPEATS * iters)

    return {
        "n": n, "chunk": chunk, "chunks": c, "k": K, "q": Q,
        "iterations": iters,
        "wall_off_s": round(wall_off, 4),
        "wall_null_s": round(wall_null, 4),
        "wall_on_s": round(wall_on, 4),
        "overhead_on": round(wall_on / max(wall_off, 1e-9) - 1.0, 4),
        "overhead_null": round(wall_null / max(wall_off, 1e-9) - 1.0, 4),
        "spans": dict(sorted(phases.items())),
        "spans_ok": spans_ok,
        "identical": _bitwise(base, traced) and _bitwise(base, null_res),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small point (CI-friendly)")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()

    points = []
    print("n,iterations,wall_off_s,wall_on_s,overhead_on,"
          "overhead_null,identical,spans_ok")
    for n, chunk in (SMOKE_GRID if args.smoke else GRID):
        p = bench_point(n, chunk)
        points.append(p)
        print(f"{n},{p['iterations']},{p['wall_off_s']},{p['wall_on_s']},"
              f"{p['overhead_on']},{p['overhead_null']},"
              f"{p['identical']},{p['spans_ok']}")

    report = {
        "bench": "obs",
        "backend": jax.default_backend(),
        "points": points,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}")

    bad = [p["n"] for p in points if not p["identical"]]
    if bad:
        print(f"REGRESSION: obs-on solve diverged bitwise at n={bad}")
        sys.exit(1)
    bad = [p["n"] for p in points if not p["spans_ok"]]
    if bad:
        print(f"REGRESSION: expected span counts missing at n={bad}")
        sys.exit(1)
    bad = [p["n"] for p in points if p["overhead_on"] > 0.10]
    if bad:
        print(f"REGRESSION: obs-on overhead above 10% at n={bad}")
        sys.exit(1)


if __name__ == "__main__":
    main()
